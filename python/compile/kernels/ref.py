"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: every kernel in mttkrp_pallas.py must match these bit-for-bit up
to float tolerance, checked by python/tests/).

The L2 graph computes a *block* of mode-1 spMTTKRP (Algorithm 2 of the
paper) over a batch of B nonzeros:

    partials[b, r] = vals[b] * D[j[b], r] * C[k[b], r]      (elementwise)
    A_tile[i, r]  += sum_b sel[i, b] * partials[b, r]       (scatter)

The scatter is expressed as a matmul with a one-hot selection matrix so
that on a real TPU it maps onto the MXU (DESIGN.md §Hardware-Adaptation).
"""

import jax.numpy as jnp


def mttkrp_partials_ref(vals, d_rows, c_rows):
    """partials[b, r] = vals[b] * d_rows[b, r] * c_rows[b, r].

    Args:
      vals:   (B,)   f32 — tensor nonzero values.
      d_rows: (B, R) f32 — gathered rows of the first factor matrix.
      c_rows: (B, R) f32 — gathered rows of the second factor matrix.
    Returns:
      (B, R) f32.
    """
    return vals[:, None] * d_rows * c_rows


def scatter_rows_ref(sel, partials):
    """A_tile = sel @ partials, where sel[i, b] one-hot encodes indI.

    Args:
      sel:      (I_TILE, B) f32 — selection (one-hot transpose) matrix.
      partials: (B, R) f32.
    Returns:
      (I_TILE, R) f32.
    """
    return sel @ partials


def mttkrp_block_ref(vals, j_idx, k_idx, d_mat, c_mat, sel):
    """Full fused block: gather -> partials -> scatter.

    Args:
      vals:  (B,)    f32
      j_idx: (B,)    i32 — row indices into d_mat.
      k_idx: (B,)    i32 — row indices into c_mat.
      d_mat: (J, R)  f32
      c_mat: (K, R)  f32
      sel:   (I_TILE, B) f32
    Returns:
      (I_TILE, R) f32 — the mode-1 MTTKRP contribution of this batch to
      an I_TILE-row tile of the output.
    """
    d_rows = jnp.take(d_mat, j_idx, axis=0)
    c_rows = jnp.take(c_mat, k_idx, axis=0)
    partials = mttkrp_partials_ref(vals, d_rows, c_rows)
    return scatter_rows_ref(sel, partials)


def mttkrp_dense_ref(tensor_dense, d_mat, c_mat):
    """Dense mode-1 MTTKRP (Equation 2 of the paper) — the ground truth
    used to validate the whole batched pipeline end-to-end.

    Args:
      tensor_dense: (I, J, K) f32
      d_mat: (J, R) f32
      c_mat: (K, R) f32
    Returns:
      (I, R) f32: A[i, r] = sum_{j,k} B[i,j,k] * D[j,r] * C[k,r]
    """
    return jnp.einsum("ijk,jr,kr->ir", tensor_dense, d_mat, c_mat)
