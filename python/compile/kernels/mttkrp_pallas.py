"""Layer-1 Pallas kernels for batched spMTTKRP.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
PEs consume one nonzero per cycle, computing `val · D[j,:] ∘ C[k,:]` on
R-lane vector units fed by the LMB memory system. On a TPU-shaped target
the same computation is re-tiled:

* `mttkrp_partials` — elementwise VPU work over a (B_TILE, R) block in
  VMEM. `BlockSpec` tiles the batch dimension; rank stays whole (R ≤ 128
  keeps a lane-width multiple).
* `scatter_rows` — the output-fiber accumulation is re-cast as a matmul
  with a one-hot selection matrix (`A_tile = sel @ partials`), which maps
  onto the MXU systolic array. The grid reduces over B tiles,
  accumulating into the output block — the VMEM-resident accumulator
  plays the role of the paper's output-fiber buffer, and the B-tile grid
  sweep is the HBM→VMEM schedule the FPGA design realized with DMA
  double-buffering.

All kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: VMEM footprint per grid step at (512, 32) f32 is
# 512·32·4 B ≈ 64 KiB per operand — comfortably inside a TPU core's
# ~16 MiB VMEM with double buffering.
B_TILE = 512


def _partials_kernel(vals_ref, d_ref, c_ref, o_ref):
    """o[b, r] = vals[b] * d[b, r] * c[b, r] over one (B_TILE, R) block."""
    vals = vals_ref[...]  # (B_TILE, 1)
    o_ref[...] = vals * d_ref[...] * c_ref[...]


def mttkrp_partials(vals, d_rows, c_rows, *, b_tile=B_TILE):
    """Batched partial products: (B,), (B, R), (B, R) → (B, R).

    The batch dimension is tiled by `b_tile`; B must be a multiple (the
    Rust coordinator pads the tail batch with zero-valued nonzeros, which
    contribute nothing downstream).
    """
    b, r = d_rows.shape
    assert vals.shape == (b,), f"vals {vals.shape} vs rows {d_rows.shape}"
    assert c_rows.shape == (b, r)
    b_tile = min(b_tile, b)
    assert b % b_tile == 0, f"B={b} not a multiple of b_tile={b_tile}"
    # Keep vals 2-D: TPU vector layouts want ≥2-D refs.
    vals2 = vals.reshape(b, 1)
    grid = (b // b_tile,)
    return pl.pallas_call(
        _partials_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, r), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b_tile, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(vals2, d_rows, c_rows)


def _scatter_kernel(sel_ref, part_ref, o_ref):
    """Accumulate one B-tile of `sel @ partials` into the output block.

    Grid dim 0 sweeps B tiles; the output BlockSpec pins the same output
    block for every step, so o_ref accumulates across the reduction.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped: (I_TILE, B_TILE) @ (B_TILE, R).
    o_ref[...] += jnp.dot(
        sel_ref[...], part_ref[...], preferred_element_type=jnp.float32
    )


def scatter_rows(sel, partials, *, b_tile=B_TILE):
    """A_tile = sel @ partials as an MXU-tiled reduction over B.

    Args:
      sel:      (I_TILE, B) f32 one-hot selection matrix.
      partials: (B, R) f32.
    Returns:
      (I_TILE, R) f32.
    """
    i_tile, b = sel.shape
    b2, r = partials.shape
    assert b == b2, f"sel {sel.shape} vs partials {partials.shape}"
    b_tile = min(b_tile, b)
    assert b % b_tile == 0, f"B={b} not a multiple of b_tile={b_tile}"
    grid = (b // b_tile,)
    return pl.pallas_call(
        _scatter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((i_tile, b_tile), lambda i: (0, i)),
            pl.BlockSpec((b_tile, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((i_tile, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((i_tile, r), jnp.float32),
        interpret=True,
    )(sel, partials)


@functools.partial(jax.jit, static_argnames=())
def mttkrp_block(vals, j_idx, k_idx, d_mat, c_mat, sel):
    """Fused L2 block (gather → partials kernel → scatter kernel).

    The gathers stay in XLA (they are the COO element/fiber loads — the
    irregular part the paper's memory system serves); the regular compute
    runs in the two Pallas kernels.
    """
    d_rows = jnp.take(d_mat, j_idx, axis=0)
    c_rows = jnp.take(c_mat, k_idx, axis=0)
    partials = mttkrp_partials(vals, d_rows, c_rows)
    return scatter_rows(sel, partials)


def vmem_bytes_per_step(b_tile: int, i_tile: int, r: int) -> int:
    """Static VMEM footprint of one grid step (both kernels), for the
    §Perf roofline estimate: vals + d + c + partials blocks, plus the
    selection block and output accumulator."""
    f32 = 4
    partials = b_tile * r * f32
    inputs = b_tile * (2 * r + 1) * f32
    scatter = i_tile * b_tile * f32 + i_tile * r * f32
    return partials + inputs + scatter


def mxu_flops_per_step(b_tile: int, i_tile: int, r: int) -> int:
    """MXU MACs per scatter grid step (the matmul 2·I·B·R flops)."""
    return 2 * i_tile * b_tile * r
