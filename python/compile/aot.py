"""AOT lowering: JAX → stablehlo → XlaComputation → HLO *text*.

HLO text (not a serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the Rust ``xla`` crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (run from
python/; the Makefile `artifacts` target does this). Writes one
``<name>.hlo.txt`` per entry point plus ``manifest.json`` describing the
monomorphic shapes for the Rust runtime.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax.jit(...).lower(...) result to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=model.B)
    ap.add_argument("--rank", type=int, default=model.R)
    ap.add_argument("--i-tile", type=int, default=model.I_TILE)
    ap.add_argument("--j-fused", type=int, default=model.J_FUSED)
    ap.add_argument("--k-fused", type=int, default=model.K_FUSED)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = {}

    # Entry 1: partials (gather done in Rust).
    text = lower_entry(
        model.mttkrp_partials_fn,
        model.partials_example_args(args.batch, args.rank),
    )
    path = os.path.join(args.out_dir, "mttkrp_partials.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entries["mttkrp_partials"] = {
        "file": "mttkrp_partials.hlo.txt",
        "batch": args.batch,
        "rank": args.rank,
        "inputs": ["vals[B]f32", "d_rows[B,R]f32", "c_rows[B,R]f32"],
        "output": "partials[B,R]f32",
    }
    print(f"wrote {path} ({len(text)} chars)")

    # Entry 2: fused gather + scatter block.
    text = lower_entry(
        model.mttkrp_fused_fn,
        model.fused_example_args(
            args.batch, args.rank, args.i_tile, args.j_fused, args.k_fused
        ),
    )
    path = os.path.join(args.out_dir, "mttkrp_fused.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entries["mttkrp_fused"] = {
        "file": "mttkrp_fused.hlo.txt",
        "batch": args.batch,
        "rank": args.rank,
        "i_tile": args.i_tile,
        "j": args.j_fused,
        "k": args.k_fused,
        "inputs": [
            "vals[B]f32",
            "j_idx[B]i32",
            "k_idx[B]i32",
            "D[J,R]f32",
            "C[K,R]f32",
            "sel[I_TILE,B]f32",
        ],
        "output": "a_tile[I_TILE,R]f32",
    }
    print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "jax": jax.__version__,
        "entries": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
