"""Layer-2 JAX model: the batched spMTTKRP compute graph that the Rust
coordinator executes through PJRT.

Two entry points are AOT-lowered (python/compile/aot.py):

* ``mttkrp_partials_fn`` — (vals[B], d_rows[B,R], c_rows[B,R]) → [B,R].
  The Rust runtime gathers factor rows itself (it owns the memory
  system) and accumulates the partials into output fibers — this mirrors
  the paper's PE structure most directly.
* ``mttkrp_fused_fn`` — (vals[B], j[B], k[B], D[J,R], C[K,R],
  sel[I_TILE,B]) → [I_TILE,R]. Gathers and the one-hot scatter-matmul
  run inside XLA; used when the factor matrices fit device memory.

Shapes are fixed at lowering time (PJRT executables are monomorphic);
the manifest records them so the Rust side pads batches accordingly.
"""

import jax.numpy as jnp

from .kernels import mttkrp_pallas as k

# Default AOT shapes — the Rust coordinator pads each batch to B.
B = 2048
R = 32
I_TILE = 128
J_FUSED = 4096
K_FUSED = 4096


def mttkrp_partials_fn(vals, d_rows, c_rows):
    """Partials-only graph (returns a 1-tuple for the HLO bridge)."""
    return (k.mttkrp_partials(vals, d_rows, c_rows),)


def mttkrp_fused_fn(vals, j_idx, k_idx, d_mat, c_mat, sel):
    """Fused gather→partials→scatter graph (1-tuple)."""
    return (k.mttkrp_block(vals, j_idx, k_idx, d_mat, c_mat, sel),)


def partials_example_args(b=B, r=R):
    """ShapeDtypeStructs used to lower ``mttkrp_partials_fn``."""
    import jax

    return (
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((b, r), jnp.float32),
        jax.ShapeDtypeStruct((b, r), jnp.float32),
    )


def fused_example_args(b=B, r=R, i_tile=I_TILE, j=J_FUSED, kk=K_FUSED):
    """ShapeDtypeStructs used to lower ``mttkrp_fused_fn``."""
    import jax

    return (
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((j, r), jnp.float32),
        jax.ShapeDtypeStruct((kk, r), jnp.float32),
        jax.ShapeDtypeStruct((i_tile, b), jnp.float32),
    )
