"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value distributions; fixed-seed cases pin
the exact AOT shapes.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from compile.kernels import mttkrp_pallas as k
from compile.kernels import ref


def _rand(rng, *shape):
    return rng.uniform(-2.0, 2.0, size=shape).astype(np.float32)


@pytest.mark.parametrize("b,r", [(512, 32), (1024, 16), (2048, 32), (512, 8)])
def test_partials_matches_ref_fixed_shapes(b, r):
    rng = np.random.default_rng(0)
    vals, d, c = _rand(rng, b), _rand(rng, b, r), _rand(rng, b, r)
    got = k.mttkrp_partials(vals, d, c)
    want = ref.mttkrp_partials_ref(vals, d, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    b_tile=st.sampled_from([128, 256, 512]),
    r=st.sampled_from([4, 8, 16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partials_matches_ref_hypothesis(tiles, b_tile, r, seed):
    b = tiles * b_tile
    rng = np.random.default_rng(seed)
    vals, d, c = _rand(rng, b), _rand(rng, b, r), _rand(rng, b, r)
    got = k.mttkrp_partials(vals, d, c, b_tile=b_tile)
    want = ref.mttkrp_partials_ref(vals, d, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_partials_rejects_non_multiple_batch():
    rng = np.random.default_rng(1)
    with pytest.raises(AssertionError):
        # 300 is not a multiple of the (clamped) 256 tile.
        k.mttkrp_partials(
            _rand(rng, 300), _rand(rng, 300, 8), _rand(rng, 300, 8), b_tile=256
        )


@pytest.mark.parametrize("i_tile,b,r", [(128, 2048, 32), (64, 512, 16), (8, 512, 4)])
def test_scatter_matches_ref(i_tile, b, r):
    rng = np.random.default_rng(2)
    partials = _rand(rng, b, r)
    # One-hot selection: each nonzero lands in a random output row.
    rows = rng.integers(0, i_tile, size=b)
    sel = np.zeros((i_tile, b), dtype=np.float32)
    sel[rows, np.arange(b)] = 1.0
    got = k.scatter_rows(sel, partials)
    want = ref.scatter_rows_ref(sel, partials)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    i_tile=st.sampled_from([8, 32, 128]),
    tiles=st.integers(min_value=1, max_value=3),
    r=st.sampled_from([8, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_scatter_hypothesis(i_tile, tiles, r, seed):
    b = tiles * 256
    rng = np.random.default_rng(seed)
    partials = _rand(rng, b, r)
    rows = rng.integers(0, i_tile, size=b)
    sel = np.zeros((i_tile, b), dtype=np.float32)
    sel[rows, np.arange(b)] = 1.0
    got = k.scatter_rows(sel, partials, b_tile=256)
    want = ref.scatter_rows_ref(sel, partials)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_zero_padding_contributes_nothing():
    # The Rust coordinator pads tail batches with vals=0: the padded lanes
    # must not perturb the scatter result.
    rng = np.random.default_rng(3)
    b, r, i_tile = 512, 16, 32
    vals = _rand(rng, b)
    vals[300:] = 0.0
    d, c = _rand(rng, b, r), _rand(rng, b, r)
    rows = rng.integers(0, i_tile, size=b)
    sel = np.zeros((i_tile, b), dtype=np.float32)
    sel[rows, np.arange(b)] = 1.0
    partials = k.mttkrp_partials(vals, d, c)
    full = k.scatter_rows(sel, partials)
    # Recompute with the padded region entirely removed (mask sel too).
    sel_masked = sel.copy()
    sel_masked[:, 300:] = 0.0
    masked = k.scatter_rows(sel_masked, partials)
    np.testing.assert_allclose(np.asarray(full), np.asarray(masked), rtol=1e-6)


def test_vmem_estimate_within_budget():
    # §Perf: the default AOT tile must fit VMEM with double buffering.
    bytes_per_step = k.vmem_bytes_per_step(k.B_TILE, 128, 32)
    assert bytes_per_step * 2 < 16 * 1024 * 1024, bytes_per_step


def test_dtype_is_preserved():
    rng = np.random.default_rng(4)
    out = k.mttkrp_partials(_rand(rng, 512), _rand(rng, 512, 8), _rand(rng, 512, 8))
    assert out.dtype == jnp.float32
