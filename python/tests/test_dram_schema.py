"""Schema check for the `dram` bench's JSON-lines output
(`MEMSYS_BENCH_JSON=<path> cargo bench --bench dram`).

The dram bench re-runs the Fig. 4 system x dataset grid on both DRAM
timing backends (`dram.model` axis: the lumped default vs the
command-level ACT/RD/WR/PRE/REF model) and dumps one `RunSet` record per
grid point. The contract machine consumers rely on:

* every record carries the sweep axes (`dram.model`, `system`,
  `dataset`), the resolved config echoes the backend back, and
  `config.dram` exposes the full timing parameter set (tRCD/tRP/tCAS/
  tCWL/tRAS/tCCD, turnaround, refresh knobs);
* `report.dram` carries the command-level counters (`refreshes`,
  `refresh_steal_cycles`, `turnaround_cycles`) and they are identically
  zero on every lumped record — the lumped report shape is frozen;
* backends paired per (system, dataset) point agree on the transaction
  stream (reads/writes/bytes) and the timed run never finishes first —
  command-level effects only cost cycles.

Runs against the file named by `MEMSYS_DRAM_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "dram_sample.jsonl"
ENV_VAR = "MEMSYS_DRAM_JSONL"

AXES = ("dram.model", "system", "dataset")
TIMING_FIELDS = (
    "banks",
    "t_row_hit",
    "t_row_miss",
    "t_precharge",
    "t_rcd",
    "t_rp",
    "t_cas",
    "t_cwl",
    "t_ras",
    "t_ccd",
    "t_wtr",
    "t_rtw",
    "refresh",
    "t_refi",
    "t_rfc",
)
COUNTER_FIELDS = ("refreshes", "refresh_steal_cycles", "turnaround_cycles")


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_axes_and_echo_the_backend(path):
    for rec in _load(path):
        for axis in AXES:
            assert axis in rec["axes"], f"missing axis {axis!r} in {rec['label']!r}"
        model = rec["axes"]["dram.model"]
        assert model in {"lumped", "timed"}, rec["label"]
        assert rec["config"]["dram"]["model"] == model, "config must echo the axis"
        for field in TIMING_FIELDS:
            assert field in rec["config"]["dram"], f"config.dram missing {field!r}"
        assert rec["config"]["dram"]["t_ccd"] >= 1
        assert rec["total_cycles"] > 0
        assert rec["report"]["total_cycles"] == rec["total_cycles"]


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_command_level_counters_are_timed_only(path):
    for rec in _load(path):
        dram = rec["report"]["dram"]
        for field in COUNTER_FIELDS:
            assert field in dram, f"{rec['label']!r}: report.dram missing {field!r}"
            assert dram[field] >= 0
        assert 0.0 <= dram["row_hit_rate"] <= 1.0
        if rec["axes"]["dram.model"] == "lumped":
            zeros = {f: dram[f] for f in COUNTER_FIELDS if dram[f] != 0}
            assert not zeros, f"{rec['label']!r}: lumped produced command counters {zeros}"


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_timed_backend_conserves_transactions_and_only_adds_cycles(path):
    by_point = {}
    for rec in _load(path):
        key = (rec["axes"]["system"], rec["axes"]["dataset"])
        by_point.setdefault(key, {})[rec["axes"]["dram.model"]] = rec
    paired = [g for g in by_point.values() if {"lumped", "timed"} <= set(g)]
    assert paired, "grid must pair lumped/timed per (system, dataset) point"
    for key, g in by_point.items():
        if not {"lumped", "timed"} <= set(g):
            continue
        lumped, timed = g["lumped"]["report"]["dram"], g["timed"]["report"]["dram"]
        for field in ("reads", "writes", "read_bytes", "write_bytes"):
            assert timed[field] == lumped[field], (
                f"{key}: backends disagree on {field} "
                f"({timed[field]} != {lumped[field]})"
            )
        assert g["timed"]["total_cycles"] >= g["lumped"]["total_cycles"], (
            f"{key}: command-level timing sped the system up "
            f"({g['timed']['total_cycles']} < {g['lumped']['total_cycles']})"
        )
