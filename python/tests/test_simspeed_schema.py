"""Schema check for the `simspeed` host-throughput bench's JSON-lines
output (`MEMSYS_BENCH_JSON=<path> cargo bench --bench simspeed`).

This is the per-PR perf trajectory for the simulator itself: one record
per (preset, dataset, system) cell per engine, where `engine` is either
`event` (the event-driven run loop) or `reference` (the seed poll loop
kept as the correctness oracle). The contract machine consumers rely on:

* every record carries the documented fields with positive timings and
  throughputs;
* each cell appears once per engine, and the paired records agree on
  `total_cycles` / `nnz` / `accesses` — the two engines are
  report-identical by construction, so a simulated-behavior mismatch in
  the artifact means the equivalence guarantee broke;
* `speedup_vs_reference` on `event` records is `reference` host time
  over `event` host time (throughput regressions show up here).

Runs against the file named by `MEMSYS_SIMSPEED_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "simspeed_sample.jsonl"
ENV_VAR = "MEMSYS_SIMSPEED_JSONL"

REQUIRED = (
    "bench",
    "preset",
    "dataset",
    "system",
    "engine",
    "total_cycles",
    "nnz",
    "accesses",
    "host_seconds",
    "mcycles_per_sec",
    "knnz_per_sec",
    "speedup_vs_reference",
)

ENGINES = {"event", "reference"}
SYSTEMS = {"ip-only", "cache-only", "dma-only", "proposed"}


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_the_documented_schema(path):
    for rec in _load(path):
        for key in REQUIRED:
            assert key in rec, f"missing {key!r} in {rec}"
        assert rec["bench"] == "simspeed"
        assert rec["engine"] in ENGINES, rec["engine"]
        assert rec["system"] in SYSTEMS, rec["system"]
        assert rec["total_cycles"] > 0
        assert rec["nnz"] > 0
        assert rec["accesses"] > 0
        assert rec["host_seconds"] > 0
        assert rec["mcycles_per_sec"] > 0
        assert rec["knnz_per_sec"] > 0
        assert rec["speedup_vs_reference"] > 0


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_engines_are_paired_and_simulation_identical(path):
    cells = {}
    for rec in _load(path):
        key = (rec["preset"], rec["dataset"], rec["system"])
        cells.setdefault(key, {})[rec["engine"]] = rec
    for key, by_engine in cells.items():
        assert set(by_engine) == ENGINES, f"{key}: engines {set(by_engine)}"
        event, reference = by_engine["event"], by_engine["reference"]
        # Simulated behavior must match exactly — only host time differs.
        for field in ("total_cycles", "nnz", "accesses"):
            assert event[field] == reference[field], (key, field)
        assert reference["speedup_vs_reference"] == 1.0, key
