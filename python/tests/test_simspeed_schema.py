"""Schema check for the `simspeed` host-throughput bench's JSON-lines
output (`MEMSYS_BENCH_JSON=<path> cargo bench --bench simspeed`).

This is the per-PR perf trajectory for the simulator itself: one record
per (preset, dataset, system) cell per engine, where `engine` is either
`event` (the event-driven run loop) or `reference` (the seed poll loop
kept as the correctness oracle), plus thread-axis records (`event`
records with `sim_threads` > 1) from the scaled point's in-run sharding
sweep. The contract machine consumers rely on:

* every record carries the documented fields with positive timings and
  throughputs; `visited_cycles` (loop iterations the engine executed —
  the skip-ahead metric) never exceeds `total_cycles` + 1;
* each cell appears once per engine at `sim_threads` == 1, and the
  paired records agree on `total_cycles` / `nnz` / `accesses` — the two
  engines are report-identical by construction, so a simulated-behavior
  mismatch in the artifact means the equivalence guarantee broke;
* thread-axis records match their cell's single-thread `event` record on
  every simulated field including `visited_cycles` — the sharded engine
  is bit-identical at any thread count;
* `speedup_vs_reference` on single-thread `event` records is `reference`
  host time over `event` host time (throughput regressions show up
  here); on thread-axis records it is the speedup over 1 thread.

Runs against the file named by `MEMSYS_SIMSPEED_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "simspeed_sample.jsonl"
ENV_VAR = "MEMSYS_SIMSPEED_JSONL"

REQUIRED = (
    "bench",
    "preset",
    "dataset",
    "system",
    "engine",
    "sim_threads",
    "total_cycles",
    "visited_cycles",
    "nnz",
    "accesses",
    "host_seconds",
    "mcycles_per_sec",
    "knnz_per_sec",
    "speedup_vs_reference",
)

ENGINES = {"event", "reference"}
SYSTEMS = {"ip-only", "cache-only", "dma-only", "proposed"}

SIM_FIELDS = ("total_cycles", "visited_cycles", "nnz", "accesses")


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_the_documented_schema(path):
    for rec in _load(path):
        for key in REQUIRED:
            assert key in rec, f"missing {key!r} in {rec}"
        assert rec["bench"] == "simspeed"
        assert rec["engine"] in ENGINES, rec["engine"]
        assert rec["system"] in SYSTEMS, rec["system"]
        assert rec["sim_threads"] >= 1
        assert rec["total_cycles"] > 0
        assert rec["nnz"] > 0
        assert rec["accesses"] > 0
        assert rec["host_seconds"] > 0
        assert rec["mcycles_per_sec"] > 0
        assert rec["knnz_per_sec"] > 0
        assert rec["speedup_vs_reference"] > 0
        # Skip-ahead can only remove iterations; the +1 covers the final
        # boundary visit of a run that ends exactly on its last cycle.
        assert 0 < rec["visited_cycles"] <= rec["total_cycles"] + 1, rec
        # The reference poll loop is never sharded.
        if rec["engine"] == "reference":
            assert rec["sim_threads"] == 1, rec


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_engines_are_paired_and_simulation_identical(path):
    cells = {}
    for rec in _load(path):
        if rec["sim_threads"] != 1:
            continue
        key = (rec["preset"], rec["dataset"], rec["system"])
        cells.setdefault(key, {})[rec["engine"]] = rec
    assert cells, "no single-thread records"
    for key, by_engine in cells.items():
        assert set(by_engine) == ENGINES, f"{key}: engines {set(by_engine)}"
        event, reference = by_engine["event"], by_engine["reference"]
        # Simulated behavior must match exactly — only host time (and,
        # between engines, visited_cycles) differs.
        for field in ("total_cycles", "nnz", "accesses"):
            assert event[field] == reference[field], (key, field)
        # Skip-ahead is the event engine's whole point: it must not
        # visit more iterations than the poll loop.
        assert event["visited_cycles"] <= reference["visited_cycles"], key
        assert reference["speedup_vs_reference"] == 1.0, key


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_thread_axis_records_are_bit_identical_to_single_thread(path):
    singles = {}
    threaded = []
    for rec in _load(path):
        key = (rec["preset"], rec["dataset"], rec["system"])
        if rec["engine"] == "event" and rec["sim_threads"] == 1:
            singles[key] = rec
        elif rec["sim_threads"] > 1:
            assert rec["engine"] == "event", rec
            threaded.append((key, rec))
    for key, rec in threaded:
        assert key in singles, f"thread-axis record without 1-thread anchor: {key}"
        for field in SIM_FIELDS:
            assert rec[field] == singles[key][field], (key, rec["sim_threads"], field)
