"""Shared discovery/loading for the JSON-lines schema tests.

Encodes the CI-gate policy in one place: every test always runs against
its committed sample (skipping only if that sample is absent), and
additionally against an operator/CI-provided file named by an env var —
where a *missing* file is a broken pipeline and must fail loudly so the
schema gate cannot silently go toothless.
"""

import json
import os
from pathlib import Path

import pytest


def schema_paths(env_var, sample):
    """Paths a schema test should parametrize over."""
    paths = [sample]
    env = os.environ.get(env_var)
    if env:
        paths.append(Path(env))
    return paths


def load_records(path, env_var, sample):
    """Parse one JSON record per line; enforce the gate policy above."""
    if not path.exists():
        if path == sample:
            pytest.skip(f"committed sample {path} not found")
        pytest.fail(f"{env_var}={path} does not exist")
    records = [json.loads(line) for line in path.read_text().splitlines() if line.strip()]
    assert records, f"{path} is empty"
    return records
