"""Schema check for the `scaling` bench's JSON-lines output
(`MEMSYS_BENCH_JSON=<path> cargo bench --bench scaling`).

The scaling bench shards a streamed `.tns` dataset across a 2-16 node
accelerator cluster per inter-node topology (plus the single-node
anchor) and dumps one record per grid point. The contract machine
consumers rely on:

* every record carries the sweep axes (`nodes`, `inter_topology`,
  `dataset`) and a `node_breakdown` with exactly `nodes` rows;
* each node's makespan decomposition is exact: compute + local-memory
  cycles tile the local run, and communication + local run is the
  node's total; the cluster makespan is the slowest node;
* nonzeros are conserved: the shard nnz sum matches the record's total,
  and every record of the file saw the same dataset;
* the network accounts for exactly the requested remote rows
  (`delivered == sum(remote_rows)`, bytes likewise), is silent at one
  node, and its sharding is topology-independent (same node count =>
  same remote-row total on every topology).

Runs against the file named by `MEMSYS_SCALING_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "scaling_sample.jsonl"
ENV_VAR = "MEMSYS_SCALING_JSONL"

AXES = ("nodes", "inter_topology", "dataset")
BREAKDOWN_FIELDS = (
    "node",
    "total_cycles",
    "compute_cycles",
    "local_memory_cycles",
    "communication_cycles",
    "local_cycles",
    "nnz",
    "remote_rows",
    "remote_bytes",
)
NETWORK_FIELDS = (
    "delivered",
    "delivered_bytes",
    "hops",
    "inject_stall_cycles",
    "cycles",
    "max_link_utilization",
    "links",
)
LINK_FIELDS = ("label", "msgs", "bytes", "stall_cycles", "peak_queue", "utilization")


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_axes_and_a_full_breakdown(path):
    for rec in _load(path):
        for axis in AXES:
            assert axis in rec["axes"], f"missing axis {axis!r} in {rec['label']!r}"
        nodes = int(rec["axes"]["nodes"])
        assert nodes >= 1
        assert rec["nodes"] == nodes, "top-level node count must echo the axis"
        breakdown = rec["node_breakdown"]
        assert len(breakdown) == nodes, f"{rec['label']!r}: breakdown rows != nodes"
        for row in breakdown:
            for field in BREAKDOWN_FIELDS:
                assert field in row, f"breakdown row missing {field!r}"
        assert rec["total_cycles"] > 0


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_makespan_decomposition_is_exact(path):
    for rec in _load(path):
        worst = 0
        crit_comm = 0
        for row in rec["node_breakdown"]:
            assert (
                row["compute_cycles"] + row["local_memory_cycles"] == row["local_cycles"]
            ), f"{rec['label']!r} node {row['node']}: breakdown must tile the local run"
            assert (
                row["communication_cycles"] + row["local_cycles"] == row["total_cycles"]
            ), f"{rec['label']!r} node {row['node']}: comm + local != total"
            if row["total_cycles"] >= worst:
                worst = row["total_cycles"]
                crit_comm = row["communication_cycles"]
        assert rec["total_cycles"] == worst, (
            f"{rec['label']!r}: makespan must be the slowest node"
        )
        frac = rec["communication_fraction"]
        assert 0.0 <= frac <= 1.0
        assert abs(frac - crit_comm / rec["total_cycles"]) < 1e-9, (
            f"{rec['label']!r}: communication_fraction must be the critical node's share"
        )


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_nonzeros_are_conserved_across_the_grid(path):
    records = _load(path)
    totals = set()
    for rec in records:
        shard_sum = sum(row["nnz"] for row in rec["node_breakdown"])
        assert shard_sum == rec["nnz"], f"{rec['label']!r}: shards lost nonzeros"
        totals.add(rec["nnz"])
    assert len(totals) == 1, f"grid points saw different datasets: {sorted(totals)}"


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_network_accounts_for_exactly_the_remote_rows(path):
    records = _load(path)
    remote_by_nodes = {}
    multi = 0
    for rec in records:
        net = rec["network"]
        for field in NETWORK_FIELDS:
            assert field in net, f"network missing {field!r}"
        rows = sum(r["remote_rows"] for r in rec["node_breakdown"])
        bytes_ = sum(r["remote_bytes"] for r in rec["node_breakdown"])
        assert net["delivered"] == rows, f"{rec['label']!r}: delivered != remote rows"
        assert net["delivered_bytes"] == bytes_, rec["label"]
        nodes = int(rec["axes"]["nodes"])
        if nodes == 1:
            assert rows == 0, "a single node must not communicate"
            assert rec["communication_fraction"] == 0.0
            assert not net["links"]
        else:
            multi += 1
            assert rows > 0, f"{rec['label']!r}: sharded run never crossed nodes"
            assert net["links"], f"{rec['label']!r}: no inter-node links reported"
            for link in net["links"]:
                for field in LINK_FIELDS:
                    assert field in link, f"link missing {field!r}"
                assert 0.0 <= link["utilization"] <= 1.0
            # Who fetches what is a property of the partition, not of
            # how messages are routed.
            remote_by_nodes.setdefault(nodes, set()).add(rows)
    assert multi > 0, "grid must contain multi-node points"
    for nodes, seen in remote_by_nodes.items():
        assert len(seen) == 1, (
            f"nodes={nodes}: remote-row totals varied by topology: {sorted(seen)}"
        )
