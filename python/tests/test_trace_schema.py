"""Schema check for the simulator's Chrome trace-event export
(`mttkrp-memsys trace --trace-out trace.json ...`, or `simulate` with
`--trace-out`).

Validates the contract Perfetto / `chrome://tracing` and our own
consumers rely on: a top-level `meta` block (label / workload /
reply_network / sample / window) plus a `traceEvents` array where every
event carries `name`/`ph`/`pid`/`tid`, complete spans (`ph == "X"`)
carry a non-negative `ts`/`dur`, instants (`ph == "i"`) carry a scope,
and the span names cover every pipeline stage the telemetry layer
documents (PE access classes, fabric transport, DRAM queue + service,
reply traversal when the reply network is on).

Runs against the file named by `MEMSYS_TRACE_JSON` when set (CI's
bench-smoke job produces one from a Table II dataset) and always
against the committed sample. Gate policy matches the JSONL checks: a
missing committed sample skips, a missing env-named file fails loudly.
Needs no third-party deps beyond pytest.
"""

import json
import os
from pathlib import Path

import pytest

SAMPLE = Path(__file__).parent / "data" / "trace_sample.json"
ENV_VAR = "MEMSYS_TRACE_JSON"

# Stages that must appear in any complete trace: the memory-side span
# chain plus at least one PE access-class span.
REQUIRED_SPANS = {"fabric", "dram.queue", "dram.service"}
ACCESS_CLASSES = {"elem", "fib1", "fib2", "store"}
META_KEYS = ("label", "workload", "reply_network", "sample", "window")
PHASES = {"X", "i", "M"}


def trace_paths():
    paths = [SAMPLE]
    env = os.environ.get(ENV_VAR)
    if env:
        paths.append(Path(env))
    return paths


def load_trace(path):
    if not path.exists():
        if path == SAMPLE:
            pytest.skip(f"committed sample {path} not found")
        pytest.fail(f"{ENV_VAR}={path} does not exist")
    doc = json.loads(path.read_text())
    assert isinstance(doc, dict), f"{path}: trace document must be an object"
    return doc


@pytest.mark.parametrize("path", trace_paths(), ids=lambda p: p.name)
def test_meta_block_documents_the_run(path):
    meta = load_trace(path)["meta"]
    for key in META_KEYS:
        assert key in meta, f"missing meta.{key}"
    assert isinstance(meta["label"], str) and meta["label"]
    assert isinstance(meta["workload"], str) and meta["workload"]
    assert isinstance(meta["reply_network"], bool)
    assert meta["sample"] >= 1
    assert meta["window"] >= 1


@pytest.mark.parametrize("path", trace_paths(), ids=lambda p: p.name)
def test_events_are_well_formed_chrome_trace_events(path):
    events = load_trace(path)["traceEvents"]
    assert events, "traceEvents must not be empty"
    for ev in events:
        assert isinstance(ev["name"], str) and ev["name"]
        assert ev["ph"] in PHASES, f"unknown phase {ev['ph']!r}"
        assert isinstance(ev["pid"], int) and ev["pid"] in (0, 1)
        assert isinstance(ev["tid"], int) and ev["tid"] >= 0
        assert isinstance(ev.get("args", {}), dict)
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0, ev
        elif ev["ph"] == "i":
            assert ev["ts"] >= 0 and ev["s"] == "t", ev


@pytest.mark.parametrize("path", trace_paths(), ids=lambda p: p.name)
def test_spans_cover_every_pipeline_stage(path):
    doc = load_trace(path)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    names = {e["name"] for e in spans}
    missing = REQUIRED_SPANS - names
    assert not missing, f"no complete span for stages {sorted(missing)}"
    assert names & ACCESS_CLASSES or doc["meta"]["sample"] > 1, (
        "at least one PE access-class span expected in an unsampled trace"
    )
    # Process metadata names both trace rows.
    meta_events = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    named = {e["args"]["name"] for e in meta_events}
    assert {"accesses", "memory"} <= named


@pytest.mark.parametrize("path", trace_paths(), ids=lambda p: p.name)
def test_reply_spans_follow_the_reply_network_knob(path):
    doc = load_trace(path)
    reply_spans = [e for e in doc["traceEvents"] if e["name"] in ("reply", "reply.hop")]
    if not doc["meta"]["reply_network"]:
        assert not reply_spans, "reply spans require the reply network"


@pytest.mark.parametrize("path", trace_paths(), ids=lambda p: p.name)
def test_dram_spans_chain_consistently(path):
    # Per request id: queue ends where service starts, and the service
    # span carries a row-buffer outcome.
    doc = load_trace(path)
    queues = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X" and ev["name"] == "dram.queue":
            queues[ev["args"]["id"]] = ev["ts"] + ev["dur"]
    checked = 0
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X" and ev["name"] == "dram.service":
            assert ev["args"]["row"] in ("hit", "miss", "conflict"), ev
            rid = ev["args"]["id"]
            if rid in queues:
                assert ev["ts"] == queues[rid], f"id {rid}: queue/service seam mismatch"
                checked += 1
    assert checked > 0, "no dram.queue -> dram.service chains to check"
