"""Schema check for the `banks` bench's JSON-lines output
(`MEMSYS_BENCH_JSON=<path> cargo bench --bench banks`).

The banks bench sweeps the per-channel LMB bank count x fabric topology
x reply-network model (config-b behind a 4-channel fabric) and dumps one
`RunSet` record per grid point. The contract machine consumers rely on:

* every record carries the sweep axes (`lmb_banks`, `topology`,
  `reply_network`) and the resolved config echoes them back;
* `report.lmbs[*].banks` has exactly `lmb_banks` entries, each with a
  populated per-bank `utilization` share (the shares of one LMB sum to
  1 whenever the LMB saw traffic);
* `report.fabric.reply` is populated when the reply network is on
  (deliveries counted, per-reply-link `utilization` present) and
  provably silent when it is off;
* turning the reply network on never reduces `total_cycles` for the
  same (banks, topology) point — the response path only costs.

Runs against the file named by `MEMSYS_BANKS_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "banks_sample.jsonl"
ENV_VAR = "MEMSYS_BANKS_JSONL"

AXES = ("lmb_banks", "topology", "reply_network")
BANK_FIELDS = (
    "cache_hits",
    "cache_misses",
    "rr_forwarded",
    "rr_absorbed",
    "rr_served_temp",
    "requests",
    "utilization",
)
LINK_FIELDS = ("label", "forwarded", "stall_cycles", "utilization")


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_axes_and_echoed_config(path):
    for rec in _load(path):
        for axis in AXES:
            assert axis in rec["axes"], f"missing axis {axis!r} in {rec['label']!r}"
        banks = int(rec["axes"]["lmb_banks"])
        assert banks >= 1
        assert rec["axes"]["reply_network"] in {"on", "off"}
        assert rec["config"]["lmb_banks"] == banks, "config must echo the axis"
        assert rec["config"]["interconnect"]["reply_network"] == (
            rec["axes"]["reply_network"] == "on"
        )
        assert rec["total_cycles"] > 0
        assert rec["report"]["total_cycles"] == rec["total_cycles"]


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_per_bank_utilization_is_populated(path):
    for rec in _load(path):
        banks = int(rec["axes"]["lmb_banks"])
        lmbs = rec["report"]["lmbs"]
        assert lmbs, f"{rec['label']!r}: no per-LMB stats in the report"
        for lmb in lmbs:
            assert len(lmb["banks"]) == banks, rec["label"]
            shares = []
            for bank in lmb["banks"]:
                for field in BANK_FIELDS:
                    assert field in bank, f"bank missing {field!r}"
                assert 0.0 <= bank["utilization"] <= 1.0
                shares.append(bank["utilization"])
            if any(b["requests"] > 0 for b in lmb["banks"]):
                assert abs(sum(shares) - 1.0) < 1e-9, f"{rec['label']!r}: {shares}"


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_reply_counters_track_the_reply_network_axis(path):
    for rec in _load(path):
        reply = rec["report"]["fabric"]["reply"]
        if rec["axes"]["reply_network"] == "on":
            assert reply["delivered"] > 0, f"{rec['label']!r}: reply network silent"
            assert reply["links"], f"{rec['label']!r}: no reply links reported"
            for link in reply["links"]:
                for field in LINK_FIELDS:
                    assert field in link, f"reply link missing {field!r}"
                assert 0.0 <= link["utilization"] <= 1.0
        else:
            assert reply["delivered"] == 0, f"{rec['label']!r}: off but delivered"
            assert not reply["links"], f"{rec['label']!r}: off but has reply links"


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_reply_network_only_adds_cycles(path):
    by_point = {}
    for rec in _load(path):
        key = (rec["axes"]["lmb_banks"], rec["axes"]["topology"])
        by_point.setdefault(key, {})[rec["axes"]["reply_network"]] = rec["total_cycles"]
    paired = [g for g in by_point.values() if {"on", "off"} <= set(g)]
    assert paired, "grid must pair reply on/off per (banks, topology) point"
    for key, g in by_point.items():
        if {"on", "off"} <= set(g):
            assert g["on"] >= g["off"], (
                f"{key}: modeling the response path sped the system up "
                f"({g['on']} < {g['off']})"
            )
