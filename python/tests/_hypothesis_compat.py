"""Hypothesis when available, a deterministic fallback when not.

CI installs the real ``hypothesis`` and gets full randomized sweeps. The
offline build image does not ship it, and the repo rule is to gate
missing dependencies rather than let collection crash — so this module
re-exports the real API when importable and otherwise substitutes a
small, seeded, deterministic runner for the subset the tests use
(``given``, ``settings``, ``st.integers``, ``st.sampled_from``).

The fallback is a smoke-level sweep (a handful of fixed examples), not a
replacement for hypothesis's shrinking search — which is exactly the
right trade for an environment where the dependency cannot be installed.
"""

try:  # pragma: no cover - exercised implicitly by which env runs it
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    # Examples per @given test in fallback mode: enough to cover several
    # shape combinations, small enough to keep the offline run fast.
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    st = _Strategies()

    def settings(max_examples=None, **_ignored):
        """Record the example budget on the (already @given-wrapped) fn."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):  # noqa: ANN002 - mirrors fn
                requested = getattr(wrapper, "_max_examples", None)
                n = min(requested or _FALLBACK_EXAMPLES, _FALLBACK_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for example in range(n):
                    drawn = {
                        name: strat.draw(rng)
                        for name, strat in strategy_kwargs.items()
                    }
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # re-raise with the example
                        raise AssertionError(
                            f"fallback example {example}: {drawn!r}: {e}"
                        ) from e

            # pytest resolves parameters via inspect.signature, which
            # follows __wrapped__ back to fn and would then demand a
            # fixture per strategy argument; present a zero-arg facade
            # instead (the strategies supply every argument).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
