"""Schema check for the Rust sweep runner's JSON-lines output
(`mttkrp-memsys sweep ... --out runs.jsonl`, or a `RunSet::write_jsonl`
dump from the figure benches).

Validates the contract machine consumers rely on: one standalone JSON
record per line carrying `label` / `axes` / `total_cycles` (mirrored
inside the full `report`), and — whenever a `system` axis is present —
speedups consistent with the paper's Fig. 4 ordering (the proposed LMB
system beats every baseline on the same workload).

Runs against the file named by `MEMSYS_SWEEP_JSONL` when set (CI's
bench-smoke job produces one with a tiny grid) and always against the
committed sample. Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "sweep_sample.jsonl"
ENV_VAR = "MEMSYS_SWEEP_JSONL"

REQUIRED_TOP_LEVEL = ("label", "axes", "config", "fmax_mhz", "total_cycles", "report")


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_the_documented_schema(path):
    for rec in _load(path):
        for key in REQUIRED_TOP_LEVEL:
            assert key in rec, f"missing {key!r} in {rec.get('label')!r}"
        assert isinstance(rec["label"], str) and rec["label"]
        assert isinstance(rec["axes"], dict)
        for axis, value in rec["axes"].items():
            assert isinstance(axis, str) and isinstance(value, str), (axis, value)
        assert rec["total_cycles"] > 0
        assert rec["fmax_mhz"] > 0
        report = rec["report"]
        assert isinstance(report, dict)
        assert report["total_cycles"] == rec["total_cycles"], "top-level mirror"
        assert isinstance(report["workload"], str) and report["workload"]
        assert isinstance(rec["config"], dict) and "kind" in rec["config"]


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_system_axis_speedups_follow_fig4_ordering(path):
    records = _load(path)
    # Group runs that differ only in the `system` axis (one Fig. 4
    # category per group) and compare their cycle counts.
    groups = {}
    for rec in records:
        axes = rec["axes"]
        if "system" not in axes:
            continue
        key = tuple(sorted((k, v) for k, v in axes.items() if k != "system"))
        groups.setdefault(key, {})[axes["system"]] = rec["total_cycles"]
    if not any("proposed" in g and len(g) > 1 for g in groups.values()):
        pytest.skip("no proposed-vs-baseline pairs in this sweep")
    for key, by_system in groups.items():
        proposed = by_system.get("proposed")
        if proposed is None:
            continue
        for baseline, cycles in by_system.items():
            if baseline == "proposed":
                continue
            speedup = cycles / proposed
            assert speedup > 1.0, (
                f"category {key}: proposed ({proposed}) must beat "
                f"{baseline} ({cycles}), got {speedup:.2f}x"
            )


def test_sample_runs_within_paper_band():
    # The committed sample mirrors the paper's headline factors, so the
    # parser above is exercised against realistic magnitudes.
    by_system = {r["axes"]["system"]: r["total_cycles"] for r in _load(SAMPLE)}
    assert set(by_system) == {"ip-only", "cache-only", "dma-only", "proposed"}
    headline = by_system["ip-only"] / by_system["proposed"]
    assert 2.0 < headline < 6.0, f"ip-only/proposed {headline:.2f} out of band"
