"""AOT bridge: lowering produces parseable HLO text with the right entry
signature, and the manifest round-trips."""

import json
import os
import subprocess
import sys

import jax

from compile import aot, model


def test_lower_partials_produces_hlo_text():
    text = aot.lower_entry(
        model.mttkrp_partials_fn, model.partials_example_args(512, 8)
    )
    assert "HloModule" in text
    assert "ENTRY" in text
    # Three parameters with the lowered shapes.
    assert "f32[512,8]" in text
    assert "f32[512]" in text


def test_lower_fused_produces_hlo_text():
    text = aot.lower_entry(
        model.mttkrp_fused_fn,
        model.fused_example_args(512, 8, 32, 64, 64),
    )
    assert "HloModule" in text
    assert "s32[512]" in text  # index operands
    assert "f32[32,512]" in text  # selection matrix
    # The scatter matmul must appear as a dot (MXU-eligible op).
    assert "dot(" in text or "dot " in text


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--batch",
            "512",
            "--rank",
            "8",
            "--i-tile",
            "32",
            "--j-fused",
            "64",
            "--k-fused",
            "64",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert set(manifest["entries"]) == {"mttkrp_partials", "mttkrp_fused"}
    for entry in manifest["entries"].values():
        text = (out / entry["file"]).read_text()
        assert text.startswith("HloModule")
    assert manifest["entries"]["mttkrp_partials"]["batch"] == 512


def test_hlo_text_has_no_64bit_id_issue_markers():
    # The text path exists precisely because .serialize() protos break
    # xla_extension 0.5.1; make sure we emit text, never proto bytes.
    text = aot.lower_entry(
        model.mttkrp_partials_fn, model.partials_example_args(512, 8)
    )
    assert isinstance(text, str)
    assert text.isprintable() or "\n" in text


def test_jax_version_recorded():
    assert jax.__version__
