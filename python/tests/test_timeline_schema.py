"""Schema check for the simulator's windowed time-series export
(`mttkrp-memsys simulate/trace --timeline tl.jsonl`).

Validates the JSONL contract phase/heatmap consumers rely on: one JSON
object per window with a strictly increasing `cycle`, per-channel
delta blocks (`reads`/`writes`/`busy_bus` plus instantaneous
`occupancy`), fabric / LMB / PE delta blocks, and instantaneous queue
depths. All deltas are non-negative — the underlying counters are
cumulative, so a negative delta means the emitter's bookkeeping broke.

Runs against the file named by `MEMSYS_TIMELINE_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "timeline_sample.jsonl"
ENV_VAR = "MEMSYS_TIMELINE_JSONL"

TOP_LEVEL = ("cycle", "channels", "fabric", "reply", "lmbs", "pe", "depths")
CHANNEL_KEYS = ("occupancy", "reads", "writes", "busy_bus")
LMB_KEYS = ("hits", "misses", "rr_served", "rr_absorbed", "rr_forwarded")
PE_KEYS = ("retired", "issued", "stalls")


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_rows_carry_the_documented_schema(path):
    for row in _load(path):
        for key in TOP_LEVEL:
            assert key in row, f"missing {key!r} in row at cycle {row.get('cycle')}"
        for ch in row["channels"]:
            for key in CHANNEL_KEYS:
                assert ch[key] >= 0, (key, ch)
        fabric = row["fabric"]
        for key in ("forwarded", "backpressure", "hops"):
            assert fabric[key] >= 0, (key, fabric)
        assert all(v >= 0 for v in fabric["links"])
        assert row["reply"]["delivered"] >= 0
        for lmb in row["lmbs"]:
            for key in LMB_KEYS:
                assert lmb[key] >= 0, (key, lmb)
        for key in PE_KEYS:
            assert row["pe"][key] >= 0, (key, row["pe"])
        depths = row["depths"]
        assert all(v >= 0 for v in depths["ingress"])
        assert depths["deliveries"] >= 0 and depths["line_events"] >= 0


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_cycles_strictly_increase(path):
    cycles = [row["cycle"] for row in _load(path)]
    assert all(a < b for a, b in zip(cycles, cycles[1:])), cycles


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_row_shapes_are_consistent_across_windows(path):
    # One run has a fixed geometry: channel / LMB / link / port counts
    # must not change between windows.
    rows = _load(path)
    first = rows[0]
    shape = (
        len(first["channels"]),
        len(first["lmbs"]),
        len(first["fabric"]["links"]),
        len(first["depths"]["ingress"]),
    )
    for row in rows[1:]:
        assert (
            len(row["channels"]),
            len(row["lmbs"]),
            len(row["fabric"]["links"]),
            len(row["depths"]["ingress"]),
        ) == shape, f"geometry changed at cycle {row['cycle']}"
