"""L2 correctness: the fused block graph vs a dense einsum MTTKRP, and a
full multi-batch sparse MTTKRP assembled the way the Rust coordinator
does it (pad → block → accumulate tiles)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from compile import model
from compile.kernels import ref
from compile.kernels import mttkrp_pallas as k


def _sparse_tensor(rng, dims, nnz):
    """Random COO with unique coordinates."""
    i = rng.integers(0, dims[0], size=nnz)
    j = rng.integers(0, dims[1], size=nnz)
    kk = rng.integers(0, dims[2], size=nnz)
    coords = np.stack([i, j, kk], axis=1)
    _, keep = np.unique(coords, axis=0, return_index=True)
    keep.sort()
    vals = rng.uniform(-1, 1, size=len(keep)).astype(np.float32)
    return i[keep], j[keep], kk[keep], vals


def _dense_of(dims, i, j, kk, vals):
    t = np.zeros(dims, dtype=np.float32)
    t[i, j, kk] = vals
    return t


def test_fused_block_matches_dense_small():
    rng = np.random.default_rng(10)
    dims, r, b = (16, 64, 64), 8, 256
    i, j, kk, vals = _sparse_tensor(rng, dims, 200)
    n = len(vals)
    d_mat = rng.uniform(-1, 1, size=(dims[1], r)).astype(np.float32)
    c_mat = rng.uniform(-1, 1, size=(dims[2], r)).astype(np.float32)
    # Pad to one block of B with zero vals.
    pad = b - n
    vals_p = np.concatenate([vals, np.zeros(pad, np.float32)])
    j_p = np.concatenate([j, np.zeros(pad, np.int64)]).astype(np.int32)
    k_p = np.concatenate([kk, np.zeros(pad, np.int64)]).astype(np.int32)
    sel = np.zeros((dims[0], b), dtype=np.float32)
    sel[i, np.arange(n)] = 1.0
    got = np.asarray(ref.mttkrp_block_ref(vals_p, j_p, k_p, d_mat, c_mat, sel))
    got_pallas = np.asarray(k.mttkrp_block(vals_p, j_p, k_p, d_mat, c_mat, sel))
    dense = _dense_of(dims, i, j, kk, vals)
    want = np.asarray(ref.mttkrp_dense_ref(dense, d_mat, c_mat))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_pallas, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_multibatch_accumulation_matches_dense(seed):
    """Assemble mode-1 MTTKRP from several blocks exactly like the Rust
    coordinator: batches of B nonzeros, per-batch I-tiles, accumulate."""
    rng = np.random.default_rng(seed)
    dims, r, b = (32, 48, 40), 8, 256
    i, j, kk, vals = _sparse_tensor(rng, dims, 600)
    d_mat = rng.uniform(-1, 1, size=(dims[1], r)).astype(np.float32)
    c_mat = rng.uniform(-1, 1, size=(dims[2], r)).astype(np.float32)
    out = np.zeros((dims[0], r), dtype=np.float32)
    for lo in range(0, len(vals), b):
        hi = min(lo + b, len(vals))
        n = hi - lo
        pad = b - n
        vals_p = np.concatenate([vals[lo:hi], np.zeros(pad, np.float32)])
        j_p = np.concatenate([j[lo:hi], np.zeros(pad, np.int64)]).astype(np.int32)
        k_p = np.concatenate([kk[lo:hi], np.zeros(pad, np.int64)]).astype(np.int32)
        sel = np.zeros((dims[0], b), dtype=np.float32)
        sel[i[lo:hi], np.arange(n)] = 1.0
        out += np.asarray(
            k.mttkrp_block(vals_p, j_p, k_p, d_mat, c_mat, sel)
        )
    dense = _dense_of(dims, i, j, kk, vals)
    want = np.asarray(ref.mttkrp_dense_ref(dense, d_mat, c_mat))
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-3)


def test_model_entry_points_return_tuples():
    rng = np.random.default_rng(11)
    b, r = 512, 8
    vals = rng.uniform(-1, 1, size=b).astype(np.float32)
    rows = rng.uniform(-1, 1, size=(b, r)).astype(np.float32)
    out = model.mttkrp_partials_fn(vals, rows, rows)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (b, r)


def test_example_args_shapes():
    args = model.partials_example_args(1024, 16)
    assert args[0].shape == (1024,)
    assert args[1].shape == (1024, 16)
    fused = model.fused_example_args(512, 8, 32, 100, 200)
    assert fused[3].shape == (100, 8)
    assert fused[5].shape == (32, 512)
