"""Schema check for the `table3` bench's JSON-lines output
(`MEMSYS_BENCH_JSON=<path> cargo bench --bench table3`).

The table3 bench writes each Table III dataset to a FROSTT `.tns`
fixture and simulates it *streamed from disk* (`Scenario::tns_file`)
over the four system variants. The contract machine consumers rely on:

* every record carries a `dataset` axis that is a `.tns` file path and a
  `system` axis naming one of the four variants, and the resolved config
  echoes the system kind back;
* `report.workload` is the dataset file's stem (the streamed source is
  named after the file it reads);
* the grid is complete — all four systems per dataset — and the
  workload-side numbers (`nnz`, `accesses`) agree across systems for the
  same dataset, since they describe the input, not the memory system;
* the proposed system beats the IP-only baseline on every dataset (the
  Fig. 4 ordering the streamed path must preserve).

Runs against the file named by `MEMSYS_TABLE3_JSONL` when set (CI's
bench-smoke job produces one) and always against the committed sample.
Needs no third-party deps beyond pytest.
"""

from pathlib import Path

import pytest

from _jsonl_schema import load_records, schema_paths

SAMPLE = Path(__file__).parent / "data" / "table3_sample.jsonl"
ENV_VAR = "MEMSYS_TABLE3_JSONL"

SYSTEMS = {"ip-only", "cache-only", "dma-only", "proposed"}


def _load(path):
    return load_records(path, ENV_VAR, SAMPLE)


def _by_dataset(records):
    grids = {}
    for rec in records:
        grids.setdefault(rec["axes"]["dataset"], {})[rec["axes"]["system"]] = rec
    return grids


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_records_carry_tns_dataset_and_system_axes(path):
    for rec in _load(path):
        dataset = rec["axes"]["dataset"]
        assert dataset.endswith(".tns"), f"{rec['label']!r}: dataset is not a .tns path"
        system = rec["axes"]["system"]
        assert system in SYSTEMS, f"{rec['label']!r}: unknown system {system!r}"
        assert rec["config"]["kind"] == system, "config must echo the system axis"
        assert rec["total_cycles"] > 0
        assert rec["report"]["total_cycles"] == rec["total_cycles"]
        assert rec["report"]["workload"] == Path(dataset).stem, (
            f"{rec['label']!r}: streamed source must be named after the file"
        )


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_grid_is_complete_and_workload_numbers_agree(path):
    grids = _by_dataset(_load(path))
    assert grids, "no datasets in the grid"
    for dataset, runs in grids.items():
        assert set(runs) == SYSTEMS, f"{dataset}: incomplete system grid {sorted(runs)}"
        nnzs = {r["report"]["nnz"] for r in runs.values()}
        accesses = {r["report"]["accesses"] for r in runs.values()}
        assert len(nnzs) == 1, f"{dataset}: nnz varies across systems: {nnzs}"
        assert len(accesses) == 1, f"{dataset}: accesses vary across systems: {accesses}"
        assert nnzs.pop() > 0
        assert accesses.pop() > 0


@pytest.mark.parametrize("path", schema_paths(ENV_VAR, SAMPLE), ids=lambda p: p.name)
def test_proposed_beats_ip_only_on_every_dataset(path):
    for dataset, runs in _by_dataset(_load(path)).items():
        ip = runs["ip-only"]["total_cycles"]
        proposed = runs["proposed"]["total_cycles"]
        assert proposed < ip, (
            f"{dataset}: proposed ({proposed}) must beat ip-only ({ip})"
        )
