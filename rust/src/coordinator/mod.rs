//! L3 coordinator: binds the memory-system simulator (timing) to the
//! PJRT compute path (numerics) and drives end-to-end workloads.
//!
//! The paper's contribution is the memory system, so the coordinator's
//! job is the glue an accelerator host would do: partition nonzeros,
//! generate the request streams, run them through the simulated LMBs for
//! the paper's *total memory access time* metric, and execute the same
//! batches through the AOT-compiled kernels for real numerics.

mod accel;
mod driver;

pub use accel::{run_accelerator, AccelReport};
pub use driver::{TimedCpAls, TimedCpAlsReport};
