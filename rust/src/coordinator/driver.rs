//! Timed CP-ALS driver (experiment E6): the end-to-end validation that
//! all three layers compose — CP-ALS numerics run through the AOT/PJRT
//! kernels while each mode's request stream is simulated on the
//! configured memory system, so the run reports both *fit convergence*
//! and *simulated memory cycles per sweep*.

use crate::config::SystemConfig;
use crate::mttkrp::{CpAls, CpAlsOptions, CpAlsReport};
use crate::runtime::{Manifest, MttkrpExecutor};
use crate::sim::{simulate, SimReport};
use crate::tensor::{CooTensor, DenseMatrix, Mode};
use crate::trace::workload_from_tensor;
use crate::Result;

/// CP-ALS report + simulated memory timing.
#[derive(Debug, Clone)]
pub struct TimedCpAlsReport {
    pub als: CpAlsReport,
    /// One memory-system simulation per mode (the access streams repeat
    /// identically every sweep, so a sweep costs the sum of the three).
    pub per_mode_sim: Vec<SimReport>,
    /// Simulated memory cycles for one full ALS sweep.
    pub cycles_per_sweep: u64,
    /// Total simulated cycles for the whole run.
    pub total_cycles: u64,
    /// Host seconds spent in PJRT execution.
    pub compute_seconds: f64,
}

/// End-to-end driver owning the executor + config.
pub struct TimedCpAls {
    cfg: SystemConfig,
    manifest: Manifest,
}

impl TimedCpAls {
    pub fn new(cfg: SystemConfig, manifest: Manifest) -> TimedCpAls {
        TimedCpAls { cfg, manifest }
    }

    /// Run CP-ALS with the PJRT MTTKRP kernel and simulate each mode's
    /// memory traffic on the configured system.
    pub fn run(&self, t: &CooTensor, opts: CpAlsOptions) -> Result<TimedCpAlsReport> {
        crate::ensure!(
            opts.rank == self.manifest.partials.rank,
            "CP-ALS rank {} != AOT rank {} — re-run `make artifacts --rank`",
            opts.rank,
            self.manifest.partials.rank
        );
        // Memory-system timing: one simulation per mode (the trace is
        // identical across sweeps — the factor values change, not the
        // access pattern).
        let mut per_mode_sim = Vec::new();
        for mode in Mode::ALL {
            let mut sorted = t.clone();
            sorted.sort_mode(mode);
            let w = workload_from_tensor(
                &sorted,
                mode,
                self.cfg.pe.fabric,
                self.cfg.pe.n_pes,
                opts.rank,
                self.cfg.dram.row_bytes,
            );
            per_mode_sim.push(simulate(&self.cfg, &w));
        }
        let cycles_per_sweep: u64 = per_mode_sim.iter().map(|s| s.total_cycles).sum();

        // Numerics through PJRT.
        let mut exec = MttkrpExecutor::new(&self.manifest)?;
        let mut als = CpAls::new(t, opts);
        let mut err: Option<crate::Error> = None;
        let report = {
            let mut kernel =
                |tt: &CooTensor, m: Mode, m1: &DenseMatrix, m2: &DenseMatrix| -> DenseMatrix {
                    match exec.mttkrp(tt, m, m1, m2) {
                        Ok(out) => out,
                        Err(e) => {
                            // Surface the first failure after the sweep.
                            if err.is_none() {
                                err = Some(e);
                            }
                            DenseMatrix::zeros(tt.dim(m) as usize, m1.cols)
                        }
                    }
                };
            als.run_with(&mut kernel)
        };
        if let Some(e) = err {
            return Err(e);
        }
        let sweeps = report.iters.len() as u64;
        Ok(TimedCpAlsReport {
            als: report,
            per_mode_sim,
            cycles_per_sweep,
            total_cycles: cycles_per_sweep * sweeps,
            compute_seconds: exec.stats.execute_seconds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifacts_dir;
    use crate::util::rng::Rng;

    #[test]
    fn timed_als_converges_and_reports_cycles() {
        let Some(dir) = find_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let rank = manifest.partials.rank;
        let mut rng = Rng::new(120);
        let t = CooTensor::random(&mut rng, [30, 40, 50], 4000);
        let driver = TimedCpAls::new(SystemConfig::config_b(), manifest);
        let report = driver
            .run(
                &t,
                CpAlsOptions {
                    rank,
                    max_iters: 3,
                    fit_tol: 0.0,
                    seed: 1,
                },
            )
            .unwrap();
        assert_eq!(report.als.iters.len(), 3);
        assert_eq!(report.per_mode_sim.len(), 3);
        assert!(report.cycles_per_sweep > 0);
        assert_eq!(report.total_cycles, report.cycles_per_sweep * 3);
        assert!(report.compute_seconds > 0.0);
    }

    #[test]
    fn rank_mismatch_rejected() {
        let Some(dir) = find_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let bad_rank = manifest.partials.rank + 3;
        let mut rng = Rng::new(121);
        let t = CooTensor::random(&mut rng, [8, 8, 8], 50);
        let driver = TimedCpAls::new(SystemConfig::config_a(), manifest);
        assert!(driver
            .run(
                &t,
                CpAlsOptions {
                    rank: bad_rank,
                    max_iters: 1,
                    ..Default::default()
                }
            )
            .is_err());
    }
}
