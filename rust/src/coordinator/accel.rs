//! Single-MTTKRP accelerator run: simulated memory timing + PJRT
//! numerics for one mode-`mode` sweep over a tensor.

use crate::config::SystemConfig;
use crate::mttkrp::mttkrp_seq;
use crate::runtime::{BatchComputeStats, Manifest, MttkrpExecutor};
use crate::sim::{simulate, SimReport};
use crate::tensor::{CooTensor, DenseMatrix, Mode};
use crate::trace::workload_from_tensor;
use crate::util::json::Json;
use crate::Result;

/// Combined timing + compute report for one accelerator run.
#[derive(Debug, Clone)]
pub struct AccelReport {
    pub sim: SimReport,
    pub compute: BatchComputeStats,
    /// Frobenius norm of the MTTKRP output (quick integrity signal).
    pub output_norm: f64,
    /// Max |Δ| between the PJRT output and the pure-Rust reference.
    pub max_diff_vs_reference: f32,
}

impl AccelReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sim", self.sim.to_json()),
            ("output_norm", Json::num(self.output_norm)),
            (
                "max_diff_vs_reference",
                Json::num(self.max_diff_vs_reference as f64),
            ),
            (
                "compute",
                Json::obj(vec![
                    ("batches", Json::num(self.compute.batches as f64)),
                    ("nnz", Json::num(self.compute.nnz as f64)),
                    (
                        "execute_seconds",
                        Json::num(self.compute.execute_seconds),
                    ),
                ]),
            ),
        ])
    }
}

/// Run one mode-`mode` MTTKRP through the full stack:
/// 1. generate the request trace for `cfg`'s fabric type,
/// 2. simulate the memory system (paper's Fig. 4 metric),
/// 3. execute the numerics via the AOT/PJRT path,
/// 4. cross-check against the pure-Rust reference.
pub fn run_accelerator(
    cfg: &SystemConfig,
    manifest: &Manifest,
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
) -> Result<(DenseMatrix, AccelReport)> {
    let workload = workload_from_tensor(
        t,
        mode,
        cfg.pe.fabric,
        cfg.pe.n_pes,
        cfg.pe.rank,
        cfg.dram.row_bytes,
    );
    let sim = simulate(cfg, &workload);

    let mut exec = MttkrpExecutor::new(manifest)?;
    let out = exec.mttkrp(t, mode, m1, m2)?;

    let reference = mttkrp_seq(t, mode, m1, m2);
    let max_diff = out.max_abs_diff(&reference);
    let report = AccelReport {
        sim,
        compute: exec.stats.clone(),
        output_norm: out.fro_norm(),
        max_diff_vs_reference: max_diff,
    };
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifacts_dir;
    use crate::util::rng::Rng;

    #[test]
    fn full_stack_roundtrip() {
        let Some(dir) = find_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let cfg = SystemConfig::config_b();
        let mut rng = Rng::new(110);
        let t = CooTensor::random(&mut rng, [32, 2000, 3000], 2500);
        let r = manifest.partials.rank;
        let d = DenseMatrix::random(&mut rng, 2000, r);
        let c = DenseMatrix::random(&mut rng, 3000, r);
        let (out, report) = run_accelerator(&cfg, &manifest, &t, Mode::I, &d, &c).unwrap();
        assert_eq!(out.rows, 32);
        assert!(report.sim.total_cycles > 0);
        assert!(report.max_diff_vs_reference < 1e-3);
        assert!(report.output_norm > 0.0);
        let j = report.to_json();
        assert!(j.get("sim").unwrap().get("total_cycles").is_some());
    }
}
