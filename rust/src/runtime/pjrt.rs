//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, execute many times.

use std::collections::HashMap;
use std::path::Path;

use crate::Result;

/// A PJRT client with a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| crate::format_err!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        crate::ensure!(path.exists(), "artifact {} missing", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| crate::format_err!("non-utf8 path"))?,
        )
        .map_err(|e| crate::format_err!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| crate::format_err!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute `name` with the given input literals. The AOT path lowers
    /// with `return_tuple=True`, so the single output is unwrapped from a
    /// 1-tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| crate::format_err!("executable {name:?} not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| crate::format_err!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| crate::format_err!("to_literal {name}: {e:?}"))?;
        lit.to_tuple1()
            .map_err(|e| crate::format_err!("untuple {name}: {e:?}"))
    }
}

/// Build an f32 literal of the given logical shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    crate::ensure!(n as usize == data.len(), "shape {shape:?} != len {}", data.len());
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(shape)
        .map_err(|e| crate::format_err!("reshape {shape:?}: {e:?}"))
}

/// Build an i32 literal (rank-1).
pub fn literal_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{find_artifacts_dir, Manifest};

    #[test]
    fn loads_and_runs_partials_artifact() {
        let Some(dir) = find_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let mut rt = PjrtRuntime::cpu().unwrap();
        rt.load_hlo_text("partials", &m.partials_path()).unwrap();
        assert!(rt.is_loaded("partials"));
        let (b, r) = (m.partials.batch, m.partials.rank);
        let vals = vec![2.0f32; b];
        let d = vec![3.0f32; b * r];
        let c = vec![0.5f32; b * r];
        let out = rt
            .execute(
                "partials",
                &[
                    literal_f32(&vals, &[b as i64]).unwrap(),
                    literal_f32(&d, &[b as i64, r as i64]).unwrap(),
                    literal_f32(&c, &[b as i64, r as i64]).unwrap(),
                ],
            )
            .unwrap();
        let v = out.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), b * r);
        assert!(v.iter().all(|&x| (x - 3.0).abs() < 1e-6), "2*3*0.5 = 3");
    }

    #[test]
    fn unknown_executable_errors() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }
}
