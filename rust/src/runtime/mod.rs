//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! Python never runs here — the artifacts are self-contained HLO text
//! (the interchange format that survives the jax≥0.5 / xla_extension
//! 0.5.1 proto-id mismatch; see DESIGN.md §3 and /opt/xla-example).

mod artifacts;
mod compute;
#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
mod pjrt;

pub use artifacts::{find_artifacts_dir, Manifest};
pub use compute::{BatchComputeStats, MttkrpExecutor};
pub use pjrt::{literal_f32 as pjrt_literal_f32, literal_i32 as pjrt_literal_i32, PjrtRuntime};
