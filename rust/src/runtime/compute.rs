//! Batch MTTKRP executor over the AOT artifacts.
//!
//! Mirrors the paper's PE pipeline in software: for each batch of B
//! nonzeros, *gather* the two factor rows (the irregular accesses the
//! memory system serves), run the AOT-compiled partials kernel through
//! PJRT (the PE compute), and *scatter-accumulate* into output fibers
//! (Algorithm 3's `temp_Y` writeback). Tail batches are padded with
//! zero-valued nonzeros, which contribute nothing (validated by
//! python/tests and `zero_padding` here).

use std::time::Instant;

use crate::mttkrp::operand_modes;
use crate::tensor::{CooTensor, DenseMatrix, Mode};
use crate::Result;

use super::artifacts::Manifest;
use super::pjrt::{literal_f32, PjrtRuntime};

/// Counters for the compute path.
#[derive(Debug, Clone, Default)]
pub struct BatchComputeStats {
    pub batches: u64,
    pub nnz: u64,
    pub padded_lanes: u64,
    pub execute_seconds: f64,
    pub gather_seconds: f64,
    pub scatter_seconds: f64,
}

/// MTTKRP executor bound to the `mttkrp_partials` artifact.
pub struct MttkrpExecutor {
    rt: PjrtRuntime,
    batch: usize,
    rank: usize,
    pub stats: BatchComputeStats,
    // Reused per-batch buffers (no allocation on the hot path).
    vals_buf: Vec<f32>,
    d_buf: Vec<f32>,
    c_buf: Vec<f32>,
}

impl MttkrpExecutor {
    /// Load artifacts and build the executor.
    pub fn new(manifest: &Manifest) -> Result<MttkrpExecutor> {
        let mut rt = PjrtRuntime::cpu()?;
        rt.load_hlo_text("partials", &manifest.partials_path())?;
        let batch = manifest.partials.batch;
        let rank = manifest.partials.rank;
        Ok(MttkrpExecutor {
            rt,
            batch,
            rank,
            stats: BatchComputeStats::default(),
            vals_buf: vec![0.0; batch],
            d_buf: vec![0.0; batch * rank],
            c_buf: vec![0.0; batch * rank],
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Mode-`mode` MTTKRP over `t` through the PJRT compute path.
    ///
    /// The executor's rank (fixed at AOT time) must equal the factor
    /// rank.
    pub fn mttkrp(
        &mut self,
        t: &CooTensor,
        mode: Mode,
        m1: &DenseMatrix,
        m2: &DenseMatrix,
    ) -> Result<DenseMatrix> {
        crate::ensure!(
            m1.cols == self.rank && m2.cols == self.rank,
            "factor rank {} != AOT rank {} — re-run `make artifacts` with --rank",
            m1.cols,
            self.rank
        );
        let (om1, om2) = operand_modes(mode);
        crate::ensure!(
            m1.rows as u64 == t.dim(om1) && m2.rows as u64 == t.dim(om2),
            "operand shape mismatch"
        );
        let r = self.rank;
        let b = self.batch;
        let mut out = DenseMatrix::zeros(t.dim(mode) as usize, r);
        let n = t.nnz();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + b).min(n);
            let filled = hi - lo;
            // Gather phase.
            let g0 = Instant::now();
            self.vals_buf[..filled].copy_from_slice(&t.vals[lo..hi]);
            self.vals_buf[filled..].fill(0.0); // zero-padding lanes
            for (bi, z) in (lo..hi).enumerate() {
                let j = t.coord(z, om1) as usize;
                let k = t.coord(z, om2) as usize;
                self.d_buf[bi * r..(bi + 1) * r].copy_from_slice(m1.row(j));
                self.c_buf[bi * r..(bi + 1) * r].copy_from_slice(m2.row(k));
            }
            // Padded rows may hold stale data; vals=0 nullifies them.
            self.stats.gather_seconds += g0.elapsed().as_secs_f64();

            // PE compute via PJRT.
            let e0 = Instant::now();
            let partials = self.rt.execute(
                "partials",
                &[
                    literal_f32(&self.vals_buf, &[b as i64])?,
                    literal_f32(&self.d_buf, &[b as i64, r as i64])?,
                    literal_f32(&self.c_buf, &[b as i64, r as i64])?,
                ],
            )?;
            let pvec = partials
                .to_vec::<f32>()
                .map_err(|e| crate::format_err!("partials to_vec: {e:?}"))?;
            self.stats.execute_seconds += e0.elapsed().as_secs_f64();

            // Scatter-accumulate into output fibers.
            let s0 = Instant::now();
            for (bi, z) in (lo..hi).enumerate() {
                let oi = t.coord(z, mode) as usize;
                let dst = out.row_mut(oi);
                let src = &pvec[bi * r..(bi + 1) * r];
                for x in 0..r {
                    dst[x] += src[x];
                }
            }
            self.stats.scatter_seconds += s0.elapsed().as_secs_f64();

            self.stats.batches += 1;
            self.stats.nnz += filled as u64;
            self.stats.padded_lanes += (b - filled) as u64;
            lo = hi;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::mttkrp_seq;
    use crate::runtime::artifacts::find_artifacts_dir;
    use crate::util::rng::Rng;

    fn executor() -> Option<MttkrpExecutor> {
        let dir = find_artifacts_dir()?;
        let m = Manifest::load(&dir).ok()?;
        MttkrpExecutor::new(&m).ok()
    }

    #[test]
    fn matches_rust_reference_all_modes() {
        let Some(mut ex) = executor() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let r = ex.rank();
        let mut rng = Rng::new(100);
        let t = CooTensor::random(&mut rng, [40, 30, 35], 3000);
        let a = DenseMatrix::random(&mut rng, 40, r);
        let d = DenseMatrix::random(&mut rng, 30, r);
        let c = DenseMatrix::random(&mut rng, 35, r);
        for (mode, m1, m2) in [(Mode::I, &d, &c), (Mode::J, &a, &c), (Mode::K, &a, &d)] {
            let got = ex.mttkrp(&t, mode, m1, m2).unwrap();
            let want = mttkrp_seq(&t, mode, m1, m2);
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-3, "mode {mode:?} diff {diff}");
        }
        assert!(ex.stats.batches >= 3);
        assert_eq!(ex.stats.nnz, 3 * t.nnz() as u64);
    }

    #[test]
    fn handles_tiny_tensor_with_padding() {
        let Some(mut ex) = executor() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let r = ex.rank();
        let mut rng = Rng::new(101);
        let t = CooTensor::random(&mut rng, [4, 5, 6], 10);
        let d = DenseMatrix::random(&mut rng, 5, r);
        let c = DenseMatrix::random(&mut rng, 6, r);
        let got = ex.mttkrp(&t, Mode::I, &d, &c).unwrap();
        let want = mttkrp_seq(&t, Mode::I, &d, &c);
        assert!(got.max_abs_diff(&want) < 1e-4);
        assert!(ex.stats.padded_lanes > 0, "tail batch must be padded");
    }

    #[test]
    fn rank_mismatch_is_error() {
        let Some(mut ex) = executor() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let bad_rank = ex.rank() + 1;
        let mut rng = Rng::new(102);
        let t = CooTensor::random(&mut rng, [4, 4, 4], 8);
        let d = DenseMatrix::random(&mut rng, 4, bad_rank);
        let c = DenseMatrix::random(&mut rng, 4, bad_rank);
        assert!(ex.mttkrp(&t, Mode::I, &d, &c).is_err());
    }
}
