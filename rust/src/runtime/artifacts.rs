//! Artifact discovery + manifest parsing.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::Result;

/// Shapes of one AOT entry point (monomorphic — fixed at lowering time).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EntryShapes {
    pub file: String,
    pub batch: usize,
    pub rank: usize,
    /// Fused entry only.
    pub i_tile: Option<usize>,
    pub j: Option<usize>,
    pub k: Option<usize>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub partials: EntryShapes,
    pub fused: Option<EntryShapes>,
}

impl Manifest {
    /// Load and validate a manifest from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| crate::format_err!("read {}/manifest.json: {e}", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| crate::format_err!("manifest parse: {e}"))?;
        let entries = j
            .get("entries")
            .ok_or_else(|| crate::format_err!("manifest missing entries"))?;
        let parse_entry = |name: &str| -> Result<EntryShapes> {
            let e = entries
                .get(name)
                .ok_or_else(|| crate::format_err!("manifest missing entry {name}"))?;
            let get = |k: &str| e.get(k).and_then(Json::as_usize);
            Ok(EntryShapes {
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| crate::format_err!("{name}: missing file"))?
                    .to_string(),
                batch: get("batch").ok_or_else(|| crate::format_err!("{name}: missing batch"))?,
                rank: get("rank").ok_or_else(|| crate::format_err!("{name}: missing rank"))?,
                i_tile: get("i_tile"),
                j: get("j"),
                k: get("k"),
            })
        };
        let partials = parse_entry("mttkrp_partials")?;
        let fused = parse_entry("mttkrp_fused").ok();
        crate::ensure!(
            dir.join(&partials.file).exists(),
            "artifact {} missing — run `make artifacts`",
            partials.file
        );
        Ok(Manifest {
            dir: dir.to_path_buf(),
            partials,
            fused,
        })
    }

    pub fn partials_path(&self) -> PathBuf {
        self.dir.join(&self.partials.file)
    }

    pub fn fused_path(&self) -> Option<PathBuf> {
        self.fused.as_ref().map(|f| self.dir.join(&f.file))
    }
}

/// Locate the artifacts directory: `$MEMSYS_ARTIFACTS`, else `artifacts/`
/// relative to the working dir or its ancestors (so tests work from any
/// cargo working directory).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("MEMSYS_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_when_artifacts_built() {
        let Some(dir) = find_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.partials.batch > 0);
        assert!(m.partials.rank > 0);
        assert!(m.partials_path().exists());
        if let Some(f) = &m.fused {
            assert!(f.i_tile.is_some());
            assert!(m.fused_path().unwrap().exists());
        }
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent/nowhere")).is_err());
    }

    #[test]
    fn malformed_manifest_is_error() {
        let dir = std::env::temp_dir().join("memsys_artifacts_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"entries\": {}}").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
