//! Offline stand-in for the PJRT client (`pjrt` feature disabled).
//!
//! The real implementation in `pjrt.rs` wraps the `xla` crate, which
//! pulls the xla_extension C++ bundle at build time — unavailable in the
//! offline build image. This stub keeps the same public surface so the
//! coordinator, executor, examples and benches all compile; constructing
//! a client fails at runtime with a clear message, and the callers that
//! already skip on missing artifacts degrade the same way. Enable the
//! `pjrt` feature (and add the `xla` dependency) to swap the real client
//! back in.

use crate::Result;

/// Uninhabited stand-in for `xla::Literal`: values can never exist
/// because [`PjrtRuntime::cpu`] always fails first.
#[derive(Debug)]
pub enum Literal {}

impl Literal {
    /// Mirror of `xla::Literal::to_vec`. Statically unreachable.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match *self {}
    }
}

/// A PJRT client placeholder with the real type's public surface.
pub struct PjrtRuntime {
    _unconstructible: (),
}

impl PjrtRuntime {
    /// Always fails: the XLA/PJRT toolchain is not compiled in.
    pub fn cpu() -> Result<PjrtRuntime> {
        crate::bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (the `xla` crate is not part of the offline build)"
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_hlo_text(&mut self, name: &str, path: &std::path::Path) -> Result<()> {
        crate::bail!("cannot load {name} from {}: pjrt feature disabled", path.display())
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    pub fn execute(&self, name: &str, _inputs: &[Literal]) -> Result<Literal> {
        crate::bail!("cannot execute {name:?}: pjrt feature disabled")
    }
}

/// Mirror of the real `literal_f32` constructor; validates the shape so
/// callers get the same error for malformed inputs, then reports the
/// missing feature.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<Literal> {
    let n: i64 = shape.iter().product();
    crate::ensure!(n as usize == data.len(), "shape {shape:?} != len {}", data.len());
    crate::bail!("cannot build literal: pjrt feature disabled")
}

/// Mirror of the real `literal_i32` constructor (infallible signature in
/// the real API, so the stub must panic rather than error).
pub fn literal_i32(_data: &[i32]) -> Literal {
    panic!("cannot build literal: pjrt feature disabled")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_cleanly() {
        let err = PjrtRuntime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn stub_literal_shape_validation_matches_real_api() {
        // Same shape check as the real literal_f32, then the feature error.
        let err = literal_f32(&[1.0, 2.0], &[3]).err().unwrap();
        assert!(err.to_string().contains("shape"));
        let err = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).err().unwrap();
        assert!(err.to_string().contains("pjrt"));
    }
}
