//! Multi-accelerator scale-out: sharded MTTKRP across routed nodes.
//!
//! One accelerator board holds the whole paper: PEs, LMB banks, the
//! intra-node fabric, per-channel DRAM. This layer asks the next
//! question — what happens when the tensor outgrows one board and the
//! nonzeros are sharded across `cluster.nodes` accelerators joined by a
//! routed inter-node network?
//!
//! # Sharding model
//!
//! The nonzeros are already split into `n_pes x nodes` fiber-aligned
//! Type-2 streams by the trace layer's `partition_by_nnz` boundary rule
//! — the cluster layer reuses that exact rule at node granularity:
//! node `m` owns streams `[m*n_pes, (m+1)*n_pes)`, a contiguous,
//! fiber-aligned nnz range. Tensor elements and output fibers are
//! node-local by construction (the partition never splits an output
//! fiber). Input factor-matrix rows are *block-distributed* over nodes
//! (`owner = row / ceil(rows/nodes)` per matrix), so a node whose shard
//! references a row it does not own must fetch it from the owner before
//! its local run: the communication phase.
//!
//! Each node's phase is one request/response exchange over
//! [`network::InterNodeNetwork`]: a [`MSG_HEADER_BYTES`] request per
//! distinct remote row (deduplicated — the fetched row lives in node
//! DRAM for the whole run), answered by a header + `R*4`-byte row
//! payload. The makespan decomposes per node into *communication*
//! (last remote row arrival), *compute* (the ideal-memory floor of its
//! local run) and *local memory* (everything the local run spends above
//! that floor); the cluster total is the slowest node's sum.
//!
//! # Identity by construction
//!
//! With `cluster.nodes = 1` (the default) [`simulate_cluster`] runs the
//! plain single-accelerator [`sim::simulate`] on the unsliced source —
//! no network, no classification pass — and
//! [`ClusterReport::into_report`] returns that report verbatim. The
//! randomized property in `tests/integration_cluster.rs` pins this.

pub mod network;
pub mod report;

use std::collections::BTreeSet;

use crate::config::{FabricType, SystemConfig};
use crate::sim::{self, Cycle};
use crate::trace::source::{TraceSource, WorkCursor, WORK_CHUNK};
use crate::trace::AddressMap;
use crate::util::ceil_div;

pub use network::{
    inter_topology_of, mesh_dims, FullyConnected, InterLinkStats, InterNodeNetwork, Mesh,
    NetRun, NetworkStats, Request,
};
pub use report::{ClusterReport, NodeComm, NodeReport};

/// Bytes of addressing/tag overhead per inter-node message. Requests
/// are exactly one header; responses are a header plus the row payload.
pub const MSG_HEADER_BYTES: u64 = 16;

/// Type-2 front ends issue up to two accesses per cycle (see
/// `MemorySystem::new`) — the issue-rate term of the compute floor.
const TYPE2_ISSUE_WIDTH: u64 = 2;

/// A contiguous window of an existing [`TraceSource`]'s streams,
/// re-exposed as a complete source with *local* PE ids `0..count` — the
/// view one cluster node has of its shard. `MemorySystem` maps stream
/// PEs onto LMB ports as `pe % n_lmbs`, so the slice must renumber from
/// zero or every node past the first would land on skewed ports.
#[derive(Debug)]
pub struct NodeSlice<'a, S: TraceSource + ?Sized> {
    inner: &'a S,
    base: usize,
    count: usize,
}

impl<'a, S: TraceSource + ?Sized> NodeSlice<'a, S> {
    pub fn new(inner: &'a S, base: usize, count: usize) -> NodeSlice<'a, S> {
        assert!(count > 0, "empty node slice");
        assert!(
            base + count <= inner.n_streams(),
            "slice [{}, {}) out of range ({} streams)",
            base,
            base + count,
            inner.n_streams()
        );
        NodeSlice { inner, base, count }
    }
}

impl<S: TraceSource + ?Sized> TraceSource for NodeSlice<'_, S> {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn fabric(&self) -> FabricType {
        self.inner.fabric()
    }
    fn nnz(&self) -> usize {
        (0..self.count).map(|s| self.inner.stream_len(self.base + s)).sum()
    }
    fn n_streams(&self) -> usize {
        self.count
    }
    fn stream_pe(&self, s: usize) -> usize {
        assert!(s < self.count);
        let pe = self.inner.stream_pe(self.base + s);
        debug_assert!(
            (self.base..self.base + self.count).contains(&pe),
            "stream {} owned by PE {} outside its node's window",
            self.base + s,
            pe
        );
        pe - self.base
    }
    fn stream_len(&self, s: usize) -> usize {
        assert!(s < self.count);
        self.inner.stream_len(self.base + s)
    }
    fn open(&self, s: usize) -> Box<dyn WorkCursor> {
        assert!(s < self.count);
        self.inner.open(self.base + s)
    }
    fn amap(&self) -> Option<&AddressMap> {
        self.inner.amap()
    }
}

/// Block distribution of the two factor matrices' rows over nodes, plus
/// the address-region inversion the remote-row classifier needs.
struct RowOwners {
    m1_base: u64,
    m1_end: u64,
    m2_base: u64,
    m2_end: u64,
    fiber_bytes: u64,
    m1_block: u64,
    m2_block: u64,
    nodes: usize,
    /// Response size: header + one factor row.
    reply_bytes: u64,
}

impl RowOwners {
    fn new(amap: &AddressMap, nodes: usize) -> RowOwners {
        let m1_rows = amap.m1_bytes / amap.fiber_bytes;
        let m2_rows = amap.m2_bytes / amap.fiber_bytes;
        RowOwners {
            m1_base: amap.m1_base,
            m1_end: amap.m1_base + amap.m1_bytes,
            m2_base: amap.m2_base,
            m2_end: amap.m2_base + amap.m2_bytes,
            fiber_bytes: amap.fiber_bytes,
            m1_block: ceil_div(m1_rows, nodes as u64).max(1),
            m2_block: ceil_div(m2_rows, nodes as u64).max(1),
            nodes,
            reply_bytes: MSG_HEADER_BYTES + amap.fiber_bytes,
        }
    }

    /// Invert a fiber-load address to `(matrix, row)`.
    fn classify(&self, addr: u64) -> (u8, u64) {
        if (self.m2_base..self.m2_end).contains(&addr) {
            (1, (addr - self.m2_base) / self.fiber_bytes)
        } else {
            debug_assert!(
                (self.m1_base..self.m1_end).contains(&addr),
                "fiber load at {addr:#x} outside both factor-matrix regions"
            );
            (0, (addr - self.m1_base) / self.fiber_bytes)
        }
    }

    /// Node owning row `row` of matrix `mat` (block distribution; the
    /// clamp folds the ragged tail block onto the last node).
    fn owner(&self, mat: u8, row: u64) -> usize {
        let block = if mat == 0 { self.m1_block } else { self.m2_block };
        ((row / block) as usize).min(self.nodes - 1)
    }
}

/// Simulate `cfg.cluster.nodes` accelerator nodes sharing `source`'s
/// streams: a remote-row communication phase over the inter-node
/// network, then each node's full single-accelerator run over its
/// shard. With one node this *is* [`sim::simulate`] — see the module
/// docs for the identity contract.
pub fn simulate_cluster<S: TraceSource + ?Sized>(
    cfg: &SystemConfig,
    source: &S,
) -> ClusterReport {
    let start = std::time::Instant::now();
    cfg.validate().expect("invalid system config");
    let nodes = cfg.cluster.nodes;
    let per_node = if nodes == 1 {
        source.n_streams()
    } else {
        assert_eq!(
            source.fabric(),
            FabricType::Type2,
            "multi-node sharding requires the Type-2 fiber-aligned partition rule"
        );
        assert_eq!(
            source.n_streams(),
            nodes * cfg.pe.n_pes,
            "cluster geometry: the source must carry n_pes x nodes streams"
        );
        cfg.pe.n_pes
    };
    let owners = (nodes > 1).then(|| {
        RowOwners::new(
            source.amap().expect("cluster sharding needs the source's address map"),
            nodes,
        )
    });
    let type2 = source.fabric() == FabricType::Type2;

    // Classification pass: one streamed scan per node (bounded by
    // WORK_CHUNK, like the simulation itself) collecting the distinct
    // remote rows and the per-PE compute floor.
    let mut requests: Vec<Request> = Vec::new();
    let mut comms: Vec<NodeComm> = Vec::with_capacity(nodes);
    let mut buf: Vec<crate::trace::NnzWork> = Vec::with_capacity(WORK_CHUNK);
    for m in 0..nodes {
        let mut remote: BTreeSet<(u8, u64)> = BTreeSet::new();
        let mut floor: Cycle = 0;
        for s in m * per_node..(m + 1) * per_node {
            let mut cur = source.open(s);
            let (mut items, mut accs) = (0u64, 0u64);
            loop {
                buf.clear();
                if cur.refill(&mut buf, WORK_CHUNK) == 0 {
                    break;
                }
                for w in &buf {
                    items += 1;
                    accs += w.n_accesses() as u64;
                    if let Some(own) = &owners {
                        // Tensor elements and output-fiber stores are
                        // node-local by the partition rule; only the two
                        // input-fiber loads can cross nodes.
                        for f in &w.fibers {
                            let (mat, row) = own.classify(f.addr);
                            if own.owner(mat, row) != m {
                                remote.insert((mat, row));
                            }
                        }
                    }
                }
            }
            debug_assert_eq!(
                items as usize,
                source.stream_len(s),
                "cursor yielded a different count than stream_len"
            );
            if type2 {
                // A PE is issue-bound or compute-bound, whichever is
                // slower; PEs run in parallel, so the node floor is the
                // max over its streams.
                let ideal = ceil_div(accs, TYPE2_ISSUE_WIDTH)
                    .max(items * cfg.pe.compute_cycles_per_nnz);
                floor = floor.max(ideal);
            }
        }
        if let Some(own) = &owners {
            for &(mat, row) in &remote {
                requests.push(Request {
                    from: m,
                    to: own.owner(mat, row),
                    bytes: MSG_HEADER_BYTES,
                    reply_bytes: own.reply_bytes,
                });
            }
        }
        comms.push(NodeComm {
            remote_rows: remote.len() as u64,
            remote_bytes: remote.len() as u64
                * owners.as_ref().map_or(0, |o| o.reply_bytes),
            comm_cycles: 0,
            compute_floor: floor,
        });
    }

    // Communication phase: every node's remote rows exchange at once
    // (the prefetch all nodes run before computing).
    let network = if nodes > 1 {
        let mut net = InterNodeNetwork::new(&cfg.cluster);
        let run = net.run(&requests);
        for (c, done) in comms.iter_mut().zip(&run.node_done) {
            c.comm_cycles = *done;
        }
        run.stats
    } else {
        NetworkStats::default()
    };

    // Local phase: each node is a full MemorySystem over its shard.
    // With `sim_threads > 1` the independent node runs fan out across a
    // scoped host pool instead (node-level parallelism strictly
    // dominates in-run sharding here, so each node run drops to the
    // single-thread engine); results are reassembled in node index
    // order, so the ClusterReport is bit-identical at any thread count.
    let threads = cfg.sim_threads.min(nodes);
    let mut node_reports = Vec::with_capacity(nodes);
    if threads <= 1 {
        for (m, comm) in comms.into_iter().enumerate() {
            let report = if nodes == 1 {
                sim::simulate(cfg, source)
            } else {
                sim::simulate(cfg, &NodeSlice::new(source, m * per_node, per_node))
            };
            node_reports.push(NodeReport { node: m, report, comm });
        }
    } else {
        let mut node_cfg = cfg.clone();
        node_cfg.sim_threads = 1;
        let node_cfg = &node_cfg;
        // Deal node indices round-robin across the pool; each worker
        // returns (node, report) pairs that merge back by index.
        let mut shards: Vec<Vec<usize>> = (0..threads).map(|_| Vec::new()).collect();
        for m in 0..nodes {
            shards[m % threads].push(m);
        }
        let mut slots: Vec<Option<sim::SimReport>> = (0..nodes).map(|_| None).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .into_iter()
                .map(|shard| {
                    s.spawn(move || {
                        shard
                            .into_iter()
                            .map(|m| {
                                let slice = NodeSlice::new(source, m * per_node, per_node);
                                (m, sim::simulate(node_cfg, &slice))
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                for (m, report) in h.join().expect("node simulation thread panicked") {
                    slots[m] = Some(report);
                }
            }
        });
        for (m, (slot, comm)) in slots.into_iter().zip(comms).enumerate() {
            let report = slot.expect("every node simulated");
            node_reports.push(NodeReport { node: m, report, comm });
        }
    }
    let total_cycles = node_reports
        .iter()
        .map(NodeReport::total_cycles)
        .max()
        .expect("cluster has at least one node");
    ClusterReport {
        label: node_reports[0].report.label.clone(),
        workload: source.name().to_string(),
        nodes,
        topology: cfg.cluster.topology.name(),
        link_bytes: cfg.cluster.link_bytes,
        node_reports,
        network,
        total_cycles,
        host_seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{InterTopologyKind, SystemConfig};
    use crate::tensor::{CooTensor, Mode};
    use crate::trace::CooStreamSource;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn cluster_cfg(nodes: usize) -> SystemConfig {
        let mut c = SystemConfig::config_b();
        c.cluster.nodes = nodes;
        c.cluster.topology = InterTopologyKind::Ring;
        c.validate().unwrap();
        c
    }

    /// A hyper-sparse tensor whose factor rows are far wider-spread than
    /// any node's block, so multi-node runs always have remote rows.
    fn source_for(cfg: &SystemConfig) -> CooStreamSource {
        let mut rng = Rng::new(7);
        let t = CooTensor::random(&mut rng, [64, 3000, 5000], 2000);
        CooStreamSource::new(
            Arc::new(t),
            Mode::I,
            FabricType::Type2,
            cfg.pe.n_pes * cfg.cluster.nodes,
            cfg.pe.rank,
            cfg.dram.row_bytes,
        )
    }

    #[test]
    fn node_slice_exposes_local_geometry() {
        let cfg = cluster_cfg(2);
        let src = source_for(&cfg);
        let n = cfg.pe.n_pes;
        let s0 = NodeSlice::new(&src, 0, n);
        let s1 = NodeSlice::new(&src, n, n);
        assert_eq!(s0.n_streams(), n);
        assert_eq!(s1.n_streams(), n);
        // PE ids renumber to the local 0..n in both slices.
        for s in 0..n {
            assert_eq!(s0.stream_pe(s), s);
            assert_eq!(s1.stream_pe(s), s);
        }
        // The slices tile the source's nnz exactly.
        assert_eq!(
            TraceSource::nnz(&s0) + TraceSource::nnz(&s1),
            TraceSource::nnz(&src)
        );
        // A slice cursor yields exactly stream_len items.
        let mut cur = s1.open(0);
        let mut buf = Vec::new();
        let mut total = 0;
        loop {
            let got = cur.refill(&mut buf, 100);
            if got == 0 {
                break;
            }
            total += got;
            buf.clear();
        }
        assert_eq!(total, s1.stream_len(0));
    }

    #[test]
    fn single_node_cluster_is_the_plain_run() {
        let cfg = cluster_cfg(1);
        let src = source_for(&cfg);
        let plain = sim::simulate(&cfg, &src);
        let cl = simulate_cluster(&cfg, &src);
        assert_eq!(cl.nodes, 1);
        assert_eq!(cl.network.delivered, 0);
        assert_eq!(cl.network.links.len(), 0);
        assert_eq!(cl.node_reports[0].comm.remote_rows, 0);
        assert_eq!(cl.total_cycles, plain.total_cycles);
        assert_eq!(cl.into_report().diff(&plain), None);
    }

    #[test]
    fn two_node_cluster_conserves_work_and_decomposes_makespan() {
        let cfg = cluster_cfg(2);
        let src = source_for(&cfg);
        let cl = simulate_cluster(&cfg, &src);
        assert_eq!(cl.node_reports.len(), 2);
        assert_eq!(cl.nnz() as usize, TraceSource::nnz(&src));
        // Randomly spread factor rows guarantee cross-node fetches.
        let remote: u64 = cl.node_reports.iter().map(|n| n.comm.remote_rows).sum();
        assert!(remote > 0, "no remote rows in a random shard");
        assert_eq!(cl.network.delivered, remote);
        let bytes: u64 = cl.node_reports.iter().map(|n| n.comm.remote_bytes).sum();
        assert_eq!(cl.network.delivered_bytes, bytes);
        for n in &cl.node_reports {
            assert_eq!(
                n.compute_cycles() + n.local_memory_cycles(),
                n.report.total_cycles,
                "node {}: breakdown must tile the local run",
                n.node
            );
            assert!(n.compute_cycles() > 0, "node {} has no compute floor", n.node);
            if n.comm.remote_rows > 0 {
                assert!(n.comm.comm_cycles > 0);
            }
        }
        let worst = cl.node_reports.iter().map(NodeReport::total_cycles).max().unwrap();
        assert_eq!(cl.total_cycles, worst);
        assert!(cl.communication_fraction() > 0.0);
    }

    #[test]
    fn node_parallel_cluster_is_bit_identical_to_sequential() {
        let cfg = cluster_cfg(2);
        let src = source_for(&cfg);
        let seq = simulate_cluster(&cfg, &src);
        for sim_threads in [2, 4] {
            let mut c = cfg.clone();
            c.sim_threads = sim_threads;
            let par = simulate_cluster(&c, &src);
            assert_eq!(par.nodes, seq.nodes);
            assert_eq!(par.total_cycles, seq.total_cycles);
            for (a, b) in par.node_reports.iter().zip(&seq.node_reports) {
                assert_eq!(a.node, b.node);
                assert_eq!(
                    a.report.diff(&b.report),
                    None,
                    "sim_threads={sim_threads}: node {} diverged",
                    a.node
                );
                assert_eq!(a.comm.remote_rows, b.comm.remote_rows);
                assert_eq!(a.comm.comm_cycles, b.comm.comm_cycles);
            }
            assert_eq!(par.into_report().diff(&seq.clone().into_report()), None);
        }
    }

    #[test]
    fn cluster_json_carries_breakdown_and_network() {
        let cfg = cluster_cfg(2);
        let src = source_for(&cfg);
        let cl = simulate_cluster(&cfg, &src);
        let j = cl.to_json();
        assert_eq!(j.get("nodes").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("topology").unwrap().as_str(), Some("ring"));
        let rows = j.get("node_breakdown").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        for r in rows {
            for k in [
                "total_cycles",
                "compute_cycles",
                "local_memory_cycles",
                "communication_cycles",
                "remote_rows",
                "remote_bytes",
            ] {
                assert!(r.get(k).is_some(), "breakdown row missing {k}");
            }
        }
        let net = j.get("network").unwrap();
        assert!(!net.get("links").unwrap().as_arr().unwrap().is_empty());
        assert!(net.get("max_link_utilization").is_some());
        assert_eq!(j.get("node_reports").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn merged_report_prefixes_link_labels_by_node() {
        // A store-and-forward intra-node fabric so per-node link labels
        // exist (and would collide without the node prefix).
        let mut cfg = cluster_cfg(2);
        cfg.interconnect.channels = 4;
        cfg.interconnect.topology = crate::config::TopologyKind::Ring;
        cfg.validate().unwrap();
        let src = source_for(&cfg);
        let cl = simulate_cluster(&cfg, &src);
        let nnz = cl.nnz();
        let makespan = cl.total_cycles;
        let merged = cl.into_report();
        assert_eq!(merged.nnz, nnz);
        assert_eq!(merged.total_cycles, makespan);
        assert!(!merged.fabric.links.is_empty(), "ring fabric has links");
        for l in &merged.fabric.links {
            assert!(
                l.label.starts_with("n0:") || l.label.starts_with("n1:"),
                "unprefixed link label {}",
                l.label
            );
        }
    }
}
