//! Cluster-level result: per-node [`SimReport`]s plus the communication
//! phase, with the makespan broken into compute, local memory, and
//! inter-node communication time.

use crate::sim::stats::SimReport;
use crate::sim::Cycle;
use crate::util::json::Json;

use super::network::NetworkStats;

/// One node's communication-phase share.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NodeComm {
    /// Distinct remote factor-matrix rows this node fetched.
    pub remote_rows: u64,
    /// Response payload bytes delivered to this node (header + row data).
    pub remote_bytes: u64,
    /// Cycle the node's last remote row arrived — the prefetch phase the
    /// node sits through before its local run can start (0 when every
    /// row it touches is node-local).
    pub comm_cycles: Cycle,
    /// Lower bound on the node's pure compute time: cycles its PEs would
    /// need with an ideal (zero-latency) memory system. Anything the
    /// local run spends beyond this floor is memory time.
    pub compute_floor: Cycle,
}

/// One node's complete result: the full single-accelerator report of its
/// shard plus its communication share.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub node: usize,
    pub report: SimReport,
    pub comm: NodeComm,
}

impl NodeReport {
    /// Compute component of the node's local run (the ideal-memory
    /// floor, clamped by the run itself — a shard can never finish
    /// below its floor, but the clamp keeps the decomposition safe
    /// against floor estimation drift).
    pub fn compute_cycles(&self) -> Cycle {
        self.comm.compute_floor.min(self.report.total_cycles)
    }

    /// Local-memory component: whatever the local run spent beyond the
    /// compute floor. `compute + local_memory == report.total_cycles`
    /// by construction.
    pub fn local_memory_cycles(&self) -> Cycle {
        self.report.total_cycles - self.compute_cycles()
    }

    /// The node's end-to-end time: remote-row prefetch, then the local
    /// run over its shard.
    pub fn total_cycles(&self) -> Cycle {
        self.comm.comm_cycles + self.report.total_cycles
    }

    /// Slim JSON view of the node's makespan decomposition (the
    /// `node_breakdown` entries of [`ClusterReport::to_json`]).
    pub fn breakdown_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::num(self.node as f64)),
            ("total_cycles", Json::num(self.total_cycles() as f64)),
            ("compute_cycles", Json::num(self.compute_cycles() as f64)),
            (
                "local_memory_cycles",
                Json::num(self.local_memory_cycles() as f64),
            ),
            (
                "communication_cycles",
                Json::num(self.comm.comm_cycles as f64),
            ),
            ("local_cycles", Json::num(self.report.total_cycles as f64)),
            ("nnz", Json::num(self.report.nnz as f64)),
            ("remote_rows", Json::num(self.comm.remote_rows as f64)),
            ("remote_bytes", Json::num(self.comm.remote_bytes as f64)),
        ])
    }
}

/// Result of [`simulate_cluster`](super::simulate_cluster).
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub label: String,
    pub workload: String,
    pub nodes: usize,
    /// Inter-node topology name ("crossbar" / "line" / "ring" / "mesh").
    pub topology: &'static str,
    /// Per-link byte budget the communication phase ran with.
    pub link_bytes: u64,
    pub node_reports: Vec<NodeReport>,
    pub network: NetworkStats,
    /// Cluster makespan: `max` over nodes of communication + local run.
    pub total_cycles: Cycle,
    pub host_seconds: f64,
}

impl ClusterReport {
    /// Nonzeros across all shards.
    pub fn nnz(&self) -> u64 {
        self.node_reports.iter().map(|n| n.report.nnz).sum()
    }

    /// Slowest node — the one that sets the makespan.
    pub fn critical_node(&self) -> &NodeReport {
        self.node_reports
            .iter()
            .max_by_key(|n| n.total_cycles())
            .expect("cluster has at least one node")
    }

    /// Makespan share spent communicating on the critical path.
    pub fn communication_fraction(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.critical_node().comm.comm_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Flatten into a single [`SimReport`] so every existing consumer
    /// (sweep tables, run sets, baselines, `SimReport::diff`) works on
    /// cluster results unchanged.
    ///
    /// With one node this returns that node's report **verbatim** — the
    /// identity the `nodes = 1` property tests pin. With several, the
    /// counters sum/merge, per-component vectors concatenate, and link
    /// labels gain an `n{i}:` node prefix; `total_cycles` becomes the
    /// cluster makespan.
    pub fn into_report(self) -> SimReport {
        let ClusterReport {
            label,
            workload,
            nodes,
            node_reports,
            total_cycles,
            host_seconds,
            ..
        } = self;
        let mut it = node_reports.into_iter();
        let first = it.next().expect("cluster has at least one node").report;
        if nodes == 1 {
            return first;
        }
        let mut out = first;
        for nr in it {
            let r = nr.report;
            out.nnz += r.nnz;
            out.accesses += r.accesses;
            out.requested_bytes += r.requested_bytes;
            out.dram.merge(&r.dram);
            out.channels.extend(r.channels);
            out.fabric.forwarded += r.fabric.forwarded;
            out.fabric.backpressure_cycles += r.fabric.backpressure_cycles;
            out.fabric.hops += r.fabric.hops;
            out.fabric.per_port_forwarded.extend(r.fabric.per_port_forwarded);
            out.fabric
                .per_channel_forwarded
                .extend(r.fabric.per_channel_forwarded);
            out.fabric.links.extend(r.fabric.links);
            out.fabric.reply.delivered += r.fabric.reply.delivered;
            out.fabric.reply.hops += r.fabric.reply.hops;
            out.fabric.reply.backpressure_cycles += r.fabric.reply.backpressure_cycles;
            out.fabric.reply.links.extend(r.fabric.reply.links);
            out.lmbs.extend(r.lmbs);
            out.pe.retired += r.pe.retired;
            out.pe.issued_accesses += r.pe.issued_accesses;
            out.pe.stall_cycles += r.pe.stall_cycles;
            out.visited_cycles += r.visited_cycles;
            for (slot, o) in out.latency.iter_mut().zip(r.latency.iter()) {
                slot.merge(o);
            }
        }
        // Every node ran the same shard geometry, so per-node link label
        // collisions are certain — prefix by node position. The labels
        // concatenated in node order, n_links per node.
        let per_node_links = out.fabric.links.len() / nodes;
        for (i, l) in out.fabric.links.iter_mut().enumerate() {
            l.label = format!("n{}:{}", i / per_node_links.max(1), l.label);
        }
        let per_node_rlinks = out.fabric.reply.links.len() / nodes;
        for (i, l) in out.fabric.reply.links.iter_mut().enumerate() {
            l.label = format!("n{}:{}", i / per_node_rlinks.max(1), l.label);
        }
        out.label = label;
        out.workload = workload;
        out.total_cycles = total_cycles;
        out.host_seconds = host_seconds;
        out
    }

    /// JSON view of the inter-node network counters (the `network`
    /// object of [`ClusterReport::to_json`]).
    pub fn network_json(&self) -> Json {
        let links = self
            .network
            .links
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("label", Json::str(l.label.clone())),
                    ("msgs", Json::num(l.msgs as f64)),
                    ("bytes", Json::num(l.bytes as f64)),
                    ("stall_cycles", Json::num(l.stall_cycles as f64)),
                    ("peak_queue", Json::num(l.peak_queue as f64)),
                    (
                        "utilization",
                        Json::num(l.utilization(self.network.cycles, self.link_bytes)),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("delivered", Json::num(self.network.delivered as f64)),
            (
                "delivered_bytes",
                Json::num(self.network.delivered_bytes as f64),
            ),
            ("hops", Json::num(self.network.hops as f64)),
            (
                "inject_stall_cycles",
                Json::num(self.network.inject_stall_cycles as f64),
            ),
            ("cycles", Json::num(self.network.cycles as f64)),
            (
                "max_link_utilization",
                Json::num(self.network.max_link_utilization(self.link_bytes)),
            ),
            ("links", Json::arr(links)),
        ])
    }

    /// Cluster summary: makespan breakdown per node, network counters,
    /// and each node's full single-accelerator report.
    pub fn to_json(&self) -> Json {
        let breakdown = self.node_reports.iter().map(NodeReport::breakdown_json).collect();
        let reports = self.node_reports.iter().map(|n| n.report.to_json()).collect();
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("workload", Json::str(self.workload.clone())),
            ("nodes", Json::num(self.nodes as f64)),
            ("topology", Json::str(self.topology)),
            ("link_bytes", Json::num(self.link_bytes as f64)),
            ("total_cycles", Json::num(self.total_cycles as f64)),
            ("nnz", Json::num(self.nnz() as f64)),
            (
                "communication_fraction",
                Json::num(self.communication_fraction()),
            ),
            ("node_breakdown", Json::arr(breakdown)),
            ("network", self.network_json()),
            ("node_reports", Json::arr(reports)),
            ("host_seconds", Json::num(self.host_seconds)),
        ])
    }
}
