//! Hop-accurate inter-node network for the cluster layer.
//!
//! This generalizes the intra-node fabric's [`Topology`] trait to the
//! scale-out setting: the same static-routing view (ingress, next hop,
//! directed link list) now connects whole accelerator nodes instead of
//! DRAM channels, and the transported unit is a sized *message* (a
//! remote-row request or its factor-row response) instead of a DRAM
//! transaction. Two topologies join the fabric's line and ring:
//!
//! * [`FullyConnected`] — the config's `crossbar`: a dedicated direct
//!   link per ordered node pair, every route one hop. This is the
//!   inter-node analogue of the fabric crossbar (which has no links at
//!   all because ports arbitrate combinationally — across chassis there
//!   is always a wire, so here the wire is explicit).
//! * [`Mesh`] — a near-square 2D grid with dimension-ordered (X-then-Y)
//!   routing. Node counts that do not fill the grid leave the last row
//!   short; routing detours *up* first when an X step would leave the
//!   grid, which adds only north-to-X turns and therefore keeps the
//!   turn set acyclic (no south-to-X turn ever occurs — the classic
//!   turn-model argument for deadlock freedom).
//!
//! # Transport model
//!
//! Store-and-forward with byte-level bandwidth budgets: a message of
//! `b` bytes occupies a directed link's wire for
//! `link_latency + ceil(b / link_bytes)` cycles per hop (SerDes +
//! serialization), waits in a bounded per-link queue (`link_queue`
//! messages) when the wire is busy, and backpressures the upstream hop
//! when the queue is full. Injection follows the bubble rule — a node
//! may inject only while the first-hop queue keeps one slot free for
//! transit traffic — which guarantees the ring's circular channel
//! dependency always has a bubble and so cannot deadlock (`link_queue
//! >= 2` is enforced by config validation for exactly this reason).
//!
//! Request/response protocol: the caller injects request messages; when
//! a request reaches its destination the destination node turns it
//! around as a response (`reply_bytes`) the following cycle, through
//! its own egress port. The run completes when every response has been
//! delivered; per-node completion cycles and per-link peak-demand
//! statistics come back in [`NetRun`].

use std::collections::{HashMap, VecDeque};

use crate::config::{ClusterConfig, InterTopologyKind};
use crate::sim::fabric::{Line, Ring, Topology};
use crate::sim::Cycle;
use crate::util::ceil_div;

/// Every ordered node pair wired directly; all routes are one hop.
pub struct FullyConnected;

impl Topology for FullyConnected {
    fn name(&self) -> &'static str {
        "crossbar"
    }

    fn next_hop(&self, at: usize, dest: usize, _nodes: usize) -> Option<usize> {
        (at != dest).then_some(dest)
    }

    fn links(&self, nodes: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(nodes.saturating_sub(1) * nodes);
        for a in 0..nodes {
            for b in 0..nodes {
                if a != b {
                    out.push((a, b));
                }
            }
        }
        out
    }
}

/// Grid shape for `nodes` mesh nodes: `(rows, cols)` with
/// `cols = ceil(sqrt(nodes))`, rows filled left-to-right so only the
/// last row can be short.
pub fn mesh_dims(nodes: usize) -> (usize, usize) {
    assert!(nodes > 0);
    let cols = (1..=nodes).find(|c| c * c >= nodes).expect("cols <= nodes");
    (nodes.div_ceil(cols), cols)
}

/// Near-square 2D mesh with X-then-Y dimension-ordered routing.
pub struct Mesh;

impl Topology for Mesh {
    fn name(&self) -> &'static str {
        "mesh"
    }

    fn next_hop(&self, at: usize, dest: usize, nodes: usize) -> Option<usize> {
        if at == dest {
            return None;
        }
        let (_, cols) = mesh_dims(nodes);
        let (ar, ac) = (at / cols, at % cols);
        let (dr, dc) = (dest / cols, dest % cols);
        if ac != dc {
            let step_c = if dc > ac { ac + 1 } else { ac - 1 };
            let cand = ar * cols + step_c;
            if cand < nodes {
                return Some(cand);
            }
            // The X step would leave a short last row: detour one row up
            // (always exists — only the last row is short). This is the
            // lone non-XY turn and it is strictly northbound, so the
            // routing relation stays cycle-free.
            return Some((ar - 1) * cols + ac);
        }
        let step_r = if dr > ar { ar + 1 } else { ar - 1 };
        Some(step_r * cols + ac)
    }

    fn links(&self, nodes: usize) -> Vec<(usize, usize)> {
        let (_, cols) = mesh_dims(nodes);
        let mut out = Vec::new();
        for a in 0..nodes {
            // Right neighbor (same row) and down neighbor, both directions.
            if a % cols + 1 < cols && a + 1 < nodes {
                out.push((a, a + 1));
                out.push((a + 1, a));
            }
            if a + cols < nodes {
                out.push((a, a + cols));
                out.push((a + cols, a));
            }
        }
        out
    }
}

/// Resolve an inter-node topology kind to its routing implementation.
/// Line and ring are literally the fabric's; crossbar and mesh are the
/// scale-out additions above.
pub fn inter_topology_of(kind: InterTopologyKind) -> &'static dyn Topology {
    match kind {
        InterTopologyKind::Crossbar => &FullyConnected,
        InterTopologyKind::Line => &Line,
        InterTopologyKind::Ring => &Ring,
        InterTopologyKind::Mesh => &Mesh,
    }
}

/// One remote-row fetch: `from` asks `to` for a row; the request is
/// `bytes` on the wire, the turned-around response `reply_bytes`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub from: usize,
    pub to: usize,
    pub bytes: u64,
    pub reply_bytes: u64,
}

/// Per-directed-link counters, including the peak queue demand the link
/// saw (the provisioning signal the byte counters alone cannot give).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct InterLinkStats {
    /// `nA->nB` label.
    pub label: String,
    /// Messages that crossed this link.
    pub msgs: u64,
    /// Payload bytes that crossed this link.
    pub bytes: u64,
    /// Cycles a wire-completed message was held by a full queue at the
    /// next hop (upstream backpressure).
    pub stall_cycles: u64,
    /// Deepest the bounded queue ever got (peak demand; capacity is
    /// `cluster.link_queue`).
    pub peak_queue: usize,
}

impl InterLinkStats {
    /// Fraction of the run's cycles this link's byte budget was spoken
    /// for (`bytes / (cycles * link_bytes)`).
    pub fn utilization(&self, total_cycles: Cycle, link_bytes: u64) -> f64 {
        if total_cycles == 0 {
            0.0
        } else {
            self.bytes as f64 / (total_cycles as f64 * link_bytes as f64)
        }
    }
}

/// Whole-network counters for one communication phase.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetworkStats {
    /// Responses delivered (== requests injected on completion).
    pub delivered: u64,
    /// Response payload bytes delivered to requesters.
    pub delivered_bytes: u64,
    /// Total link traversals (requests + responses).
    pub hops: u64,
    /// Cycles a node's injection port was blocked by the bubble rule.
    pub inject_stall_cycles: u64,
    /// Cycles the communication phase ran.
    pub cycles: Cycle,
    pub links: Vec<InterLinkStats>,
}

impl NetworkStats {
    /// Highest per-link byte utilization over the phase.
    pub fn max_link_utilization(&self, link_bytes: u64) -> f64 {
        self.links
            .iter()
            .map(|l| l.utilization(self.cycles, link_bytes))
            .fold(0.0, f64::max)
    }
}

/// Result of one network run: counters plus, per node, the cycle its
/// last response arrived (0 for nodes that requested nothing).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetRun {
    pub stats: NetworkStats,
    pub node_done: Vec<Cycle>,
}

/// A message in flight (requests remember their response size).
#[derive(Debug, Clone, Copy)]
struct Flight {
    src: usize,
    dst: usize,
    bytes: u64,
    /// `Some(reply_bytes)` for requests, `None` for responses.
    reply: Option<u64>,
}

struct LinkState {
    to: usize,
    /// Waiting messages with the cycle they were enqueued (a message
    /// becomes eligible for the wire the cycle *after* it arrives —
    /// store-and-forward, no cut-through).
    queue: VecDeque<(Flight, Cycle)>,
    /// Message on the wire and the cycle its transfer completes.
    wire: Option<(Flight, Cycle)>,
    stats: InterLinkStats,
}

/// The simulator: fixed topology + link parameters, run once per
/// communication phase.
pub struct InterNodeNetwork {
    topo: &'static dyn Topology,
    nodes: usize,
    link_bytes: u64,
    link_latency: u64,
    queue_cap: usize,
    links: Vec<LinkState>,
    index: HashMap<(usize, usize), usize>,
}

impl InterNodeNetwork {
    pub fn new(cfg: &ClusterConfig) -> InterNodeNetwork {
        let topo = inter_topology_of(cfg.topology);
        let mut links = Vec::new();
        let mut index = HashMap::new();
        for (from, to) in topo.links(cfg.nodes) {
            index.insert((from, to), links.len());
            links.push(LinkState {
                to,
                queue: VecDeque::new(),
                wire: None,
                stats: InterLinkStats {
                    label: format!("n{from}->n{to}"),
                    ..InterLinkStats::default()
                },
            });
        }
        InterNodeNetwork {
            topo,
            nodes: cfg.nodes,
            link_bytes: cfg.link_bytes,
            link_latency: cfg.link_latency,
            queue_cap: cfg.link_queue,
            links,
            index,
        }
    }

    fn first_link(&self, at: usize, dst: usize) -> usize {
        let next = self
            .topo
            .next_hop(at, dst, self.nodes)
            .expect("messages never target their own node");
        self.index[&(at, next)]
    }

    fn wire_cycles(&self, bytes: u64) -> Cycle {
        self.link_latency + ceil_div(bytes.max(1), self.link_bytes)
    }

    /// Run the request/response exchange to completion. Requests inject
    /// in slice order (at most one message per node per cycle — the
    /// egress port); each delivered request re-injects its response from
    /// the destination the next cycle.
    pub fn run(&mut self, requests: &[Request]) -> NetRun {
        let mut egress: Vec<VecDeque<(Flight, Cycle)>> = vec![VecDeque::new(); self.nodes];
        for r in requests {
            assert!(r.from != r.to, "remote request to own node");
            assert!(r.from < self.nodes && r.to < self.nodes);
            egress[r.from].push_back((
                Flight { src: r.from, dst: r.to, bytes: r.bytes, reply: Some(r.reply_bytes) },
                0,
            ));
        }
        let mut stats = NetworkStats::default();
        let mut node_done: Vec<Cycle> = vec![0; self.nodes];
        if requests.is_empty() {
            stats.links = self.links.iter().map(|l| l.stats.clone()).collect();
            return NetRun { stats, node_done };
        }
        let mut pending = requests.len() as u64;
        // Livelock/deadlock watchdog: with the bubble rule the network
        // always drains, so any run past this (very loose) bound is a
        // model bug, not a long simulation.
        let worst_hop = self.wire_cycles(
            requests.iter().map(|r| r.bytes.max(r.reply_bytes)).max().unwrap_or(1),
        );
        let bound = 64
            + 4 * (2 * requests.len() as Cycle)
                * worst_hop
                * (self.nodes as Cycle + 2)
                * (self.queue_cap as Cycle);
        let mut now: Cycle = 0;
        loop {
            // 1. Wire completions: deliver, or forward to the next hop's
            //    queue (blocking on the wire while that queue is full).
            #[allow(clippy::needless_range_loop)] // also indexes links[nli]
            for li in 0..self.links.len() {
                let Some((flight, done)) = self.links[li].wire else { continue };
                if done > now {
                    continue;
                }
                let at = self.links[li].to;
                if at == flight.dst {
                    self.links[li].wire = None;
                    stats.hops += 1;
                    match flight.reply {
                        Some(reply_bytes) => {
                            // Request arrived: turn it around next cycle.
                            egress[at].push_back((
                                Flight {
                                    src: at,
                                    dst: flight.src,
                                    bytes: reply_bytes,
                                    reply: None,
                                },
                                now + 1,
                            ));
                        }
                        None => {
                            node_done[at] = node_done[at].max(now);
                            stats.delivered += 1;
                            stats.delivered_bytes += flight.bytes;
                            pending -= 1;
                        }
                    }
                } else {
                    let nli = self.first_link(at, flight.dst);
                    if self.links[nli].queue.len() < self.queue_cap {
                        self.links[nli].queue.push_back((flight, now));
                        self.links[li].wire = None;
                        stats.hops += 1;
                    } else {
                        self.links[li].stats.stall_cycles += 1;
                    }
                }
            }
            if pending == 0 {
                break;
            }
            // 2. Wire starts: an idle wire picks up its queue head once
            //    the head has sat in the queue for a full cycle.
            for l in &mut self.links {
                if l.wire.is_some() {
                    continue;
                }
                let ready = matches!(l.queue.front(), Some(&(_, enq)) if enq < now);
                if ready {
                    let (flight, _) = l.queue.pop_front().expect("checked front");
                    l.wire = Some((flight, now + self.wire_cycles(flight.bytes)));
                    l.stats.msgs += 1;
                    l.stats.bytes += flight.bytes;
                }
            }
            // 3. Injection (bubble rule: leave one queue slot for
            //    transit traffic so ring routes cannot deadlock).
            for n in 0..self.nodes {
                let Some(&(flight, ready)) = egress[n].front() else { continue };
                if ready > now {
                    continue;
                }
                let li = self.first_link(n, flight.dst);
                if self.links[li].queue.len() + 1 < self.queue_cap {
                    self.links[li].queue.push_back((flight, now));
                    egress[n].pop_front();
                } else {
                    stats.inject_stall_cycles += 1;
                }
            }
            for l in &mut self.links {
                l.stats.peak_queue = l.stats.peak_queue.max(l.queue.len());
            }
            now += 1;
            assert!(
                now < bound,
                "inter-node network stuck after {now} cycles ({pending} responses pending)"
            );
        }
        stats.cycles = now + 1;
        stats.links = self.links.iter().map(|l| l.stats.clone()).collect();
        NetRun { stats, node_done }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(nodes: usize, topology: InterTopologyKind) -> ClusterConfig {
        ClusterConfig { nodes, topology, ..ClusterConfig::single_node() }
    }

    #[test]
    fn mesh_dims_near_square() {
        assert_eq!(mesh_dims(1), (1, 1));
        assert_eq!(mesh_dims(2), (1, 2));
        assert_eq!(mesh_dims(3), (2, 2));
        assert_eq!(mesh_dims(4), (2, 2));
        assert_eq!(mesh_dims(7), (3, 3));
        assert_eq!(mesh_dims(8), (3, 3));
        assert_eq!(mesh_dims(16), (4, 4));
    }

    #[test]
    fn every_topology_routes_every_pair_over_real_links() {
        for kind in InterTopologyKind::ALL {
            let topo = inter_topology_of(kind);
            for nodes in 1..=17 {
                let links: std::collections::HashSet<(usize, usize)> =
                    topo.links(nodes).into_iter().collect();
                for src in 0..nodes {
                    for dst in 0..nodes {
                        let mut at = src;
                        let mut hops = 0;
                        while let Some(next) = topo.next_hop(at, dst, nodes) {
                            assert!(
                                links.contains(&(at, next)),
                                "{}: {at}->{next} not a link ({nodes} nodes)",
                                topo.name()
                            );
                            at = next;
                            hops += 1;
                            assert!(hops <= nodes, "{}: loop {src}->{dst}", topo.name());
                        }
                        assert_eq!(at, dst, "{}: route ended early", topo.name());
                        if kind == InterTopologyKind::Crossbar && src != dst {
                            assert_eq!(hops, 1, "crossbar is single-hop");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mesh_links_are_grid_neighbors_both_ways() {
        let links = Mesh.links(8); // 3x3 grid, last row short (nodes 6,7)
        for (a, b) in &links {
            assert!(links.contains(&(*b, *a)), "missing reverse of {a}->{b}");
            let (_, cols) = mesh_dims(8);
            let dr = (*a / cols).abs_diff(*b / cols);
            let dc = (*a % cols).abs_diff(*b % cols);
            assert_eq!(dr + dc, 1, "{a}->{b} is not a grid neighbor");
        }
        assert!(!links.contains(&(5, 8)), "node 8 does not exist");
    }

    #[test]
    fn single_message_latency_is_hops_times_wire_time() {
        // Crossbar, 1 hop each way: request 16 B then response 64 B over
        // a 16 B/cycle link with 8-cycle hop latency. Store-and-forward
        // costs one queue cycle per hop plus one turnaround cycle at the
        // destination; pin the exact constant to keep the timing model
        // deterministic under refactoring.
        let mut net = InterNodeNetwork::new(&cfg(2, InterTopologyKind::Crossbar));
        let run =
            net.run(&[Request { from: 0, to: 1, bytes: 16, reply_bytes: 64 }]);
        assert_eq!(run.stats.delivered, 1);
        assert_eq!(run.stats.delivered_bytes, 64);
        assert_eq!(run.stats.hops, 2);
        let expect_req = 8 + 1; // latency + ceil(16/16)
        let expect_resp = 8 + 4; // latency + ceil(64/16)
        // inject@0 -> wire start@1 -> arrive@1+9=10; response enqueued
        // ready@11, injected@11, wire start@12, arrives@12+12=24.
        assert_eq!(run.node_done[0], 1 + expect_req + 2 + expect_resp);
        assert_eq!(run.node_done[1], 0, "node 1 requested nothing");
        assert!(run.stats.cycles >= run.node_done[0]);
    }

    #[test]
    fn ring_all_to_opposite_drains_with_tiny_queues() {
        // The deadlock-prone pattern: every node floods its antipode so
        // both ring directions develop circular link demand. The bubble
        // rule must keep it live even at the minimum legal queue depth.
        let mut c = cfg(4, InterTopologyKind::Ring);
        c.link_queue = 2;
        let mut net = InterNodeNetwork::new(&c);
        let mut reqs = Vec::new();
        for n in 0..4usize {
            for _ in 0..40 {
                reqs.push(Request {
                    from: n,
                    to: (n + 2) % 4,
                    bytes: 16,
                    reply_bytes: 144,
                });
            }
        }
        let run = net.run(&reqs);
        assert_eq!(run.stats.delivered, 160);
        assert_eq!(run.stats.delivered_bytes, 160 * 144);
        // Peak demand is visible and bounded by the queue capacity.
        for l in &run.stats.links {
            assert!(l.peak_queue <= 2, "{}: queue overflow", l.label);
        }
        assert!(
            run.stats.links.iter().any(|l| l.peak_queue > 0),
            "load never queued anywhere"
        );
    }

    #[test]
    fn mesh_many_to_many_conserves_bytes_and_counts_hops() {
        let mut net = InterNodeNetwork::new(&cfg(9, InterTopologyKind::Mesh));
        let mut reqs = Vec::new();
        for from in 0..9usize {
            for to in 0..9usize {
                if from != to {
                    reqs.push(Request { from, to, bytes: 16, reply_bytes: 128 });
                }
            }
        }
        let run = net.run(&reqs);
        assert_eq!(run.stats.delivered, 72);
        assert_eq!(run.stats.delivered_bytes, 72 * 128);
        // Hops ≥ 2 per exchange (1 out + 1 back minimum), and the link
        // byte counters account for every traversal exactly.
        assert!(run.stats.hops >= 144);
        let link_msgs: u64 = run.stats.links.iter().map(|l| l.msgs).sum();
        assert_eq!(link_msgs, run.stats.hops);
        for n in 0..9 {
            assert!(run.node_done[n] > 0, "node {n} never completed");
        }
    }

    #[test]
    fn empty_request_set_is_a_zero_cycle_phase() {
        let mut net = InterNodeNetwork::new(&cfg(4, InterTopologyKind::Ring));
        let run = net.run(&[]);
        assert_eq!(run.stats.cycles, 0);
        assert_eq!(run.stats.delivered, 0);
        assert_eq!(run.node_done, vec![0; 4]);
    }
}
