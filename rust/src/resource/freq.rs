//! Max-frequency model — captures the two §IV-E synthesis observations:
//!
//! * "Increasing the number of DMAs ... negatively impacts the maximum
//!   operating frequency due to increased place and route complexity."
//! * "We further observed that the cache size also influences the
//!   maximum operating frequency of the overall design."
//!
//! Modeled as a base user clock degraded by routing-congestion terms in
//! the DMA buffer count, cache capacity, and LMB fan-in. Constants chosen
//! so the paper's configurations sit at the MIG's 300 MHz user clock.

use crate::config::SystemConfig;

/// Estimated maximum operating frequency (MHz) for a configuration.
pub fn max_frequency_mhz(cfg: &SystemConfig) -> f64 {
    let base = 322.0;
    // DMA routing congestion: mild up to 4 buffers, steep beyond (the
    // paper's "saturates after 4" ablation pairs with this).
    let n_dma = cfg.dma.n_buffers as f64;
    let dma_penalty = if n_dma <= 4.0 {
        1.5 * n_dma
    } else {
        6.0 + 7.0 * (n_dma - 4.0)
    };
    // Cache capacity: deeper URAM/BRAM cascades lengthen the critical
    // path roughly with log2 of capacity beyond 256 KiB.
    let cap_kib = cfg.cache.capacity_bytes() as f64 / 1024.0;
    let cache_penalty = 8.0 * (cap_kib / 256.0).log2().max(0.0);
    // PE fan-in per LMB ("the complexity of the connection between PEs
    // and LMB exponentially increases with the number of PEs", §IV).
    let fanin = cfg.pes_per_lmb() as f64;
    let fanin_penalty = 0.6 * fanin * fanin;
    (base - dma_penalty - cache_penalty - fanin_penalty).max(50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_meet_the_mig_user_clock() {
        // Both published configurations must close timing at ~300 MHz.
        let fa = max_frequency_mhz(&SystemConfig::config_a());
        let fb = max_frequency_mhz(&SystemConfig::config_b());
        assert!((295.0..330.0).contains(&fa), "config-a {fa} MHz");
        assert!((295.0..330.0).contains(&fb), "config-b {fb} MHz");
    }

    #[test]
    fn more_dma_buffers_lower_fmax() {
        let mut prev = f64::INFINITY;
        for n in [1, 2, 4, 6, 8] {
            let mut cfg = SystemConfig::config_a();
            cfg.dma.n_buffers = n;
            let f = max_frequency_mhz(&cfg);
            assert!(f <= prev, "fmax should fall with DMA count: {n} → {f}");
            prev = f;
        }
        // The drop beyond 4 is steeper than before 4 (§IV-E).
        let f = |n: usize| {
            let mut c = SystemConfig::config_a();
            c.dma.n_buffers = n;
            max_frequency_mhz(&c)
        };
        let slope_before = f(2) - f(4);
        let slope_after = f(4) - f(6);
        assert!(slope_after > slope_before);
    }

    #[test]
    fn bigger_caches_lower_fmax() {
        let f = |lines: usize| {
            let mut c = SystemConfig::config_a();
            c.cache.lines = lines;
            max_frequency_mhz(&c)
        };
        assert!(f(16384) < f(8192));
        assert!(f(32768) < f(16384));
    }

    #[test]
    fn fmax_floor_holds() {
        let mut cfg = SystemConfig::config_a();
        cfg.dma.n_buffers = 64;
        cfg.cache.lines = 1 << 20;
        assert!(max_frequency_mhz(&cfg) >= 50.0);
    }
}
