//! Per-module resource formulas, calibrated against the paper's two
//! published configurations (Table II).
//!
//! Calibration data (percent of U250 resources):
//!
//! | module (cfg)     | LUT%  | FF%   | BRAM% | URAM% |
//! |------------------|-------|-------|-------|-------|
//! | Cache (A)        | 1.87  | 1.24  | 0.24  | 1.25  |
//! | Cache (B)        | 0.65  | 0.64  | 0.06  | 0.63  |
//! | DMA Engine       | 0.04  | 0.01  | —     | 0.25  |
//! | Request Reductor | 0.08  | 0.10  | —     | 1.25  |
//! | LMB (A)          | 2.03  | 1.41  | 0.24  | 2.75  |
//! | LMB (B)          | 0.85  | 0.81  | 0.06  | 2.13  |
//! | System (A, 1 LMB)| 2.25  | 1.54  | 0.24  | 2.75  |
//! | System (B, 4 LMB)| 3.61  | 3.35  | 0.24  | 8.52  |

use crate::config::SystemConfig;
use crate::util::table::{Align, Table};

use super::Device;

/// Xilinx Alveo U250 (paper §V-A: 1728 K LUTs, 3456 K FFs; device totals
/// for BRAM36/URAM from the U250 datasheet).
pub const U250: Device = Device {
    luts: 1_728_000,
    ffs: 3_456_000,
    bram36: 2_688,
    uram: 1_280,
};

/// Absolute utilization of one module.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModuleUtil {
    pub luts: f64,
    pub ffs: f64,
    pub bram36: f64,
    pub uram: f64,
}

impl ModuleUtil {
    pub fn add(&self, o: &ModuleUtil) -> ModuleUtil {
        ModuleUtil {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            bram36: self.bram36 + o.bram36,
            uram: self.uram + o.uram,
        }
    }

    pub fn scale(&self, f: f64) -> ModuleUtil {
        ModuleUtil {
            luts: self.luts * f,
            ffs: self.ffs * f,
            bram36: self.bram36 * f,
            uram: self.uram * f,
        }
    }

    /// Percentages of a device.
    pub fn percent(&self, dev: &Device) -> [f64; 4] {
        [
            100.0 * self.luts / dev.luts as f64,
            100.0 * self.ffs / dev.ffs as f64,
            100.0 * self.bram36 / dev.bram36 as f64,
            100.0 * self.uram / dev.uram as f64,
        ]
    }
}

/// The analytic model over a full system configuration.
pub struct ResourceModel<'a> {
    pub cfg: &'a SystemConfig,
    pub dev: Device,
}

/// URAM288 block = 288 Kib = 36 KiB of storage.
const URAM_BYTES: f64 = 36.0 * 1024.0;
/// BRAM36 block = 36 Kib of storage.
const BRAM_BITS: f64 = 36.0 * 1024.0;

impl<'a> ResourceModel<'a> {
    pub fn new(cfg: &'a SystemConfig) -> ResourceModel<'a> {
        ResourceModel { cfg, dev: U250 }
    }

    /// Cache: URAM data array, BRAM tag array, LUT comparators/muxes,
    /// FF pipeline registers.
    pub fn cache(&self) -> ModuleUtil {
        let c = &self.cfg.cache;
        let lines = c.lines as f64;
        let ways = c.associativity as f64;
        let line_bytes = c.line_bytes() as f64;
        // Data array in URAM (512 KiB @A → 16 blocks; 256 KiB @B → 8).
        let uram = (lines * line_bytes / URAM_BYTES).ceil();
        // Tag array in BRAM: tag+state ≈ (31 − log2(line) − log2(sets))
        // + 4 bits per line.
        let sets = (lines / ways).max(1.0);
        let tag_bits = 31.0 - (line_bytes).log2() - sets.log2() + 4.0;
        // Tags pack into true-dual-port BRAM36s (two tag reads per probe
        // in a 2-way cache add a second bank).
        let bram = (lines * tag_bits / (2.0 * BRAM_BITS)).ceil()
            + if ways > 1.0 { 4.0 } else { 0.0 };
        // Control logic — affine in line×way count (calibrated on A/B).
        let luts = 4_200.0 + 1.715 * lines * ways;
        let ffs = 1_420.0 + 5.06 * lines;
        ModuleUtil {
            luts,
            ffs,
            bram36: bram,
            uram,
        }
    }

    /// DMA engine: buffers in URAM + per-buffer descriptor logic.
    pub fn dma(&self) -> ModuleUtil {
        let n = self.cfg.dma.n_buffers as f64;
        ModuleUtil {
            luts: 173.0 * n,
            ffs: 86.4 * n,
            bram36: 0.0,
            uram: 0.8 * n,
        }
    }

    /// Request Reductor: CAM temp buffer (LUT-hungry per entry) + RRSH
    /// XOR-hash table in URAM.
    pub fn request_reductor(&self) -> ModuleUtil {
        let tb = self.cfg.rr.temp_buffer_entries as f64;
        let rrsh = self.cfg.rr.rrsh_entries as f64;
        ModuleUtil {
            luts: 120.0 * tb + 0.1 * rrsh,
            ffs: 40.0 * tb + 0.8 * rrsh,
            bram36: 0.0,
            uram: (rrsh / 256.0).ceil(),
        }
    }

    /// One LMB = cache + DMA + RR + glue.
    pub fn lmb(&self) -> ModuleUtil {
        let glue = ModuleUtil {
            luts: 1_000.0,
            ffs: 900.0,
            bram36: 0.0,
            uram: 0.0,
        };
        self.cache()
            .add(&self.dma())
            .add(&self.request_reductor())
            .add(&glue)
    }

    /// Request router: arbitration + data fan-out, grows with ports.
    pub fn router(&self) -> ModuleUtil {
        let ports = self.cfg.n_lmbs as f64;
        ModuleUtil {
            luts: 3_400.0 + 180.0 * ports,
            ffs: 4_000.0 + 120.0 * ports,
            bram36: 0.0,
            uram: 0.0,
        }
    }

    /// Complete system: n LMBs + router.
    pub fn system(&self) -> ModuleUtil {
        self.lmb().scale(self.cfg.n_lmbs as f64).add(&self.router())
    }
}

/// Render paper Table II for a list of configurations.
pub fn table2(configs: &[&SystemConfig]) -> String {
    let mut t = Table::new(&[
        "Module", "Configuration", "LUT(%)", "FF(%)", "BRAM(%)", "URAM(%)",
    ])
    .aligns(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for cfg in configs {
        let m = ResourceModel::new(cfg);
        let rows: [(&str, String, ModuleUtil); 5] = [
            (
                "Cache",
                format!(
                    "{}-way, {} lines, {}b",
                    cfg.cache.associativity, cfg.cache.lines, cfg.cache.line_bits
                ),
                m.cache(),
            ),
            (
                "DMA Engine",
                format!("{} buffers x {} B", cfg.dma.n_buffers, cfg.dma.buffer_bytes),
                m.dma(),
            ),
            (
                "Request Reductor",
                format!(
                    "RRSH {}, TB {}",
                    cfg.rr.rrsh_entries, cfg.rr.temp_buffer_entries
                ),
                m.request_reductor(),
            ),
            ("LMB", "cache + DMA + RR".to_string(), m.lmb()),
            (
                "Complete System",
                format!("{} LMB(s)", cfg.n_lmbs),
                m.system(),
            ),
        ];
        for (name, spec, util) in rows {
            let p = util.percent(&m.dev);
            t.row(&[
                format!("{} ({})", name, cfg.label),
                spec,
                format!("{:.2}", p[0]),
                format!("{:.2}", p[1]),
                format!("{:.2}", p[2]),
                format!("{:.2}", p[3]),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Assert a modeled percentage is within `tol_pp` percentage points
    /// of the paper's value.
    fn close(pct: f64, paper: f64, tol_pp: f64, what: &str) {
        assert!(
            (pct - paper).abs() <= tol_pp,
            "{what}: model {pct:.3}% vs paper {paper:.3}% (tol ±{tol_pp}pp)"
        );
    }

    #[test]
    fn config_a_matches_paper_table2() {
        let cfg = SystemConfig::config_a();
        let m = ResourceModel::new(&cfg);
        let c = m.cache().percent(&m.dev);
        close(c[0], 1.87, 0.15, "cache-A LUT");
        close(c[1], 1.24, 0.15, "cache-A FF");
        close(c[2], 0.24, 0.15, "cache-A BRAM");
        close(c[3], 1.25, 0.15, "cache-A URAM");
        let d = m.dma().percent(&m.dev);
        close(d[0], 0.04, 0.02, "dma LUT");
        close(d[1], 0.01, 0.02, "dma FF");
        close(d[3], 0.25, 0.05, "dma URAM");
        let r = m.request_reductor().percent(&m.dev);
        close(r[0], 0.08, 0.03, "rr LUT");
        close(r[1], 0.10, 0.03, "rr FF");
        close(r[3], 1.25, 0.1, "rr URAM");
        let l = m.lmb().percent(&m.dev);
        close(l[0], 2.03, 0.2, "lmb-A LUT");
        close(l[1], 1.41, 0.2, "lmb-A FF");
        close(l[3], 2.75, 0.2, "lmb-A URAM");
        let s = m.system().percent(&m.dev);
        close(s[0], 2.25, 0.25, "system-A LUT");
        close(s[1], 1.54, 0.25, "system-A FF");
        close(s[3], 2.75, 0.25, "system-A URAM");
    }

    #[test]
    fn config_b_matches_paper_table2() {
        let cfg = SystemConfig::config_b();
        let m = ResourceModel::new(&cfg);
        let c = m.cache().percent(&m.dev);
        close(c[0], 0.65, 0.15, "cache-B LUT");
        close(c[1], 0.64, 0.15, "cache-B FF");
        close(c[2], 0.06, 0.1, "cache-B BRAM");
        close(c[3], 0.63, 0.1, "cache-B URAM");
        let l = m.lmb().percent(&m.dev);
        close(l[0], 0.85, 0.2, "lmb-B LUT");
        close(l[1], 0.81, 0.2, "lmb-B FF");
        close(l[3], 2.13, 0.2, "lmb-B URAM");
        let s = m.system().percent(&m.dev);
        close(s[0], 3.61, 0.4, "system-B LUT");
        close(s[1], 3.35, 0.4, "system-B FF");
        close(s[2], 0.24, 0.15, "system-B BRAM");
        close(s[3], 8.52, 0.5, "system-B URAM");
    }

    #[test]
    fn scaling_trends_are_monotone() {
        // Bigger cache ⇒ more of everything storage-ish.
        let a = SystemConfig::config_a();
        let mut bigger = a.clone();
        bigger.cache.lines *= 2;
        let ra = ResourceModel::new(&a).cache();
        let rb = ResourceModel::new(&bigger).cache();
        assert!(rb.luts > ra.luts);
        assert!(rb.uram > ra.uram);
        // More DMA buffers ⇒ more LUTs.
        let mut dmas = a.clone();
        dmas.dma.n_buffers = 8;
        assert!(ResourceModel::new(&dmas).dma().luts > ResourceModel::new(&a).dma().luts);
    }

    #[test]
    fn table2_renders_both_configs() {
        let a = SystemConfig::config_a();
        let b = SystemConfig::config_b();
        let s = table2(&[&a, &b]);
        assert!(s.contains("Cache (config-a)"));
        assert!(s.contains("Complete System (config-b)"));
        assert!(s.contains("LUT(%)"));
    }
}
