//! Analytic FPGA resource model — reproduces paper Table II.
//!
//! The paper reports post-place-and-route utilization on a Xilinx Alveo
//! U250 (Vivado 2020.2). Without Vivado, we model each module's LUT /
//! FF / BRAM / URAM cost as closed-form functions of its configuration
//! parameters, with per-module constants *calibrated against the two
//! published configurations* (Config-A and Config-B). The model then
//! extrapolates for ablations (cache-size sweeps, DMA-count sweeps) the
//! way real synthesis trends would: storage scales with capacity bits,
//! control logic with ports and comparators, CAMs quadratically-ish with
//! entries × tag width.
//!
//! A simple max-frequency model captures the two §IV-E claims: more DMA
//! buffers and bigger caches both lower the achievable clock (routing
//! congestion / deeper muxes).

mod freq;
mod model;

pub use freq::max_frequency_mhz;
pub use model::{table2, ModuleUtil, ResourceModel, U250};

/// U250 device totals used for percentages.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub luts: u64,
    pub ffs: u64,
    /// BRAM36 blocks.
    pub bram36: u64,
    /// URAM288 blocks.
    pub uram: u64,
}
