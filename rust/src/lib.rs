//! # mttkrp-memsys
//!
//! Reproduction of *"Reconfigurable Low-latency Memory System for Sparse
//! Matricized Tensor Times Khatri-Rao Product on FPGA"* (Wijeratne, Kannan,
//! Prasanna — 2021) as a three-layer Rust + JAX/Pallas + PJRT stack.
//!
//! The paper's contribution — a reconfigurable **Local Memory Block (LMB)**
//! memory system (non-blocking cache + Request Reductor + DMA engine behind
//! a request router) for sparse MTTKRP accelerators — is reproduced as a
//! cycle-level simulator ([`sim`]), driven by request traces generated from
//! real sparse tensors ([`tensor`], [`trace`]). The MTTKRP arithmetic runs
//! through AOT-compiled JAX/Pallas HLO via PJRT ([`runtime`]), orchestrated
//! by the [`coordinator`]. FPGA resource utilization (paper Table II) is
//! reproduced by an analytic model ([`resource`]).
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — event loop, memory-system simulation, batching,
//!   routing, CLI, metrics. Drivers compose simulations through the
//!   [`experiment`] API (scenario builder + parallel sweep runner);
//!   [`cluster`] shards a tensor across several such accelerators behind
//!   a routed inter-node network.
//! * **L2 (python/compile/model.py)** — batched spMTTKRP JAX graph.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (partials +
//!   scatter-as-matmul), lowered with `interpret=True` into the same HLO.
//!
//! ## Where to read next
//!
//! `docs/ARCHITECTURE.md` walks the full request lifecycle (tensor
//! element → PE → LMB bank → fabric → DRAM channel → reply network →
//! retire) and maps every module to the paper section/figure it
//! reproduces and every bench/test to the claim it pins. Each `sim`
//! and `experiment` module carries the corresponding paper quotes and
//! invariants in its rustdoc header (this documentation builds
//! warning-clean under `cargo doc --no-deps`, gated in CI).
//!
//! ## Quickstart
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiment;
pub mod mttkrp;
pub mod resource;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod trace;
pub mod util;

pub use util::error::Error;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;
