//! Configuration system: every synthesis-time knob of the paper's memory
//! system plus simulator/workload parameters, with the paper's presets
//! (Configuration-A, Configuration-B) and baseline variants.
//!
//! Configs load from a simple `key = value` file (serde is unavailable
//! offline) and accept `--key value` CLI overrides, mirroring how the
//! paper's design is "configured during the synthesis step" (§IV-E).

mod parse;

pub use parse::{parse_kv_file, parse_kv_str};

use crate::util::{is_pow2, json::Json, NameParseError};

/// Which memory-system variant to simulate (§V-B baselines + proposed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemKind {
    /// Direct connection to the commercial memory-controller IP.
    IpOnly,
    /// All traffic through a conventional non-blocking cache (with MSHR).
    CacheOnly,
    /// All traffic through single-request-at-a-time DMA engines.
    DmaOnly,
    /// The paper's LMB-based system (cache + RR/RRSH + DMA per LMB).
    Proposed,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::IpOnly => "ip-only",
            SystemKind::CacheOnly => "cache-only",
            SystemKind::DmaOnly => "dma-only",
            SystemKind::Proposed => "proposed",
        }
    }

    #[deprecated(note = "use `s.parse::<SystemKind>()` instead")]
    pub fn from_name(s: &str) -> Option<SystemKind> {
        s.parse().ok()
    }

    pub const ALL: [SystemKind; 4] = [
        SystemKind::IpOnly,
        SystemKind::CacheOnly,
        SystemKind::DmaOnly,
        SystemKind::Proposed,
    ];
}

impl std::str::FromStr for SystemKind {
    type Err = NameParseError;

    fn from_str(s: &str) -> Result<SystemKind, NameParseError> {
        match s {
            "ip-only" | "ip" => Ok(SystemKind::IpOnly),
            "cache-only" | "cache" => Ok(SystemKind::CacheOnly),
            "dma-only" | "dma" => Ok(SystemKind::DmaOnly),
            "proposed" | "lmb" => Ok(SystemKind::Proposed),
            _ => Err(NameParseError::new(
                "system",
                s,
                &["ip-only", "cache-only", "dma-only", "proposed"],
            )),
        }
    }
}

/// Compute-fabric communication type (§V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FabricType {
    /// Systolic-array fabrics with a single point of access to external
    /// memory per data structure (shared MLU/TLU/MSU), e.g. Tensaurus.
    Type1,
    /// Fabrics with multiple independent points of access — one per PE
    /// running Algorithm 3 on its own partition.
    Type2,
}

impl FabricType {
    pub fn name(&self) -> &'static str {
        match self {
            FabricType::Type1 => "type1",
            FabricType::Type2 => "type2",
        }
    }

    #[deprecated(note = "use `s.parse::<FabricType>()` instead")]
    pub fn from_name(s: &str) -> Option<FabricType> {
        s.parse().ok()
    }
}

impl std::str::FromStr for FabricType {
    type Err = NameParseError;

    fn from_str(s: &str) -> Result<FabricType, NameParseError> {
        match s {
            "type1" | "1" => Ok(FabricType::Type1),
            "type2" | "2" => Ok(FabricType::Type2),
            _ => Err(NameParseError::new("fabric", s, &["type1", "type2"])),
        }
    }
}

/// Interconnect topology between the request ports and the DRAM
/// channels (multi-channel generalization of the paper's single request
/// router; see `sim::fabric`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    /// Full crossbar: every port arbitrates directly at every channel
    /// (one-cycle arbitration, no store-and-forward hops). With one
    /// channel this is exactly the paper's request router.
    Crossbar,
    /// Fabric nodes in a row; requests hop node-to-node over per-link
    /// bounded queues.
    Line,
    /// Like `Line` but closed into a ring; requests take the shortest
    /// direction.
    Ring,
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Crossbar => "crossbar",
            TopologyKind::Line => "line",
            TopologyKind::Ring => "ring",
        }
    }

    #[deprecated(note = "use `s.parse::<TopologyKind>()` instead")]
    pub fn from_name(s: &str) -> Option<TopologyKind> {
        s.parse().ok()
    }

    pub const ALL: [TopologyKind; 3] = [
        TopologyKind::Crossbar,
        TopologyKind::Line,
        TopologyKind::Ring,
    ];
}

impl std::str::FromStr for TopologyKind {
    type Err = NameParseError;

    fn from_str(s: &str) -> Result<TopologyKind, NameParseError> {
        match s {
            "crossbar" | "xbar" => Ok(TopologyKind::Crossbar),
            "line" => Ok(TopologyKind::Line),
            "ring" => Ok(TopologyKind::Ring),
            _ => Err(NameParseError::new("topology", s, &["crossbar", "line", "ring"])),
        }
    }
}

/// Inter-node topology joining cluster nodes (`cluster::network`). A
/// superset of [`TopologyKind`]: the intra-node fabric keys one node per
/// DRAM channel, while the cluster layer is free to pick a mesh when the
/// node count is not tied to the channel count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterTopologyKind {
    /// Every node pair exchanges messages directly (one arbitration
    /// stage, no store-and-forward hops).
    Crossbar,
    /// Nodes in a row; messages hop neighbor-to-neighbor.
    Line,
    /// A line closed into a ring; messages take the shortest direction.
    Ring,
    /// Near-square 2D mesh with dimension-order (XY) routing.
    Mesh,
}

impl InterTopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            InterTopologyKind::Crossbar => "crossbar",
            InterTopologyKind::Line => "line",
            InterTopologyKind::Ring => "ring",
            InterTopologyKind::Mesh => "mesh",
        }
    }

    pub const ALL: [InterTopologyKind; 4] = [
        InterTopologyKind::Crossbar,
        InterTopologyKind::Line,
        InterTopologyKind::Ring,
        InterTopologyKind::Mesh,
    ];
}

impl std::str::FromStr for InterTopologyKind {
    type Err = NameParseError;

    fn from_str(s: &str) -> Result<InterTopologyKind, NameParseError> {
        match s {
            "crossbar" | "xbar" => Ok(InterTopologyKind::Crossbar),
            "line" => Ok(InterTopologyKind::Line),
            "ring" => Ok(InterTopologyKind::Ring),
            "mesh" => Ok(InterTopologyKind::Mesh),
            _ => Err(NameParseError::new(
                "inter-node topology",
                s,
                &["crossbar", "line", "ring", "mesh"],
            )),
        }
    }
}

/// Multi-accelerator scale-out parameters (`cluster`): how many
/// accelerator nodes share the tensor and how the inter-node network
/// joining them is shaped. The default — one node — is the literal
/// single-accelerator code path (`sim::simulate`), the same
/// identity-by-construction convention `lmb_banks == 1` and
/// `reply_network == false` follow.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterConfig {
    /// Accelerator nodes, each a full memory system (PEs, LMB banks,
    /// fabric, DRAM channels). 1 = single accelerator, no cluster layer.
    pub nodes: usize,
    /// Inter-node topology (independent of the intra-node fabric's).
    pub topology: InterTopologyKind,
    /// Payload bytes one directed inter-node link moves per cycle — the
    /// byte-level bandwidth budget (serial transceiver model, so a
    /// `rank x 4`-byte factor row occupies the wire for several cycles).
    pub link_bytes: u64,
    /// Per-hop transport latency in cycles (SerDes + synchronization).
    pub link_latency: u64,
    /// Bounded queue depth (messages) per directed link; full queues
    /// backpressure upstream senders.
    pub link_queue: usize,
}

impl ClusterConfig {
    /// The default: one node — exactly today's single-accelerator system.
    pub fn single_node() -> ClusterConfig {
        ClusterConfig {
            nodes: 1,
            topology: InterTopologyKind::Ring,
            link_bytes: 16,
            link_latency: 8,
            link_queue: 16,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.nodes == 0 {
            return Err("cluster: nodes must be > 0".into());
        }
        if self.link_bytes == 0 {
            return Err("cluster: link_bytes must be > 0".into());
        }
        if self.link_latency == 0 {
            return Err("cluster: link_latency must be > 0 (a hop takes a cycle)".into());
        }
        if self.link_queue < 2 {
            // The inter-node network's injection rule keeps one queue
            // slot free for transit traffic (bubble flow control); with
            // a single-slot queue no node could ever inject.
            return Err("cluster: link_queue must be >= 2 (bubble flow control)".into());
        }
        Ok(())
    }
}

/// Multi-channel interconnect parameters (`sim::fabric`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterconnectConfig {
    /// Independent DRAM channels behind the fabric (power of two;
    /// 1 = the paper's single memory-interface IP).
    pub channels: usize,
    /// How ports reach channels.
    pub topology: TopologyKind,
    /// Requests one directed fabric link can forward per cycle
    /// (line/ring store-and-forward links only).
    pub link_width: usize,
    /// Store-and-forward queue depth per directed link.
    pub link_queue: usize,
    /// Channel-interleave granularity of the physical address space in
    /// bytes (power of two).
    pub interleave_bytes: u64,
    /// Model the response path as a first-class reply network: DRAM
    /// completions traverse the topology back to the requesting port
    /// over dedicated reply links (per-link bandwidth, bounded queues,
    /// backpressure) instead of arriving for free. `false` keeps the
    /// seed behavior — the return path is combinational, exactly the
    /// pre-reply-network system.
    pub reply_network: bool,
}

impl InterconnectConfig {
    /// The seed configuration: one channel behind a crossbar — exactly
    /// the paper's single `Router -> Dram` pipe.
    pub fn single_channel() -> InterconnectConfig {
        InterconnectConfig {
            channels: 1,
            topology: TopologyKind::Crossbar,
            link_width: 1,
            link_queue: 16,
            interleave_bytes: 4096,
            reply_network: false,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !is_pow2(self.channels as u64) {
            return Err(format!(
                "interconnect: channels {} must be a power of two",
                self.channels
            ));
        }
        if self.link_width == 0 || self.link_queue == 0 {
            return Err("interconnect: link_width and link_queue must be > 0".into());
        }
        if !is_pow2(self.interleave_bytes) {
            return Err(format!(
                "interconnect: interleave_bytes {} must be a power of two",
                self.interleave_bytes
            ));
        }
        Ok(())
    }
}

/// Telemetry knobs (`sim::telemetry`): request-lifecycle tracing and the
/// windowed time-series. Everything defaults to **off** — the disabled
/// path is the literal pre-telemetry code path, and enabling any product
/// never perturbs simulated behavior (pinned by the engine-equivalence
/// matrix in `tests/integration_engine.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Record per-request lifecycle spans, exported as Chrome
    /// trace-event JSON (Perfetto / `chrome://tracing`).
    pub trace: bool,
    /// Snapshot windowed counter deltas into a JSONL timeline.
    pub timeline: bool,
    /// Trace 1-in-N sampling: only every `sample`-th PE access (and
    /// DRAM transaction) opens spans. 1 = trace everything.
    pub sample: u64,
    /// Timeline window width in cycles.
    pub window: u64,
}

impl TelemetryConfig {
    /// The default: every product off, neutral sampling/window.
    pub fn off() -> TelemetryConfig {
        TelemetryConfig { trace: false, timeline: false, sample: 1, window: 10_000 }
    }

    /// Any product enabled?
    pub fn enabled(&self) -> bool {
        self.trace || self.timeline
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.sample == 0 {
            return Err("telemetry: sample must be > 0".into());
        }
        if self.window == 0 {
            return Err("telemetry: window must be > 0".into());
        }
        Ok(())
    }
}

/// Cache parameters (paper Table II rows "Cache").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    /// Degree of set-associativity (A: 2, B: 1).
    pub associativity: usize,
    /// Total number of cache lines (A: 8192, B: 4096).
    pub lines: usize,
    /// Cache-line width in BITS, kept equal to the memory interface IP data
    /// width (512) to avoid implementation complexities (§IV-B).
    pub line_bits: usize,
    /// Hit-pipeline depth (paper: 3-stage for high frequency).
    pub pipeline_stages: u64,
    /// MSHR primary-miss entries (used by the cache-only baseline; the
    /// proposed system absorbs secondary misses in the RRSH instead).
    pub mshr_entries: usize,
    /// Secondary misses a single MSHR entry can track before stalling —
    /// the "conventional MSHR cannot handle a large number of secondary
    /// cache misses" knob (§V-D).
    pub mshr_secondary_cap: usize,
}

impl CacheConfig {
    pub fn line_bytes(&self) -> u64 {
        (self.line_bits / 8) as u64
    }

    pub fn sets(&self) -> usize {
        self.lines / self.associativity
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.lines as u64 * self.line_bytes()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.associativity == 0 || self.lines == 0 {
            return Err("cache: associativity and lines must be > 0".into());
        }
        if self.lines % self.associativity != 0 {
            return Err(format!(
                "cache: lines {} not divisible by associativity {}",
                self.lines, self.associativity
            ));
        }
        if !is_pow2(self.sets() as u64) {
            return Err(format!("cache: sets {} must be a power of two", self.sets()));
        }
        if self.line_bits % 8 != 0 || !is_pow2(self.line_bytes()) {
            return Err("cache: line width must be a power-of-two byte count".into());
        }
        Ok(())
    }
}

/// DMA engine parameters (Table II "DMA Engine").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DmaConfig {
    /// Number of parallel DMA buffers (paper: 4; saturates after 4, §IV-E).
    pub n_buffers: usize,
    /// Size of a single DMA buffer in bytes (paper: 256 B).
    pub buffer_bytes: u64,
}

impl DmaConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.n_buffers == 0 {
            return Err("dma: n_buffers must be > 0".into());
        }
        if self.buffer_bytes == 0 || !is_pow2(self.buffer_bytes) {
            return Err("dma: buffer_bytes must be a power of two".into());
        }
        Ok(())
    }
}

/// Request Reductor parameters (Table II "Request Reductor").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RrConfig {
    /// RRSH (XOR-hash table) entries; paper uses 4096 —
    /// proportional to cache_lines / associativity (§IV-C1).
    pub rrsh_entries: usize,
    /// CAM temporary-buffer entries holding recent cache lines (paper: 8,
    /// "since CAMs are hardware expensive, we keep [it] small").
    pub temp_buffer_entries: usize,
    /// RR pipeline depth (paper: 2-stage).
    pub pipeline_stages: u64,
}

impl RrConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !is_pow2(self.rrsh_entries as u64) {
            return Err("rr: rrsh_entries must be a power of two".into());
        }
        if self.temp_buffer_entries == 0 {
            return Err("rr: temp_buffer_entries must be > 0".into());
        }
        Ok(())
    }
}

/// Which DRAM timing backend simulates a channel (see `sim::dram` and
/// `sim::dram_timed`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DramModelKind {
    /// The fast regression backend: DDR4 bank timing folded into lumped
    /// `t_row_hit`/`t_row_miss`/`t_precharge` user-clock latencies.
    Lumped,
    /// Command-level backend: explicit ACT/RD/WR/PRE/REF per bank with
    /// tRCD/tRP/tCAS/tCWL/tRAS timing, periodic refresh (tREFI/tRFC)
    /// and tWTR/tRTW bus turnaround.
    Timed,
}

impl DramModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            DramModelKind::Lumped => "lumped",
            DramModelKind::Timed => "timed",
        }
    }

    pub const ALL: [DramModelKind; 2] = [DramModelKind::Lumped, DramModelKind::Timed];
}

impl std::str::FromStr for DramModelKind {
    type Err = NameParseError;

    fn from_str(s: &str) -> Result<DramModelKind, NameParseError> {
        match s {
            "lumped" => Ok(DramModelKind::Lumped),
            "timed" => Ok(DramModelKind::Timed),
            _ => Err(NameParseError::new("dram.model", s, &["lumped", "timed"])),
        }
    }
}

/// DRAM / memory-interface-IP timing model (user-clock cycles @300 MHz).
///
/// The paper connects to the Xilinx UltraScale Memory Interface IP
/// (512-bit data, 31-bit address). The default `lumped` backend folds
/// DDR4 bank timing into user-clock latencies (see DESIGN.md §6); the
/// `timed` backend replays the underlying DDR4 command schedule with the
/// `t_rcd`..`t_rfc` parameters below.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DramConfig {
    /// Timing backend for every channel of this config.
    pub model: DramModelKind,
    /// Data-bus width in bits (Xilinx MIG on U250: 512 with ECC).
    pub data_bits: usize,
    /// Number of DRAM banks the address space interleaves over.
    pub banks: usize,
    /// Row-buffer size per bank in bytes (DDR4 x4 rank: 1 KiB columns × 8).
    pub row_bytes: u64,
    /// Latency of a row-buffer hit (tCL + controller), user cycles.
    pub t_row_hit: u64,
    /// Latency of a row miss (tRCD + tCL + controller), user cycles.
    pub t_row_miss: u64,
    /// Extra precharge penalty when the bank has a different open row.
    pub t_precharge: u64,
    /// Fixed front-end overhead of the memory controller IP per request.
    pub t_controller: u64,
    /// Maximum outstanding requests the controller accepts (queue depth).
    pub max_outstanding: usize,
    /// Address width in bits (MIG on U250: 31).
    pub addr_bits: usize,
    /// Bus-admission guard: the scheduler refuses to start a new request
    /// while the data bus is already booked more than
    /// `bus_admission_factor * t_row_miss` cycles into the future.
    /// Models the bounded command queue between the controller's bank
    /// machines and the shared data bus — without it a burst of row hits
    /// on one bank could book the bus arbitrarily far ahead and starve
    /// ready requests at other banks.
    pub bus_admission_factor: u64,
    /// tRCD: ACT-to-column command delay, user cycles (timed backend).
    pub t_rcd: u64,
    /// tRP: precharge-to-ACT delay, user cycles (timed backend).
    pub t_rp: u64,
    /// tCAS/CL: read column command to data, user cycles (timed backend).
    pub t_cas: u64,
    /// tCWL: write column command to data, user cycles (timed backend).
    pub t_cwl: u64,
    /// tRAS: minimum ACT-to-PRE interval, user cycles (timed backend).
    pub t_ras: u64,
    /// tCCD: column-to-column spacing on one bank, user cycles (timed
    /// backend; the lumped backend hardcodes the same 4-cycle value for
    /// back-to-back row hits).
    pub t_ccd: u64,
    /// tWTR: write-to-read bus turnaround, user cycles (timed backend).
    pub t_wtr: u64,
    /// tRTW: read-to-write bus turnaround, user cycles (timed backend).
    pub t_rtw: u64,
    /// Periodic refresh on/off (timed backend; lumped never refreshes).
    pub refresh: bool,
    /// tREFI: refresh command interval, user cycles (timed backend).
    pub t_refi: u64,
    /// tRFC: refresh cycle time stolen from every bank, user cycles
    /// (timed backend).
    pub t_rfc: u64,
}

impl DramConfig {
    pub fn beat_bytes(&self) -> u64 {
        (self.data_bits / 8) as u64
    }

    pub fn validate(&self) -> Result<(), String> {
        if !is_pow2(self.banks as u64) || !is_pow2(self.row_bytes) {
            return Err("dram: banks and row_bytes must be powers of two".into());
        }
        if self.data_bits % 8 != 0 {
            return Err("dram: data_bits must be byte aligned".into());
        }
        if self.max_outstanding == 0 {
            return Err("dram: max_outstanding must be > 0".into());
        }
        if self.bus_admission_factor == 0 {
            return Err("dram: bus_admission_factor must be > 0".into());
        }
        if self.t_ccd == 0 {
            return Err("dram: t_ccd must be > 0".into());
        }
        if self.t_ras < self.t_rcd + self.t_cas {
            // A row must stay open at least long enough to activate and
            // read it — anything shorter is a nonsense DDR4 schedule.
            return Err(format!(
                "dram: t_ras {} < t_rcd {} + t_cas {}",
                self.t_ras, self.t_rcd, self.t_cas
            ));
        }
        if self.refresh {
            if self.t_refi == 0 {
                return Err("dram: refresh enabled but t_refi is 0".into());
            }
            if self.t_rfc >= self.t_refi {
                // Refresh must leave some interval for real work or the
                // channel spends 100% of its time refreshing.
                return Err(format!(
                    "dram: t_rfc {} must be < t_refi {}",
                    self.t_rfc, self.t_refi
                ));
            }
        }
        Ok(())
    }
}

/// PE / workload front-end parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeConfig {
    /// Number of processing elements.
    pub n_pes: usize,
    /// Compute fabric type (decides the trace shape + LMB attachment).
    pub fabric: FabricType,
    /// Rank R — elements per factor-matrix fiber (paper evaluation: 32).
    pub rank: usize,
    /// Cycles the PE spends computing per nonzero once operands arrive
    /// (vectorized across rank lanes; memory time dominates at 1–2).
    pub compute_cycles_per_nnz: u64,
    /// Outstanding nonzeros a PE may have in flight (decoupling depth).
    pub max_inflight: usize,
}

impl PeConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.n_pes == 0 || self.rank == 0 || self.max_inflight == 0 {
            return Err("pe: n_pes, rank, max_inflight must be > 0".into());
        }
        Ok(())
    }
}

/// Full memory-system configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SystemConfig {
    pub kind: SystemKind,
    /// Number of LMBs (A: 1, B: 4). PEs are distributed round-robin.
    pub n_lmbs: usize,
    /// Cache + Request-Reductor banks inside each LMB (power of two;
    /// 1 = the paper's single shared bank). Banks are selected by the
    /// same `ChannelMap` interleaving the DRAM side uses, so with
    /// `lmb_banks == interconnect.channels` bank *b* fronts exactly
    /// channel *b*. Cache lines, MSHR entries and RRSH entries are
    /// *sharded* across banks (total capacity constant); the CAM temp
    /// buffer and the MSHR secondary cap stay per-bank (they are width,
    /// not capacity).
    pub lmb_banks: usize,
    pub cache: CacheConfig,
    pub dma: DmaConfig,
    pub rr: RrConfig,
    pub dram: DramConfig,
    pub interconnect: InterconnectConfig,
    pub pe: PeConfig,
    /// Multi-accelerator scale-out (defaults to one node — no cluster
    /// layer; see [`ClusterConfig`]).
    pub cluster: ClusterConfig,
    /// Observability products (off by default — see [`TelemetryConfig`]).
    pub telemetry: TelemetryConfig,
    /// In-run engine worker threads (`--sim-threads`). 1 = the
    /// single-thread event engine; >1 shards DRAM-channel ticking and
    /// PE window fill/retire across `std::thread::scope` workers with a
    /// per-visited-cycle barrier. Host-side only: the report is
    /// bit-identical at every thread count. Distinct from the sweep
    /// runner's `--threads` (a pool of whole runs).
    pub sim_threads: usize,
    /// Human label ("config-a", "config-b", ...).
    pub label: String,
}

/// Parse an `on|off`-style boolean override value.
fn parse_on_off(key: &str, value: &str) -> Result<bool, String> {
    match value {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => Err(format!("{key} {other:?}: expected on|off")),
    }
}

impl SystemConfig {
    /// Paper Configuration-A: one large LMB for Type-1 fabrics.
    /// Cache: 2-way, 8192 lines, 512-bit lines. DMA: 4 × 256 B. RRSH 4096.
    pub fn config_a() -> SystemConfig {
        SystemConfig {
            kind: SystemKind::Proposed,
            n_lmbs: 1,
            lmb_banks: 1,
            cache: CacheConfig {
                associativity: 2,
                lines: 8192,
                line_bits: 512,
                pipeline_stages: 3,
                mshr_entries: 8,
                mshr_secondary_cap: 1,
            },
            dma: DmaConfig {
                n_buffers: 4,
                buffer_bytes: 256,
            },
            rr: RrConfig {
                rrsh_entries: 4096,
                temp_buffer_entries: 8,
                pipeline_stages: 2,
            },
            dram: DramConfig::mig_u250(),
            interconnect: InterconnectConfig::single_channel(),
            cluster: ClusterConfig::single_node(),
            telemetry: TelemetryConfig::off(),
            sim_threads: 1,
            pe: PeConfig {
                n_pes: 4,
                fabric: FabricType::Type1,
                rank: 32,
                compute_cycles_per_nnz: 1,
                max_inflight: 8,
            },
            label: "config-a".into(),
        }
    }

    /// Paper Configuration-B: 4 small LMBs (direct-mapped 4096-line caches),
    /// one per Type-2 PE.
    pub fn config_b() -> SystemConfig {
        let mut c = SystemConfig::config_a();
        c.n_lmbs = 4;
        c.cache.associativity = 1;
        c.cache.lines = 4096;
        c.pe.fabric = FabricType::Type2;
        c.label = "config-b".into();
        c
    }

    /// A baseline variant derived from this config (same DRAM + PEs).
    pub fn as_baseline(&self, kind: SystemKind) -> SystemConfig {
        let mut c = self.clone();
        c.kind = kind;
        c.label = format!("{}-{}", self.label, kind.name());
        c
    }

    /// Per-LMB PE count (PEs are distributed round-robin over LMBs).
    pub fn pes_per_lmb(&self) -> usize {
        crate::util::ceil_div(self.pe.n_pes as u64, self.n_lmbs as u64) as usize
    }

    /// Cache geometry of ONE LMB bank: the configured lines — and the
    /// MSHR's primary-miss entries — are sharded over `lmb_banks`
    /// (total capacity constant, so banked comparisons never get free
    /// extra miss-handling hardware). `mshr_secondary_cap` stays
    /// per-entry width, like the RR's CAM. With one bank this is
    /// exactly `self.cache`.
    pub fn bank_cache(&self) -> CacheConfig {
        // Exact division (validated): no silent round-up, and banks=1
        // reproduces `self.cache` bit-for-bit.
        CacheConfig {
            lines: self.cache.lines / self.lmb_banks.max(1),
            mshr_entries: self.cache.mshr_entries / self.lmb_banks.max(1),
            ..self.cache.clone()
        }
    }

    /// Request-Reductor geometry of ONE LMB bank: RRSH entries are
    /// sharded over `lmb_banks`; the CAM temp buffer stays per-bank.
    /// With one bank this is exactly `self.rr`.
    pub fn bank_rr(&self) -> RrConfig {
        RrConfig {
            rrsh_entries: (self.rr.rrsh_entries / self.lmb_banks.max(1)).max(1),
            ..self.rr.clone()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_lmbs == 0 {
            return Err("system: n_lmbs must be > 0".into());
        }
        if self.n_lmbs > self.pe.n_pes {
            return Err(format!(
                "system: n_lmbs {} > n_pes {}",
                self.n_lmbs, self.pe.n_pes
            ));
        }
        if self.lmb_banks == 0 || !is_pow2(self.lmb_banks as u64) {
            return Err(format!(
                "system: lmb_banks {} must be a power of two",
                self.lmb_banks
            ));
        }
        if self.cache.lines % self.lmb_banks != 0 {
            return Err(format!(
                "system: cache.lines {} not divisible by lmb_banks {}",
                self.cache.lines, self.lmb_banks
            ));
        }
        if self.cache.mshr_entries % self.lmb_banks != 0 {
            // Sharding must not round up: a non-divisible MSHR file
            // would silently grant banked configs extra miss-handling
            // hardware and bias banked-vs-monolithic comparisons.
            return Err(format!(
                "system: cache.mshr_entries {} not divisible by lmb_banks {}",
                self.cache.mshr_entries, self.lmb_banks
            ));
        }
        if self.rr.rrsh_entries < 2 * self.lmb_banks {
            // Each bank's sharded RRSH is a hash table needing >= 2
            // entries — catch it here as a config error, not a panic
            // deep inside system construction.
            return Err(format!(
                "system: rr.rrsh_entries {} must be >= 2 x lmb_banks {}",
                self.rr.rrsh_entries, self.lmb_banks
            ));
        }
        if self.lmb_banks > 1 && self.interconnect.interleave_bytes < self.cache.line_bytes() {
            return Err(format!(
                "system: interleave_bytes {} < cache line {} B — a line \
                 would span LMB banks",
                self.interconnect.interleave_bytes,
                self.cache.line_bytes()
            ));
        }
        self.cache.validate().map_err(|e| format!("{}: {e}", self.label))?;
        // The sharded per-bank geometry must itself be a valid cache
        // (associativity divides the per-bank lines, sets stay pow2).
        self.bank_cache()
            .validate()
            .map_err(|e| format!("{}: per-bank {e}", self.label))?;
        self.dma.validate().map_err(|e| format!("{}: {e}", self.label))?;
        self.rr.validate().map_err(|e| format!("{}: {e}", self.label))?;
        self.dram.validate().map_err(|e| format!("{}: {e}", self.label))?;
        self.interconnect.validate().map_err(|e| format!("{}: {e}", self.label))?;
        self.pe.validate().map_err(|e| format!("{}: {e}", self.label))?;
        self.cluster.validate().map_err(|e| format!("{}: {e}", self.label))?;
        if self.cluster.nodes > 1 && self.pe.fabric != FabricType::Type2 {
            // Node sharding reuses the Type-2 per-PE partitioning rule;
            // the Type-1 systolic stream has a single point of access
            // and cannot be split across accelerators.
            return Err(format!(
                "{}: cluster.nodes {} needs a type2 fabric (type1 has a \
                 single access stream)",
                self.label, self.cluster.nodes
            ));
        }
        self.telemetry.validate().map_err(|e| format!("{}: {e}", self.label))?;
        if self.sim_threads == 0 {
            return Err(format!(
                "{}: sim_threads must be >= 1 (1 = single-thread engine)",
                self.label
            ));
        }
        Ok(())
    }

    /// Apply `--section.key value`-style overrides (from CLI or file).
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_usize =
            |v: &str| v.parse::<usize>().map_err(|e| format!("{key}={v}: {e}"));
        let parse_u64 = |v: &str| v.parse::<u64>().map_err(|e| format!("{key}={v}: {e}"));
        // Interconnect + LMB shorthands (`--channels 4`, `--lmb-banks 4`,
        // `--reply-network on` on the CLI).
        let key = match key {
            "channels" => "interconnect.channels",
            "topology" => "interconnect.topology",
            "link_width" | "link-width" => "interconnect.link_width",
            "reply_network" | "reply-network" => "interconnect.reply_network",
            "lmb_banks" | "lmb-banks" => "system.lmb_banks",
            "nodes" => "cluster.nodes",
            "inter_topology" | "inter-topology" => "cluster.topology",
            "sim_threads" | "sim-threads" => "system.sim_threads",
            "dram_model" | "dram-model" => "dram.model",
            other => other,
        };
        match key {
            "system.kind" => {
                self.kind = value.parse::<SystemKind>().map_err(|e| e.to_string())?
            }
            "system.n_lmbs" => self.n_lmbs = parse_usize(value)?,
            "system.lmb_banks" => self.lmb_banks = parse_usize(value)?,
            "system.sim_threads" => self.sim_threads = parse_usize(value)?,
            "cache.associativity" => self.cache.associativity = parse_usize(value)?,
            "cache.lines" => self.cache.lines = parse_usize(value)?,
            "cache.line_bits" => self.cache.line_bits = parse_usize(value)?,
            "cache.mshr_entries" => self.cache.mshr_entries = parse_usize(value)?,
            "cache.mshr_secondary_cap" => self.cache.mshr_secondary_cap = parse_usize(value)?,
            "dma.n_buffers" => self.dma.n_buffers = parse_usize(value)?,
            "dma.buffer_bytes" => self.dma.buffer_bytes = parse_u64(value)?,
            "rr.rrsh_entries" => self.rr.rrsh_entries = parse_usize(value)?,
            "rr.temp_buffer_entries" => self.rr.temp_buffer_entries = parse_usize(value)?,
            "pe.n_pes" => self.pe.n_pes = parse_usize(value)?,
            "pe.rank" => self.pe.rank = parse_usize(value)?,
            "pe.fabric" => {
                self.pe.fabric = value.parse::<FabricType>().map_err(|e| e.to_string())?
            }
            "pe.compute_cycles_per_nnz" => self.pe.compute_cycles_per_nnz = parse_u64(value)?,
            "pe.max_inflight" => self.pe.max_inflight = parse_usize(value)?,
            "interconnect.channels" => self.interconnect.channels = parse_usize(value)?,
            "interconnect.topology" => {
                self.interconnect.topology =
                    value.parse::<TopologyKind>().map_err(|e| e.to_string())?
            }
            "interconnect.link_width" => self.interconnect.link_width = parse_usize(value)?,
            "interconnect.link_queue" => self.interconnect.link_queue = parse_usize(value)?,
            "interconnect.interleave_bytes" => {
                self.interconnect.interleave_bytes = parse_u64(value)?
            }
            "interconnect.reply_network" => {
                self.interconnect.reply_network = match value {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => return Err(format!("reply_network {other:?}: expected on|off")),
                }
            }
            "dram.model" => {
                self.dram.model = value.parse::<DramModelKind>().map_err(|e| e.to_string())?
            }
            "dram.t_row_hit" => self.dram.t_row_hit = parse_u64(value)?,
            "dram.t_row_miss" => self.dram.t_row_miss = parse_u64(value)?,
            "dram.t_precharge" => self.dram.t_precharge = parse_u64(value)?,
            "dram.t_controller" => self.dram.t_controller = parse_u64(value)?,
            "dram.max_outstanding" => self.dram.max_outstanding = parse_usize(value)?,
            "dram.banks" => self.dram.banks = parse_usize(value)?,
            "dram.bus_admission_factor" => {
                self.dram.bus_admission_factor = parse_u64(value)?
            }
            "dram.t_rcd" => self.dram.t_rcd = parse_u64(value)?,
            "dram.t_rp" => self.dram.t_rp = parse_u64(value)?,
            "dram.t_cas" => self.dram.t_cas = parse_u64(value)?,
            "dram.t_cwl" => self.dram.t_cwl = parse_u64(value)?,
            "dram.t_ras" => self.dram.t_ras = parse_u64(value)?,
            "dram.t_ccd" => self.dram.t_ccd = parse_u64(value)?,
            "dram.t_wtr" => self.dram.t_wtr = parse_u64(value)?,
            "dram.t_rtw" => self.dram.t_rtw = parse_u64(value)?,
            "dram.refresh" => self.dram.refresh = parse_on_off(key, value)?,
            "dram.t_refi" => self.dram.t_refi = parse_u64(value)?,
            "dram.t_rfc" => self.dram.t_rfc = parse_u64(value)?,
            "cluster.nodes" => self.cluster.nodes = parse_usize(value)?,
            "cluster.topology" => {
                self.cluster.topology =
                    value.parse::<InterTopologyKind>().map_err(|e| e.to_string())?
            }
            "cluster.link_bytes" => self.cluster.link_bytes = parse_u64(value)?,
            "cluster.link_latency" => self.cluster.link_latency = parse_u64(value)?,
            "cluster.link_queue" => self.cluster.link_queue = parse_usize(value)?,
            "telemetry.trace" => self.telemetry.trace = parse_on_off(key, value)?,
            "telemetry.timeline" => self.telemetry.timeline = parse_on_off(key, value)?,
            "telemetry.sample" => self.telemetry.sample = parse_u64(value)?,
            "telemetry.window" => self.telemetry.window = parse_u64(value)?,
            _ => return Err(format!("unknown config key {key:?}")),
        }
        Ok(())
    }

    /// Load a preset by name, then apply `key = value` overrides from `src`.
    pub fn from_kv(preset: &str, src: &str) -> Result<SystemConfig, String> {
        let mut cfg = match preset {
            "config-a" | "a" => SystemConfig::config_a(),
            "config-b" | "b" => SystemConfig::config_b(),
            other => return Err(format!("unknown preset {other:?}")),
        };
        for (k, v) in parse_kv_str(src)? {
            cfg.apply_override(&k, &v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// JSON dump for experiment records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("kind", Json::str(self.kind.name())),
            ("n_lmbs", Json::num(self.n_lmbs as f64)),
            ("lmb_banks", Json::num(self.lmb_banks as f64)),
            ("sim_threads", Json::num(self.sim_threads as f64)),
            (
                "cache",
                Json::obj(vec![
                    ("associativity", Json::num(self.cache.associativity as f64)),
                    ("lines", Json::num(self.cache.lines as f64)),
                    ("line_bits", Json::num(self.cache.line_bits as f64)),
                    ("mshr_entries", Json::num(self.cache.mshr_entries as f64)),
                ]),
            ),
            (
                "dma",
                Json::obj(vec![
                    ("n_buffers", Json::num(self.dma.n_buffers as f64)),
                    ("buffer_bytes", Json::num(self.dma.buffer_bytes as f64)),
                ]),
            ),
            (
                "rr",
                Json::obj(vec![
                    ("rrsh_entries", Json::num(self.rr.rrsh_entries as f64)),
                    (
                        "temp_buffer_entries",
                        Json::num(self.rr.temp_buffer_entries as f64),
                    ),
                ]),
            ),
            (
                "dram",
                Json::obj(vec![
                    ("model", Json::str(self.dram.model.name())),
                    ("banks", Json::num(self.dram.banks as f64)),
                    ("t_row_hit", Json::num(self.dram.t_row_hit as f64)),
                    ("t_row_miss", Json::num(self.dram.t_row_miss as f64)),
                    ("t_precharge", Json::num(self.dram.t_precharge as f64)),
                    ("t_rcd", Json::num(self.dram.t_rcd as f64)),
                    ("t_rp", Json::num(self.dram.t_rp as f64)),
                    ("t_cas", Json::num(self.dram.t_cas as f64)),
                    ("t_cwl", Json::num(self.dram.t_cwl as f64)),
                    ("t_ras", Json::num(self.dram.t_ras as f64)),
                    ("t_ccd", Json::num(self.dram.t_ccd as f64)),
                    ("t_wtr", Json::num(self.dram.t_wtr as f64)),
                    ("t_rtw", Json::num(self.dram.t_rtw as f64)),
                    ("refresh", Json::Bool(self.dram.refresh)),
                    ("t_refi", Json::num(self.dram.t_refi as f64)),
                    ("t_rfc", Json::num(self.dram.t_rfc as f64)),
                ]),
            ),
            (
                "interconnect",
                Json::obj(vec![
                    ("channels", Json::num(self.interconnect.channels as f64)),
                    ("topology", Json::str(self.interconnect.topology.name())),
                    ("link_width", Json::num(self.interconnect.link_width as f64)),
                    ("link_queue", Json::num(self.interconnect.link_queue as f64)),
                    ("interleave_bytes", Json::num(self.interconnect.interleave_bytes as f64)),
                    ("reply_network", Json::Bool(self.interconnect.reply_network)),
                ]),
            ),
            (
                "pe",
                Json::obj(vec![
                    ("n_pes", Json::num(self.pe.n_pes as f64)),
                    ("fabric", Json::str(self.pe.fabric.name())),
                    ("rank", Json::num(self.pe.rank as f64)),
                ]),
            ),
            (
                "cluster",
                Json::obj(vec![
                    ("nodes", Json::num(self.cluster.nodes as f64)),
                    ("topology", Json::str(self.cluster.topology.name())),
                    ("link_bytes", Json::num(self.cluster.link_bytes as f64)),
                    ("link_latency", Json::num(self.cluster.link_latency as f64)),
                    ("link_queue", Json::num(self.cluster.link_queue as f64)),
                ]),
            ),
            (
                "telemetry",
                Json::obj(vec![
                    ("trace", Json::Bool(self.telemetry.trace)),
                    ("timeline", Json::Bool(self.telemetry.timeline)),
                    ("sample", Json::num(self.telemetry.sample as f64)),
                    ("window", Json::num(self.telemetry.window as f64)),
                ]),
            ),
        ])
    }
}

impl DramConfig {
    /// Xilinx MIG-like DDR4 channel on Alveo U250 (see DESIGN.md §6).
    ///
    /// The command-level parameters are DDR4-2400-class values expressed
    /// in 300 MHz user-clock cycles, calibrated against the lumped
    /// latencies: a hit costs `t_cas` (28 = `t_row_hit`), an empty-bank
    /// activate `t_rcd + t_cas` (52 = `t_row_miss`), a conflict
    /// `t_rp + t_rcd + t_cas` (64 = `t_row_miss + t_precharge`).
    /// `t_cwl` is kept equal to `t_cas` (the folded user-clock write
    /// path) so timed never undercuts lumped; `t_refi`/`t_rfc` are
    /// 7.8 µs / 350 ns at 300 MHz.
    pub fn mig_u250() -> DramConfig {
        DramConfig {
            model: DramModelKind::Lumped,
            data_bits: 512,
            banks: 16,
            row_bytes: 8192,
            t_row_hit: 28,
            t_row_miss: 52,
            t_precharge: 12,
            t_controller: 8,
            max_outstanding: 32,
            addr_bits: 31,
            bus_admission_factor: 4,
            t_rcd: 24,
            t_rp: 12,
            t_cas: 28,
            t_cwl: 28,
            t_ras: 56,
            t_ccd: 4,
            t_wtr: 8,
            t_rtw: 6,
            refresh: true,
            t_refi: 2340,
            t_rfc: 105,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table2() {
        let a = SystemConfig::config_a();
        assert_eq!(a.n_lmbs, 1);
        assert_eq!(a.cache.associativity, 2);
        assert_eq!(a.cache.lines, 8192);
        assert_eq!(a.cache.line_bits, 512);
        assert_eq!(a.dma.n_buffers, 4);
        assert_eq!(a.dma.buffer_bytes, 256);
        assert_eq!(a.rr.rrsh_entries, 4096);
        assert_eq!(a.rr.temp_buffer_entries, 8);
        a.validate().unwrap();

        let b = SystemConfig::config_b();
        assert_eq!(b.n_lmbs, 4);
        assert_eq!(b.cache.associativity, 1);
        assert_eq!(b.cache.lines, 4096);
        assert_eq!(b.pe.fabric, FabricType::Type2);
        b.validate().unwrap();
    }

    #[test]
    fn rrsh_sizing_rule_holds_for_presets() {
        // §IV-C1: RRSH entries ∝ cache lines / associativity.
        let a = SystemConfig::config_a();
        assert_eq!(a.rr.rrsh_entries, a.cache.lines / a.cache.associativity / 1);
        let b = SystemConfig::config_b();
        assert_eq!(b.rr.rrsh_entries, b.cache.lines / b.cache.associativity);
    }

    #[test]
    fn overrides_and_validation() {
        let mut c = SystemConfig::config_a();
        c.apply_override("cache.lines", "2048").unwrap();
        c.apply_override("dma.n_buffers", "8").unwrap();
        c.apply_override("pe.fabric", "type2").unwrap();
        assert_eq!(c.cache.lines, 2048);
        assert_eq!(c.dma.n_buffers, 8);
        assert_eq!(c.pe.fabric, FabricType::Type2);
        assert_eq!(c.dram.bus_admission_factor, 4, "mig_u250 default");
        c.apply_override("dram.bus_admission_factor", "6").unwrap();
        assert_eq!(c.dram.bus_admission_factor, 6);
        c.dram.bus_admission_factor = 0;
        assert!(c.validate().is_err(), "factor 0 would stall the bus forever");
        c.dram.bus_admission_factor = 4;
        assert!(c.apply_override("bogus.key", "1").is_err());
        assert!(c.apply_override("cache.lines", "not-a-number").is_err());

        c.cache.lines = 3000; // 1500 sets, not a power of two
        assert!(c.validate().is_err());
    }

    #[test]
    fn dram_model_overrides_and_aliases() {
        let mut c = SystemConfig::config_b();
        assert_eq!(c.dram.model, DramModelKind::Lumped, "lumped is the default");
        // Kebab-case is the documented CLI spelling; snake_case and the
        // full dotted key stay as compatibility aliases.
        c.apply_override("dram-model", "timed").unwrap();
        assert_eq!(c.dram.model, DramModelKind::Timed);
        c.apply_override("dram_model", "lumped").unwrap();
        assert_eq!(c.dram.model, DramModelKind::Lumped);
        c.apply_override("dram.model", "timed").unwrap();
        assert_eq!(c.dram.model, DramModelKind::Timed);
        assert!(c.apply_override("dram.model", "dramsim3").is_err());

        // Every command-timing knob round-trips through overrides.
        for (key, get) in [
            ("dram.t_rcd", (|d: &DramConfig| d.t_rcd) as fn(&DramConfig) -> u64),
            ("dram.t_rp", |d| d.t_rp),
            ("dram.t_cas", |d| d.t_cas),
            ("dram.t_cwl", |d| d.t_cwl),
            ("dram.t_ras", |d| d.t_ras),
            ("dram.t_ccd", |d| d.t_ccd),
            ("dram.t_wtr", |d| d.t_wtr),
            ("dram.t_rtw", |d| d.t_rtw),
            ("dram.t_refi", |d| d.t_refi),
            ("dram.t_rfc", |d| d.t_rfc),
            ("dram.t_precharge", |d| d.t_precharge),
        ] {
            c.apply_override(key, "77").unwrap();
            assert_eq!(get(&c.dram), 77, "{key}");
            assert!(c.apply_override(key, "many").is_err(), "{key}");
        }
        c.apply_override("dram.refresh", "off").unwrap();
        assert!(!c.dram.refresh);
        c.apply_override("dram.refresh", "on").unwrap();
        assert!(c.dram.refresh);
        assert!(c.apply_override("dram.refresh", "sometimes").is_err());
    }

    #[test]
    fn dram_timing_validation_rejects_nonsense_combinations() {
        let mut c = SystemConfig::config_a();
        c.validate().unwrap();

        // tRAS must cover activate + read.
        c.dram.t_ras = c.dram.t_rcd + c.dram.t_cas - 1;
        let err = c.validate().unwrap_err();
        assert!(err.contains("t_ras"), "got: {err}");
        c.dram.t_ras = c.dram.t_rcd + c.dram.t_cas;
        c.validate().unwrap();

        // Refresh enabled needs a positive interval longer than tRFC.
        c.dram.refresh = true;
        c.dram.t_refi = 0;
        let err = c.validate().unwrap_err();
        assert!(err.contains("t_refi"), "got: {err}");
        c.dram.t_refi = 100;
        c.dram.t_rfc = 100;
        let err = c.validate().unwrap_err();
        assert!(err.contains("t_rfc"), "got: {err}");
        c.dram.t_rfc = 99;
        c.validate().unwrap();
        // With refresh off the interval fields are dormant — any value
        // passes (the degenerate-equivalence configs rely on this).
        c.dram.refresh = false;
        c.dram.t_refi = 0;
        c.validate().unwrap();

        // Zero column spacing would let one bank book the bus forever.
        c.dram.t_ccd = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn dram_json_echoes_model_and_timing_fields() {
        let mut c = SystemConfig::config_b();
        c.apply_override("dram-model", "timed").unwrap();
        let j = c.to_json();
        let d = j.get("dram").expect("config JSON must carry a dram object");
        assert_eq!(d.get("model").unwrap().as_str(), Some("timed"));
        for key in [
            "banks", "t_row_hit", "t_row_miss", "t_precharge", "t_rcd", "t_rp", "t_cas",
            "t_cwl", "t_ras", "t_ccd", "t_wtr", "t_rtw", "t_refi", "t_rfc",
        ] {
            assert!(d.get(key).unwrap().as_f64().is_some(), "dram.{key}");
        }
        assert!(matches!(d.get("refresh"), Some(Json::Bool(true))));
    }

    #[test]
    fn baseline_derivation() {
        let a = SystemConfig::config_a();
        let c = a.as_baseline(SystemKind::CacheOnly);
        assert_eq!(c.kind, SystemKind::CacheOnly);
        assert_eq!(c.cache, a.cache);
        assert!(c.label.contains("cache-only"));
    }

    #[test]
    fn from_kv_parses_preset_plus_overrides() {
        let cfg = SystemConfig::from_kv(
            "config-b",
            "# comment\ncache.lines = 1024\npe.rank=16\n",
        )
        .unwrap();
        assert_eq!(cfg.cache.lines, 1024);
        assert_eq!(cfg.pe.rank, 16);
        assert!(SystemConfig::from_kv("nope", "").is_err());
    }

    #[test]
    fn interconnect_defaults_reproduce_seed_single_channel() {
        let a = SystemConfig::config_a();
        assert_eq!(a.interconnect.channels, 1);
        assert_eq!(a.interconnect.topology, TopologyKind::Crossbar);
        let b = SystemConfig::config_b();
        assert_eq!(b.interconnect, InterconnectConfig::single_channel());
    }

    #[test]
    fn interconnect_overrides_and_aliases() {
        let mut c = SystemConfig::config_b();
        c.apply_override("interconnect.channels", "4").unwrap();
        c.apply_override("topology", "ring").unwrap();
        c.apply_override("link_width", "2").unwrap();
        c.apply_override("interconnect.interleave_bytes", "8192").unwrap();
        assert_eq!(c.interconnect.channels, 4);
        assert_eq!(c.interconnect.topology, TopologyKind::Ring);
        assert_eq!(c.interconnect.link_width, 2);
        assert_eq!(c.interconnect.interleave_bytes, 8192);
        // Kebab-case spelling is the documented form; snake_case stays
        // as a compatibility alias.
        c.apply_override("link-width", "4").unwrap();
        assert_eq!(c.interconnect.link_width, 4);
        c.apply_override("link_width", "2").unwrap();
        assert_eq!(c.interconnect.link_width, 2);
        c.validate().unwrap();
        assert!(c.apply_override("topology", "torus").is_err());

        c.interconnect.channels = 3;
        assert!(c.validate().is_err());
        c.interconnect.channels = 2;
        c.interconnect.interleave_bytes = 1000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn sim_threads_default_aliases_and_validation() {
        let a = SystemConfig::config_a();
        assert_eq!(a.sim_threads, 1, "single-thread engine by default");
        let mut c = SystemConfig::config_b();
        // Kebab-case is the documented CLI spelling; snake_case stays as
        // a compatibility alias (same policy as link-width).
        c.apply_override("sim-threads", "4").unwrap();
        assert_eq!(c.sim_threads, 4);
        c.apply_override("sim_threads", "2").unwrap();
        assert_eq!(c.sim_threads, 2);
        c.apply_override("system.sim_threads", "8").unwrap();
        assert_eq!(c.sim_threads, 8);
        c.validate().unwrap();
        c.sim_threads = 0;
        let err = c.validate().unwrap_err();
        assert!(
            err.contains("sim_threads must be >= 1"),
            "uniform validation message, got: {err}"
        );
    }

    #[test]
    fn lmb_bank_defaults_and_sharding() {
        // Default: one shared bank — the paper's LMB, bit-identical to
        // the pre-bank system.
        let a = SystemConfig::config_a();
        assert_eq!(a.lmb_banks, 1);
        assert_eq!(a.bank_cache(), a.cache);
        assert_eq!(a.bank_rr(), a.rr);
        assert!(!a.interconnect.reply_network);

        // Banks shard cache lines + RRSH entries; the CAM stays as-is.
        let mut b = SystemConfig::config_b();
        b.apply_override("lmb_banks", "4").unwrap();
        assert_eq!(b.lmb_banks, 4);
        b.validate().unwrap();
        assert_eq!(b.bank_cache().lines, 1024);
        assert_eq!(b.bank_cache().associativity, b.cache.associativity);
        assert_eq!(b.bank_cache().mshr_entries, 2, "MSHR entries shard too");
        assert_eq!(b.bank_cache().mshr_secondary_cap, b.cache.mshr_secondary_cap);
        assert_eq!(b.bank_rr().rrsh_entries, 1024);
        assert_eq!(b.bank_rr().temp_buffer_entries, b.rr.temp_buffer_entries);
    }

    #[test]
    fn lmb_bank_validation() {
        let mut c = SystemConfig::config_b();
        c.lmb_banks = 3;
        assert!(c.validate().is_err(), "banks must be a power of two");
        c.lmb_banks = 0;
        assert!(c.validate().is_err());
        c.lmb_banks = 2;
        c.cache.lines = 4098; // not divisible by banks
        assert!(c.validate().is_err());
        c.cache.lines = 4096;
        c.validate().unwrap();
        // A cache line must never span banks.
        c.interconnect.interleave_bytes = 32;
        assert!(c.validate().is_err());
        c.interconnect.interleave_bytes = 64;
        c.validate().unwrap();
        // Each bank's sharded RRSH must hold at least 2 entries.
        c.rr.rrsh_entries = 2;
        assert!(c.validate().is_err(), "2 entries over 2 banks is too small");
        c.rr.rrsh_entries = 4;
        c.validate().unwrap();
        // The MSHR file must shard evenly too — no silent round-up.
        c.cache.mshr_entries = 3;
        assert!(c.validate().is_err(), "3 MSHR entries cannot shard over 2 banks");
        c.cache.mshr_entries = 4;
        c.validate().unwrap();
        assert_eq!(c.bank_cache().mshr_entries, 2);
    }

    #[test]
    fn reply_network_override_round_trips() {
        let mut c = SystemConfig::config_b();
        for (v, want) in [("on", true), ("off", false), ("true", true), ("0", false)] {
            c.apply_override("reply-network", v).unwrap();
            assert_eq!(c.interconnect.reply_network, want, "{v}");
        }
        c.apply_override("interconnect.reply_network", "1").unwrap();
        assert!(c.interconnect.reply_network);
        assert!(c.apply_override("reply_network", "maybe").is_err());
        let j = c.to_json();
        assert_eq!(
            j.get("interconnect").unwrap().get("reply_network").unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(j.get("lmb_banks").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn topology_names_round_trip() {
        for t in TopologyKind::ALL {
            assert_eq!(t.name().parse(), Ok(t));
        }
        assert_eq!("xbar".parse(), Ok(TopologyKind::Crossbar));
        assert!("mesh".parse::<TopologyKind>().is_err());
        // The inter-node layer is where mesh lives.
        for t in InterTopologyKind::ALL {
            assert_eq!(t.name().parse(), Ok(t));
        }
        assert_eq!("mesh".parse(), Ok(InterTopologyKind::Mesh));
        assert!("torus".parse::<InterTopologyKind>().is_err());
    }

    #[test]
    fn cluster_defaults_single_node_and_overrides_round_trip() {
        // Default: one node — the literal single-accelerator code path.
        let c = SystemConfig::config_b();
        assert_eq!(c.cluster, ClusterConfig::single_node());
        assert_eq!(c.cluster.nodes, 1);
        c.validate().unwrap();

        let mut c = SystemConfig::config_b();
        c.apply_override("nodes", "4").unwrap();
        c.apply_override("inter-topology", "mesh").unwrap();
        c.apply_override("cluster.link_bytes", "32").unwrap();
        c.apply_override("cluster.link_latency", "12").unwrap();
        c.apply_override("cluster.link_queue", "8").unwrap();
        assert_eq!(c.cluster.nodes, 4);
        assert_eq!(c.cluster.topology, InterTopologyKind::Mesh);
        assert_eq!(c.cluster.link_bytes, 32);
        assert_eq!(c.cluster.link_latency, 12);
        assert_eq!(c.cluster.link_queue, 8);
        c.validate().unwrap();
        // Snake_case alias, like the other shorthands.
        c.apply_override("inter_topology", "line").unwrap();
        assert_eq!(c.cluster.topology, InterTopologyKind::Line);
        assert!(c.apply_override("inter-topology", "torus").is_err());

        let j = c.to_json();
        let cl = j.get("cluster").unwrap();
        assert_eq!(cl.get("nodes").unwrap().as_usize(), Some(4));
        assert_eq!(cl.get("topology").unwrap().as_str(), Some("line"));
        assert_eq!(cl.get("link_bytes").unwrap().as_usize(), Some(32));
    }

    #[test]
    fn cluster_validation() {
        let mut c = SystemConfig::config_b();
        c.cluster.nodes = 0;
        assert!(c.validate().is_err());
        c.cluster.nodes = 3; // any count >= 1 is fine, not only powers of two
        c.validate().unwrap();
        c.cluster.link_bytes = 0;
        assert!(c.validate().is_err());
        c.cluster.link_bytes = 16;
        c.cluster.link_latency = 0;
        assert!(c.validate().is_err());
        c.cluster.link_latency = 1;
        c.cluster.link_queue = 0;
        assert!(c.validate().is_err());
        // Depth 1 leaves no bubble for transit traffic — also rejected.
        c.cluster.link_queue = 1;
        assert!(c.validate().is_err());
        c.cluster.link_queue = 4;
        c.validate().unwrap();
        // Multi-node sharding needs the Type-2 per-PE partition rule.
        let mut a = SystemConfig::config_a();
        a.cluster.nodes = 2;
        let err = a.validate().unwrap_err();
        assert!(err.contains("type2"), "{err}");
        a.pe.fabric = FabricType::Type2;
        a.validate().unwrap();
    }

    #[test]
    fn name_parsing_round_trips_and_reports_valid_values() {
        for k in SystemKind::ALL {
            assert_eq!(k.name().parse(), Ok(k));
        }
        assert_eq!("lmb".parse(), Ok(SystemKind::Proposed));
        assert_eq!("1".parse(), Ok(FabricType::Type1));
        assert_eq!("type2".parse(), Ok(FabricType::Type2));

        let err = "bogus".parse::<SystemKind>().unwrap_err();
        assert_eq!(
            err.to_string(),
            "unknown system \"bogus\" (expected ip-only|cache-only|dma-only|proposed)"
        );
        let err = "3".parse::<FabricType>().unwrap_err();
        assert!(err.to_string().contains("type1|type2"), "{err}");

        // The deprecated wrappers stay behaviour-compatible.
        #[allow(deprecated)]
        {
            assert_eq!(SystemKind::from_name("dma"), Some(SystemKind::DmaOnly));
            assert_eq!(FabricType::from_name("nope"), None);
            assert_eq!(TopologyKind::from_name("ring"), Some(TopologyKind::Ring));
        }
    }

    #[test]
    fn json_dump_has_interconnect_fields() {
        let mut c = SystemConfig::config_a();
        c.interconnect.channels = 4;
        let j = c.to_json();
        let ic = j.get("interconnect").unwrap();
        assert_eq!(ic.get("channels").unwrap().as_usize(), Some(4));
        assert_eq!(ic.get("topology").unwrap().as_str(), Some("crossbar"));
        assert_eq!(ic.get("link_queue").unwrap().as_usize(), Some(16));
    }

    #[test]
    fn cache_geometry_helpers() {
        let a = SystemConfig::config_a();
        assert_eq!(a.cache.line_bytes(), 64);
        assert_eq!(a.cache.sets(), 4096);
        assert_eq!(a.cache.capacity_bytes(), 8192 * 64);
        assert_eq!(a.dram.beat_bytes(), 64);
    }

    #[test]
    fn json_dump_has_key_fields() {
        let j = SystemConfig::config_a().to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("proposed"));
        assert!(j.get("cache").unwrap().get("lines").is_some());
    }

    #[test]
    fn telemetry_defaults_off_and_overrides_round_trip() {
        let c = SystemConfig::config_a();
        assert_eq!(c.telemetry, TelemetryConfig::off());
        assert!(!c.telemetry.enabled());

        let mut c = SystemConfig::config_b();
        c.apply_override("telemetry.trace", "on").unwrap();
        c.apply_override("telemetry.timeline", "1").unwrap();
        c.apply_override("telemetry.sample", "16").unwrap();
        c.apply_override("telemetry.window", "5000").unwrap();
        assert!(c.telemetry.trace && c.telemetry.timeline && c.telemetry.enabled());
        assert_eq!(c.telemetry.sample, 16);
        assert_eq!(c.telemetry.window, 5000);
        c.validate().unwrap();
        assert!(c.apply_override("telemetry.trace", "maybe").is_err());

        let tj = c.to_json();
        let t = tj.get("telemetry").unwrap();
        assert_eq!(t.get("trace").unwrap().as_bool(), Some(true));
        assert_eq!(t.get("sample").unwrap().as_usize(), Some(16));

        c.telemetry.sample = 0;
        assert!(c.validate().is_err(), "sample 0 must be rejected");
        c.telemetry.sample = 1;
        c.telemetry.window = 0;
        assert!(c.validate().is_err(), "window 0 must be rejected");
    }
}
