//! `key = value` config-file parser (one setting per line, `#` comments).

/// Parse `key = value` lines from a string. Returns pairs in file order.
pub fn parse_kv_str(src: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or(format!("line {}: expected `key = value`, got {raw:?}", lineno + 1))?;
        let key = k.trim();
        let val = v.trim();
        if key.is_empty() || val.is_empty() {
            return Err(format!("line {}: empty key or value in {raw:?}", lineno + 1));
        }
        out.push((key.to_string(), val.to_string()));
    }
    Ok(out)
}

/// Parse a `key = value` file from disk.
pub fn parse_kv_file(path: &std::path::Path) -> Result<Vec<(String, String)>, String> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse_kv_str(&src)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_lines_comments_whitespace() {
        let kv = parse_kv_str(
            "
            # a comment
            cache.lines = 4096   # trailing comment
            pe.rank=32
            ",
        )
        .unwrap();
        assert_eq!(
            kv,
            vec![
                ("cache.lines".to_string(), "4096".to_string()),
                ("pe.rank".to_string(), "32".to_string())
            ]
        );
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_kv_str("just-a-token").is_err());
        assert!(parse_kv_str("key =").is_err());
        assert!(parse_kv_str("= value").is_err());
    }

    #[test]
    fn empty_input_ok() {
        assert!(parse_kv_str("").unwrap().is_empty());
        assert!(parse_kv_str("# only comments\n\n").unwrap().is_empty());
    }
}
