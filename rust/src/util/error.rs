//! In-tree replacement for the `anyhow` error crate (the last external
//! dependency) — the subset this crate actually uses: a message-carrying
//! [`Error`], `?`-conversion from any `std::error::Error`, and the
//! [`format_err!`](crate::format_err)/[`bail!`](crate::bail)/
//! [`ensure!`](crate::ensure) macros. Dropping the dependency makes the
//! committed `Cargo.lock` a single-package file with no registry
//! checksums, so `cargo build --locked` is reproducible offline.
//!
//! Differences from `anyhow`, deliberate and harmless here:
//!
//! * The source error is flattened to its `Display` string at conversion
//!   time (no cause chain, no backtrace). Every error in this crate is
//!   either terminal (printed and exited) or asserted on in tests — the
//!   chain was never inspected.
//! * Like `anyhow::Error`, [`Error`] does **not** implement
//!   `std::error::Error`; that is what makes the blanket `From` impl
//!   coherent alongside the reflexive `From<Error> for Error` that `?`
//!   uses within the crate.

/// A flattened error message. Construct with [`Error::msg`], the
/// [`format_err!`](crate::format_err) macro, or any `?` on a
/// `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: std::fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Debug prints the message too: `fn main() -> Result<()>` and
/// `unwrap()` show the human text, not a struct dump.
impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `?`-conversion from any standard error (IO, parse, ...). Coherent
/// because [`Error`] itself does not implement `std::error::Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Construct an [`Error`](crate::Error) from a format string, or wrap a
/// single printable expression (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! format_err {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`](crate::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::format_err!($($arg)+))
    };
}

/// Return early with a formatted [`Error`](crate::Error) unless the
/// condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return ::std::result::Result::Err($crate::format_err!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> crate::Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn display_and_debug_show_the_message() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
    }

    #[test]
    fn macros_format_and_wrap() {
        let e = format_err!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let owned: String = "owned".into();
        assert_eq!(format_err!(owned).to_string(), "owned");
        assert_eq!(fails(false).unwrap(), 7);
        assert_eq!(fails(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn bail_returns_early() {
        fn f() -> crate::Result<()> {
            bail!("stopped at {}", "once");
        }
        assert_eq!(f().unwrap_err().to_string(), "stopped at once");
    }
}
