//! Minimal JSON value, writer, and recursive-descent parser
//! (serde/serde_json are unavailable offline).
//!
//! Used for the artifacts manifest, experiment result dumps, and config
//! files. Supports the full JSON grammar minus exotic number forms; numbers
//! are stored as `f64` (adequate for our payloads).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Field access on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// First structural difference between two values, as a dotted path like
    /// `fabric.links[3].forwarded`, or `None` if they match. Object keys whose
    /// name appears in `ignore` are skipped at any depth (used to mask
    /// host-dependent fields such as `host_seconds` when diffing reports).
    pub fn first_diff(&self, other: &Json, ignore: &[&str]) -> Option<String> {
        self.diff_at(other, ignore, String::new())
    }

    fn diff_at(&self, other: &Json, ignore: &[&str], path: String) -> Option<String> {
        let here = |path: String| if path.is_empty() { "<root>".to_string() } else { path };
        match (self, other) {
            (Json::Obj(a), Json::Obj(b)) => {
                for key in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                    if ignore.contains(&key.as_str()) {
                        continue;
                    }
                    let sub = if path.is_empty() {
                        key.clone()
                    } else {
                        format!("{path}.{key}")
                    };
                    match (a.get(key), b.get(key)) {
                        (Some(va), Some(vb)) => {
                            if let Some(d) = va.diff_at(vb, ignore, sub) {
                                return Some(d);
                            }
                        }
                        _ => return Some(sub),
                    }
                }
                None
            }
            (Json::Arr(a), Json::Arr(b)) => {
                if a.len() != b.len() {
                    return Some(format!("{}.len", here(path)));
                }
                for (i, (va, vb)) in a.iter().zip(b).enumerate() {
                    if let Some(d) = va.diff_at(vb, ignore, format!("{path}[{i}]")) {
                        return Some(d);
                    }
                }
                None
            }
            (a, b) => {
                if a == b {
                    None
                } else {
                    Some(here(path))
                }
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("short \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::obj(vec![
            ("name", Json::str("synth01")),
            ("nnz", Json::num(28_000_000u32 as f64)),
            ("ok", Json::Bool(true)),
            ("dims", Json::arr(vec![Json::num(22000), Json::num(22000), Json::num(23000000)])),
            ("none", Json::Null),
        ]);
        let s = v.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![(
            "nested",
            Json::obj(vec![("a", Json::num(1.5)), ("b", Json::arr(vec![]))]),
        )]);
        let s = v.to_string_pretty();
        assert!(s.contains('\n'));
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#"{"s":"a\nb\t\"q\" é"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\nb\t\"q\" é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn first_diff_paths() {
        let a = Json::parse(r#"{"x":1,"fabric":{"links":[{"forwarded":3},{"forwarded":4}]}}"#)
            .unwrap();
        let b = Json::parse(r#"{"x":1,"fabric":{"links":[{"forwarded":3},{"forwarded":9}]}}"#)
            .unwrap();
        assert_eq!(a.first_diff(&a, &[]), None);
        assert_eq!(
            a.first_diff(&b, &[]),
            Some("fabric.links[1].forwarded".to_string())
        );
        // Missing key on either side reports the key itself.
        let c = Json::parse(r#"{"x":1}"#).unwrap();
        assert_eq!(c.first_diff(&a, &[]), Some("fabric".to_string()));
        assert_eq!(a.first_diff(&c, &[]), Some("fabric".to_string()));
        // Length mismatch reports the array, not an element.
        let d = Json::parse(r#"{"x":1,"fabric":{"links":[{"forwarded":3}]}}"#).unwrap();
        assert_eq!(a.first_diff(&d, &[]), Some("fabric.links.len".to_string()));
        // Type mismatch at the root.
        assert_eq!(
            Json::num(1).first_diff(&Json::str("1"), &[]),
            Some("<root>".to_string())
        );
    }

    #[test]
    fn first_diff_honors_ignore_list() {
        let a = Json::parse(r#"{"host_seconds":1.5,"cycles":10,"sub":{"host_seconds":2}}"#)
            .unwrap();
        let b = Json::parse(r#"{"host_seconds":9.5,"cycles":10,"sub":{"host_seconds":3}}"#)
            .unwrap();
        assert_eq!(a.first_diff(&b, &["host_seconds"]), None);
        assert_eq!(a.first_diff(&b, &[]), Some("host_seconds".to_string()));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        // Integer-valued floats serialize without a fraction.
        assert_eq!(Json::num(3.0).to_string_compact(), "3");
        assert_eq!(Json::num(3.25).to_string_compact(), "3.25");
    }
}
