//! Small statistics helpers shared by the simulator and bench harness.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Percentile over a copied, sorted sample (nearest-rank).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).floor() as usize;
    v[rank.min(v.len() - 1)]
}

/// Fixed-width histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    bucket_width: u64,
    buckets: Vec<u64>,
    overflow: u64,
    count: u64,
    sum: u64,
    max: u64,
}

impl Histogram {
    pub fn new(bucket_width: u64, n_buckets: usize) -> Self {
        Histogram {
            bucket_width: bucket_width.max(1),
            buckets: vec![0; n_buckets],
            overflow: 0,
            count: 0,
            sum: 0,
            max: 0,
        }
    }

    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
        let idx = (v / self.bucket_width) as usize;
        if idx < self.buckets.len() {
            self.buckets[idx] += 1;
        } else {
            self.overflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate percentile from the bucketed distribution.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (i as u64 + 1) * self.bucket_width - 1;
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bucket_width, other.bucket_width);
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_moments() {
        let mut a = Accum::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 8);
        assert!((a.mean() - 5.0).abs() < 1e-12);
        assert!((a.stddev() - 2.138089935).abs() < 1e-6);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn histogram_records_and_percentiles() {
        let mut h = Histogram::new(10, 100);
        for v in 0..1000u64 {
            h.record(v % 100);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 49.5).abs() < 1e-9);
        let p50 = h.percentile(50.0);
        assert!((40..=60).contains(&p50), "p50 {p50}");
        assert_eq!(h.max(), 99);
    }

    #[test]
    fn histogram_overflow_and_merge() {
        let mut a = Histogram::new(1, 4);
        a.record(10); // overflow
        a.record(1);
        let mut b = Histogram::new(1, 4);
        b.record(2);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 10);
    }
}
