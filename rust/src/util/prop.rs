//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` random inputs drawn from a
//! caller-supplied generator. On failure it retries with progressively
//! simpler inputs from the generator's `shrink` hook (if provided) and
//! reports the seed so the failure is reproducible:
//!
//! ```text
//! property failed (seed=0xDEADBEEF case=17): <message>
//! ```

use super::rng::Rng;

/// Outcome of a property over one input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` inputs produced by `gen`. Panics with the
/// failing seed + case index on the first failure.
///
/// The base seed is taken from `MEMSYS_PROP_SEED` if set (to replay a
/// failure), otherwise a fixed default keeps CI deterministic.
pub fn check<T, G, P>(name: &str, cases: u32, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let base_seed = std::env::var("MEMSYS_PROP_SEED")
        .ok()
        .and_then(|s| parse_seed(&s))
        .unwrap_or(0x5EED_CAFE_F00D_u64);
    for case in 0..cases {
        // Derive the case seed so any failing case replays in isolation.
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed (seed={base_seed:#x} case={case}, \
                 case_seed={seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

fn parse_seed(s: &str) -> Option<u64> {
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Assert helper producing `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert two values equal, producing `PropResult`.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{} (left={:?} right={:?})", format!($($fmt)+), a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "addition commutes",
            50,
            |r| (r.gen_range(1000), r.gen_range(1000)),
            |&(a, b)| {
                n += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check(
            "always fails",
            10,
            |r| r.gen_range(10),
            |_| Err("expected failure".into()),
        );
    }

    #[test]
    fn prop_macros_work() {
        fn inner(x: u32) -> PropResult {
            prop_assert!(x < 100, "x too big: {x}");
            prop_assert_eq!(x % 1, 0, "mod identity");
            Ok(())
        }
        assert!(inner(5).is_ok());
        assert!(inner(200).is_err());
    }

    #[test]
    fn seed_parsing() {
        assert_eq!(parse_seed("0x10"), Some(16));
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed("zzz"), None);
    }
}
