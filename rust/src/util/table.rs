//! ASCII table renderer for experiment outputs (Table II/III, Fig. 4 rows).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            aligns: headers.iter().map(|_| Align::Left).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (defaults to left).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with `| col | col |` borders.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&render_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

fn render_row(cells: &[String], widths: &[usize], aligns: &[Align]) -> String {
    let mut s = String::from("|");
    for ((c, w), a) in cells.iter().zip(widths).zip(aligns) {
        let pad = w - c.chars().count();
        match a {
            Align::Left => {
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
            }
            Align::Right => {
                s.push_str(&" ".repeat(pad + 1));
                s.push_str(c);
                s.push(' ');
            }
        }
        s.push('|');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_alignment() {
        let mut t = Table::new(&["name", "value"]).aligns(&[Align::Left, Align::Right]);
        t.row_strs(&["alpha", "1"]);
        t.row_strs(&["b", "12345"]);
        let s = t.render();
        assert!(s.contains("| alpha |     1 |"), "got:\n{s}");
        assert!(s.contains("| b     | 12345 |"), "got:\n{s}");
        // Borders present.
        assert!(s.starts_with('+'));
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn wrong_row_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
