//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, and
//! subcommands. Typed getters parse on demand and produce friendly errors.

use std::collections::HashMap;

/// Parsed command-line arguments.
///
/// Options may repeat (`--axis a=1 --axis b=2`): [`Args::get`] returns
/// the last occurrence, [`Args::get_all`] every occurrence in order.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Name of the subcommand (first non-flag token), if any was requested.
    pub subcommand: Option<String>,
    opts: HashMap<String, Vec<String>>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. When `with_subcommand` is true the
    /// first positional token is treated as the subcommand name.
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I, with_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    // `--key value` if the next token is not itself a flag,
                    // otherwise a boolean flag.
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        let v = it.next().unwrap();
                        args.opts.entry(body.to_string()).or_default().push(v);
                    } else {
                        args.flags.push(body.to_string());
                    }
                }
            } else if with_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        args
    }

    /// Parse from `std::env::args()` (skipping argv\[0\]).
    pub fn parse_env(with_subcommand: bool) -> Args {
        Args::parse_from(std::env::args().skip(1), with_subcommand)
    }

    /// True if `--name` was given as a bare flag OR as `--name true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || matches!(self.get(name), Some("true") | Some("1"))
    }

    /// Raw option value (the last occurrence when repeated).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// Every occurrence of a repeatable option, in command-line order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.opts
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a clear message on parse error.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(raw) => raw.parse().unwrap_or_else(|e| {
                eprintln!("error: --{name} {raw}: {e}");
                std::process::exit(2);
            }),
        }
    }

    /// `usize` option with default.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_parsed(name, default)
    }

    /// `u64` option with default.
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get_parsed(name, default)
    }

    /// `f64` option with default.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get_parsed(name, default)
    }

    /// Positional arguments (after the subcommand, if any).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// All `--key value` pairs (used for config overrides); repeated
    /// options yield one pair per occurrence.
    pub fn options(&self) -> impl Iterator<Item = (&str, &str)> {
        self.opts
            .iter()
            .flat_map(|(k, vs)| vs.iter().map(move |v| (k.as_str(), v.as_str())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positionals() {
        let a = Args::parse_from(toks("fig4 --scale 0.5 --verbose --out=res.json extra"), true);
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert_eq!(a.get("scale"), Some("0.5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("res.json"));
        assert_eq!(a.positionals(), &["extra".to_string()]);
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = Args::parse_from(toks("--n 42 --x 1.5"), false);
        assert_eq!(a.get_usize("n", 0), 42);
        assert_eq!(a.get_f64("x", 0.0), 1.5);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_str("name", "dflt"), "dflt");
    }

    #[test]
    fn flag_as_value_form() {
        let a = Args::parse_from(toks("--quick true --slow"), false);
        assert!(a.flag("quick"));
        assert!(a.flag("slow"));
        assert!(!a.flag("absent"));
    }

    #[test]
    fn repeated_options_accumulate_in_order() {
        let a = Args::parse_from(toks("sweep --axis system=a,b --axis channels=1,2"), true);
        assert_eq!(a.get("axis"), Some("channels=1,2"), "get returns the last");
        assert_eq!(a.get_all("axis"), vec!["system=a,b", "channels=1,2"]);
        assert!(a.get_all("missing").is_empty());
        assert_eq!(a.options().filter(|(k, _)| *k == "axis").count(), 2);
    }

    #[test]
    fn no_subcommand_mode_treats_first_token_as_positional() {
        let a = Args::parse_from(toks("file.tns --rank 8"), false);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positionals(), &["file.tns".to_string()]);
        assert_eq!(a.get_usize("rank", 0), 8);
    }
}
