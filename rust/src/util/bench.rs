//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a plain `main()` (`harness = false`) that
//! calls [`Bench::run`] for timing loops and/or prints experiment tables.
//! Reports mean ± stddev over measured iterations after warmup, plus
//! throughput when an item count is supplied.

use std::time::{Duration, Instant};

use super::stats::Accum;

/// One timing result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: u64,
    /// items/second if `throughput_items` was set.
    pub throughput: Option<f64>,
}

impl Measurement {
    pub fn report_line(&self) -> String {
        let tp = match self.throughput {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>10.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:>10.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ± {:>10} (min {:>12}, max {:>12}, n={}){}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.min),
            fmt_dur(self.max),
            self.iters,
            tp
        )
    }
}

/// Format a duration with adaptive units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner configuration.
pub struct Bench {
    warmup: u64,
    min_iters: u64,
    max_iters: u64,
    target_time: Duration,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_secs(2),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI / smoke runs.
    pub fn quick() -> Self {
        Bench {
            warmup: 1,
            min_iters: 2,
            max_iters: 10,
            target_time: Duration::from_millis(300),
            results: Vec::new(),
        }
    }

    pub fn with_target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Time `f` (which must fully perform the work per call). `items` is the
    /// per-iteration work amount used for throughput reporting (0 = none).
    pub fn run<F: FnMut()>(&mut self, name: &str, items: u64, mut f: F) -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut acc = Accum::new();
        let start = Instant::now();
        let mut iters = 0;
        while iters < self.min_iters
            || (start.elapsed() < self.target_time && iters < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            acc.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let mean = acc.mean();
        let m = Measurement {
            name: name.to_string(),
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(acc.stddev()),
            min: Duration::from_secs_f64(acc.min()),
            max: Duration::from_secs_f64(acc.max()),
            iters,
            throughput: if items > 0 && mean > 0.0 {
                Some(items as f64 / mean)
            } else {
                None
            },
        };
        println!("{}", m.report_line());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header so bench output is scannable.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::quick().with_target_time(Duration::from_millis(10));
        let mut acc = 0u64;
        let m = b.run("noop-ish", 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(m.iters >= 2);
        assert!(m.throughput.unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
