//! Deterministic PRNG — xoshiro256\*\* seeded via SplitMix64.
//!
//! The offline registry has no `rand`; this is the standard xoshiro256\*\*
//! generator (Blackman & Vigna), good enough for synthetic-tensor
//! generation, property tests, and simulator tie-breaking. Deterministic by
//! seed so every experiment is reproducible from its config.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256\*\* PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire: unbiased bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine
    /// for init of factor matrices).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-like sample over `[0, n)` with exponent `alpha` using inverse
    /// CDF on a power-law approximation — used to generate skewed fiber
    /// popularity resembling real tensors.
    pub fn gen_zipf(&mut self, n: u64, alpha: f64) -> u64 {
        debug_assert!(n > 0);
        if alpha <= 0.0 {
            return self.gen_range(n);
        }
        // Inverse transform on the continuous bounded Pareto CDF.
        let u = self.gen_f64();
        let one_minus = 1.0 - alpha.min(0.9999); // continuous exponent
        let nmax = n as f64;
        let x = ((nmax.powf(one_minus) - 1.0) * u + 1.0).powf(1.0 / one_minus) - 1.0;
        (x as u64).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: u64, k: usize) -> Vec<u64> {
        debug_assert!(k as u64 <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k as u64)..n {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fork a child generator (stream split) — deterministic given call order.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn gen_f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn zipf_is_skewed_toward_low_indices() {
        let mut r = Rng::new(3);
        let mut low = 0;
        for _ in 0..10_000 {
            if r.gen_zipf(1000, 1.2) < 100 {
                low += 1;
            }
        }
        // With alpha>1 the first decile should hold much more than 10%.
        assert!(low > 3000, "zipf low-decile count {low}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique_and_in_range() {
        let mut r = Rng::new(11);
        let s = r.sample_distinct(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&v| v < 50));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.gen_normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
