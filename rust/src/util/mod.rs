//! In-tree replacements for crates unavailable in the offline registry
//! (anyhow, clap, serde_json, criterion, proptest, rand) plus small
//! shared helpers.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

/// Error for parsing a named enum variant (`SystemKind`, `FabricType`,
/// `TopologyKind`, `Mode`) from a string: records what was being parsed,
/// the rejected input, and the accepted spellings — so every `FromStr`
/// in the crate reports the same way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NameParseError {
    /// What kind of name was expected, e.g. `"system"`.
    pub what: &'static str,
    /// The rejected input.
    pub input: String,
    /// Valid spellings, shown `a|b|c`.
    pub expected: &'static [&'static str],
}

impl NameParseError {
    pub fn new(what: &'static str, input: &str, expected: &'static [&'static str]) -> Self {
        NameParseError { what, input: input.to_string(), expected }
    }
}

impl std::fmt::Display for NameParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown {} {:?} (expected {})",
            self.what,
            self.input,
            self.expected.join("|")
        )
    }
}

impl std::error::Error for NameParseError {}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: u64, b: u64) -> u64 {
    ceil_div(a, b) * b
}

/// `true` iff `x` is a power of two (and non-zero).
#[inline]
pub fn is_pow2(x: u64) -> bool {
    x != 0 && x & (x - 1) == 0
}

/// log2 of a power of two.
#[inline]
pub fn log2(x: u64) -> u32 {
    debug_assert!(is_pow2(x), "log2 of non-power-of-two {x}");
    x.trailing_zeros()
}

/// Format a byte count human-readably (KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(128, 64), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 64), 0);
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn pow2_helpers() {
        assert!(is_pow2(1));
        assert!(is_pow2(4096));
        assert!(!is_pow2(0));
        assert!(!is_pow2(12));
        assert_eq!(log2(1), 0);
        assert_eq!(log2(8192), 13);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(12), "12");
    }
}
