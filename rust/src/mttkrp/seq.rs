//! Algorithm 2 — COO-based sequential spMTTKRP for third-order tensors.
//!
//! ```text
//! for z = 0 to nnz:
//!     i = indI[z]; j = indJ[z]; k = indK[z]
//!     for r = 0 to R:
//!         A[i][r] += vals[z] * D[j][r] * C[k][r]
//! ```
//!
//! Generalized over the output mode (lines 2–4 of Algorithm 1 run it for
//! each mode in turn).

use super::operand_modes;
use crate::tensor::{CooTensor, DenseMatrix, Mode};

/// Mode-`mode` sequential MTTKRP: returns the (dim(mode) × R) output.
///
/// `m1`, `m2` are the factor matrices of the two *other* modes in cyclic
/// order (see [`operand_modes`]).
pub fn mttkrp_seq(t: &CooTensor, mode: Mode, m1: &DenseMatrix, m2: &DenseMatrix) -> DenseMatrix {
    super::check_shapes(t, mode, m1, m2, &DenseMatrix::zeros(t.dim(mode) as usize, m1.cols));
    let (om1, om2) = operand_modes(mode);
    let r = m1.cols;
    let mut out = DenseMatrix::zeros(t.dim(mode) as usize, r);
    for z in 0..t.nnz() {
        let oi = t.coord(z, mode) as usize;
        let a = t.coord(z, om1) as usize;
        let b = t.coord(z, om2) as usize;
        let v = t.vals[z];
        let row1 = m1.row(a);
        let row2 = m2.row(b);
        let dst = out.row_mut(oi);
        for x in 0..r {
            dst[x] += v * row1[x] * row2[x];
        }
    }
    out
}

/// f64-accumulating variant — the numerical oracle for everything else
/// (f32 accumulation order differences stay below its precision).
pub fn mttkrp_seq_f64(t: &CooTensor, mode: Mode, m1: &DenseMatrix, m2: &DenseMatrix) -> Vec<f64> {
    let (om1, om2) = operand_modes(mode);
    let r = m1.cols;
    let mut out = vec![0f64; t.dim(mode) as usize * r];
    for z in 0..t.nnz() {
        let oi = t.coord(z, mode) as usize;
        let a = t.coord(z, om1) as usize;
        let b = t.coord(z, om2) as usize;
        let v = t.vals[z] as f64;
        for x in 0..r {
            out[oi * r + x] += v * m1.at(a, x) as f64 * m2.at(b, x) as f64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Hand-computed 2×2×2 example.
    #[test]
    fn tiny_hand_computed() {
        // B[0,1,0] = 2, B[1,0,1] = 3.
        let mut t = CooTensor::new("t", [2, 2, 2]);
        t.push(0, 1, 0, 2.0);
        t.push(1, 0, 1, 3.0);
        // D (J×R), C (K×R), R = 2.
        let d = DenseMatrix {
            rows: 2,
            cols: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let c = DenseMatrix {
            rows: 2,
            cols: 2,
            data: vec![5.0, 6.0, 7.0, 8.0],
        };
        let a = mttkrp_seq(&t, Mode::I, &d, &c);
        // A[0] = 2 * D[1] ∘ C[0] = 2*[3*5, 4*6]  = [30, 48]
        // A[1] = 3 * D[0] ∘ C[1] = 3*[1*7, 2*8]  = [21, 48]
        assert_eq!(a.row(0), &[30.0, 48.0]);
        assert_eq!(a.row(1), &[21.0, 48.0]);
    }

    #[test]
    fn matches_f64_oracle_all_modes() {
        let mut rng = Rng::new(10);
        let t = CooTensor::random(&mut rng, [12, 14, 16], 300);
        let r = 8;
        let a = DenseMatrix::random(&mut rng, 12, r);
        let d = DenseMatrix::random(&mut rng, 14, r);
        let c = DenseMatrix::random(&mut rng, 16, r);
        for (mode, m1, m2) in [
            (Mode::I, &d, &c),
            (Mode::J, &a, &c),
            (Mode::K, &a, &d),
        ] {
            let got = mttkrp_seq(&t, mode, m1, m2);
            let oracle = mttkrp_seq_f64(&t, mode, m1, m2);
            for (x, (g, o)) in got.data.iter().zip(&oracle).enumerate() {
                assert!(
                    (*g as f64 - o).abs() < 1e-3,
                    "mode {mode:?} idx {x}: {g} vs {o}"
                );
            }
        }
    }

    #[test]
    fn empty_tensor_gives_zeros() {
        let t = CooTensor::new("e", [3, 4, 5]);
        let d = DenseMatrix::zeros(4, 2);
        let c = DenseMatrix::zeros(5, 2);
        let a = mttkrp_seq(&t, Mode::I, &d, &c);
        assert!(a.data.iter().all(|&v| v == 0.0));
        assert_eq!(a.rows, 3);
    }

    #[test]
    fn linear_in_values() {
        let mut rng = Rng::new(11);
        let t = CooTensor::random(&mut rng, [6, 6, 6], 50);
        let mut t2 = t.clone();
        for v in &mut t2.vals {
            *v *= 2.0;
        }
        let d = DenseMatrix::random(&mut rng, 6, 4);
        let c = DenseMatrix::random(&mut rng, 6, 4);
        let a1 = mttkrp_seq(&t, Mode::I, &d, &c);
        let a2 = mttkrp_seq(&t2, Mode::I, &d, &c);
        for (x, y) in a1.data.iter().zip(&a2.data) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }
}
