//! Small dense linear algebra for the CP-ALS normal equations:
//! matmul, symmetric-positive-definite solves (Cholesky with a
//! Tikhonov-regularized fallback), and the CP fit computation helpers.

use crate::tensor::DenseMatrix;

/// C = A · B (naïve; operands here are at most (dim × R) with R ≤ 64).
pub fn matmul(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = DenseMatrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let aik = a.at(i, k);
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let crow = c.row_mut(i);
            for j in 0..b.cols {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Cholesky factorization of a symmetric positive-definite R×R matrix.
/// Returns the lower-triangular factor, or None if not SPD.
pub fn cholesky(g: &DenseMatrix) -> Option<DenseMatrix> {
    assert_eq!(g.rows, g.cols);
    let n = g.rows;
    let mut l = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = g.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = (sum.sqrt()) as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve X · G = B for X (row-wise), where G is SPD R×R and B is (n × R).
/// This is the ALS update `A ← MTTKRP(B) · (CᵀC ∗ DᵀD)⁻¹`; we solve
/// Gᵀ Xᵀ = Bᵀ via Cholesky (G symmetric ⇒ G = L Lᵀ).
///
/// If G is singular/ill-conditioned, a small ridge (λI) is added —
/// standard practice in CP-ALS implementations.
pub fn solve_gram(b: &DenseMatrix, g: &DenseMatrix) -> DenseMatrix {
    assert_eq!(g.rows, g.cols);
    assert_eq!(b.cols, g.rows);
    let mut g_reg = g.clone();
    let mut l = cholesky(&g_reg);
    let mut ridge = 1e-8f32 * (1.0 + g.fro_norm() as f32);
    while l.is_none() && ridge < 1e6 {
        for d in 0..g_reg.rows {
            *g_reg.at_mut(d, d) = g.at(d, d) + ridge;
        }
        l = cholesky(&g_reg);
        ridge *= 10.0;
    }
    let l = l.expect("gram matrix irreparably singular");
    let n = g.rows;
    let mut x = b.clone();
    // For each row of B: solve L y = bᵀ then Lᵀ x = y.
    for row in 0..b.rows {
        let xr = x.row_mut(row);
        // Forward substitution.
        for i in 0..n {
            let mut v = xr[i] as f64;
            for k in 0..i {
                v -= l.at(i, k) as f64 * xr[k] as f64;
            }
            xr[i] = (v / l.at(i, i) as f64) as f32;
        }
        // Backward substitution (Lᵀ).
        for i in (0..n).rev() {
            let mut v = xr[i] as f64;
            for k in (i + 1)..n {
                v -= l.at(k, i) as f64 * xr[k] as f64;
            }
            xr[i] = (v / l.at(i, i) as f64) as f32;
        }
    }
    x
}

/// Sum of the elementwise product of two equally-shaped matrices
/// (⟨A, B⟩_F) in f64.
pub fn dot_f64(a: &DenseMatrix, b: &DenseMatrix) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| x as f64 * y as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_small() {
        let a = DenseMatrix {
            rows: 2,
            cols: 3,
            data: vec![1., 2., 3., 4., 5., 6.],
        };
        let b = DenseMatrix {
            rows: 3,
            cols: 2,
            data: vec![7., 8., 9., 10., 11., 12.],
        };
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn cholesky_recomposes() {
        let mut rng = Rng::new(40);
        let m = DenseMatrix::random(&mut rng, 8, 5);
        let g = m.gram(); // SPD with probability 1
        let l = cholesky(&g).expect("gram should be SPD");
        // L·Lᵀ == G.
        let mut lt = DenseMatrix::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                *lt.at_mut(i, j) = l.at(j, i);
            }
        }
        let recomposed = matmul(&l, &lt);
        assert!(recomposed.max_abs_diff(&g) < 1e-3 * (1.0 + g.fro_norm() as f32));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let g = DenseMatrix {
            rows: 2,
            cols: 2,
            data: vec![1.0, 2.0, 2.0, 1.0], // eigenvalues 3, -1
        };
        assert!(cholesky(&g).is_none());
    }

    #[test]
    fn solve_gram_inverts() {
        let mut rng = Rng::new(41);
        let m = DenseMatrix::random(&mut rng, 10, 4);
        let g = m.gram();
        let x_true = DenseMatrix::random(&mut rng, 6, 4);
        let b = matmul(&x_true, &g); // B = X·G
        let x = solve_gram(&b, &g);
        assert!(
            x.max_abs_diff(&x_true) < 1e-2,
            "diff {}",
            x.max_abs_diff(&x_true)
        );
    }

    #[test]
    fn solve_gram_survives_singular_with_ridge() {
        let g = DenseMatrix {
            rows: 2,
            cols: 2,
            data: vec![1.0, 1.0, 1.0, 1.0], // rank-1
        };
        let b = DenseMatrix {
            rows: 1,
            cols: 2,
            data: vec![2.0, 2.0],
        };
        let x = solve_gram(&b, &g); // must not panic
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dot_f64_is_frobenius_inner_product() {
        let a = DenseMatrix {
            rows: 1,
            cols: 3,
            data: vec![1., 2., 3.],
        };
        let b = DenseMatrix {
            rows: 1,
            cols: 3,
            data: vec![4., 5., 6.],
        };
        assert_eq!(dot_f64(&a, &b), 32.0);
    }
}
