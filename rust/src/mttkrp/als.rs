//! Algorithm 1 — CP-ALS for third-order tensors.
//!
//! ```text
//! while not converged:
//!     A ← B₍₁₎(D ⊙ C)(CᵀC ∗ DᵀD)⁻¹
//!     D ← B₍₂₎(A ⊙ C)(CᵀC ∗ AᵀA)⁻¹
//!     C ← B₍₃₎(D ⊙ A)(AᵀA ∗ DᵀD)⁻¹
//!     normalize columns → λ
//! ```
//!
//! The MTTKRP (`B₍ₙ₎(· ⊙ ·)`) is pluggable so the same driver can run the
//! pure-Rust reference or the AOT-compiled JAX/Pallas path via PJRT
//! (`coordinator::driver` injects the latter).

use crate::tensor::{CooTensor, DenseMatrix, Mode};
use crate::util::rng::Rng;

use super::linalg::solve_gram;
use super::seq::mttkrp_seq;

/// Pluggable MTTKRP kernel: (tensor-sorted-along-mode, mode, m1, m2) → out.
pub type MttkrpFn<'a> =
    dyn FnMut(&CooTensor, Mode, &DenseMatrix, &DenseMatrix) -> DenseMatrix + 'a;

/// CP-ALS options.
#[derive(Debug, Clone)]
pub struct CpAlsOptions {
    pub rank: usize,
    pub max_iters: usize,
    /// Stop when |fit_t − fit_{t−1}| < tol.
    pub fit_tol: f64,
    pub seed: u64,
}

impl Default for CpAlsOptions {
    fn default() -> Self {
        CpAlsOptions {
            rank: 16,
            max_iters: 25,
            fit_tol: 1e-5,
            seed: 7,
        }
    }
}

/// Per-iteration record.
#[derive(Debug, Clone)]
pub struct CpAlsIter {
    pub iter: usize,
    pub fit: f64,
    pub rel_error: f64,
}

/// Final CP-ALS report.
#[derive(Debug, Clone)]
pub struct CpAlsReport {
    pub iters: Vec<CpAlsIter>,
    pub final_fit: f64,
    pub converged: bool,
}

/// CP decomposition state (factors A: I×R, D: J×R, C: K×R as in Alg. 1).
pub struct CpAls {
    pub a: DenseMatrix,
    pub d: DenseMatrix,
    pub c: DenseMatrix,
    pub lambda: Vec<f32>,
    opts: CpAlsOptions,
    /// Mode-sorted copies (sorting once beats re-sorting every sweep).
    t_i: CooTensor,
    t_j: CooTensor,
    t_k: CooTensor,
    norm_b_sq: f64,
}

impl CpAls {
    /// Initialize with uniform-random factors (standard CP-ALS init).
    pub fn new(t: &CooTensor, opts: CpAlsOptions) -> CpAls {
        let mut rng = Rng::new(opts.seed);
        let r = opts.rank;
        let a = DenseMatrix::random(&mut rng, t.dims[0] as usize, r);
        let d = DenseMatrix::random(&mut rng, t.dims[1] as usize, r);
        let c = DenseMatrix::random(&mut rng, t.dims[2] as usize, r);
        let mut t_i = t.clone();
        t_i.sort_mode(Mode::I);
        let mut t_j = t.clone();
        t_j.sort_mode(Mode::J);
        let mut t_k = t.clone();
        t_k.sort_mode(Mode::K);
        let norm_b_sq = t.vals.iter().map(|&v| v as f64 * v as f64).sum();
        CpAls {
            a,
            d,
            c,
            lambda: vec![1.0; r],
            opts,
            t_i,
            t_j,
            t_k,
            norm_b_sq,
        }
    }

    /// Run CP-ALS with the reference (pure Rust, Algorithm 2) MTTKRP.
    pub fn run(&mut self) -> CpAlsReport {
        let mut f = |t: &CooTensor, m: Mode, m1: &DenseMatrix, m2: &DenseMatrix| {
            mttkrp_seq(t, m, m1, m2)
        };
        self.run_with(&mut f)
    }

    /// Run CP-ALS with a caller-supplied MTTKRP kernel.
    pub fn run_with(&mut self, mttkrp: &mut MttkrpFn) -> CpAlsReport {
        let mut iters = Vec::new();
        let mut prev_fit = f64::NEG_INFINITY;
        let mut converged = false;
        for it in 0..self.opts.max_iters {
            let (fit, rel_error) = self.sweep(mttkrp);
            iters.push(CpAlsIter {
                iter: it,
                fit,
                rel_error,
            });
            if (fit - prev_fit).abs() < self.opts.fit_tol {
                converged = true;
                break;
            }
            prev_fit = fit;
        }
        CpAlsReport {
            final_fit: iters.last().map(|i| i.fit).unwrap_or(0.0),
            iters,
            converged,
        }
    }

    /// One ALS sweep (lines 2–5 of Algorithm 1). Returns (fit, rel_error).
    fn sweep(&mut self, mttkrp: &mut MttkrpFn) -> (f64, f64) {
        // A ← B₍₁₎(D ⊙ C)(CᵀC ∗ DᵀD)⁻¹   — mode-I, operands (D, C).
        let m = mttkrp(&self.t_i, Mode::I, &self.d, &self.c);
        let g = self.c.gram().hadamard(&self.d.gram());
        self.a = solve_gram(&m, &g);

        // D ← B₍₂₎(A ⊙ C)(CᵀC ∗ AᵀA)⁻¹   — mode-J, operands (A, C).
        let m = mttkrp(&self.t_j, Mode::J, &self.a, &self.c);
        let g = self.c.gram().hadamard(&self.a.gram());
        self.d = solve_gram(&m, &g);

        // C ← B₍₃₎(D ⊙ A)(AᵀA ∗ DᵀD)⁻¹   — mode-K, operands (A, D).
        let m_last = mttkrp(&self.t_k, Mode::K, &self.a, &self.d);
        let g = self.a.gram().hadamard(&self.d.gram());
        self.c = solve_gram(&m_last, &g);

        // Normalize columns; store norms in λ (line 5).
        let la = self.a.normalize_columns();
        let ld = self.d.normalize_columns();
        let lc = self.c.normalize_columns();
        for r in 0..self.opts.rank {
            self.lambda[r] = la[r] * ld[r] * lc[r];
        }

        self.fit(&m_last, &lc)
    }

    /// Standard CP-ALS fit: 1 − ‖B − ⟦λ; A, D, C⟧‖ / ‖B‖, computed without
    /// materializing the reconstruction:
    /// ‖B − M‖² = ‖B‖² + ‖M‖² − 2⟨B, M⟩, with ⟨B, M⟩ recovered from the
    /// last MTTKRP output (`m_last` pairs with C before normalization; the
    /// column norms `lc` rescale it afterwards).
    fn fit(&self, m_last: &DenseMatrix, lc: &[f32]) -> (f64, f64) {
        let r = self.opts.rank;
        // ‖M‖² = Σ_{r,s} λ_r λ_s (a_r·a_s)(d_r·d_s)(c_r·c_s)
        let ga = self.a.gram();
        let gd = self.d.gram();
        let gc = self.c.gram();
        let mut norm_m_sq = 0f64;
        for x in 0..r {
            for y in 0..r {
                norm_m_sq += self.lambda[x] as f64
                    * self.lambda[y] as f64
                    * ga.at(x, y) as f64
                    * gd.at(x, y) as f64
                    * gc.at(x, y) as f64;
            }
        }
        // ⟨B, M⟩: m_last[k,r] = Σ val·A_pre[i,r]·D_pre[j,r] was computed
        // with the pre-normalization A, D (norms la·ld). With normalized
        // factors, M[i,j,k] = Σ_r λ_r a[i,r] d[j,r] c[k,r] and
        // λ_r = la·ld·lc ⇒ ⟨B, M⟩ = Σ_{k,r} m_last[k,r]·c_norm[k,r]·lc[r].
        let mut inner = 0f64;
        for row in 0..self.c.rows {
            for x in 0..r {
                inner += m_last.at(row, x) as f64
                    * self.c.at(row, x) as f64
                    * lc[x] as f64;
            }
        }
        let resid_sq = (self.norm_b_sq + norm_m_sq - 2.0 * inner).max(0.0);
        let rel_error = resid_sq.sqrt() / self.norm_b_sq.sqrt().max(1e-30);
        (1.0 - rel_error, rel_error)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an exactly rank-`r` tensor (sum of outer products) so ALS can
    /// drive the error to ~0.
    fn low_rank_tensor(seed: u64, dims: [u64; 3], rank: usize, keep: f64) -> CooTensor {
        let mut rng = Rng::new(seed);
        let a = DenseMatrix::random(&mut rng, dims[0] as usize, rank);
        let d = DenseMatrix::random(&mut rng, dims[1] as usize, rank);
        let c = DenseMatrix::random(&mut rng, dims[2] as usize, rank);
        let mut t = CooTensor::new("lowrank", dims);
        for i in 0..dims[0] as usize {
            for j in 0..dims[1] as usize {
                for k in 0..dims[2] as usize {
                    if rng.gen_f64() > keep {
                        continue; // sparsify by sampling observed entries
                    }
                    let mut v = 0f32;
                    for x in 0..rank {
                        v += a.at(i, x) * d.at(j, x) * c.at(k, x);
                    }
                    t.push(i as u32, j as u32, k as u32, v);
                }
            }
        }
        t
    }

    #[test]
    fn fit_improves_and_error_drops_on_low_rank_data() {
        let t = low_rank_tensor(50, [12, 10, 8], 3, 1.0); // dense low-rank
        let mut als = CpAls::new(
            &t,
            CpAlsOptions {
                rank: 4,
                max_iters: 30,
                fit_tol: 1e-9,
                seed: 3,
            },
        );
        let report = als.run();
        assert!(report.iters.len() >= 3);
        let first = report.iters.first().unwrap().rel_error;
        let last = report.iters.last().unwrap().rel_error;
        assert!(
            last < first * 0.5,
            "rel_error did not drop: {first} → {last}"
        );
        assert!(last < 0.15, "final rel_error too high: {last}");
    }

    #[test]
    fn fit_is_monotone_nonincreasing_error_mostly() {
        let t = low_rank_tensor(51, [10, 10, 10], 2, 1.0);
        let mut als = CpAls::new(
            &t,
            CpAlsOptions {
                rank: 3,
                max_iters: 15,
                fit_tol: 0.0,
                seed: 5,
            },
        );
        let report = als.run();
        // ALS is monotone in the exact objective; allow tiny fp jitter.
        for w in report.iters.windows(2) {
            assert!(
                w[1].rel_error <= w[0].rel_error + 1e-3,
                "error increased: {} → {}",
                w[0].rel_error,
                w[1].rel_error
            );
        }
    }

    #[test]
    fn pluggable_kernel_is_used() {
        let t = low_rank_tensor(52, [6, 6, 6], 2, 1.0);
        let mut calls = 0usize;
        {
            let mut als = CpAls::new(
                &t,
                CpAlsOptions {
                    rank: 2,
                    max_iters: 2,
                    fit_tol: 0.0,
                    seed: 1,
                },
            );
            let mut kernel = |tt: &CooTensor, m: Mode, m1: &DenseMatrix, m2: &DenseMatrix| {
                calls += 1;
                mttkrp_seq(tt, m, m1, m2)
            };
            als.run_with(&mut kernel);
        }
        assert_eq!(calls, 6, "3 modes × 2 iters");
    }

    #[test]
    fn lambda_collects_column_norms() {
        let t = low_rank_tensor(53, [8, 8, 8], 2, 1.0);
        let mut als = CpAls::new(&t, CpAlsOptions { rank: 2, max_iters: 3, ..Default::default() });
        als.run();
        // After normalization the factor columns are unit-norm.
        for (m, name) in [(&als.a, "A"), (&als.d, "D"), (&als.c, "C")] {
            for x in 0..2 {
                let norm: f64 = (0..m.rows)
                    .map(|row| (m.at(row, x) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                assert!((norm - 1.0).abs() < 1e-3, "{name} col {x} norm {norm}");
            }
        }
        assert!(als.lambda.iter().all(|&l| l > 0.0));
    }
}
