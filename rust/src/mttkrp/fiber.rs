//! Fiber-oriented MTTKRP formulations — Eq. (3) and Eq. (4) of the paper.
//!
//! State-of-the-art fabrics execute one of:
//!
//! ```text
//! (3)  fiber_out = scalar · Σ_K Σ_J (fiber_k ∘ fiber_j)
//! (4)  fiber_out = Σ_K Σ_J  fiber_k ∘ (scalar · fiber_j)
//! ```
//!
//! Both reassociate the same sum; the *memory access pattern* is what the
//! paper cares about: load input fibers (streaming → DMA), load scalars
//! (element-wise, cached), store output fibers (streaming → DMA). These
//! implementations are organized around those three access types so the
//! trace generator mirrors them 1:1.

use super::operand_modes;
use crate::tensor::{CooTensor, DenseMatrix, Mode};

/// Eq. (3)-shaped evaluation: group nonzeros by output fiber; for each
/// nonzero accumulate the Hadamard product of the two input fibers, scaled
/// once by the tensor scalar at the end of each product term.
pub fn mttkrp_fiber_eq3(
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
) -> DenseMatrix {
    fiber_impl(t, mode, m1, m2, true)
}

/// Eq. (4)-shaped evaluation: scale the first input fiber by the scalar,
/// then Hadamard with the second.
pub fn mttkrp_fiber_eq4(
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
) -> DenseMatrix {
    fiber_impl(t, mode, m1, m2, false)
}

fn fiber_impl(
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
    scale_after: bool,
) -> DenseMatrix {
    super::check_shapes(t, mode, m1, m2, &DenseMatrix::zeros(t.dim(mode) as usize, m1.cols));
    assert!(t.is_sorted_mode(mode), "fiber evaluation needs mode-sorted input");
    let (om1, om2) = operand_modes(mode);
    let r = m1.cols;
    let mut out = DenseMatrix::zeros(t.dim(mode) as usize, r);
    let mut fiber_out = vec![0f32; r];
    let mut z = 0usize;
    while z < t.nnz() {
        let oi = t.coord(z, mode);
        fiber_out.fill(0.0);
        // Accumulate all nonzeros of this output fiber.
        while z < t.nnz() && t.coord(z, mode) == oi {
            let scalar = t.vals[z];
            let fj = m1.row(t.coord(z, om1) as usize); // "fiber_j" (DMA load)
            let fk = m2.row(t.coord(z, om2) as usize); // "fiber_k" (DMA load)
            if scale_after {
                // Eq. (3): scalar · (fiber_k ∘ fiber_j)
                for x in 0..r {
                    fiber_out[x] += scalar * (fk[x] * fj[x]);
                }
            } else {
                // Eq. (4): fiber_k ∘ (scalar · fiber_j)
                for x in 0..r {
                    fiber_out[x] += fk[x] * (scalar * fj[x]);
                }
            }
            z += 1;
        }
        // Store the output fiber (DMA store).
        out.row_mut(oi as usize).copy_from_slice(&fiber_out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::seq::mttkrp_seq;
    use crate::util::rng::Rng;

    #[test]
    fn eq3_eq4_and_alg2_agree() {
        let mut rng = Rng::new(30);
        let t = CooTensor::random(&mut rng, [24, 18, 20], 900);
        let d = DenseMatrix::random(&mut rng, 18, 8);
        let c = DenseMatrix::random(&mut rng, 20, 8);
        let a2 = mttkrp_seq(&t, Mode::I, &d, &c);
        let e3 = mttkrp_fiber_eq3(&t, Mode::I, &d, &c);
        let e4 = mttkrp_fiber_eq4(&t, Mode::I, &d, &c);
        assert!(e3.max_abs_diff(&a2) < 1e-4, "eq3 vs alg2: {}", e3.max_abs_diff(&a2));
        assert!(e4.max_abs_diff(&a2) < 1e-4, "eq4 vs alg2: {}", e4.max_abs_diff(&a2));
        assert!(e3.max_abs_diff(&e4) < 1e-4);
    }

    #[test]
    fn single_fiber_tensor() {
        let mut t = CooTensor::new("one", [1, 3, 3]);
        t.push(0, 0, 1, 2.0);
        t.push(0, 2, 0, -1.0);
        let mut rng = Rng::new(31);
        let d = DenseMatrix::random(&mut rng, 3, 4);
        let c = DenseMatrix::random(&mut rng, 3, 4);
        let e3 = mttkrp_fiber_eq3(&t, Mode::I, &d, &c);
        let a2 = mttkrp_seq(&t, Mode::I, &d, &c);
        assert!(e3.max_abs_diff(&a2) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "mode-sorted")]
    fn unsorted_panics() {
        let mut t = CooTensor::new("u", [4, 2, 2]);
        t.push(3, 0, 0, 1.0);
        t.push(0, 1, 1, 1.0); // descending i — unsorted
        let d = DenseMatrix::zeros(2, 2);
        let c = DenseMatrix::zeros(2, 2);
        mttkrp_fiber_eq3(&t, Mode::I, &d, &c);
    }
}
