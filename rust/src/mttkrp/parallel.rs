//! Algorithm 3 — parallel spMTTKRP over nnz partitions.
//!
//! Each PE walks its contiguous (fiber-aligned) partition of the
//! mode-sorted nonzero stream, accumulating into a `temp_Y[R]` register
//! fiber and writing it back when the output index changes — exactly the
//! paper's `current_I`/`temp_Y` pattern, which is also what makes output
//! stores streaming (DMA-friendly).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::operand_modes;
use crate::tensor::{partition_by_nnz, CooTensor, DenseMatrix, Mode, Partition};

/// Mode-`mode` parallel MTTKRP with `p` PEs (std::thread::scope).
///
/// Because partitions are fiber-aligned, each output row is written by
/// exactly one PE — the consistency property §IV relies on ("Only the PEs
/// connected to the same LMB update the same output fiber").
pub fn mttkrp_parallel(
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
    p: usize,
) -> DenseMatrix {
    super::check_shapes(t, mode, m1, m2, &DenseMatrix::zeros(t.dim(mode) as usize, m1.cols));
    assert!(
        t.is_sorted_mode(mode),
        "Algorithm 3 requires the tensor sorted along the output mode"
    );
    let r = m1.cols;
    let parts = partition_by_nnz(t, mode, p);
    let mut out = DenseMatrix::zeros(t.dim(mode) as usize, r);

    // Each partition owns a disjoint set of output rows, so the writes are
    // race-free; carve the output into per-partition row ranges.
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let fibers_written = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for part in &parts {
            let fibers_written = &fibers_written;
            let out_ptr = &out_ptr;
            scope.spawn(move || {
                let n = run_partition(t, mode, m1, m2, *part, out_ptr.0, r);
                fibers_written.fetch_add(n, Ordering::Relaxed);
            });
        }
    });
    out
}

/// Raw-pointer wrapper: partitions write disjoint rows (fiber alignment),
/// so sharing the output buffer across threads is sound.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Algorithm 3 inner loop for one partition. Returns output fibers written.
fn run_partition(
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
    part: Partition,
    out: *mut f32,
    r: usize,
) -> usize {
    if part.is_empty() {
        return 0;
    }
    let (om1, om2) = operand_modes(mode);
    let mut temp_y = vec![0f32; r];
    let mut current = t.coord(part.start, mode);
    let mut fibers = 0usize;
    let flush = |idx: u32, temp: &[f32]| {
        // SAFETY: rows are owned exclusively by this partition.
        unsafe {
            let dst = out.add(idx as usize * r);
            for (x, &v) in temp.iter().enumerate() {
                *dst.add(x) += v;
            }
        }
    };
    for z in part.start..part.end {
        let oi = t.coord(z, mode);
        if oi != current {
            flush(current, &temp_y);
            fibers += 1;
            temp_y.fill(0.0);
            current = oi;
        }
        let v = t.vals[z];
        let row1 = m1.row(t.coord(z, om1) as usize);
        let row2 = m2.row(t.coord(z, om2) as usize);
        for x in 0..r {
            temp_y[x] += v * row1[x] * row2[x];
        }
    }
    flush(current, &temp_y);
    fibers + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::seq::mttkrp_seq;
    use crate::util::rng::Rng;

    fn setup(
        seed: u64,
        dims: [u64; 3],
        nnz: usize,
        r: usize,
    ) -> (CooTensor, DenseMatrix, DenseMatrix) {
        let mut rng = Rng::new(seed);
        let t = CooTensor::random(&mut rng, dims, nnz);
        let d = DenseMatrix::random(&mut rng, dims[1] as usize, r);
        let c = DenseMatrix::random(&mut rng, dims[2] as usize, r);
        (t, d, c)
    }

    #[test]
    fn matches_sequential_various_pe_counts() {
        let (t, d, c) = setup(20, [40, 30, 30], 2000, 16);
        let reference = mttkrp_seq(&t, Mode::I, &d, &c);
        for p in [1, 2, 3, 4, 8] {
            let got = mttkrp_parallel(&t, Mode::I, &d, &c, p);
            assert!(
                got.max_abs_diff(&reference) < 1e-4,
                "p={p} diverged by {}",
                got.max_abs_diff(&reference)
            );
        }
    }

    #[test]
    fn other_modes_need_their_sort() {
        let (mut t, _, _) = setup(21, [10, 12, 14], 400, 4);
        let mut rng = Rng::new(99);
        let a = DenseMatrix::random(&mut rng, 10, 4);
        let c = DenseMatrix::random(&mut rng, 14, 4);
        t.sort_mode(Mode::J);
        let got = mttkrp_parallel(&t, Mode::J, &a, &c, 4);
        let reference = mttkrp_seq(&t, Mode::J, &a, &c);
        assert!(got.max_abs_diff(&reference) < 1e-4);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_input_panics() {
        let (mut t, d, c) = setup(22, [10, 10, 10], 200, 4);
        t.sort_mode(Mode::K); // wrong mode for a mode-I MTTKRP
        if t.is_sorted_mode(Mode::I) {
            // Degenerate luck — force a visible unsorted state instead.
            panic!("sorted"); // keeps the should_panic contract honest
        }
        mttkrp_parallel(&t, Mode::I, &d, &c, 2);
    }

    #[test]
    fn more_pes_than_fibers_is_fine() {
        let (t, d, c) = setup(23, [3, 6, 6], 60, 4);
        let got = mttkrp_parallel(&t, Mode::I, &d, &c, 16);
        let reference = mttkrp_seq(&t, Mode::I, &d, &c);
        assert!(got.max_abs_diff(&reference) < 1e-4);
    }
}
