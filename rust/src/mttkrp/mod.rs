//! MTTKRP reference implementations and the CP-ALS driver.
//!
//! * [`seq`] — Algorithm 2 (COO-based sequential spMTTKRP).
//! * [`parallel`] — Algorithm 3 (partitioned parallel spMTTKRP with the
//!   `current_I`/`temp_Y` output-fiber register pattern).
//! * [`fiber`] — the fiber-oriented formulations Eq. (3)/(4) that the
//!   paper's Type-1/Type-2 compute fabrics execute.
//! * [`linalg`] — small dense kernels for the ALS normal equations.
//! * [`als`] — Algorithm 1 (CP-ALS) built on the above.
//!
//! All variants are cross-checked against each other and against the
//! AOT-compiled JAX/Pallas path in `runtime::compute`.

pub mod als;
pub mod fiber;
pub mod linalg;
pub mod parallel;
pub mod seq;

pub use als::{CpAls, CpAlsOptions, CpAlsReport};
pub use parallel::mttkrp_parallel;
pub use seq::mttkrp_seq;

use crate::tensor::{CooTensor, DenseMatrix, Mode};

/// Operand matrices for a mode-`mode` MTTKRP: output rows indexed by
/// `mode`'s coordinate, inputs by the other two (in cyclic order).
///
/// mode-I: A[i] += val · D[j] ∘ C[k]
/// mode-J: D[j] += val · A[i] ∘ C[k]
/// mode-K: C[k] += val · A[i] ∘ D[j]
pub fn operand_modes(mode: Mode) -> (Mode, Mode) {
    match mode {
        Mode::I => (Mode::J, Mode::K),
        Mode::J => (Mode::I, Mode::K),
        Mode::K => (Mode::I, Mode::J),
    }
}

/// Validate operand shapes for a mode-`mode` MTTKRP over `t`.
pub fn check_shapes(
    t: &CooTensor,
    mode: Mode,
    m1: &DenseMatrix,
    m2: &DenseMatrix,
    out: &DenseMatrix,
) {
    let (om1, om2) = operand_modes(mode);
    assert_eq!(m1.rows as u64, t.dim(om1), "first operand rows != dim {om1:?}");
    assert_eq!(m2.rows as u64, t.dim(om2), "second operand rows != dim {om2:?}");
    assert_eq!(out.rows as u64, t.dim(mode), "output rows != dim {mode:?}");
    assert_eq!(m1.cols, m2.cols, "rank mismatch");
    assert_eq!(m1.cols, out.cols, "rank mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_mode_cycle() {
        assert_eq!(operand_modes(Mode::I), (Mode::J, Mode::K));
        assert_eq!(operand_modes(Mode::J), (Mode::I, Mode::K));
        assert_eq!(operand_modes(Mode::K), (Mode::I, Mode::J));
    }

    #[test]
    #[should_panic(expected = "rank mismatch")]
    fn shape_check_catches_rank() {
        let t = CooTensor::new("t", [2, 3, 4]);
        let m1 = DenseMatrix::zeros(3, 4);
        let m2 = DenseMatrix::zeros(4, 5);
        let out = DenseMatrix::zeros(2, 4);
        check_shapes(&t, Mode::I, &m1, &m2, &out);
    }
}
