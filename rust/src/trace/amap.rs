//! Address map over the memory-interface IP's 31-bit byte-address space:
//! `[tensor | matrix-1 | matrix-2 | output]`, each region aligned to the
//! DRAM row size so streams from different structures never share a row.

use crate::tensor::coo::COO_ELEM_BYTES;
use crate::util::round_up;

/// Byte layout of the four MTTKRP data structures in external memory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AddressMap {
    pub tensor_base: u64,
    pub tensor_bytes: u64,
    pub m1_base: u64,
    pub m1_bytes: u64,
    pub m2_base: u64,
    pub m2_bytes: u64,
    pub out_base: u64,
    pub out_bytes: u64,
    /// Fiber length in bytes (R·4).
    pub fiber_bytes: u64,
    /// Region alignment used (DRAM row bytes).
    pub align: u64,
}

impl AddressMap {
    /// Lay out a tensor with `nnz` elements and factor matrices with
    /// `m1_rows`/`m2_rows`/`out_rows` rows of rank `rank`.
    pub fn new(
        nnz: u64,
        m1_rows: u64,
        m2_rows: u64,
        out_rows: u64,
        rank: usize,
        align: u64,
    ) -> AddressMap {
        let fiber_bytes = rank as u64 * 4;
        let tensor_bytes = nnz * COO_ELEM_BYTES;
        let m1_bytes = m1_rows * fiber_bytes;
        let m2_bytes = m2_rows * fiber_bytes;
        let out_bytes = out_rows * fiber_bytes;
        let tensor_base = 0;
        let m1_base = round_up(tensor_base + tensor_bytes, align);
        let m2_base = round_up(m1_base + m1_bytes, align);
        let out_base = round_up(m2_base + m2_bytes, align);
        AddressMap {
            tensor_base,
            tensor_bytes,
            m1_base,
            m1_bytes,
            m2_base,
            m2_bytes,
            out_base,
            out_bytes,
            fiber_bytes,
            align,
        }
    }

    /// Address of stored tensor element `z` (COO / CISS stream order).
    #[inline]
    pub fn elem(&self, z: u64) -> u64 {
        self.tensor_base + z * COO_ELEM_BYTES
    }

    /// Address of row `r` of input matrix 1 (row-major).
    #[inline]
    pub fn m1_row(&self, r: u64) -> u64 {
        self.m1_base + r * self.fiber_bytes
    }

    /// Address of row `r` of input matrix 2.
    #[inline]
    pub fn m2_row(&self, r: u64) -> u64 {
        self.m2_base + r * self.fiber_bytes
    }

    /// Address of output row `r`.
    #[inline]
    pub fn out_row(&self, r: u64) -> u64 {
        self.out_base + r * self.fiber_bytes
    }

    /// Total mapped bytes.
    pub fn total_bytes(&self) -> u64 {
        self.out_base + self.out_bytes
    }

    /// True if the layout fits a 31-bit address space (MIG on U250).
    pub fn fits_addr_bits(&self, bits: usize) -> bool {
        self.total_bytes() <= 1u64 << bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_ordered_aligned_disjoint() {
        let m = AddressMap::new(1000, 50, 60, 70, 32, 8192);
        assert_eq!(m.tensor_base, 0);
        assert_eq!(m.fiber_bytes, 128);
        assert!(m.m1_base >= m.tensor_bytes);
        assert_eq!(m.m1_base % 8192, 0);
        assert_eq!(m.m2_base % 8192, 0);
        assert_eq!(m.out_base % 8192, 0);
        assert!(m.m2_base >= m.m1_base + m.m1_bytes);
        assert!(m.out_base >= m.m2_base + m.m2_bytes);
    }

    #[test]
    fn element_and_row_addressing() {
        let m = AddressMap::new(10, 4, 4, 4, 8, 4096);
        assert_eq!(m.elem(0), 0);
        assert_eq!(m.elem(3), 48);
        assert_eq!(m.m1_row(2) - m.m1_base, 64);
        assert_eq!(m.out_row(1) - m.out_base, 32);
    }

    #[test]
    fn addr_width_check() {
        let small = AddressMap::new(1000, 10, 10, 10, 8, 4096);
        assert!(small.fits_addr_bits(31));
        // Synth-02-at-full-scale-like sizes exceed 2 GiB.
        let huge = AddressMap::new(144_000_000, 3_000_000, 25_000_000, 2_000_000, 32, 8192);
        assert!(!huge.fits_addr_bits(31));
        assert!(huge.fits_addr_bits(34));
    }
}
