//! Workload generation: tensor + fabric type → per-PE request streams.
//!
//! * **Type-1** (systolic, Tensaurus-like): a single point of access per
//!   data structure — one shared Tensor Loading Unit streams the CISS-
//!   interleaved elements, one Matrix Loading Unit streams fibers, one
//!   Matrix Store Unit drains output fibers. We model the three shared
//!   units as ONE PE front end (pe 0) whose stream interleaves slices.
//! * **Type-2** (Algorithm 3): `p` independent PEs, each replaying its
//!   fiber-aligned partition of the mode-sorted COO stream.

use super::amap::AddressMap;
use super::{Access, AccessClass, NnzWork, PeTrace};
use crate::config::FabricType;
use crate::mttkrp::operand_modes;
use crate::tensor::{partition_by_nnz, CissTensor, CooTensor, Mode};

/// A complete simulator workload: per-PE streams + the address map +
/// summary counters.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub fabric: FabricType,
    pub rank: usize,
    pub amap: AddressMap,
    pub pe_traces: Vec<PeTrace>,
    pub nnz: usize,
}

impl Workload {
    pub fn n_accesses(&self) -> usize {
        self.pe_traces.iter().map(PeTrace::n_accesses).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.pe_traces.iter().map(PeTrace::total_bytes).sum()
    }
}

/// Build the mode-`mode` MTTKRP workload for `t` on a `fabric` fabric with
/// `n_pes` PEs and rank `rank`. `row_align` is the DRAM row size used to
/// align regions (pass `DramConfig::row_bytes`).
pub fn workload_from_tensor(
    t: &CooTensor,
    mode: Mode,
    fabric: FabricType,
    n_pes: usize,
    rank: usize,
    row_align: u64,
) -> Workload {
    let (om1, om2) = operand_modes(mode);
    let amap = AddressMap::new(
        t.nnz() as u64,
        t.dim(om1),
        t.dim(om2),
        t.dim(mode),
        rank,
        row_align,
    );
    let mut sorted = t.clone();
    if !sorted.is_sorted_mode(mode) {
        sorted.sort_mode(mode);
    }
    let pe_traces = match fabric {
        FabricType::Type1 => type1_trace(&sorted, mode, om1, om2, n_pes, &amap),
        FabricType::Type2 => type2_traces(&sorted, mode, om1, om2, n_pes, &amap),
    };
    Workload {
        name: t.name.clone(),
        fabric,
        rank,
        amap,
        pe_traces,
        nnz: sorted.nnz(),
    }
}

fn access(class: AccessClass, addr: u64, bytes: u64) -> Access {
    Access {
        class,
        addr,
        bytes: bytes as u32,
    }
}

/// Work item for nonzero stream position `pos` whose element lives at
/// stream address `pos` (Type-1 streams CISS order, Type-2 COO order).
/// Shared with the streaming sources in [`super::source`], which must
/// emit byte-identical items.
pub(crate) fn work_item(
    amap: &AddressMap,
    pos: u64,
    j: u64,
    k: u64,
    store_row: Option<u64>,
) -> NnzWork {
    NnzWork {
        elem: access(AccessClass::TensorElem, amap.elem(pos), 16),
        fibers: [
            access(AccessClass::FiberLoad, amap.m1_row(j), amap.fiber_bytes),
            access(AccessClass::FiberLoad, amap.m2_row(k), amap.fiber_bytes),
        ],
        store: store_row
            .map(|r| access(AccessClass::FiberStore, amap.out_row(r), amap.fiber_bytes)),
    }
}

/// Type-1: one shared front end streaming the CISS-interleaved elements.
/// Stores fire on `end_of_slice` markers (the systolic array drains the
/// finished output fiber through the shared MSU).
fn type1_trace(
    t: &CooTensor,
    _mode: Mode,
    om1: Mode,
    om2: Mode,
    n_pes: usize,
    amap: &AddressMap,
) -> Vec<PeTrace> {
    // The CISS layout interleaves slices over the systolic columns.
    let ciss = CissTensor::from_coo(t, _mode, n_pes.max(1));
    let mut work = Vec::with_capacity(ciss.nnz());
    for (pos, e) in ciss.elems.iter().enumerate() {
        let (c1, c2) = match (om1, om2) {
            (Mode::J, Mode::K) => (e.j, e.k),
            (Mode::I, Mode::K) => (e.i, e.k),
            (Mode::I, Mode::J) => (e.i, e.j),
            _ => unreachable!("operand modes are always cyclic"),
        };
        let out_idx = match _mode {
            Mode::I => e.i,
            Mode::J => e.j,
            Mode::K => e.k,
        };
        work.push(work_item(
            amap,
            pos as u64,
            c1 as u64,
            c2 as u64,
            e.end_of_slice.then_some(out_idx as u64),
        ));
    }
    vec![PeTrace { pe: 0, work }]
}

/// Type-2: independent PEs over fiber-aligned partitions (Algorithm 3).
/// Stores fire when the output index changes and at partition end.
fn type2_traces(
    t: &CooTensor,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    n_pes: usize,
    amap: &AddressMap,
) -> Vec<PeTrace> {
    let parts = partition_by_nnz(t, mode, n_pes);
    let mut traces = Vec::with_capacity(parts.len());
    for part in parts {
        let mut work = Vec::with_capacity(part.len());
        for z in part.start..part.end {
            let j = t.coord(z, om1) as u64;
            let k = t.coord(z, om2) as u64;
            let oi = t.coord(z, mode) as u64;
            // Algorithm 3 writes temp_Y back when indI changes; in the
            // request stream that is a store attached to the *last*
            // element of each fiber run.
            let is_last_of_fiber =
                z + 1 == part.end || t.coord(z + 1, mode) as u64 != oi;
            work.push(work_item(amap, z as u64, j, k, is_last_of_fiber.then_some(oi)));
        }
        traces.push(PeTrace { pe: part.pe, work });
    }
    traces
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor(seed: u64) -> CooTensor {
        let mut rng = Rng::new(seed);
        CooTensor::random(&mut rng, [32, 24, 28], 600)
    }

    #[test]
    fn type2_covers_all_nonzeros_once() {
        let t = tensor(60);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type2, 4, 32, 8192);
        assert_eq!(w.pe_traces.len(), 4);
        let total: usize = w.pe_traces.iter().map(|p| p.work.len()).sum();
        assert_eq!(total, t.nnz());
        assert_eq!(w.nnz, t.nnz());
    }

    #[test]
    fn type1_single_front_end() {
        let t = tensor(61);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type1, 4, 32, 8192);
        assert_eq!(w.pe_traces.len(), 1, "Type-1 has one point of access");
        assert_eq!(w.pe_traces[0].work.len(), t.nnz());
    }

    #[test]
    fn store_count_equals_fiber_count_type2() {
        let t = tensor(62);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type2, 4, 32, 8192);
        let stores: usize = w
            .pe_traces
            .iter()
            .flat_map(|p| &p.work)
            .filter(|x| x.store.is_some())
            .count();
        // Fiber-aligned partitions ⇒ exactly one store per distinct i.
        assert_eq!(stores, t.distinct_along(Mode::I));
    }

    #[test]
    fn store_count_equals_slice_count_type1() {
        let t = tensor(63);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type1, 4, 32, 8192);
        let stores: usize = w.pe_traces[0]
            .work
            .iter()
            .filter(|x| x.store.is_some())
            .count();
        assert_eq!(stores, t.distinct_along(Mode::I));
    }

    #[test]
    fn addresses_fall_in_their_regions() {
        let t = tensor(64);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type2, 2, 16, 8192);
        let a = &w.amap;
        for p in &w.pe_traces {
            for x in &p.work {
                assert!(x.elem.addr < a.m1_base);
                assert!(x.fibers[0].addr >= a.m1_base && x.fibers[0].addr < a.m2_base);
                assert!(x.fibers[1].addr >= a.m2_base && x.fibers[1].addr < a.out_base);
                if let Some(s) = x.store {
                    assert!(s.addr >= a.out_base);
                    assert_eq!(s.bytes as u64, a.fiber_bytes);
                }
            }
        }
    }

    #[test]
    fn elements_are_sequential_per_stream() {
        let t = tensor(65);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type1, 4, 32, 8192);
        let addrs: Vec<u64> = w.pe_traces[0].work.iter().map(|x| x.elem.addr).collect();
        for (i, pair) in addrs.windows(2).enumerate() {
            assert_eq!(pair[1] - pair[0], 16, "gap at {i}");
        }
    }

    #[test]
    fn other_mode_workloads() {
        let t = tensor(66);
        let w = workload_from_tensor(&t, Mode::J, FabricType::Type2, 4, 8, 8192);
        // Output rows indexed by j (dim 24), operands by i (32) and k (28).
        assert_eq!(w.amap.fiber_bytes, 32);
        let total: usize = w.pe_traces.iter().map(|p| p.work.len()).sum();
        assert_eq!(total, t.nnz());
    }
}
