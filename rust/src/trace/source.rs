//! Streaming trace sources — the pull side of the workload API.
//!
//! The simulator used to require a fully materialized [`Workload`]
//! (`Vec<NnzWork>` per PE, ~100 B per nonzero) before a single cycle ran,
//! which capped runs at scaled-down datasets. This module inverts the
//! contract: a [`TraceSource`] describes the per-PE streams up front
//! (count, owner PE, length) and hands out chunked [`WorkCursor`]s that
//! generate [`NnzWork`] items on demand, so peak workload-side memory is
//! bounded by [`WORK_CHUNK`] per front end — independent of nnz.
//!
//! Three implementations, all report-identical by construction (and by
//! the randomized property in `tests/integration_engine.rs`):
//!
//! * [`Workload`] — the materialized streams, kept as the regression
//!   oracle; its cursors replay the pre-built vectors.
//! * [`CooStreamSource`] — generates the Type-1 (CISS-interleaved) or
//!   Type-2 (fiber-aligned partitions) stream lazily from an in-memory
//!   [`CooTensor`]; only the 16 B/nnz tensor is resident, never the
//!   ~100 B/nnz access stream.
//! * [`TnsStreamSource`] — generates the same streams straight from a
//!   mode-sorted FROSTT `.tns` file: a scan pass records nnz, dims and
//!   partition byte offsets, then each cursor re-reads its slice of the
//!   file through a [`TnsReader`]. Peak memory is a few `BufReader`s —
//!   full-scale Table III datasets fit on any host.
//!
//! # Cursor lifecycle
//!
//! `MemorySystem::new` calls [`TraceSource::open`] once per stream; each
//! [`PeFrontEnd`](crate::sim::pe::PeFrontEnd) then pulls up to
//! [`WORK_CHUNK`] items at a time via [`WorkCursor::refill`] as its
//! decoupling window drains. [`TraceSource::stream_len`] is exact (the
//! run loop sizes its watchdog and report totals from it), so a cursor
//! returning 0 before `stream_len` items is a contract violation and
//! panics in the front end.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::amap::AddressMap;
use super::gen::{work_item, Workload};
use super::NnzWork;
use crate::config::FabricType;
use crate::mttkrp::operand_modes;
use crate::tensor::io::{scan_tns, TnsReader, TnsScan};
use crate::tensor::{partition_by_nnz, CooTensor, Mode, Partition};

/// Max work items a front end pulls per [`WorkCursor::refill`] — the
/// workload-side memory bound per stream (~100 B per item).
pub const WORK_CHUNK: usize = 1024;

/// A chunked pull cursor over one PE's work stream.
pub trait WorkCursor: Send {
    /// Append up to `max` items to `out`; returns how many were
    /// appended. 0 means the stream is exhausted.
    fn refill(&mut self, out: &mut Vec<NnzWork>, max: usize) -> usize;
}

/// A workload described as per-PE streams that are generated on demand.
///
/// Stream geometry (count, PE ids, exact lengths) is known up front;
/// the work items themselves are pulled chunk-wise through
/// [`WorkCursor`]s. See the module docs for the lifecycle.
pub trait TraceSource: Send + Sync + std::fmt::Debug {
    /// Workload label (dataset name) used in reports.
    fn name(&self) -> &str;
    /// Compute-fabric type the streams were generated for.
    fn fabric(&self) -> FabricType;
    /// Total nonzeros across all streams.
    fn nnz(&self) -> usize;
    /// Number of independent streams (Type-1: 1; Type-2: one per PE).
    fn n_streams(&self) -> usize;
    /// PE id that owns stream `s`.
    fn stream_pe(&self, s: usize) -> usize;
    /// Exact number of work items stream `s` will yield.
    fn stream_len(&self, s: usize) -> usize;
    /// Open a fresh cursor at the start of stream `s`.
    fn open(&self, s: usize) -> Box<dyn WorkCursor>;
    /// The address map the streams were generated against, when the
    /// source knows it. Consumers that must invert addresses back to
    /// (structure, row) — the cluster layer's remote-row classifier —
    /// require `Some`; every in-tree source provides it.
    fn amap(&self) -> Option<&AddressMap> {
        None
    }
}

/// Forward through `Arc` so shared sources (sweep dedup) plug directly
/// into the generic `MemorySystem::new<S: TraceSource + ?Sized>`.
impl<S: TraceSource + ?Sized> TraceSource for Arc<S> {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn fabric(&self) -> FabricType {
        (**self).fabric()
    }
    fn nnz(&self) -> usize {
        (**self).nnz()
    }
    fn n_streams(&self) -> usize {
        (**self).n_streams()
    }
    fn stream_pe(&self, s: usize) -> usize {
        (**self).stream_pe(s)
    }
    fn stream_len(&self, s: usize) -> usize {
        (**self).stream_len(s)
    }
    fn open(&self, s: usize) -> Box<dyn WorkCursor> {
        (**self).open(s)
    }
    fn amap(&self) -> Option<&AddressMap> {
        (**self).amap()
    }
}

/// Cursor over a pre-materialized vector (the [`Workload`] oracle and
/// unit-test front ends).
pub struct VecCursor {
    work: Vec<NnzWork>,
    pos: usize,
}

impl VecCursor {
    pub fn new(work: Vec<NnzWork>) -> VecCursor {
        VecCursor { work, pos: 0 }
    }
}

impl WorkCursor for VecCursor {
    fn refill(&mut self, out: &mut Vec<NnzWork>, max: usize) -> usize {
        let n = max.min(self.work.len() - self.pos);
        out.extend_from_slice(&self.work[self.pos..self.pos + n]);
        self.pos += n;
        n
    }
}

/// The materialized workload is one (regression-oracle) trace source.
impl TraceSource for Workload {
    fn name(&self) -> &str {
        &self.name
    }
    fn fabric(&self) -> FabricType {
        self.fabric
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn n_streams(&self) -> usize {
        self.pe_traces.len()
    }
    fn stream_pe(&self, s: usize) -> usize {
        self.pe_traces[s].pe
    }
    fn stream_len(&self, s: usize) -> usize {
        self.pe_traces[s].work.len()
    }
    fn open(&self, s: usize) -> Box<dyn WorkCursor> {
        Box::new(VecCursor::new(self.pe_traces[s].work.clone()))
    }
    fn amap(&self) -> Option<&AddressMap> {
        Some(&self.amap)
    }
}

// ---------------------------------------------------------------------
// Streaming from an in-memory COO tensor
// ---------------------------------------------------------------------

/// Streams the mode-sorted access pattern lazily from a [`CooTensor`].
///
/// Construction sorts the tensor along `mode` (one clone) only when it
/// is not already in mode order — the same rule `workload_from_tensor`
/// uses — and computes the address map plus (Type-2) the fiber-aligned
/// partitions. No access stream is ever materialized.
#[derive(Debug)]
pub struct CooStreamSource {
    tensor: Arc<CooTensor>,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    fabric: FabricType,
    amap: AddressMap,
    /// Type-1 CISS interleave width (the systolic column count).
    n_channels: usize,
    /// Type-2 fiber-aligned partitions (empty for Type-1).
    parts: Vec<Partition>,
}

impl CooStreamSource {
    pub fn new(
        t: Arc<CooTensor>,
        mode: Mode,
        fabric: FabricType,
        n_pes: usize,
        rank: usize,
        row_align: u64,
    ) -> CooStreamSource {
        let (om1, om2) = operand_modes(mode);
        let amap = AddressMap::new(
            t.nnz() as u64,
            t.dim(om1),
            t.dim(om2),
            t.dim(mode),
            rank,
            row_align,
        );
        let tensor = if t.is_sorted_mode(mode) {
            t
        } else {
            let mut sorted = (*t).clone();
            sorted.sort_mode(mode);
            Arc::new(sorted)
        };
        let parts = match fabric {
            FabricType::Type1 => Vec::new(),
            FabricType::Type2 => partition_by_nnz(&tensor, mode, n_pes),
        };
        CooStreamSource {
            tensor,
            mode,
            om1,
            om2,
            fabric,
            amap,
            n_channels: n_pes.max(1),
            parts,
        }
    }

    pub fn amap(&self) -> &AddressMap {
        &self.amap
    }
}

impl TraceSource for CooStreamSource {
    fn name(&self) -> &str {
        &self.tensor.name
    }
    fn fabric(&self) -> FabricType {
        self.fabric
    }
    fn nnz(&self) -> usize {
        self.tensor.nnz()
    }
    fn n_streams(&self) -> usize {
        match self.fabric {
            FabricType::Type1 => 1,
            FabricType::Type2 => self.parts.len(),
        }
    }
    fn stream_pe(&self, s: usize) -> usize {
        match self.fabric {
            FabricType::Type1 => 0,
            FabricType::Type2 => self.parts[s].pe,
        }
    }
    fn stream_len(&self, s: usize) -> usize {
        match self.fabric {
            FabricType::Type1 => self.tensor.nnz(),
            FabricType::Type2 => self.parts[s].len(),
        }
    }
    fn open(&self, s: usize) -> Box<dyn WorkCursor> {
        match self.fabric {
            FabricType::Type1 => {
                assert_eq!(s, 0, "Type-1 has a single stream");
                let chans = (0..self.n_channels)
                    .map(|ch| CooChanStream {
                        t: self.tensor.clone(),
                        mode: self.mode,
                        om1: self.om1,
                        om2: self.om2,
                        ch,
                        n_channels: self.n_channels,
                        z: 0,
                        slice_end: 0,
                        scan_from: 0,
                        next_slice_idx: 0,
                    })
                    .collect();
                Box::new(Type1Cursor {
                    chans,
                    next_ch: 0,
                    pos: 0,
                    remaining: self.tensor.nnz(),
                    amap: self.amap.clone(),
                })
            }
            FabricType::Type2 => {
                let part = self.parts[s];
                Box::new(CooType2Cursor {
                    t: self.tensor.clone(),
                    amap: self.amap.clone(),
                    mode: self.mode,
                    om1: self.om1,
                    om2: self.om2,
                    z: part.start,
                    end: part.end,
                })
            }
        }
    }
    fn amap(&self) -> Option<&AddressMap> {
        Some(&self.amap)
    }
}

/// Type-2 cursor: walks one contiguous partition of the sorted stream.
struct CooType2Cursor {
    t: Arc<CooTensor>,
    amap: AddressMap,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    z: usize,
    end: usize,
}

impl WorkCursor for CooType2Cursor {
    fn refill(&mut self, out: &mut Vec<NnzWork>, max: usize) -> usize {
        let n = max.min(self.end - self.z);
        for _ in 0..n {
            let z = self.z;
            let oi = self.t.coord(z, self.mode) as u64;
            // Algorithm 3 writes temp_Y back when the output index
            // changes: a store rides on the last element of each fiber.
            let last = z + 1 == self.end || self.t.coord(z + 1, self.mode) as u64 != oi;
            out.push(work_item(
                &self.amap,
                z as u64,
                self.t.coord(z, self.om1) as u64,
                self.t.coord(z, self.om2) as u64,
                last.then_some(oi),
            ));
            self.z += 1;
        }
        n
    }
}

// ---------------------------------------------------------------------
// Type-1 interleaving, shared by the COO and .tns backends
// ---------------------------------------------------------------------

/// One CISS channel's element stream: yields
/// `(operand-1 coord, operand-2 coord, output index, end_of_slice)` for
/// the slices dealt to this channel (slice index mod channel count).
trait ChanStream: Send {
    fn next(&mut self) -> Option<(u64, u64, u64, bool)>;
}

/// The Type-1 single-stream cursor: one element per non-exhausted
/// channel per beat, exactly the `CissTensor::from_coo` interleave, with
/// a global position counter addressing the interleaved element store.
struct Type1Cursor<C> {
    chans: Vec<C>,
    /// Round-robin pointer (persists across refills mid-beat).
    next_ch: usize,
    /// Interleaved stream position — the element's stored address.
    pos: u64,
    remaining: usize,
    amap: AddressMap,
}

impl<C: ChanStream> WorkCursor for Type1Cursor<C> {
    fn refill(&mut self, out: &mut Vec<NnzWork>, max: usize) -> usize {
        let mut n = 0;
        while n < max && self.remaining > 0 {
            let ch = self.next_ch;
            self.next_ch = (self.next_ch + 1) % self.chans.len();
            if let Some((c1, c2, oi, eos)) = self.chans[ch].next() {
                out.push(work_item(&self.amap, self.pos, c1, c2, eos.then_some(oi)));
                self.pos += 1;
                self.remaining -= 1;
                n += 1;
            }
        }
        n
    }
}

/// Lazy channel scan over a mode-sorted [`CooTensor`]: O(1) state, no
/// per-slice index. Each channel walks the whole stream but only emits
/// the slices dealt to it.
struct CooChanStream {
    t: Arc<CooTensor>,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    ch: usize,
    n_channels: usize,
    /// Current adopted slice: next element `z`, exclusive end.
    z: usize,
    slice_end: usize,
    /// Scan frontier for finding this channel's next slice.
    scan_from: usize,
    next_slice_idx: usize,
}

impl CooChanStream {
    fn slice_end_from(&self, start: usize) -> usize {
        let n = self.t.nnz();
        let c = self.t.coord(start, self.mode);
        let mut z = start + 1;
        while z < n && self.t.coord(z, self.mode) == c {
            z += 1;
        }
        z
    }
}

impl ChanStream for CooChanStream {
    fn next(&mut self) -> Option<(u64, u64, u64, bool)> {
        if self.z >= self.slice_end {
            loop {
                if self.scan_from >= self.t.nnz() {
                    return None;
                }
                let start = self.scan_from;
                let end = self.slice_end_from(start);
                let idx = self.next_slice_idx;
                self.next_slice_idx += 1;
                self.scan_from = end;
                if idx % self.n_channels == self.ch {
                    self.z = start;
                    self.slice_end = end;
                    break;
                }
            }
        }
        let z = self.z;
        self.z += 1;
        Some((
            self.t.coord(z, self.om1) as u64,
            self.t.coord(z, self.om2) as u64,
            self.t.coord(z, self.mode) as u64,
            self.z == self.slice_end,
        ))
    }
}

// ---------------------------------------------------------------------
// Streaming straight from a FROSTT `.tns` file
// ---------------------------------------------------------------------

/// One Type-2 partition of the file: nonzero range plus where its first
/// line starts (byte offset + preceding line count, so reopened readers
/// keep correct error context).
#[derive(Debug, Clone, Copy)]
struct TnsPart {
    pe: usize,
    start: usize,
    end: usize,
    offset: u64,
    lines_before: usize,
}

/// Streams the access pattern directly from a `.tns` file that is
/// already sorted along the MTTKRP mode (FROSTT files are mode-0
/// sorted). Construction scans the file once for geometry; cursors then
/// re-read only their slice. For files *not* sorted along the requested
/// mode, load them with [`crate::tensor::io::read_tns`] and use
/// [`CooStreamSource`] (what `Scenario::trace_source` falls back to).
#[derive(Debug)]
pub struct TnsStreamSource {
    path: PathBuf,
    name: String,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    fabric: FabricType,
    amap: AddressMap,
    nnz: usize,
    n_channels: usize,
    parts: Vec<TnsPart>,
}

impl TnsStreamSource {
    /// Scan `path` and build the source. Errors if the file is empty or
    /// not sorted along `mode`.
    pub fn open(
        path: &Path,
        mode: Mode,
        fabric: FabricType,
        n_pes: usize,
        rank: usize,
        row_align: u64,
    ) -> crate::Result<TnsStreamSource> {
        let scan = scan_tns(path)?;
        TnsStreamSource::from_scan(path, &scan, mode, fabric, n_pes, rank, row_align)
    }

    /// Build from a pre-computed [`scan_tns`] result (avoids re-scanning
    /// when the caller already inspected the file).
    pub fn from_scan(
        path: &Path,
        scan: &TnsScan,
        mode: Mode,
        fabric: FabricType,
        n_pes: usize,
        rank: usize,
        row_align: u64,
    ) -> crate::Result<TnsStreamSource> {
        crate::ensure!(scan.nnz > 0, "{}: empty tensor", path.display());
        crate::ensure!(
            scan.sorted[mode.index()],
            "{}: not sorted along mode {} — sort the file, or load it \
             with read_tns and use CooStreamSource",
            path.display(),
            mode.name()
        );
        let (om1, om2) = operand_modes(mode);
        let amap = AddressMap::new(
            scan.nnz as u64,
            scan.dims[om1.index()],
            scan.dims[om2.index()],
            scan.dims[mode.index()],
            rank,
            row_align,
        );
        let parts = match fabric {
            FabricType::Type1 => Vec::new(),
            FabricType::Type2 => tns_partitions(path, mode, n_pes, scan.nnz)?,
        };
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| "tns".into());
        Ok(TnsStreamSource {
            path: path.to_path_buf(),
            name,
            mode,
            om1,
            om2,
            fabric,
            amap,
            nnz: scan.nnz,
            n_channels: n_pes.max(1),
            parts,
        })
    }

    pub fn amap(&self) -> &AddressMap {
        &self.amap
    }
}

/// Replays `partition_by_nnz`'s boundary rule over the file: balanced
/// nnz targets, each end advanced to the next fiber boundary, the last
/// partition absorbing the remainder — recording where each partition's
/// first line lives so cursors can seek straight to it.
fn tns_partitions(path: &Path, mode: Mode, p: usize, n: usize) -> crate::Result<Vec<TnsPart>> {
    assert!(p > 0);
    let target = n as f64 / p as f64;
    let ideal = |pe: usize| ((pe + 1) as f64 * target).round() as usize;
    let mut r = TnsReader::open(path)?;
    let mut parts = Vec::with_capacity(p);
    let mut start = 0usize;
    let mut start_off = 0u64;
    let mut start_lines = 0usize;
    let mut prev_coord: Option<u32> = None;
    let mut z = 0usize;
    while let Some(e) = r.next_elem()? {
        let c = e.idx[mode.index()];
        // Close every partition whose (fiber-aligned) end is this z.
        while parts.len() + 1 < p
            && z >= ideal(parts.len()).clamp(start, n)
            && (z == start || prev_coord != Some(c))
        {
            parts.push(TnsPart {
                pe: parts.len(),
                start,
                end: z,
                offset: start_off,
                lines_before: start_lines,
            });
            start = z;
            start_off = e.offset;
            start_lines = e.lineno - 1;
        }
        z += 1;
        prev_coord = Some(c);
    }
    crate::ensure!(
        z == n,
        "{}: file changed during scan ({z} nonzeros, expected {n})",
        path.display()
    );
    // Open partitions (the last always, earlier ones when no fiber
    // boundary appeared past their target) all end at n.
    while parts.len() < p {
        parts.push(TnsPart {
            pe: parts.len(),
            start,
            end: n,
            offset: start_off,
            lines_before: start_lines,
        });
        start = n;
        start_off = r.offset();
        start_lines = r.lines_read();
    }
    Ok(parts)
}

impl TraceSource for TnsStreamSource {
    fn name(&self) -> &str {
        &self.name
    }
    fn fabric(&self) -> FabricType {
        self.fabric
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn n_streams(&self) -> usize {
        match self.fabric {
            FabricType::Type1 => 1,
            FabricType::Type2 => self.parts.len(),
        }
    }
    fn stream_pe(&self, s: usize) -> usize {
        match self.fabric {
            FabricType::Type1 => 0,
            FabricType::Type2 => self.parts[s].pe,
        }
    }
    fn stream_len(&self, s: usize) -> usize {
        match self.fabric {
            FabricType::Type1 => self.nnz,
            FabricType::Type2 => self.parts[s].end - self.parts[s].start,
        }
    }
    fn open(&self, s: usize) -> Box<dyn WorkCursor> {
        // The file was validated at construction; losing it mid-run is
        // unrecoverable for the simulation, so cursors panic on IO
        // errors with file context rather than threading Results
        // through the hot path.
        match self.fabric {
            FabricType::Type1 => {
                assert_eq!(s, 0, "Type-1 has a single stream");
                let chans = (0..self.n_channels)
                    .map(|ch| {
                        TnsChanStream::new(&self.path, self.mode, self.om1, self.om2, ch, self.n_channels)
                            .unwrap_or_else(|e| panic!("{}: {e}", self.path.display()))
                    })
                    .collect();
                Box::new(Type1Cursor {
                    chans,
                    next_ch: 0,
                    pos: 0,
                    remaining: self.nnz,
                    amap: self.amap.clone(),
                })
            }
            FabricType::Type2 => {
                let part = self.parts[s];
                let mut rdr = TnsReader::open_at(&self.path, part.offset, part.lines_before)
                    .unwrap_or_else(|e| panic!("{}: {e}", self.path.display()));
                let ahead = if part.end > part.start {
                    Some(next_idx(&mut rdr, &self.path))
                } else {
                    None
                };
                Box::new(TnsType2Cursor {
                    rdr,
                    path: self.path.clone(),
                    amap: self.amap.clone(),
                    mode: self.mode,
                    om1: self.om1,
                    om2: self.om2,
                    z: part.start,
                    end: part.end,
                    ahead,
                })
            }
        }
    }
    fn amap(&self) -> Option<&AddressMap> {
        Some(&self.amap)
    }
}

/// Next element's coordinates, panicking with context on IO/parse
/// errors or a file shorter than the scan said (see [`TraceSource::open`]).
fn next_idx(rdr: &mut TnsReader, path: &Path) -> [u32; 3] {
    rdr.next_elem()
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()))
        .unwrap_or_else(|| panic!("{}: file shrank during simulation", path.display()))
        .idx
}

/// Type-2 cursor: seeked to its partition's first line, reads
/// `end - start` elements with one element of lookahead for the
/// fiber-boundary store rule.
struct TnsType2Cursor {
    rdr: TnsReader,
    path: PathBuf,
    amap: AddressMap,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    z: usize,
    end: usize,
    ahead: Option<[u32; 3]>,
}

impl WorkCursor for TnsType2Cursor {
    fn refill(&mut self, out: &mut Vec<NnzWork>, max: usize) -> usize {
        let mut n = 0;
        while n < max && self.z < self.end {
            let cur = self.ahead.take().expect("scan counted this element");
            self.ahead = if self.z + 1 < self.end {
                Some(next_idx(&mut self.rdr, &self.path))
            } else {
                None
            };
            let mi = self.mode.index();
            let oi = cur[mi] as u64;
            let last = match self.ahead {
                None => true,
                Some(nxt) => nxt[mi] != cur[mi],
            };
            out.push(work_item(
                &self.amap,
                self.z as u64,
                cur[self.om1.index()] as u64,
                cur[self.om2.index()] as u64,
                last.then_some(oi),
            ));
            self.z += 1;
            n += 1;
        }
        n
    }
}

/// Per-channel file reader for the Type-1 interleave: walks the whole
/// file, tracks the slice index (mode-coordinate changes), and emits
/// only the slices dealt to its channel.
struct TnsChanStream {
    rdr: TnsReader,
    path: PathBuf,
    mode: Mode,
    om1: Mode,
    om2: Mode,
    ch: usize,
    n_channels: usize,
    /// Lookahead element + the slice index it belongs to.
    ahead: Option<([u32; 3], usize)>,
}

impl TnsChanStream {
    fn new(
        path: &Path,
        mode: Mode,
        om1: Mode,
        om2: Mode,
        ch: usize,
        n_channels: usize,
    ) -> crate::Result<TnsChanStream> {
        let mut rdr = TnsReader::open(path)?;
        let ahead = rdr.next_elem()?.map(|e| (e.idx, 0));
        Ok(TnsChanStream {
            rdr,
            path: path.to_path_buf(),
            mode,
            om1,
            om2,
            ch,
            n_channels,
            ahead,
        })
    }
}

impl ChanStream for TnsChanStream {
    fn next(&mut self) -> Option<(u64, u64, u64, bool)> {
        let mi = self.mode.index();
        loop {
            let (cur, sidx) = self.ahead.take()?;
            let nxt = self
                .rdr
                .next_elem()
                .unwrap_or_else(|e| panic!("{}: {e}", self.path.display()))
                .map(|e| e.idx);
            let (eos, nsidx) = match nxt {
                None => (true, sidx),
                Some(nx) => {
                    let change = nx[mi] != cur[mi];
                    (change, sidx + usize::from(change))
                }
            };
            self.ahead = nxt.map(|nx| (nx, nsidx));
            if sidx % self.n_channels == self.ch {
                return Some((
                    cur[self.om1.index()] as u64,
                    cur[self.om2.index()] as u64,
                    cur[mi] as u64,
                    eos,
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::io::write_tns;
    use crate::trace::workload_from_tensor;
    use crate::util::rng::Rng;

    fn drain(src: &dyn TraceSource, s: usize) -> Vec<NnzWork> {
        let mut cur = src.open(s);
        let mut out = Vec::new();
        // Tiny chunk size exercises refill boundaries.
        while cur.refill(&mut out, 7) > 0 {}
        out
    }

    fn assert_matches_workload(src: &dyn TraceSource, w: &Workload) {
        assert_eq!(src.n_streams(), w.pe_traces.len());
        assert_eq!(src.nnz(), w.nnz);
        assert_eq!(src.fabric(), w.fabric);
        for (s, t) in w.pe_traces.iter().enumerate() {
            assert_eq!(src.stream_pe(s), t.pe);
            assert_eq!(src.stream_len(s), t.work.len(), "stream {s} length");
            let got = drain(src, s);
            assert_eq!(got.len(), t.work.len(), "stream {s} drained length");
            for (i, (a, b)) in got.iter().zip(&t.work).enumerate() {
                assert_eq!(a, b, "stream {s} item {i}");
            }
        }
    }

    #[test]
    fn coo_stream_matches_materialized_both_fabrics() {
        let mut rng = Rng::new(71);
        let t = CooTensor::random(&mut rng, [24, 300, 400], 700);
        for fabric in [FabricType::Type1, FabricType::Type2] {
            let w = workload_from_tensor(&t, Mode::I, fabric, 4, 32, 8192);
            let src = CooStreamSource::new(Arc::new(t.clone()), Mode::I, fabric, 4, 32, 8192);
            assert_matches_workload(&src, &w);
        }
    }

    #[test]
    fn coo_stream_matches_materialized_other_modes() {
        let mut rng = Rng::new(72);
        let t = CooTensor::random(&mut rng, [16, 20, 24], 500);
        for mode in [Mode::J, Mode::K] {
            for fabric in [FabricType::Type1, FabricType::Type2] {
                let w = workload_from_tensor(&t, mode, fabric, 3, 16, 4096);
                let src =
                    CooStreamSource::new(Arc::new(t.clone()), mode, fabric, 3, 16, 4096);
                assert_matches_workload(&src, &w);
            }
        }
    }

    #[test]
    fn workload_oracle_streams_itself() {
        let mut rng = Rng::new(73);
        let t = CooTensor::random(&mut rng, [12, 40, 50], 200);
        let w = workload_from_tensor(&t, Mode::I, FabricType::Type2, 2, 8, 4096);
        assert_matches_workload(&w, &w);
    }

    #[test]
    fn tns_stream_matches_materialized_both_fabrics() {
        let mut rng = Rng::new(74);
        let mut t = CooTensor::random(&mut rng, [20, 60, 70], 400);
        t.sort_mode(Mode::I);
        let dir = std::env::temp_dir().join(format!("memsys-src-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.tns");
        write_tns(&t, &path).unwrap();
        for fabric in [FabricType::Type1, FabricType::Type2] {
            let w = workload_from_tensor(&t, Mode::I, fabric, 4, 32, 8192);
            let src = TnsStreamSource::open(&path, Mode::I, fabric, 4, 32, 8192).unwrap();
            assert_matches_workload(&src, &w);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tns_partitions_match_in_memory_partitioning() {
        let mut rng = Rng::new(75);
        let mut t = CooTensor::random(&mut rng, [9, 30, 30], 250);
        t.sort_mode(Mode::I);
        let dir = std::env::temp_dir().join(format!("memsys-src-p{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("parts.tns");
        write_tns(&t, &path).unwrap();
        // More PEs than fibers → some partitions are empty; boundaries
        // must still match partition_by_nnz exactly.
        for p in [1usize, 3, 4, 16] {
            let expect = partition_by_nnz(&t, Mode::I, p);
            let src = TnsStreamSource::open(&path, Mode::I, FabricType::Type2, p, 8, 4096)
                .unwrap();
            let got: Vec<(usize, usize)> =
                src.parts.iter().map(|q| (q.start, q.end)).collect();
            let want: Vec<(usize, usize)> =
                expect.iter().map(|q| (q.start, q.end)).collect();
            assert_eq!(got, want, "p={p}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tns_source_rejects_unsorted_and_empty() {
        let dir = std::env::temp_dir().join(format!("memsys-src-b{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let unsorted = dir.join("unsorted.tns");
        std::fs::write(&unsorted, "2 1 1 1.0\n1 1 1 2.0\n").unwrap();
        let err = TnsStreamSource::open(&unsorted, Mode::I, FabricType::Type2, 2, 8, 4096)
            .unwrap_err()
            .to_string();
        assert!(err.contains("not sorted"), "{err}");
        // Sorted along J though — the same file streams fine for mode j.
        assert!(TnsStreamSource::open(&unsorted, Mode::J, FabricType::Type2, 2, 8, 4096).is_ok());
        let empty = dir.join("empty.tns");
        std::fs::write(&empty, "# only a comment\n").unwrap();
        assert!(TnsStreamSource::open(&empty, Mode::I, FabricType::Type2, 2, 8, 4096).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
