//! Trace layer: converts (tensor, fabric type, layout) into the exact
//! per-PE memory-request streams §IV of the paper describes —
//! (a) input-fiber loads, (b) tensor-scalar loads, (c) output-fiber
//! stores — which the simulator's PE front ends then replay.

mod amap;
mod gen;
pub mod source;

pub use amap::AddressMap;
pub use gen::{workload_from_tensor, Workload};
pub use source::{CooStreamSource, TnsStreamSource, TraceSource, WorkCursor, WORK_CHUNK};

/// The three access classes of spMTTKRP (§IV): the paper's entire design
/// is about serving each with the right memory primitive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessClass {
    /// Element-wise load of a tensor nonzero (16 B) — spatial + temporal
    /// locality ⇒ cache path in the proposed system.
    TensorElem,
    /// Streaming load of a factor-matrix fiber (R·4 B) — spatial locality
    /// only ⇒ DMA path.
    FiberLoad,
    /// Streaming store of an output fiber ⇒ DMA path.
    FiberStore,
}

impl AccessClass {
    pub fn name(&self) -> &'static str {
        match self {
            AccessClass::TensorElem => "tensor-elem",
            AccessClass::FiberLoad => "fiber-load",
            AccessClass::FiberStore => "fiber-store",
        }
    }

    pub fn is_write(&self) -> bool {
        matches!(self, AccessClass::FiberStore)
    }
}

/// One memory access (byte-addressed over the 31-bit MIG address space).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub class: AccessClass,
    pub addr: u64,
    pub bytes: u32,
}

/// The accesses belonging to one nonzero's processing: the scalar element,
/// the two input fibers, and (at an output-fiber boundary) the store of
/// the finished output fiber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NnzWork {
    pub elem: Access,
    pub fibers: [Access; 2],
    /// Store of the *previous* output fiber, issued when the output index
    /// changes (Algorithm 3's `current_I` writeback) or at stream end.
    pub store: Option<Access>,
}

impl NnzWork {
    /// All accesses in issue order.
    pub fn accesses(&self) -> impl Iterator<Item = Access> + '_ {
        [Some(self.elem), Some(self.fibers[0]), Some(self.fibers[1])]
            .into_iter()
            .flatten()
            .chain(self.store.into_iter())
    }

    pub fn n_accesses(&self) -> usize {
        3 + usize::from(self.store.is_some())
    }
}

/// One PE front end's full request stream.
#[derive(Debug, Clone, Default)]
pub struct PeTrace {
    pub pe: usize,
    pub work: Vec<NnzWork>,
}

impl PeTrace {
    pub fn n_accesses(&self) -> usize {
        self.work.iter().map(NnzWork::n_accesses).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.work
            .iter()
            .flat_map(|w| w.accesses())
            .map(|a| a.bytes as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_class_names_and_rw() {
        assert_eq!(AccessClass::TensorElem.name(), "tensor-elem");
        assert!(!AccessClass::FiberLoad.is_write());
        assert!(AccessClass::FiberStore.is_write());
    }

    #[test]
    fn nnz_work_access_iteration() {
        let a = |class, addr| Access {
            class,
            addr,
            bytes: 16,
        };
        let w = NnzWork {
            elem: a(AccessClass::TensorElem, 0),
            fibers: [a(AccessClass::FiberLoad, 64), a(AccessClass::FiberLoad, 128)],
            store: Some(a(AccessClass::FiberStore, 256)),
        };
        assert_eq!(w.n_accesses(), 4);
        assert_eq!(w.accesses().count(), 4);
        let w2 = NnzWork { store: None, ..w };
        assert_eq!(w2.n_accesses(), 3);
        assert_eq!(w2.accesses().count(), 3);
    }
}
