//! `mttkrp-memsys` — CLI for the reconfigurable-memory-system
//! reproduction. Every simulating subcommand composes the simulator
//! through the `experiment` API (Scenario → Sweep → RunSet).
//!
//! Subcommands:
//!   fig4       Regenerate the paper's Fig. 4 speedup comparison.
//!   table2     Print the Table II resource-utilization model.
//!   table3     Print the Table III dataset summary.
//!   simulate   Run one memory-system simulation (config + workload).
//!   trace      Simulate with request-lifecycle tracing; write Chrome trace JSON.
//!   report-diff  Compare two SimReport JSON files field by field.
//!   sweep      Run a config/scenario grid in parallel; table + JSON-lines.
//!   mttkrp     Run one MTTKRP through the full stack (sim + PJRT).
//!   als        Timed CP-ALS (experiment E6).
//!   gen        Generate a synthetic tensor to a .tns file.
//!   freq       Max-frequency model sweep (§IV-E ablation).

use std::sync::Arc;

use mttkrp_memsys::config::{SystemConfig, SystemKind};
use mttkrp_memsys::coordinator::TimedCpAls;
use mttkrp_memsys::experiment::{self, default_threads, Scenario, Sweep};
use mttkrp_memsys::mttkrp::CpAlsOptions;
use mttkrp_memsys::resource::{max_frequency_mhz, table2};
use mttkrp_memsys::runtime::{find_artifacts_dir, Manifest};
use mttkrp_memsys::sim::{MemorySystem, SimReport};
use mttkrp_memsys::tensor::{gen, io, CooTensor, DenseMatrix, Mode};
use mttkrp_memsys::trace::{TraceSource, WORK_CHUNK};
use mttkrp_memsys::util::cli::Args;
use mttkrp_memsys::util::json::Json;
use mttkrp_memsys::util::rng::Rng;
use mttkrp_memsys::util::table::{Align, Table};
use mttkrp_memsys::util::{fmt_bytes, fmt_count};

fn main() {
    let args = Args::parse_env(true);
    let result = match args.subcommand.as_deref() {
        Some("fig4") => cmd_fig4(&args),
        Some("table2") => cmd_table2(),
        Some("table3") => cmd_table3(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("trace") => cmd_trace(&args),
        Some("report-diff") => cmd_report_diff(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("mttkrp") => cmd_mttkrp(&args),
        Some("als") => cmd_als(&args),
        Some("gen") => cmd_gen(&args),
        Some("freq") => cmd_freq(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "mttkrp-memsys — reconfigurable low-latency memory system for sparse MTTKRP

USAGE: mttkrp-memsys <subcommand> [--options]

  fig4      [--scale 0.01] [--mode i|j|k] [--threads N] [--sim-threads N]
            Fig. 4 speedups (systems × configs × datasets)
  table2                              Table II resource model
  table3    [--scale 1.0]             Table III dataset summary
  simulate  [--preset a|b] [--system proposed|ip-only|cache-only|dma-only]
            [--mode i|j|k] [--channels N] [--topology crossbar|line|ring]
            [--link-width W] [--lmb-banks N] [--reply-network on|off]
            [--dram-model lumped|timed]
            [--nodes N] [--inter-topology crossbar|line|ring|mesh]
            [--sim-threads N]
            [--scale 0.01] [--dataset synth01|synth02|file.tns] [--<section.key> v]
            [--trace-out trace.json] [--timeline tl.jsonl] [--sample N] [--window W]
            (--nodes > 1 shards the tensor across a routed accelerator
             cluster and prints the per-node makespan breakdown)
  trace     --trace-out trace.json [--timeline tl.jsonl] [--sample N] [--window W]
            (simulate with tracing forced on; all simulate options apply;
             load the JSON in Perfetto / chrome://tracing)
  report-diff  a.json b.json       first diverging field of two SimReports
  sweep     --axis key=v1,v2,... [--axis ...] [--threads N] [--sim-threads N]
            [--baseline axis=value] [--out runs.jsonl] [--resume]
            [--preset b] [--dataset synth01|file.tns] [--scale 0.01] [--mode i|j|k]
            [--telemetry-dir DIR]
            (axes: system, preset, dataset, scale, mode, fabric, channels,
             topology, link-width, lmb-banks, dram-model, reply-network,
             nodes, inter-topology, sim-threads, and any --<section.key>
             override key, e.g. telemetry.trace; dataset values may be
             synthetic names or .tns paths; --resume skips cells already
             in --out and appends only the new ones)

  DRAM backends: --dram-model lumped (default; per-access latency classes)
  or timed (command-level ACT/RD/WR/PRE/REF DDR4 timing; knobs
  --dram.t_rcd/t_rp/t_cas/t_cwl/t_ras/t_ccd/t_wtr/t_rtw, refresh via
  --dram.refresh on|off with --dram.t_refi/t_rfc).

  thread flags: --threads N is the HOST pool — how many whole simulations
  run concurrently (sweep/fig4 grids). --sim-threads N parallelizes the
  inside of ONE run (shards DRAM channels + PE fill/retire across worker
  threads; with --nodes > 1, fans node runs out instead). Reports and
  telemetry are bit-identical at every --sim-threads value. snake_case
  spellings (--sim_threads, --link_width, ...) work everywhere.
  mttkrp    [--preset b] [--scale 0.005]   full-stack MTTKRP (sim + PJRT numerics)
  als       [--scale 0.002] [--iters 10] [--preset b]  timed CP-ALS (E6)
  gen       --out t.tns [--dataset synth01] [--scale 0.01]
  freq                                max-frequency model sweep (§IV-E)"
    );
}

/// `--mode i|j|k` (default: mode-1/`i`, the paper's evaluation mode).
fn mode_arg(args: &Args) -> mttkrp_memsys::Result<Mode> {
    Ok(args.get_str("mode", "i").parse::<Mode>()?)
}

/// `--dataset`/`--scale`/`--mode` → a Scenario shaped for `cfg`.
fn scenario_arg(args: &Args, cfg: &SystemConfig) -> mttkrp_memsys::Result<Scenario> {
    let name = args.get_str("dataset", "synth01");
    let scale = args.get_f64("scale", 0.01);
    let scenario = Scenario::dataset(&name, scale).map_err(mttkrp_memsys::Error::msg)?;
    Ok(scenario.mode(mode_arg(args)?).for_config(cfg))
}

fn preset_cfg(args: &Args) -> mttkrp_memsys::Result<SystemConfig> {
    let mut cfg = experiment::preset(&args.get_str("preset", "b")).map_err(mttkrp_memsys::Error::msg)?;
    if let Some(sys) = args.get("system") {
        let kind: SystemKind = sys.parse()?;
        cfg = cfg.as_baseline(kind);
    }
    // Pass through any config-style overrides (`--cache.lines 4096`).
    for (k, v) in args.options() {
        if k.contains('.') {
            cfg.apply_override(k, v).map_err(|e| mttkrp_memsys::format_err!(e))?;
        }
    }
    // Interconnect + LMB + cluster + engine shorthands: `--channels 4
    // --topology ring --link-width 2 --lmb-banks 4 --reply-network on
    // --nodes 4 --inter-topology mesh --sim-threads 4` (snake_case
    // spellings stay as hidden aliases).
    for key in [
        "channels",
        "topology",
        "link-width",
        "link_width",
        "lmb-banks",
        "lmb_banks",
        "dram-model",
        "dram_model",
        "nodes",
        "inter-topology",
        "inter_topology",
        "sim-threads",
        "sim_threads",
    ] {
        if let Some(v) = args.get(key) {
            cfg.apply_override(key, v).map_err(|e| mttkrp_memsys::format_err!(e))?;
        }
    }
    for key in ["reply-network", "reply_network"] {
        if let Some(v) = args.get(key) {
            cfg.apply_override(key, v).map_err(|e| mttkrp_memsys::format_err!(e))?;
        } else if args.flag(key) {
            // Bare `--reply-network` means "turn it on".
            cfg.apply_override(key, "on").map_err(|e| mttkrp_memsys::format_err!(e))?;
        }
    }
    cfg.validate().map_err(|e| mttkrp_memsys::format_err!(e))?;
    Ok(cfg)
}

fn load_tensor(args: &Args) -> mttkrp_memsys::Result<Arc<CooTensor>> {
    let name = args.get_str("dataset", "synth01");
    let scale = args.get_f64("scale", 0.01);
    let scenario = Scenario::dataset(&name, scale).map_err(mttkrp_memsys::Error::msg)?;
    Ok(scenario.tensor())
}

fn manifest() -> mttkrp_memsys::Result<Manifest> {
    let dir = find_artifacts_dir()
        .ok_or_else(|| mttkrp_memsys::format_err!("artifacts not found — run `make artifacts`"))?;
    Manifest::load(&dir)
}

fn cmd_fig4(args: &Args) -> mttkrp_memsys::Result<()> {
    let scale = args.get_f64("scale", 0.01);
    let mode = mode_arg(args)?;
    println!("Fig. 4 — memory-access-time speedup over IP-only (scale {scale})\n");
    if mode != Mode::I {
        println!("(MTTKRP mode {})\n", mode.name());
    }
    // The paper's grid: (Config-A/Type-1, Config-B/Type-2) × dataset ×
    // system variant, one sweep, IP-only as the per-category baseline.
    let mut sweep = Sweep::new(SystemConfig::config_a(), Scenario::synth01(scale).mode(mode))
        .zip_axis(&["preset", "fabric"], &[&["a", "type1"], &["b", "type2"]])
        .axis("dataset", &["synth01", "synth02"])
        .axis("system", &["ip-only", "cache-only", "dma-only", "proposed"])
        .threads(args.get_usize("threads", default_threads()));
    // `--sim-threads N`: in-run sharding for every grid point. Applied
    // as a single-value axis so the preset axis (which rebuilds the
    // config per point) cannot drop it.
    for key in ["sim-threads", "sim_threads"] {
        if let Some(v) = args.get(key) {
            sweep = sweep.axis("sim-threads", &[v]);
        }
    }
    let runs = sweep.run().map_err(mttkrp_memsys::Error::msg)?;
    let mut table = Table::new(&[
        "category",
        "ip-only",
        "cache-only",
        "dma-only",
        "proposed",
        "elem lat",
        "p95",
    ])
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for (preset, label) in [("a", "A_1"), ("b", "B_2")] {
        for (ds, tname) in [("synth01", "S1"), ("synth02", "S2")] {
            let cell = |system: &str| {
                runs.get(&[("preset", preset), ("dataset", ds), ("system", system)])
                    .expect("sweep covers the fig4 grid")
            };
            let ip = cell("ip-only");
            // Mean/p95 element latency of the proposed system (cycles).
            let [lat_mean, lat_p95, _, _] = cell("proposed").report.latency_cells();
            table.row(&[
                format!("{label}_{tname}"),
                "1.00".to_string(),
                format!("{:.2}", cell("cache-only").report.speedup_over(&ip.report)),
                format!("{:.2}", cell("dma-only").report.speedup_over(&ip.report)),
                format!("{:.2}", cell("proposed").report.speedup_over(&ip.report)),
                lat_mean,
                lat_p95,
            ]);
        }
    }
    println!("{}", table.render());
    println!("\npaper: proposed ≈ 3.5× vs IP-only, ≈ 2× vs cache-only, ≈ 1.26× vs DMA-only");
    Ok(())
}

fn cmd_table2() -> mttkrp_memsys::Result<()> {
    let a = experiment::preset("a").map_err(mttkrp_memsys::Error::msg)?;
    let b = experiment::preset("b").map_err(mttkrp_memsys::Error::msg)?;
    println!("Table II — module configuration and resource utilization (model)\n");
    println!("{}", table2(&[&a, &b]));
    Ok(())
}

fn cmd_table3(args: &Args) -> mttkrp_memsys::Result<()> {
    let scale = args.get_f64("scale", 1.0);
    println!("Table III — sparse 3D tensor datasets (scale {scale})\n");
    let mut t = Table::new(&["Tensor", "Dimensions", "Nonzeros", "Density"]).aligns(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
    ]);
    for spec in [gen::SYNTH_01.scaled(scale), gen::SYNTH_02.scaled(scale)] {
        t.row(&[
            spec.name.to_string(),
            format!("{} x {} x {}", spec.dims[0], spec.dims[1], spec.dims[2]),
            fmt_count(spec.nnz),
            format!("{:.2E}", spec.density()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Telemetry output destinations: `--trace-out FILE` / `--timeline FILE`.
/// Naming a file turns the matching product on (equivalent to the
/// `--telemetry.trace on` / `--telemetry.timeline on` overrides);
/// `--sample N` / `--window W` shorthand the other two knobs.
struct TelemetryPaths {
    trace: Option<String>,
    timeline: Option<String>,
}

fn telemetry_paths(args: &Args, cfg: &mut SystemConfig) -> mttkrp_memsys::Result<TelemetryPaths> {
    let paths = TelemetryPaths {
        trace: args.get("trace-out").map(str::to_string),
        timeline: args.get("timeline").map(str::to_string),
    };
    if paths.trace.is_some() {
        cfg.telemetry.trace = true;
    }
    if paths.timeline.is_some() {
        cfg.telemetry.timeline = true;
    }
    cfg.telemetry.sample = args.get_u64("sample", cfg.telemetry.sample);
    cfg.telemetry.window = args.get_u64("window", cfg.telemetry.window);
    cfg.validate().map_err(|e| mttkrp_memsys::format_err!(e))?;
    Ok(paths)
}

/// Simulate from a streaming trace source, then write any requested
/// telemetry artifacts.
fn run_with_telemetry(
    cfg: &SystemConfig,
    src: &Arc<dyn TraceSource>,
    paths: &TelemetryPaths,
) -> mttkrp_memsys::Result<SimReport> {
    let name = src.name().to_string();
    let mut sys = MemorySystem::new(cfg, src);
    let report = sys.run(&name);
    let out = sys.take_telemetry(&name);
    if let Some(path) = &paths.trace {
        let trace = out.trace.expect("tracing forced on by --trace-out");
        std::fs::write(path, trace.to_string_compact())?;
        println!("wrote trace to {path} (load in Perfetto / chrome://tracing)");
    }
    if let Some(path) = &paths.timeline {
        let mut body = String::new();
        for row in &out.timeline {
            body.push_str(&row.to_string_compact());
            body.push('\n');
        }
        std::fs::write(path, body)?;
        println!("wrote {} timeline rows to {path}", out.timeline.len());
    }
    Ok(report)
}

fn cmd_simulate(args: &Args) -> mttkrp_memsys::Result<()> {
    let mut cfg = preset_cfg(args)?;
    // Cluster runs (`--nodes N`, N > 1): shard the tensor across N
    // accelerator nodes and print the full cluster report with its
    // per-node makespan breakdown. Telemetry products are per-node
    // artifacts the merged view cannot represent, so they are rejected
    // rather than silently dropped.
    if cfg.cluster.nodes > 1 {
        mttkrp_memsys::ensure!(
            args.get("trace-out").is_none() && args.get("timeline").is_none(),
            "--trace-out/--timeline are single-node telemetry; not available with --nodes > 1"
        );
        let scenario = scenario_arg(args, &cfg)?;
        let src = scenario.trace_source().map_err(mttkrp_memsys::Error::msg)?;
        println!(
            "cluster workload: {} nnz={} nodes={} x {} PE streams ({})",
            src.name(),
            fmt_count(src.nnz() as u64),
            cfg.cluster.nodes,
            cfg.pe.n_pes,
            cfg.cluster.topology.name()
        );
        let cluster = experiment::run_cluster(&cfg, &scenario);
        println!("{}", cluster.to_json().to_string_pretty());
        return Ok(());
    }
    let paths = telemetry_paths(args, &mut cfg)?;
    let scenario = scenario_arg(args, &cfg)?;
    let src = scenario.trace_source().map_err(mttkrp_memsys::Error::msg)?;
    println!(
        "workload: {} nnz={} streams={} (streaming, <= {WORK_CHUNK} items buffered per stream)",
        src.name(),
        fmt_count(src.nnz() as u64),
        src.n_streams()
    );
    let report = run_with_telemetry(&cfg, &src, &paths)?;
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

/// `trace` — `simulate` with request-lifecycle tracing forced on.
fn cmd_trace(args: &Args) -> mttkrp_memsys::Result<()> {
    let mut cfg = preset_cfg(args)?;
    cfg.telemetry.trace = true;
    let paths = telemetry_paths(args, &mut cfg)?;
    mttkrp_memsys::ensure!(
        paths.trace.is_some(),
        "trace wants --trace-out <file.json> (add --timeline <file.jsonl> for the time-series)"
    );
    let scenario = scenario_arg(args, &cfg)?;
    let src = scenario.trace_source().map_err(mttkrp_memsys::Error::msg)?;
    println!(
        "tracing {} (sample 1-in-{}, window {} cycles)",
        src.name(),
        cfg.telemetry.sample,
        cfg.telemetry.window
    );
    let report = run_with_telemetry(&cfg, &src, &paths)?;
    println!(
        "cycles={} accesses={} elem p95={} fiber p95={}",
        fmt_count(report.total_cycles),
        fmt_count(report.accesses),
        report.elem_latency_p95(),
        report.fiber_latency_p95()
    );
    Ok(())
}

/// `report-diff a.json b.json` — print the first diverging field of two
/// SimReport dumps (host timing is masked). Exits 1 on divergence so the
/// command doubles as a regression check in scripts.
fn cmd_report_diff(args: &Args) -> mttkrp_memsys::Result<()> {
    let [a_path, b_path] = args.positionals() else {
        mttkrp_memsys::bail!("report-diff wants exactly two positional report.json paths");
    };
    let load = |p: &String| -> mttkrp_memsys::Result<Json> {
        let src = std::fs::read_to_string(p)
            .map_err(|e| mttkrp_memsys::format_err!("cannot read {p}: {e}"))?;
        Json::parse(&src).map_err(|e| mttkrp_memsys::format_err!("{p}: {e}"))
    };
    let (a, b) = (load(a_path)?, load(b_path)?);
    // Host wall time is machine noise, never a simulation divergence.
    match a.first_diff(&b, &["host_seconds"]) {
        None => {
            println!("reports match ({a_path} == {b_path}, ignoring host_seconds)");
            Ok(())
        }
        Some(path) => {
            let show = |v: &Json| {
                let mut cur = v;
                for part in path.split('.') {
                    let (key, idx) = match part.split_once('[') {
                        Some((k, rest)) => (k, rest.strip_suffix(']').and_then(|s| s.parse().ok())),
                        None => (part, None),
                    };
                    if !key.is_empty() {
                        cur = cur.get(key).unwrap_or(&Json::Null);
                    }
                    if let (Some(i), Some(items)) = (idx, cur.as_arr()) {
                        cur = items.get(i).unwrap_or(&Json::Null);
                    }
                }
                cur.to_string_compact()
            };
            println!("reports diverge at `{path}`");
            println!("  {a_path}: {}", show(&a));
            println!("  {b_path}: {}", show(&b));
            std::process::exit(1);
        }
    }
}

fn cmd_sweep(args: &Args) -> mttkrp_memsys::Result<()> {
    let cfg = preset_cfg(args)?;
    let scenario = scenario_arg(args, &cfg)?;
    let threads = args.get_usize("threads", default_threads());
    let mut sweep = Sweep::new(cfg, scenario).threads(threads);
    // Per-run trace/timeline files for grid points that enable
    // telemetry (e.g. via an `--axis telemetry.trace=off,on`).
    let telemetry_dir = args.get("telemetry-dir");
    if let Some(dir) = telemetry_dir {
        sweep = sweep.telemetry_dir(dir);
    }
    // `--resume`: skip grid cells whose label already sits in `--out`,
    // then append only the newly-run cells to the same file.
    let resume = args.flag("resume");
    if resume {
        let out = args
            .get("out")
            .ok_or_else(|| mttkrp_memsys::format_err!("--resume needs --out <runs.jsonl>"))?;
        sweep = sweep.resume_from(out);
    }
    let specs = args.get_all("axis");
    mttkrp_memsys::ensure!(
        !specs.is_empty(),
        "at least one --axis required, e.g. --axis system=ip-only,proposed"
    );
    let mut has_preset_axis = false;
    for spec in specs {
        let (key, vals) = spec
            .split_once('=')
            .ok_or_else(|| mttkrp_memsys::format_err!("--axis wants key=v1,v2,..., got {spec:?}"))?;
        let values: Vec<&str> = vals.split(',').filter(|v| !v.is_empty()).collect();
        mttkrp_memsys::ensure!(!values.is_empty(), "axis {key:?} has no values");
        has_preset_axis |= key == "preset";
        sweep = sweep.axis(key, &values);
    }
    // A preset axis rebuilds the config from scratch at every grid
    // point, so base-level config flags would be silently lost.
    let has_base_overrides = args.options().any(|(k, _)| k.contains('.'))
        || [
            "system",
            "channels",
            "topology",
            "link-width",
            "link_width",
            "lmb-banks",
            "lmb_banks",
            "dram-model",
            "dram_model",
            "reply-network",
            "reply_network",
            "nodes",
            "inter-topology",
            "inter_topology",
            "sim-threads",
            "sim_threads",
        ]
        .iter()
        .any(|k| args.get(k).is_some())
        // Bare `--reply-network` (flag form) also sets the base config.
        || ["reply-network", "reply_network"].iter().any(|k| args.flag(k));
    if has_preset_axis && has_base_overrides {
        eprintln!(
            "warning: --axis preset=... resets the config per grid point; base --system, \
             --<section.key>, --channels/--topology/--link-width/--lmb-banks/--dram-model/\
             --reply-network/--nodes/--inter-topology/--sim-threads flags are ignored there"
        );
    }
    let baseline = match args.get("baseline") {
        Some(spec) => Some(
            spec.split_once('=')
                .ok_or_else(|| mttkrp_memsys::format_err!("--baseline wants axis=value, got {spec:?}"))?,
        ),
        None => None,
    };
    let wall_t0 = std::time::Instant::now();
    let runs = sweep.run().map_err(mttkrp_memsys::Error::msg)?;
    let wall = wall_t0.elapsed().as_secs_f64();
    println!("{}", runs.to_table(baseline).render());
    let sim_host: f64 = runs.runs.iter().map(|r| r.report.host_seconds).sum();
    println!(
        "\n{} runs in {wall:.2}s wall ({sim_host:.2}s of simulation across {threads} threads)",
        runs.len()
    );
    if let Some(path) = args.get("out") {
        if resume && std::path::Path::new(path).exists() {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(path)?;
            f.write_all(runs.to_jsonl().as_bytes())?;
            println!("appended {} JSON-lines to {path}", runs.len());
        } else {
            runs.write_jsonl(std::path::Path::new(path))?;
            println!("wrote {} JSON-lines to {path}", runs.len());
        }
    }
    if let Some(dir) = telemetry_dir {
        let traced = runs.runs.iter().filter(|r| r.cfg.telemetry.enabled()).count();
        println!("wrote telemetry artifacts for {traced} runs to {dir}/");
    }
    Ok(())
}

fn cmd_mttkrp(args: &Args) -> mttkrp_memsys::Result<()> {
    let cfg = preset_cfg(args)?;
    let man = manifest()?;
    let mut t = load_tensor(args)?;
    // Generated tensors are already mode-I sorted; clone only if not.
    if !t.is_sorted_mode(Mode::I) {
        let mut sorted = (*t).clone();
        sorted.sort_mode(Mode::I);
        t = Arc::new(sorted);
    }
    let r = man.partials.rank;
    let mut rng = Rng::new(args.get_u64("seed", 7));
    let d = DenseMatrix::random(&mut rng, t.dims[1] as usize, r);
    let c = DenseMatrix::random(&mut rng, t.dims[2] as usize, r);
    let (_out, report) =
        mttkrp_memsys::coordinator::run_accelerator(&cfg, &man, &t, Mode::I, &d, &c)?;
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

fn cmd_als(args: &Args) -> mttkrp_memsys::Result<()> {
    let cfg = preset_cfg(args)?;
    let man = manifest()?;
    let t = load_tensor(args)?;
    let opts = CpAlsOptions {
        rank: man.partials.rank,
        max_iters: args.get_usize("iters", 10),
        fit_tol: args.get_f64("tol", 1e-5),
        seed: args.get_u64("seed", 7),
    };
    let driver = TimedCpAls::new(cfg, man);
    let report = driver.run(&t, opts)?;
    for it in &report.als.iters {
        println!(
            "iter {:>3}  fit {:.6}  rel_error {:.6}",
            it.iter, it.fit, it.rel_error
        );
    }
    println!(
        "cycles/sweep {}  total cycles {}  compute {:.2}s  converged {}",
        fmt_count(report.cycles_per_sweep),
        fmt_count(report.total_cycles),
        report.compute_seconds,
        report.als.converged
    );
    Ok(())
}

fn cmd_gen(args: &Args) -> mttkrp_memsys::Result<()> {
    let t = load_tensor(args)?;
    let out = args
        .get("out")
        .ok_or_else(|| mttkrp_memsys::format_err!("--out <file.tns> required"))?;
    io::write_tns(&t, std::path::Path::new(out))?;
    println!(
        "wrote {} ({} nnz, {})",
        out,
        fmt_count(t.nnz() as u64),
        fmt_bytes(t.stored_bytes())
    );
    Ok(())
}

fn cmd_freq() -> mttkrp_memsys::Result<()> {
    println!("max-frequency model (§IV-E): DMA-count and cache-size sweeps\n");
    // Model-only grids (no simulation): the Sweep resolves the configs,
    // the resource model prices each point.
    let base = SystemConfig::config_a();
    let scenario = Scenario::synth01(0.01).for_config(&base);
    let dma_grid = Sweep::new(base.clone(), scenario.clone())
        .axis("dma.n_buffers", &["1", "2", "4", "6", "8"])
        .grid()
        .map_err(mttkrp_memsys::Error::msg)?;
    let cache_grid = Sweep::new(base, scenario)
        .axis("cache.lines", &["2048", "4096", "8192", "16384", "32768"])
        .grid()
        .map_err(mttkrp_memsys::Error::msg)?;
    let mut t = Table::new(&["dma buffers", "fmax (MHz)", "", "cache lines", "fmax (MHz)"])
        .aligns(&[
            Align::Right,
            Align::Right,
            Align::Left,
            Align::Right,
            Align::Right,
        ]);
    for (d, c) in dma_grid.iter().zip(&cache_grid) {
        t.row(&[
            d.axes[0].1.clone(),
            format!("{:.0}", max_frequency_mhz(&d.cfg)),
            String::new(),
            c.axes[0].1.clone(),
            format!("{:.0}", max_frequency_mhz(&c.cfg)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
