//! `mttkrp-memsys` — CLI for the reconfigurable-memory-system
//! reproduction.
//!
//! Subcommands:
//!   fig4       Regenerate the paper's Fig. 4 speedup comparison.
//!   table2     Print the Table II resource-utilization model.
//!   table3     Print the Table III dataset summary.
//!   simulate   Run one memory-system simulation (config + workload).
//!   mttkrp     Run one MTTKRP through the full stack (sim + PJRT).
//!   als        Timed CP-ALS (experiment E6).
//!   gen        Generate a synthetic tensor to a .tns file.
//!   freq       Max-frequency model sweep (§IV-E ablation).

use mttkrp_memsys::config::{FabricType, SystemConfig, SystemKind};
use mttkrp_memsys::coordinator::TimedCpAls;
use mttkrp_memsys::mttkrp::CpAlsOptions;
use mttkrp_memsys::resource::{max_frequency_mhz, table2};
use mttkrp_memsys::runtime::{find_artifacts_dir, Manifest};
use mttkrp_memsys::sim::simulate;
use mttkrp_memsys::tensor::{gen, io, CooTensor, DenseMatrix, Mode};
use mttkrp_memsys::trace::workload_from_tensor;
use mttkrp_memsys::util::cli::Args;
use mttkrp_memsys::util::rng::Rng;
use mttkrp_memsys::util::table::{Align, Table};
use mttkrp_memsys::util::{fmt_bytes, fmt_count};

fn main() {
    let args = Args::parse_env(true);
    let result = match args.subcommand.as_deref() {
        Some("fig4") => cmd_fig4(&args),
        Some("table2") => cmd_table2(),
        Some("table3") => cmd_table3(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("mttkrp") => cmd_mttkrp(&args),
        Some("als") => cmd_als(&args),
        Some("gen") => cmd_gen(&args),
        Some("freq") => cmd_freq(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "mttkrp-memsys — reconfigurable low-latency memory system for sparse MTTKRP

USAGE: mttkrp-memsys <subcommand> [--options]

  fig4      [--scale 0.01]            Fig. 4 speedups (all systems × configs × datasets)
  table2                              Table II resource model
  table3    [--scale 1.0]             Table III dataset summary
  simulate  [--preset a|b] [--system proposed|ip-only|cache-only|dma-only]
            [--channels N] [--topology crossbar|line|ring] [--link_width W]
            [--scale 0.01] [--dataset synth01|synth02] [--<section.key> v]
  mttkrp    [--preset b] [--scale 0.005]   full-stack MTTKRP (sim + PJRT numerics)
  als       [--scale 0.002] [--iters 10] [--preset b]  timed CP-ALS (E6)
  gen       --out t.tns [--dataset synth01] [--scale 0.01]
  freq                                max-frequency model sweep (§IV-E)"
    );
}

fn load_tensor(args: &Args) -> CooTensor {
    let scale = args.get_f64("scale", 0.01);
    match args.get_str("dataset", "synth01").as_str() {
        "synth02" => gen::synth_02(scale),
        _ => gen::synth_01(scale),
    }
}

fn preset(args: &Args) -> anyhow::Result<SystemConfig> {
    let name = args.get_str("preset", "b");
    let mut cfg = match name.as_str() {
        "a" | "config-a" => SystemConfig::config_a(),
        "b" | "config-b" => SystemConfig::config_b(),
        other => anyhow::bail!("unknown preset {other:?}"),
    };
    if let Some(sys) = args.get("system") {
        let kind = SystemKind::from_name(sys)
            .ok_or_else(|| anyhow::anyhow!("unknown system {sys:?}"))?;
        cfg = cfg.as_baseline(kind);
    }
    // Pass through any config-style overrides (`--cache.lines 4096`).
    for (k, v) in args.options() {
        if k.contains('.') {
            cfg.apply_override(k, v).map_err(|e| anyhow::anyhow!(e))?;
        }
    }
    // Interconnect shorthands: `--channels 4 --topology ring --link_width 2`.
    for key in ["channels", "topology", "link_width"] {
        if let Some(v) = args.get(key) {
            cfg.apply_override(key, v).map_err(|e| anyhow::anyhow!(e))?;
        }
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

fn manifest() -> anyhow::Result<Manifest> {
    let dir = find_artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts not found — run `make artifacts`"))?;
    Manifest::load(&dir)
}

fn cmd_fig4(args: &Args) -> anyhow::Result<()> {
    let scale = args.get_f64("scale", 0.01);
    println!("Fig. 4 — memory-access-time speedup over IP-only (scale {scale})\n");
    let mut table = Table::new(&["category", "ip-only", "cache-only", "dma-only", "proposed"])
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
    for (cfg_base, fabric, label) in [
        (SystemConfig::config_a(), FabricType::Type1, "A_1"),
        (SystemConfig::config_b(), FabricType::Type2, "B_2"),
    ] {
        for (ds, tname) in [("synth01", "S1"), ("synth02", "S2")] {
            let t = match ds {
                "synth02" => gen::synth_02(scale),
                _ => gen::synth_01(scale),
            };
            let w = workload_from_tensor(
                &t,
                Mode::I,
                fabric,
                cfg_base.pe.n_pes,
                cfg_base.pe.rank,
                cfg_base.dram.row_bytes,
            );
            let reports: Vec<_> = SystemKind::ALL
                .iter()
                .map(|&k| {
                    let mut c = cfg_base.as_baseline(k);
                    c.pe.fabric = fabric;
                    simulate(&c, &w)
                })
                .collect();
            let ip = &reports[0];
            table.row(&[
                format!("{label}_{tname}"),
                "1.00".to_string(),
                format!("{:.2}", reports[1].speedup_over(ip)),
                format!("{:.2}", reports[2].speedup_over(ip)),
                format!("{:.2}", reports[3].speedup_over(ip)),
            ]);
        }
    }
    println!("{}", table.render());
    println!("\npaper: proposed ≈ 3.5× vs IP-only, ≈ 2× vs cache-only, ≈ 1.26× vs DMA-only");
    Ok(())
}

fn cmd_table2() -> anyhow::Result<()> {
    let a = SystemConfig::config_a();
    let b = SystemConfig::config_b();
    println!("Table II — module configuration and resource utilization (model)\n");
    println!("{}", table2(&[&a, &b]));
    Ok(())
}

fn cmd_table3(args: &Args) -> anyhow::Result<()> {
    let scale = args.get_f64("scale", 1.0);
    println!("Table III — sparse 3D tensor datasets (scale {scale})\n");
    let mut t = Table::new(&["Tensor", "Dimensions", "Nonzeros", "Density"]).aligns(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
    ]);
    for spec in [gen::SYNTH_01.scaled(scale), gen::SYNTH_02.scaled(scale)] {
        t.row(&[
            spec.name.to_string(),
            format!("{} x {} x {}", spec.dims[0], spec.dims[1], spec.dims[2]),
            fmt_count(spec.nnz),
            format!("{:.2E}", spec.density()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = preset(args)?;
    let t = load_tensor(args);
    let w = workload_from_tensor(
        &t,
        Mode::I,
        cfg.pe.fabric,
        cfg.pe.n_pes,
        cfg.pe.rank,
        cfg.dram.row_bytes,
    );
    println!(
        "workload: {} nnz={} accesses={} bytes={}",
        t.name,
        fmt_count(t.nnz() as u64),
        fmt_count(w.n_accesses() as u64),
        fmt_bytes(w.total_bytes())
    );
    let report = simulate(&cfg, &w);
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

fn cmd_mttkrp(args: &Args) -> anyhow::Result<()> {
    let cfg = preset(args)?;
    let man = manifest()?;
    let mut t = load_tensor(args);
    t.sort_mode(Mode::I);
    let r = man.partials.rank;
    let mut rng = Rng::new(args.get_u64("seed", 7));
    let d = DenseMatrix::random(&mut rng, t.dims[1] as usize, r);
    let c = DenseMatrix::random(&mut rng, t.dims[2] as usize, r);
    let (_out, report) =
        mttkrp_memsys::coordinator::run_accelerator(&cfg, &man, &t, Mode::I, &d, &c)?;
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

fn cmd_als(args: &Args) -> anyhow::Result<()> {
    let cfg = preset(args)?;
    let man = manifest()?;
    let t = load_tensor(args);
    let opts = CpAlsOptions {
        rank: man.partials.rank,
        max_iters: args.get_usize("iters", 10),
        fit_tol: args.get_f64("tol", 1e-5),
        seed: args.get_u64("seed", 7),
    };
    let driver = TimedCpAls::new(cfg, man);
    let report = driver.run(&t, opts)?;
    for it in &report.als.iters {
        println!(
            "iter {:>3}  fit {:.6}  rel_error {:.6}",
            it.iter, it.fit, it.rel_error
        );
    }
    println!(
        "cycles/sweep {}  total cycles {}  compute {:.2}s  converged {}",
        fmt_count(report.cycles_per_sweep),
        fmt_count(report.total_cycles),
        report.compute_seconds,
        report.als.converged
    );
    Ok(())
}

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let t = load_tensor(args);
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out <file.tns> required"))?;
    io::write_tns(&t, std::path::Path::new(out))?;
    println!(
        "wrote {} ({} nnz, {})",
        out,
        fmt_count(t.nnz() as u64),
        fmt_bytes(t.stored_bytes())
    );
    Ok(())
}

fn cmd_freq() -> anyhow::Result<()> {
    println!("max-frequency model (§IV-E): DMA-count and cache-size sweeps\n");
    let mut t = Table::new(&["dma buffers", "fmax (MHz)", "", "cache lines", "fmax (MHz)"])
        .aligns(&[
            Align::Right,
            Align::Right,
            Align::Left,
            Align::Right,
            Align::Right,
        ]);
    let dmas = [1usize, 2, 4, 6, 8];
    let lines = [2048usize, 4096, 8192, 16384, 32768];
    for i in 0..5 {
        let mut ca = SystemConfig::config_a();
        ca.dma.n_buffers = dmas[i];
        let mut cb = SystemConfig::config_a();
        cb.cache.lines = lines[i];
        t.row(&[
            dmas[i].to_string(),
            format!("{:.0}", max_frequency_mhz(&ca)),
            String::new(),
            lines[i].to_string(),
            format!("{:.0}", max_frequency_mhz(&cb)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
