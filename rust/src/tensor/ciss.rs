//! CISS-like compressed interleaved layout (Tensaurus' Compressed
//! Interleaved Sparse Slice is "also a variation of COO format", §V-A1).
//!
//! The format interleaves the nonzeros of `n_channels` slices so a
//! systolic fabric (Type-1) streams one element per channel per beat.
//! The simulator uses it to generate Type-1 element streams whose address
//! pattern is sequential per channel — the layout the paper's cache path
//! is designed around.

use super::coo::{CooTensor, Mode, COO_ELEM_BYTES};

/// One interleaved element (flattened back to coordinates + value).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CissElem {
    pub i: u32,
    pub j: u32,
    pub k: u32,
    pub val: f32,
    /// Which interleave channel the element belongs to.
    pub channel: u16,
    /// Marks the last element of a slice run (fiber boundary signal the
    /// compute fabric uses to flush its output fiber).
    pub end_of_slice: bool,
}

/// A tensor re-laid-out in interleaved slice order.
#[derive(Debug, Clone)]
pub struct CissTensor {
    pub dims: [u64; 3],
    pub n_channels: usize,
    pub elems: Vec<CissElem>,
    pub name: String,
}

impl CissTensor {
    /// Build from a COO tensor sorted along `mode`. Slices along `mode`
    /// are dealt round-robin to channels, then the channel streams are
    /// interleaved element-by-element.
    pub fn from_coo(t: &CooTensor, mode: Mode, n_channels: usize) -> CissTensor {
        assert!(n_channels > 0);
        let mut sorted = t.clone();
        // Order-based check (not the `sorted_mode` flag): tensors loaded
        // from already-sorted `.tns` files carry no flag, and re-sorting
        // them lexicographically would reorder within slices — breaking
        // identity with the streaming Type-1 source, which trusts file
        // order.
        if !sorted.is_sorted_mode(mode) {
            sorted.sort_mode(mode);
        }
        // Slice boundaries along the sorted mode.
        let n = sorted.nnz();
        let mut slices: Vec<(usize, usize)> = Vec::new();
        let mut start = 0usize;
        for z in 1..=n {
            if z == n || sorted.coord(z, mode) != sorted.coord(start, mode) {
                slices.push((start, z));
                start = z;
            }
        }
        // Deal slices round-robin to channels.
        let mut channels: Vec<Vec<CissElem>> = vec![Vec::new(); n_channels];
        for (s_idx, &(lo, hi)) in slices.iter().enumerate() {
            let ch = s_idx % n_channels;
            for z in lo..hi {
                let (i, j, k) = sorted.coords(z);
                channels[ch].push(CissElem {
                    i,
                    j,
                    k,
                    val: sorted.vals[z],
                    channel: ch as u16,
                    end_of_slice: z + 1 == hi,
                });
            }
        }
        // Interleave: one element per channel per beat.
        let mut elems = Vec::with_capacity(n);
        let mut cursors = vec![0usize; n_channels];
        let mut remaining = n;
        while remaining > 0 {
            for ch in 0..n_channels {
                if cursors[ch] < channels[ch].len() {
                    elems.push(channels[ch][cursors[ch]]);
                    cursors[ch] += 1;
                    remaining -= 1;
                }
            }
        }
        CissTensor {
            dims: sorted.dims,
            n_channels,
            elems,
            name: format!("{}-ciss{}", t.name, n_channels),
        }
    }

    pub fn nnz(&self) -> usize {
        self.elems.len()
    }

    /// Byte address of interleaved element `z` (stored contiguously,
    /// 16 B/element like the COO stream).
    #[inline]
    pub fn elem_addr(&self, z: usize) -> u64 {
        z as u64 * COO_ELEM_BYTES
    }

    /// Recover a COO tensor (for correctness checks).
    pub fn to_coo(&self) -> CooTensor {
        let mut t = CooTensor::new(&self.name, self.dims);
        for e in &self.elems {
            t.push(e.i, e.j, e.k, e.val);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrips_all_nonzeros() {
        let mut rng = Rng::new(4);
        let t = CooTensor::random(&mut rng, [8, 8, 8], 64);
        let c = CissTensor::from_coo(&t, Mode::I, 4);
        assert_eq!(c.nnz(), t.nnz());
        let mut back = c.to_coo();
        back.sum_duplicates();
        let mut orig = t.clone();
        orig.sum_duplicates();
        assert_eq!(back.nnz(), orig.nnz());
        let sum_a: f32 = back.vals.iter().sum();
        let sum_b: f32 = orig.vals.iter().sum();
        assert!((sum_a - sum_b).abs() < 1e-4);
    }

    #[test]
    fn slices_stay_within_one_channel() {
        let mut rng = Rng::new(5);
        let t = CooTensor::random(&mut rng, [6, 16, 16], 120);
        let c = CissTensor::from_coo(&t, Mode::I, 3);
        // All elements with the same i share a channel.
        let mut chan_of_i = std::collections::HashMap::new();
        for e in &c.elems {
            let prev = chan_of_i.insert(e.i, e.channel);
            if let Some(p) = prev {
                assert_eq!(p, e.channel, "slice i={} split across channels", e.i);
            }
        }
    }

    #[test]
    fn end_of_slice_flags_count_matches_slices() {
        let mut t = CooTensor::new("s", [4, 4, 4]);
        t.push(0, 0, 0, 1.0);
        t.push(0, 1, 0, 1.0);
        t.push(2, 0, 0, 1.0);
        t.push(3, 1, 2, 1.0);
        let c = CissTensor::from_coo(&t, Mode::I, 2);
        let ends = c.elems.iter().filter(|e| e.end_of_slice).count();
        assert_eq!(ends, 3); // slices: i=0 (2 elems), i=2, i=3
    }

    #[test]
    fn interleaving_alternates_channels_at_head() {
        let mut rng = Rng::new(6);
        let t = CooTensor::random(&mut rng, [16, 8, 8], 100);
        let c = CissTensor::from_coo(&t, Mode::I, 4);
        // The first 4 elements must be 4 distinct channels (all non-empty
        // at this size).
        let head: Vec<u16> = c.elems[..4].iter().map(|e| e.channel).collect();
        let set: std::collections::HashSet<_> = head.iter().collect();
        assert_eq!(set.len(), 4, "head channels {head:?}");
    }
}
