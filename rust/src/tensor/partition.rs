//! Nonzero partitioning for parallel PEs (Algorithm 3: "for each
//! partition_q parallel do ... for z = 0 to M/p").
//!
//! Partitions are contiguous ranges of the mode-sorted nonzero stream,
//! balanced by nnz, and — critically for the paper's consistency argument
//! (§IV: "Only the PEs connected to the same LMB update the same output
//! fiber") — aligned to output-fiber boundaries so no output row spans
//! two partitions.

use super::coo::{CooTensor, Mode};

/// One PE's share of the nonzero stream: the half-open range `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    pub pe: usize,
    pub start: usize,
    pub end: usize,
}

impl Partition {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Split `t` (sorted along `mode`) into `p` contiguous partitions balanced
/// by nnz and aligned to `mode`-fiber boundaries.
///
/// Guarantees:
/// * partitions are disjoint, ordered, and cover `[0, nnz)`;
/// * no output index (coordinate along `mode`) appears in two partitions;
/// * sizes are within one fiber of the balanced target.
pub fn partition_by_nnz(t: &CooTensor, mode: Mode, p: usize) -> Vec<Partition> {
    assert!(p > 0);
    assert!(
        t.sorted_mode == Some(mode) || t.is_sorted_mode(mode),
        "tensor must be sorted along {mode:?} before partitioning"
    );
    let n = t.nnz();
    let mut parts = Vec::with_capacity(p);
    let target = n as f64 / p as f64;
    let mut start = 0usize;
    for pe in 0..p {
        let ideal_end = if pe + 1 == p {
            n
        } else {
            ((pe + 1) as f64 * target).round() as usize
        };
        // Advance end to the next fiber boundary (do not split an output row).
        let mut end = ideal_end.clamp(start, n);
        while end > start && end < n && t.coord(end, mode) == t.coord(end - 1, mode) {
            end += 1;
        }
        parts.push(Partition { pe, start, end });
        start = end;
    }
    // The last partition absorbs any remainder.
    if let Some(last) = parts.last_mut() {
        last.end = n;
    }
    parts
}

/// Check the fiber-alignment invariant (used by property tests).
pub fn partitions_fiber_aligned(t: &CooTensor, mode: Mode, parts: &[Partition]) -> bool {
    for w in parts.windows(2) {
        let (a, b) = (w[0], w[1]);
        if a.end != b.start {
            return false;
        }
        if !a.is_empty() && !b.is_empty() && t.coord(a.end - 1, mode) == t.coord(b.start, mode) {
            return false;
        }
    }
    !parts.is_empty()
        && parts[0].start == 0
        && parts.last().unwrap().end == t.nnz()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sorted_random(seed: u64, dims: [u64; 3], nnz: usize) -> CooTensor {
        let mut rng = Rng::new(seed);
        let mut t = CooTensor::random(&mut rng, dims, nnz);
        t.sort_mode(Mode::I);
        t
    }

    #[test]
    fn covers_disjoint_ordered() {
        let t = sorted_random(1, [32, 16, 16], 500);
        let parts = partition_by_nnz(&t, Mode::I, 4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].start, 0);
        assert_eq!(parts.last().unwrap().end, t.nnz());
        for w in parts.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn no_fiber_spans_two_partitions() {
        let t = sorted_random(2, [20, 8, 8], 400);
        let parts = partition_by_nnz(&t, Mode::I, 4);
        assert!(partitions_fiber_aligned(&t, Mode::I, &parts));
        // Direct check of the invariant.
        for w in parts.windows(2) {
            if !w[0].is_empty() && !w[1].is_empty() {
                assert_ne!(
                    t.coord(w[0].end - 1, Mode::I),
                    t.coord(w[1].start, Mode::I)
                );
            }
        }
    }

    #[test]
    fn roughly_balanced() {
        let t = sorted_random(3, [128, 32, 32], 4000);
        let parts = partition_by_nnz(&t, Mode::I, 8);
        let target = t.nnz() / 8;
        for p in &parts {
            // Balance within a generous factor (fiber alignment shifts
            // boundaries; fibers here are small).
            assert!(
                p.len() < target * 2 + 64,
                "partition {} too large: {}",
                p.pe,
                p.len()
            );
        }
    }

    #[test]
    fn single_partition_and_more_parts_than_fibers() {
        let t = sorted_random(4, [4, 8, 8], 100);
        let one = partition_by_nnz(&t, Mode::I, 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len(), t.nnz());
        // p > #fibers: some partitions may be empty, but coverage holds.
        let many = partition_by_nnz(&t, Mode::I, 16);
        assert!(partitions_fiber_aligned(&t, Mode::I, &many));
        let total: usize = many.iter().map(|p| p.len()).sum();
        assert_eq!(total, t.nnz());
    }

    #[test]
    fn works_along_other_modes() {
        let mut t = sorted_random(5, [16, 24, 12], 600);
        t.sort_mode(Mode::J);
        let parts = partition_by_nnz(&t, Mode::J, 3);
        assert!(partitions_fiber_aligned(&t, Mode::J, &parts));
    }
}
