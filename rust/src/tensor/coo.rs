//! COO (coordinate) sparse tensor — the storage format the paper's
//! accelerators consume ("all the FPGA or CGRA based implementations use a
//! variation of COO format", §IV-E).
//!
//! Each nonzero is `(i, j, k, value)`; one stored element is 16 bytes
//! (3 × u32 coordinates + f32 value), matching §V-A1.

use crate::util::rng::Rng;

/// Which mode the MTTKRP output is computed along (mode-n MTTKRP updates
/// the mode-n factor matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    I,
    J,
    K,
}

impl Mode {
    pub const ALL: [Mode; 3] = [Mode::I, Mode::J, Mode::K];

    pub fn index(&self) -> usize {
        match self {
            Mode::I => 0,
            Mode::J => 1,
            Mode::K => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::I => "i",
            Mode::J => "j",
            Mode::K => "k",
        }
    }

    #[deprecated(note = "use `s.parse::<Mode>()` instead")]
    pub fn from_name(s: &str) -> Option<Mode> {
        s.parse().ok()
    }
}

impl std::str::FromStr for Mode {
    type Err = crate::util::NameParseError;

    fn from_str(s: &str) -> Result<Mode, crate::util::NameParseError> {
        match s {
            "i" | "I" | "0" => Ok(Mode::I),
            "j" | "J" | "1" => Ok(Mode::J),
            "k" | "K" | "2" => Ok(Mode::K),
            _ => Err(crate::util::NameParseError::new("mode", s, &["i", "j", "k"])),
        }
    }
}

/// Size in bytes of one stored COO element (i, j, k, val @ 4 B each), §V-A1.
pub const COO_ELEM_BYTES: u64 = 16;

/// A third-order sparse tensor in COO format.
///
/// Kept as structure-of-arrays for cache-friendly sweeps; the *stored*
/// layout (what the simulator's address map sees) is array-of-structures,
/// 16 B per element, as in the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct CooTensor {
    pub dims: [u64; 3],
    pub ind_i: Vec<u32>,
    pub ind_j: Vec<u32>,
    pub ind_k: Vec<u32>,
    pub vals: Vec<f32>,
    /// Mode the nonzeros are currently sorted along (None = unsorted).
    pub sorted_mode: Option<Mode>,
    /// Human-readable dataset name (e.g. "synth01").
    pub name: String,
}

impl CooTensor {
    /// Create an empty tensor with the given dimensions.
    pub fn new(name: &str, dims: [u64; 3]) -> CooTensor {
        CooTensor {
            dims,
            ind_i: Vec::new(),
            ind_j: Vec::new(),
            ind_k: Vec::new(),
            vals: Vec::new(),
            sorted_mode: None,
            name: name.to_string(),
        }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Density = nnz / (I·J·K).
    pub fn density(&self) -> f64 {
        let cells = self.dims[0] as f64 * self.dims[1] as f64 * self.dims[2] as f64;
        if cells == 0.0 {
            0.0
        } else {
            self.nnz() as f64 / cells
        }
    }

    /// Stored size in bytes (COO, 16 B/element).
    pub fn stored_bytes(&self) -> u64 {
        self.nnz() as u64 * COO_ELEM_BYTES
    }

    /// Push one nonzero (invalidates sortedness).
    pub fn push(&mut self, i: u32, j: u32, k: u32, v: f32) {
        debug_assert!((i as u64) < self.dims[0], "i {i} out of range {:?}", self.dims);
        debug_assert!((j as u64) < self.dims[1], "j {j} out of range {:?}", self.dims);
        debug_assert!((k as u64) < self.dims[2], "k {k} out of range {:?}", self.dims);
        self.ind_i.push(i);
        self.ind_j.push(j);
        self.ind_k.push(k);
        self.vals.push(v);
        self.sorted_mode = None;
    }

    /// Coordinates of nonzero `z` in mode order `(mode, other1, other2)`.
    #[inline]
    pub fn coords(&self, z: usize) -> (u32, u32, u32) {
        (self.ind_i[z], self.ind_j[z], self.ind_k[z])
    }

    /// The coordinate of nonzero `z` along `mode`.
    #[inline]
    pub fn coord(&self, z: usize, mode: Mode) -> u32 {
        match mode {
            Mode::I => self.ind_i[z],
            Mode::J => self.ind_j[z],
            Mode::K => self.ind_k[z],
        }
    }

    /// Dimension along `mode`.
    pub fn dim(&self, mode: Mode) -> u64 {
        self.dims[mode.index()]
    }

    /// Sort nonzeros along `mode` (stable lexicographic with the other two
    /// modes as tie-breakers) — the matricization order accelerators use so
    /// output-fiber writes are consolidated (Algorithm 3's `current_I`).
    pub fn sort_mode(&mut self, mode: Mode) {
        let n = self.nnz();
        let mut order: Vec<u32> = (0..n as u32).collect();
        let key = |z: usize| -> (u32, u32, u32) {
            let (i, j, k) = self.coords(z);
            match mode {
                Mode::I => (i, j, k),
                Mode::J => (j, k, i),
                Mode::K => (k, i, j),
            }
        };
        order.sort_by_key(|&z| key(z as usize));
        self.permute(&order);
        self.sorted_mode = Some(mode);
    }

    /// Apply a permutation (order[dst] = src).
    fn permute(&mut self, order: &[u32]) {
        let take = |src: &Vec<u32>| -> Vec<u32> {
            order.iter().map(|&z| src[z as usize]).collect()
        };
        self.ind_i = take(&self.ind_i);
        self.ind_j = take(&self.ind_j);
        self.ind_k = take(&self.ind_k);
        self.vals = order.iter().map(|&z| self.vals[z as usize]).collect();
    }

    /// Verify sortedness along `mode`.
    pub fn is_sorted_mode(&self, mode: Mode) -> bool {
        (1..self.nnz()).all(|z| self.coord(z - 1, mode) <= self.coord(z, mode))
    }

    /// Deduplicate identical coordinates by summing values (requires any
    /// full sort first; does its own lexicographic sort).
    pub fn sum_duplicates(&mut self) {
        let n = self.nnz();
        if n == 0 {
            return;
        }
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_by_key(|&z| self.coords(z as usize));
        self.permute(&order);
        let mut w = 0usize;
        for z in 1..n {
            if self.coords(z) == self.coords(w) {
                self.vals[w] += self.vals[z];
            } else {
                w += 1;
                self.ind_i[w] = self.ind_i[z];
                self.ind_j[w] = self.ind_j[z];
                self.ind_k[w] = self.ind_k[z];
                self.vals[w] = self.vals[z];
            }
        }
        self.truncate(w + 1);
        self.sorted_mode = Some(Mode::I);
    }

    fn truncate(&mut self, len: usize) {
        self.ind_i.truncate(len);
        self.ind_j.truncate(len);
        self.ind_k.truncate(len);
        self.vals.truncate(len);
    }

    /// Number of distinct indices along `mode` (= number of output fibers
    /// touched by mode-`mode` MTTKRP).
    pub fn distinct_along(&self, mode: Mode) -> usize {
        let mut seen: Vec<u32> = (0..self.nnz()).map(|z| self.coord(z, mode)).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    /// Byte address of stored element `z` relative to the tensor base.
    #[inline]
    pub fn elem_addr(&self, z: usize) -> u64 {
        z as u64 * COO_ELEM_BYTES
    }

    /// A small random tensor for tests.
    pub fn random(rng: &mut Rng, dims: [u64; 3], nnz: usize) -> CooTensor {
        let mut t = CooTensor::new("random", dims);
        for _ in 0..nnz {
            t.push(
                rng.gen_range(dims[0]) as u32,
                rng.gen_range(dims[1]) as u32,
                rng.gen_range(dims[2]) as u32,
                rng.gen_f32_range(-1.0, 1.0),
            );
        }
        t.sum_duplicates();
        t.sort_mode(Mode::I);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_round_trip() {
        for m in Mode::ALL {
            assert_eq!(m.name().parse(), Ok(m));
        }
        assert_eq!("J".parse(), Ok(Mode::J));
        assert_eq!("2".parse(), Ok(Mode::K));
        let err = "x".parse::<Mode>().unwrap_err();
        assert_eq!(err.to_string(), "unknown mode \"x\" (expected i|j|k)");
        #[allow(deprecated)]
        {
            assert_eq!(Mode::from_name("k"), Some(Mode::K));
            assert_eq!(Mode::from_name("x"), None);
        }
    }

    fn toy() -> CooTensor {
        let mut t = CooTensor::new("toy", [4, 5, 6]);
        t.push(3, 0, 0, 1.0);
        t.push(1, 2, 3, 2.0);
        t.push(1, 0, 5, 3.0);
        t.push(0, 4, 2, 4.0);
        t
    }

    #[test]
    fn push_and_counts() {
        let t = toy();
        assert_eq!(t.nnz(), 4);
        assert_eq!(t.stored_bytes(), 64);
        assert!((t.density() - 4.0 / 120.0).abs() < 1e-12);
        assert_eq!(t.elem_addr(2), 32);
    }

    #[test]
    fn sort_modes() {
        for mode in Mode::ALL {
            let mut t = toy();
            t.sort_mode(mode);
            assert!(t.is_sorted_mode(mode), "not sorted along {:?}", mode);
            assert_eq!(t.sorted_mode, Some(mode));
            // Values follow their coordinates.
            let total: f32 = t.vals.iter().sum();
            assert_eq!(total, 10.0);
        }
    }

    #[test]
    fn sort_is_lexicographic_with_tiebreakers() {
        let mut t = CooTensor::new("tie", [2, 4, 4]);
        t.push(1, 3, 0, 1.0);
        t.push(1, 0, 2, 2.0);
        t.push(1, 0, 1, 3.0);
        t.sort_mode(Mode::I);
        assert_eq!(t.ind_j, vec![0, 0, 3]);
        assert_eq!(t.ind_k, vec![1, 2, 0]);
        assert_eq!(t.vals, vec![3.0, 2.0, 1.0]);
    }

    #[test]
    fn dedup_sums_values() {
        let mut t = CooTensor::new("dup", [2, 2, 2]);
        t.push(1, 1, 1, 1.5);
        t.push(0, 0, 0, 1.0);
        t.push(1, 1, 1, 2.5);
        t.sum_duplicates();
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.coords(0), (0, 0, 0));
        assert_eq!(t.vals[1], 4.0);
    }

    #[test]
    fn distinct_along_counts_fibers() {
        let t = toy();
        assert_eq!(t.distinct_along(Mode::I), 3); // i ∈ {0,1,3}
        assert_eq!(t.distinct_along(Mode::J), 3); // j ∈ {0,2,4}
        assert_eq!(t.distinct_along(Mode::K), 4);
    }

    #[test]
    fn random_tensor_in_bounds_sorted() {
        let mut rng = Rng::new(1);
        let t = CooTensor::random(&mut rng, [10, 11, 12], 200);
        assert!(t.nnz() <= 200);
        assert!(t.nnz() > 100); // dedup shouldn't kill most of them
        assert!(t.is_sorted_mode(Mode::I));
        for z in 0..t.nnz() {
            let (i, j, k) = t.coords(z);
            assert!((i as u64) < 10 && (j as u64) < 11 && (k as u64) < 12);
        }
    }
}
