//! Synthetic sparse-tensor generators reproducing paper Table III.
//!
//! | Tensor   | Dimensions        | Nonzeros | Density  |
//! |----------|-------------------|----------|----------|
//! | Synth 01 | 22K × 22K × 23M   | 28M      | 2.37E-09 |
//! | Synth 02 | 3M × 2M × 25M     | 144M     | 9.05E-13 |
//!
//! Full-size tensors are generated only on demand (`scale = 1.0`); the
//! default experiment scale shrinks nnz (and the long mode) by the same
//! factor, which preserves the *ratios* Fig. 4 reports (density, reuse
//! distance and fiber lengths are scale-free — see EXPERIMENTS.md
//! §Sensitivity). Real-world tensors are hyper-sparse with skewed fiber
//! popularity; `GenParams::skew` reproduces that with a Zipf-like sampler.

use super::coo::{CooTensor, Mode};
use crate::util::rng::Rng;

/// Declarative description of a synthetic dataset (Table III row).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: &'static str,
    pub dims: [u64; 3],
    pub nnz: u64,
}

impl TensorSpec {
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.dims[0] as f64 * self.dims[1] as f64 * self.dims[2] as f64)
    }

    /// Scale the spec: nnz and the *long* mode scale by `scale`; the two
    /// fiber-row modes (I, J) keep their full extent so the factor-matrix
    /// working sets stay far larger than any on-chip cache — the locality
    /// regime the paper's design targets. (Shrinking J/K with nnz would
    /// let the whole factor matrix fit in the 512 KiB cache and invert
    /// the Fig. 4 ranking; see EXPERIMENTS.md §Sensitivity.)
    pub fn scaled(&self, scale: f64) -> TensorSpec {
        assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0, 1]");
        if scale == 1.0 {
            return self.clone();
        }
        let s = |d: u64| -> u64 { ((d as f64 * scale) as u64).max(64) };
        TensorSpec {
            name: self.name,
            dims: [self.dims[0], self.dims[1], s(self.dims[2])],
            nnz: ((self.nnz as f64 * scale) as u64).max(1024),
        }
    }
}

/// Paper Table III, row 1.
pub const SYNTH_01: TensorSpec = TensorSpec {
    name: "synth01",
    dims: [22_000, 22_000, 23_000_000],
    nnz: 28_000_000,
};

/// Paper Table III, row 2.
pub const SYNTH_02: TensorSpec = TensorSpec {
    name: "synth02",
    dims: [3_000_000, 2_000_000, 25_000_000],
    nnz: 144_000_000,
};

/// Generator tuning parameters.
#[derive(Debug, Clone)]
pub struct GenParams {
    pub seed: u64,
    /// Zipf exponent for mode-0/1 fiber popularity (0 = uniform). Real
    /// tensors (NELL, Netflix) have strongly skewed slice sizes.
    pub skew: f64,
    /// Fraction of nonzeros clustered into "dense-ish" fiber runs, which
    /// produces the spatial locality the paper's cache path exploits.
    pub cluster_frac: f64,
    /// Average run length of a cluster along the sorted mode.
    pub cluster_len: usize,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            seed: 0xC0FFEE,
            skew: 1.05,
            cluster_frac: 0.35,
            cluster_len: 8,
        }
    }
}

/// Generate a COO tensor matching `spec` (deduplicated, sorted along I).
///
/// Nonzeros are drawn with skewed i/j popularity and optional clustered
/// runs (consecutive k within one (i, j) slice) so the element stream has
/// the spatial/temporal structure §IV-E attributes to real workloads.
pub fn generate(spec: &TensorSpec, p: &GenParams) -> CooTensor {
    let mut rng = Rng::new(p.seed ^ spec.nnz ^ spec.dims[2]);
    let mut t = CooTensor::new(spec.name, spec.dims);
    let [di, dj, dk] = spec.dims;
    // Oversample a little: dedup removes collisions (rare at these
    // densities but possible at small scales).
    let target = spec.nnz as usize;
    let budget = target + target / 16 + 16;
    while t.nnz() < budget {
        let i = rng.gen_zipf(di, p.skew) as u32;
        let j = rng.gen_zipf(dj, p.skew) as u32;
        if p.cluster_frac > 0.0 && rng.gen_bool(p.cluster_frac) {
            // A clustered run: consecutive k for a fixed (i, j) fiber.
            let len = 1 + rng.gen_usize(0, p.cluster_len.max(1) * 2 - 1);
            let start = rng.gen_range(dk.saturating_sub(len as u64).max(1));
            for off in 0..len {
                let k = start + off as u64;
                if k >= dk || t.nnz() >= budget {
                    break;
                }
                t.push(i, j, k as u32, rng.gen_f32_range(-1.0, 1.0));
            }
        } else {
            let k = rng.gen_range(dk) as u32;
            t.push(i, j, k, rng.gen_f32_range(-1.0, 1.0));
        }
    }
    t.sum_duplicates();
    // Trim to the exact target so Table III's nnz column is met.
    if t.nnz() > target {
        t.ind_i.truncate(target);
        t.ind_j.truncate(target);
        t.ind_k.truncate(target);
        t.vals.truncate(target);
    }
    t.sort_mode(Mode::I);
    t
}

/// Synth 01 at a given scale.
pub fn synth_01(scale: f64) -> CooTensor {
    generate(&SYNTH_01.scaled(scale), &GenParams::default())
}

/// Synth 02 at a given scale.
pub fn synth_02(scale: f64) -> CooTensor {
    // Synth 02 is sparser and less clustered (density 9e-13).
    let p = GenParams {
        skew: 0.8,
        cluster_frac: 0.2,
        ..GenParams::default()
    };
    generate(&SYNTH_02.scaled(scale), &p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_table_iii() {
        assert_eq!(SYNTH_01.dims, [22_000, 22_000, 23_000_000]);
        assert_eq!(SYNTH_01.nnz, 28_000_000);
        // Paper: 2.37E-09.
        assert!((SYNTH_01.density() / 2.37e-9 - 1.0).abs() < 0.1);
        assert_eq!(SYNTH_02.dims, [3_000_000, 2_000_000, 25_000_000]);
        assert_eq!(SYNTH_02.nnz, 144_000_000);
        // Paper: 9.05E-13 (actual 9.6e-13; paper value within 7%).
        assert!((SYNTH_02.density() / 9.05e-13 - 1.0).abs() < 0.1);
    }

    #[test]
    fn scaling_preserves_floors_and_roughly_nnz() {
        let s = SYNTH_01.scaled(0.001);
        assert_eq!(s.nnz, 28_000);
        assert!(s.dims.iter().all(|&d| d >= 64));
        let full = SYNTH_01.scaled(1.0);
        assert_eq!(full, SYNTH_01);
    }

    #[test]
    fn generated_tensor_matches_spec() {
        let spec = SYNTH_01.scaled(0.0005); // 14K nnz — fast
        let t = generate(&spec, &GenParams::default());
        assert_eq!(t.nnz() as u64, spec.nnz);
        assert_eq!(t.dims, spec.dims);
        assert!(t.is_sorted_mode(Mode::I));
        // No duplicate coordinates.
        let mut coords: Vec<_> = (0..t.nnz()).map(|z| t.coords(z)).collect();
        coords.sort_unstable();
        let before = coords.len();
        coords.dedup();
        assert_eq!(coords.len(), before, "duplicates survived dedup");
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = SYNTH_01.scaled(0.0002);
        let a = generate(&spec, &GenParams::default());
        let b = generate(&spec, &GenParams::default());
        assert_eq!(a, b);
        let c = generate(
            &spec,
            &GenParams {
                seed: 99,
                ..GenParams::default()
            },
        );
        assert_ne!(a, c);
    }

    #[test]
    fn skew_concentrates_mass() {
        let spec = TensorSpec {
            name: "t",
            dims: [1000, 1000, 100_000],
            nnz: 20_000,
        };
        let skewed = generate(
            &spec,
            &GenParams {
                skew: 1.3,
                ..GenParams::default()
            },
        );
        // Top decile of i-indices should hold well over 10% of nonzeros.
        let low = (0..skewed.nnz())
            .filter(|&z| skewed.ind_i[z] < 100)
            .count();
        assert!(
            low as f64 > 0.3 * skewed.nnz() as f64,
            "low-decile mass {low}/{}",
            skewed.nnz()
        );
    }
}
