//! `.tns` text I/O (FROSTT-style: one `i j k value` line per nonzero,
//! 1-based indices) so external tensors can be fed to the system.
//!
//! The reader comes in three sizes:
//!
//! * [`TnsReader`] — a buffered streaming cursor yielding one element at
//!   a time with its byte offset and line number, resumable mid-file via
//!   [`TnsReader::open_at`]. This is what the streaming trace sources
//!   build on; memory is one `BufReader` regardless of file size.
//! * [`scan_tns`] — a single pass recording nnz, dimensions, and which
//!   modes the file is sorted along, without keeping any element.
//! * [`read_tns`] — materializes the whole file into a [`CooTensor`]
//!   (fine for fixtures and for files that must be re-sorted).

use std::io::{BufRead, BufReader, BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::coo::CooTensor;
use crate::Result;

/// Write a tensor in FROSTT `.tns` format (1-based indices).
pub fn write_tns(t: &CooTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for z in 0..t.nnz() {
        let (i, j, k) = t.coords(z);
        writeln!(w, "{} {} {} {}", i + 1, j + 1, k + 1, t.vals[z])?;
    }
    w.flush()?;
    Ok(())
}

/// One parsed `.tns` nonzero, with enough position info to seek back to
/// its line later (partition boundaries) and to report errors in
/// `path:lineno` form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TnsElem {
    /// 0-based coordinates (the file stores them 1-based).
    pub idx: [u32; 3],
    pub val: f32,
    /// Byte offset of the start of this element's line.
    pub offset: u64,
    /// 1-based line number of this element's line.
    pub lineno: usize,
}

/// Buffered streaming reader over a `.tns` file: skips comments and
/// blank lines, validates as it goes, tracks byte offsets so a second
/// reader can resume at any previously seen element.
#[derive(Debug)]
pub struct TnsReader {
    r: BufReader<std::fs::File>,
    path: PathBuf,
    buf: String,
    /// Lines consumed so far (== lineno of the last line read).
    lineno: usize,
    /// Byte offset the next `read_line` starts at.
    offset: u64,
}

impl TnsReader {
    /// Open at the start of the file.
    pub fn open(path: &Path) -> Result<TnsReader> {
        TnsReader::open_at(path, 0, 0)
    }

    /// Open positioned at byte `offset`, which must be the start of a
    /// line preceded by `lines_before` lines (both typically taken from
    /// an earlier reader's [`TnsElem`]) so line numbers in errors stay
    /// correct.
    pub fn open_at(path: &Path, offset: u64, lines_before: usize) -> Result<TnsReader> {
        let mut f = std::fs::File::open(path)?;
        if offset > 0 {
            f.seek(SeekFrom::Start(offset))?;
        }
        Ok(TnsReader {
            r: BufReader::new(f),
            path: path.to_path_buf(),
            buf: String::new(),
            lineno: lines_before,
            offset,
        })
    }

    /// Byte offset the next line would be read from (end of file once
    /// `next_elem` has returned `None`).
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Lines consumed so far (including comments and blanks).
    pub fn lines_read(&self) -> usize {
        self.lineno
    }

    /// The next nonzero, or `None` at end of file.
    pub fn next_elem(&mut self) -> Result<Option<TnsElem>> {
        loop {
            self.buf.clear();
            let line_start = self.offset;
            let n = self.r.read_line(&mut self.buf)?;
            if n == 0 {
                return Ok(None);
            }
            self.offset += n as u64;
            self.lineno += 1;
            let line = self.buf.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
                continue;
            }
            let mut it = line.split_whitespace();
            let mut idx = [0u32; 3];
            for m in &mut idx {
                let x: u64 = it
                    .next()
                    .ok_or_else(|| {
                        crate::format_err!("{}:{}: too few fields", self.path.display(), self.lineno)
                    })?
                    .parse()
                    .map_err(|e| {
                        crate::format_err!("{}:{}: bad index: {e}", self.path.display(), self.lineno)
                    })?;
                crate::ensure!(
                    x >= 1,
                    "{}:{}: indices are 1-based",
                    self.path.display(),
                    self.lineno
                );
                crate::ensure!(
                    x <= u32::MAX as u64,
                    "{}:{}: index {x} out of range",
                    self.path.display(),
                    self.lineno
                );
                *m = (x - 1) as u32;
            }
            let val: f32 = it
                .next()
                .ok_or_else(|| {
                    crate::format_err!("{}:{}: missing value", self.path.display(), self.lineno)
                })?
                .parse()
                .map_err(|e| {
                    crate::format_err!("{}:{}: bad value: {e}", self.path.display(), self.lineno)
                })?;
            return Ok(Some(TnsElem {
                idx,
                val,
                offset: line_start,
                lineno: self.lineno,
            }));
        }
    }
}

/// Geometry of a `.tns` file from one streaming pass: nonzero count,
/// inferred dims (max index per mode), and per-mode sortedness (mode
/// coordinate non-decreasing — the same order-based predicate as
/// [`CooTensor::is_sorted_mode`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TnsScan {
    pub nnz: usize,
    pub dims: [u64; 3],
    pub sorted: [bool; 3],
}

/// Scan a `.tns` file for its geometry without materializing it.
pub fn scan_tns(path: &Path) -> Result<TnsScan> {
    let mut r = TnsReader::open(path)?;
    let mut scan = TnsScan {
        nnz: 0,
        dims: [0; 3],
        sorted: [true; 3],
    };
    let mut prev: Option<[u32; 3]> = None;
    while let Some(e) = r.next_elem()? {
        scan.nnz += 1;
        for m in 0..3 {
            scan.dims[m] = scan.dims[m].max(e.idx[m] as u64 + 1);
            if let Some(p) = prev {
                scan.sorted[m] &= p[m] <= e.idx[m];
            }
        }
        prev = Some(e.idx);
    }
    Ok(scan)
}

/// Read a 3-mode FROSTT `.tns` file. Dimensions are inferred from the
/// maximum index per mode unless `dims` is given.
pub fn read_tns(path: &Path, dims: Option<[u64; 3]>) -> Result<CooTensor> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "tns".into());
    let mut r = TnsReader::open(path)?;
    let mut is = Vec::new();
    let mut js = Vec::new();
    let mut ks = Vec::new();
    let mut vs = Vec::new();
    let mut max = [0u64; 3];
    while let Some(e) = r.next_elem()? {
        for (m, &x) in max.iter_mut().zip(&e.idx) {
            *m = (*m).max(x as u64 + 1);
        }
        is.push(e.idx[0]);
        js.push(e.idx[1]);
        ks.push(e.idx[2]);
        vs.push(e.val);
    }
    let dims = dims.unwrap_or(max);
    crate::ensure!(
        dims[0] >= max[0] && dims[1] >= max[1] && dims[2] >= max[2],
        "given dims {dims:?} smaller than data extent {max:?}"
    );
    let mut t = CooTensor::new(&name, dims);
    t.ind_i = is;
    t.ind_j = js;
    t.ind_k = ks;
    t.vals = vs;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::coo::Mode;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_tns() {
        let mut rng = Rng::new(8);
        let mut t = CooTensor::random(&mut rng, [10, 12, 14], 80);
        t.sort_mode(Mode::I);
        let dir = std::env::temp_dir().join("memsys_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tns");
        write_tns(&t, &path).unwrap();
        let back = read_tns(&path, Some(t.dims)).unwrap();
        assert_eq!(back.nnz(), t.nnz());
        for z in 0..t.nnz() {
            assert_eq!(back.coords(z), t.coords(z));
            assert!((back.vals[z] - t.vals[z]).abs() < 1e-5);
        }
    }

    #[test]
    fn infers_dims_and_skips_comments() {
        let dir = std::env::temp_dir().join("memsys_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.tns");
        std::fs::write(&path, "# header\n2 3 4 1.5\n% other comment\n1 1 1 -2\n").unwrap();
        let t = read_tns(&path, None).unwrap();
        assert_eq!(t.dims, [2, 3, 4]);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.coords(0), (1, 2, 3));
        assert_eq!(t.vals[1], -2.0);
    }

    #[test]
    fn rejects_bad_input() {
        let dir = std::env::temp_dir().join("memsys_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("short.tns");
        std::fs::write(&p1, "1 2 3\n").unwrap();
        assert!(read_tns(&p1, None).is_err());
        let p2 = dir.join("zero.tns");
        std::fs::write(&p2, "0 1 1 2.0\n").unwrap();
        assert!(read_tns(&p2, None).is_err(), "0-based index must fail");
        let p3 = dir.join("dims.tns");
        std::fs::write(&p3, "5 1 1 2.0\n").unwrap();
        assert!(read_tns(&p3, Some([2, 2, 2])).is_err(), "extent check");
    }

    #[test]
    fn reader_reports_line_numbers_through_comments() {
        let dir = std::env::temp_dir().join("memsys_io_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lines.tns");
        std::fs::write(&path, "# one\n\n1 1 1 3.0\n% four\nbad line here\n").unwrap();
        let mut r = TnsReader::open(&path).unwrap();
        let e = r.next_elem().unwrap().unwrap();
        assert_eq!(e.lineno, 3);
        assert_eq!(e.idx, [0, 0, 0]);
        let err = r.next_elem().unwrap_err().to_string();
        assert!(err.contains(":5:"), "error should carry lineno 5: {err}");
    }

    #[test]
    fn reader_resumes_at_recorded_offsets() {
        let dir = std::env::temp_dir().join("memsys_io_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume.tns");
        std::fs::write(&path, "# hdr\n1 2 3 1.0\n2 3 4 2.0\n3 4 5 -3.5\n").unwrap();
        let mut r = TnsReader::open(&path).unwrap();
        let mut elems = Vec::new();
        while let Some(e) = r.next_elem().unwrap() {
            elems.push(e);
        }
        assert_eq!(elems.len(), 3);
        // Reopen at each element's offset: the remainder must replay
        // identically, line numbers included.
        for (i, start) in elems.iter().enumerate() {
            let mut r2 = TnsReader::open_at(&path, start.offset, start.lineno - 1).unwrap();
            for want in &elems[i..] {
                assert_eq!(r2.next_elem().unwrap().unwrap(), *want);
            }
            assert!(r2.next_elem().unwrap().is_none());
        }
    }

    #[test]
    fn committed_fixture_round_trips() {
        // The checked-in FROSTT-style fixture: comments in both styles,
        // blank lines, negative and exponent-notation values, dims far
        // from square.
        let fixture = Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/tests/data/sample.tns"
        ));
        let t = read_tns(fixture, None).unwrap();
        assert_eq!(t.dims, [5, 400, 7000]);
        assert_eq!(t.nnz(), 12);
        assert!(t.is_sorted_mode(Mode::I), "fixture is mode-i sorted");
        assert_eq!(t.coords(1), (0, 36, 4095));
        assert_eq!(t.vals[1], -3.25);
        assert_eq!(t.vals[4], -1.5e2);
        assert_eq!(t.vals[10], 3.0e-1);
        assert_eq!(t.vals[11], -42.0);
        let scan = scan_tns(fixture).unwrap();
        assert_eq!(scan.nnz, 12);
        assert_eq!(scan.dims, t.dims);
        assert!(scan.sorted[0]);

        // write → read is lossless on the fixture's values.
        let dir = std::env::temp_dir().join("memsys_io_test7");
        std::fs::create_dir_all(&dir).unwrap();
        let copy = dir.join("sample_copy.tns");
        write_tns(&t, &copy).unwrap();
        let back = read_tns(&copy, None).unwrap();
        assert_eq!(back.dims, t.dims);
        assert_eq!(back.ind_i, t.ind_i);
        assert_eq!(back.ind_j, t.ind_j);
        assert_eq!(back.ind_k, t.ind_k);
        assert_eq!(back.vals, t.vals);
    }

    #[test]
    fn scan_reports_geometry_and_sortedness() {
        let dir = std::env::temp_dir().join("memsys_io_test6");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scan.tns");
        // i ascending, j ascending, k not.
        std::fs::write(&path, "1 1 9 1.0\n1 2 4 2.0\n3 2 5 3.0\n").unwrap();
        let s = scan_tns(&path).unwrap();
        assert_eq!(s.nnz, 3);
        assert_eq!(s.dims, [3, 2, 9]);
        assert_eq!(s.sorted, [true, true, false]);
        // Empty (comment-only) file: zero nnz, trivially sorted.
        let empty = dir.join("scan_empty.tns");
        std::fs::write(&empty, "# nothing\n").unwrap();
        let s = scan_tns(&empty).unwrap();
        assert_eq!(s.nnz, 0);
        assert_eq!(s.sorted, [true, true, true]);
    }
}
