//! `.tns` text I/O (FROSTT-style: one `i j k value` line per nonzero,
//! 1-based indices) so external tensors can be fed to the system.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::coo::CooTensor;
use crate::Result;

/// Write a tensor in FROSTT `.tns` format (1-based indices).
pub fn write_tns(t: &CooTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for z in 0..t.nnz() {
        let (i, j, k) = t.coords(z);
        writeln!(w, "{} {} {} {}", i + 1, j + 1, k + 1, t.vals[z])?;
    }
    w.flush()?;
    Ok(())
}

/// Read a 3-mode FROSTT `.tns` file. Dimensions are inferred from the
/// maximum index per mode unless `dims` is given.
pub fn read_tns(path: &Path, dims: Option<[u64; 3]>) -> Result<CooTensor> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "tns".into());
    let mut is = Vec::new();
    let mut js = Vec::new();
    let mut ks = Vec::new();
    let mut vs = Vec::new();
    let mut max = [0u64; 3];
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let mut idx = [0u64; 3];
        for m in &mut idx {
            *m = it
                .next()
                .ok_or_else(|| {
                    anyhow::anyhow!("{}:{}: too few fields", path.display(), lineno + 1)
                })?
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("{}:{}: bad index: {e}", path.display(), lineno + 1))?;
            anyhow::ensure!(*m >= 1, "{}:{}: indices are 1-based", path.display(), lineno + 1);
        }
        let v: f32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("{}:{}: missing value", path.display(), lineno + 1))?
            .parse()
            .map_err(|e| anyhow::anyhow!("{}:{}: bad value: {e}", path.display(), lineno + 1))?;
        for (m, &x) in max.iter_mut().zip(&idx) {
            *m = (*m).max(x);
        }
        is.push((idx[0] - 1) as u32);
        js.push((idx[1] - 1) as u32);
        ks.push((idx[2] - 1) as u32);
        vs.push(v);
    }
    let dims = dims.unwrap_or(max);
    anyhow::ensure!(
        dims[0] >= max[0] && dims[1] >= max[1] && dims[2] >= max[2],
        "given dims {dims:?} smaller than data extent {max:?}"
    );
    let mut t = CooTensor::new(&name, dims);
    t.ind_i = is;
    t.ind_j = js;
    t.ind_k = ks;
    t.vals = vs;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::coo::Mode;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_tns() {
        let mut rng = Rng::new(8);
        let mut t = CooTensor::random(&mut rng, [10, 12, 14], 80);
        t.sort_mode(Mode::I);
        let dir = std::env::temp_dir().join("memsys_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tns");
        write_tns(&t, &path).unwrap();
        let back = read_tns(&path, Some(t.dims)).unwrap();
        assert_eq!(back.nnz(), t.nnz());
        for z in 0..t.nnz() {
            assert_eq!(back.coords(z), t.coords(z));
            assert!((back.vals[z] - t.vals[z]).abs() < 1e-5);
        }
    }

    #[test]
    fn infers_dims_and_skips_comments() {
        let dir = std::env::temp_dir().join("memsys_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.tns");
        std::fs::write(&path, "# header\n2 3 4 1.5\n% other comment\n1 1 1 -2\n").unwrap();
        let t = read_tns(&path, None).unwrap();
        assert_eq!(t.dims, [2, 3, 4]);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.coords(0), (1, 2, 3));
        assert_eq!(t.vals[1], -2.0);
    }

    #[test]
    fn rejects_bad_input() {
        let dir = std::env::temp_dir().join("memsys_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("short.tns");
        std::fs::write(&p1, "1 2 3\n").unwrap();
        assert!(read_tns(&p1, None).is_err());
        let p2 = dir.join("zero.tns");
        std::fs::write(&p2, "0 1 1 2.0\n").unwrap();
        assert!(read_tns(&p2, None).is_err(), "0-based index must fail");
        let p3 = dir.join("dims.tns");
        std::fs::write(&p3, "5 1 1 2.0\n").unwrap();
        assert!(read_tns(&p3, Some([2, 2, 2])).is_err(), "extent check");
    }
}
