//! Dense factor matrices in row-major order — "the dense matrices use a
//! row-major format ... because the MTTKRP algorithm encourages row-wise
//! matrix accesses" (§IV-A). Element size 4 B (f32), rank R per row (§V-A1).

use crate::util::rng::Rng;

/// Bytes per dense element (§V-A1: "keeping each element 4 Byte").
pub const DENSE_ELEM_BYTES: u64 = 4;

/// Row-major dense matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Uniform random in [0,1) — standard CP-ALS init.
    pub fn random(rng: &mut Rng, rows: usize, cols: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.gen_f32();
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// A row (fiber) as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Byte address of row `r` relative to the matrix base (row-major).
    #[inline]
    pub fn row_addr(&self, r: usize) -> u64 {
        r as u64 * self.row_bytes()
    }

    /// Bytes per row (= fiber length in bytes = R·4).
    #[inline]
    pub fn row_bytes(&self) -> u64 {
        self.cols as u64 * DENSE_ELEM_BYTES
    }

    /// Total stored bytes.
    pub fn stored_bytes(&self) -> u64 {
        self.rows as u64 * self.row_bytes()
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Gram matrix AᵀA (R×R) — used by CP-ALS normal equations.
    pub fn gram(&self) -> DenseMatrix {
        let r = self.cols;
        let mut g = DenseMatrix::zeros(r, r);
        for row in 0..self.rows {
            let x = self.row(row);
            for a in 0..r {
                let xa = x[a];
                if xa == 0.0 {
                    continue;
                }
                for b in a..r {
                    g.data[a * r + b] += xa * x[b];
                }
            }
        }
        // Mirror the upper triangle.
        for a in 0..r {
            for b in 0..a {
                g.data[a * r + b] = g.data[b * r + a];
            }
        }
        g
    }

    /// Elementwise (Hadamard) product — `C^TC * D^TD` in Algorithm 1.
    pub fn hadamard(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Normalize each column to unit 2-norm; returns the norms (λ).
    pub fn normalize_columns(&mut self) -> Vec<f32> {
        let mut norms = vec![0f32; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let v = self.at(r, c);
                norms[c] += v * v;
            }
        }
        for n in &mut norms {
            *n = n.sqrt();
        }
        for r in 0..self.rows {
            for c in 0..self.cols {
                if norms[c] > 1e-20 {
                    *self.at_mut(r, c) /= norms[c];
                }
            }
        }
        norms
    }

    /// Max absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_addressing() {
        let mut m = DenseMatrix::zeros(3, 4);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.data[6], 5.0);
        assert_eq!(m.row_addr(2), 32);
        assert_eq!(m.row_bytes(), 16);
        assert_eq!(m.stored_bytes(), 48);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn gram_is_correct_small() {
        // A = [[1,2],[3,4]]; AᵀA = [[10,14],[14,20]]
        let m = DenseMatrix {
            rows: 2,
            cols: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let g = m.gram();
        assert_eq!(g.data, vec![10.0, 14.0, 14.0, 20.0]);
    }

    #[test]
    fn hadamard_elementwise() {
        let a = DenseMatrix {
            rows: 1,
            cols: 3,
            data: vec![1.0, 2.0, 3.0],
        };
        let b = DenseMatrix {
            rows: 1,
            cols: 3,
            data: vec![4.0, 5.0, 6.0],
        };
        assert_eq!(a.hadamard(&b).data, vec![4.0, 10.0, 18.0]);
    }

    #[test]
    fn normalize_columns_unit_norm() {
        let mut m = DenseMatrix {
            rows: 2,
            cols: 2,
            data: vec![3.0, 0.0, 4.0, 2.0],
        };
        let norms = m.normalize_columns();
        assert!((norms[0] - 5.0).abs() < 1e-6);
        assert!((norms[1] - 2.0).abs() < 1e-6);
        // Column 0 now (0.6, 0.8).
        assert!((m.at(0, 0) - 0.6).abs() < 1e-6);
        assert!((m.at(1, 0) - 0.8).abs() < 1e-6);
    }

    #[test]
    fn fro_norm_and_diff() {
        let a = DenseMatrix {
            rows: 1,
            cols: 2,
            data: vec![3.0, 4.0],
        };
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        let b = DenseMatrix {
            rows: 1,
            cols: 2,
            data: vec![3.5, 4.0],
        };
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn random_in_unit_interval() {
        let mut rng = Rng::new(2);
        let m = DenseMatrix::random(&mut rng, 10, 10);
        assert!(m.data.iter().all(|&v| (0.0..1.0).contains(&v)));
    }
}
