//! Sparse-tensor substrate: COO tensors, CISS-like interleaved layout,
//! synthetic dataset generators (paper Table III), dense factor matrices,
//! `.tns` I/O, and nonzero partitioning for parallel PEs (Algorithm 3).

pub mod ciss;
pub mod coo;
pub mod dense;
pub mod gen;
pub mod io;
pub mod partition;

pub use ciss::CissTensor;
pub use coo::{CooTensor, Mode};
pub use dense::DenseMatrix;
pub use gen::{synth_01, synth_02, GenParams, TensorSpec};
pub use partition::{partition_by_nnz, Partition};
