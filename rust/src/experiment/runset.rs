//! RunSet: the result of a [`super::Sweep`] — one [`Run`] per grid
//! point, in grid order — with baseline/speedup lookups, an ASCII table
//! view, and JSON-lines serialization (one `SimReport` + its axes per
//! line) so experiments produce machine-readable output.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::config::SystemConfig;
use crate::sim::SimReport;
use crate::util::json::Json;
use crate::util::table::{Align, Table};

/// One executed grid point.
#[derive(Debug, Clone)]
pub struct Run {
    /// `(axis key, value)` pairs in axis-declaration order.
    pub axes: Vec<(String, String)>,
    /// The fully-resolved config this run simulated.
    pub cfg: SystemConfig,
    /// Modelled maximum operating frequency of `cfg` (§IV-E).
    pub fmax_mhz: f64,
    pub report: SimReport,
}

impl Run {
    /// Value this run took on `axis`, if the sweep had that axis.
    pub fn axis(&self, name: &str) -> Option<&str> {
        self.axes.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// `key=value key=value` label (falls back to the config label for
    /// an axis-less single run).
    pub fn label(&self) -> String {
        axes_label(&self.axes, &self.cfg.label)
    }

    /// True when this run matches every `(axis, value)` selector.
    pub fn matches(&self, sel: &[(&str, &str)]) -> bool {
        sel.iter().all(|(k, v)| self.axis(k) == Some(*v))
    }

    /// One JSON-lines record: label + axes + resolved config + report
    /// (`total_cycles` is mirrored at top level for cheap consumers).
    pub fn to_json(&self) -> Json {
        let axes: BTreeMap<String, Json> = self
            .axes
            .iter()
            .map(|(k, v)| (k.clone(), Json::str(v.clone())))
            .collect();
        Json::obj(vec![
            ("label", Json::str(self.label())),
            ("axes", Json::Obj(axes)),
            ("config", self.cfg.to_json()),
            ("fmax_mhz", Json::num(self.fmax_mhz)),
            ("total_cycles", Json::num(self.report.total_cycles as f64)),
            ("report", self.report.to_json()),
        ])
    }
}

/// The label a grid point will have once run — shared by [`Run::label`]
/// and the sweep's resume filter, so "already in the output file" and
/// "what this run will be called" can never drift apart.
pub(crate) fn axes_label(axes: &[(String, String)], cfg_label: &str) -> String {
    if axes.is_empty() {
        return cfg_label.to_string();
    }
    let mut out = String::new();
    for (i, (k, v)) in axes.iter().enumerate() {
        if i > 0 {
            out.push(' ');
        }
        out.push_str(k);
        out.push('=');
        out.push_str(v);
    }
    out
}

/// All runs of one sweep, in deterministic grid order.
#[derive(Debug, Clone)]
pub struct RunSet {
    /// Flattened axis keys in declaration order.
    pub axis_names: Vec<String>,
    pub runs: Vec<Run>,
}

impl RunSet {
    pub fn len(&self) -> usize {
        self.runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// First run matching every `(axis, value)` selector.
    pub fn get(&self, sel: &[(&str, &str)]) -> Option<&Run> {
        self.runs.iter().find(|r| r.matches(sel))
    }

    /// The run that differs from `run` only in `axis`, where it takes
    /// `value` — the Fig. 4-style within-category baseline.
    pub fn baseline_for(&self, run: &Run, axis: &str, value: &str) -> Option<&Run> {
        self.runs.iter().find(|b| {
            b.axis(axis) == Some(value)
                && run
                    .axes
                    .iter()
                    .all(|(k, v)| k == axis || b.axis(k) == Some(v.as_str()))
        })
    }

    /// Speedup of `run` over its within-category baseline
    /// (`baseline_cycles / run_cycles`; 1.0 for the baseline itself).
    /// Computed as a plain cycle ratio — baselining a scenario axis
    /// (e.g. `dataset`) compares across workloads by explicit request,
    /// so the within-workload assert of `SimReport::speedup_over` does
    /// not apply here.
    pub fn speedup_over_baseline(&self, run: &Run, axis: &str, value: &str) -> Option<f64> {
        let baseline = self.baseline_for(run, axis, value)?;
        if run.report.total_cycles == 0 {
            return None;
        }
        Some(baseline.report.total_cycles as f64 / run.report.total_cycles as f64)
    }

    /// ASCII table: one row per run (axes, cycles, per-class latency
    /// mean + p95, optional speedup over the `(axis, value)` baseline,
    /// modelled fmax).
    pub fn to_table(&self, baseline: Option<(&str, &str)>) -> Table {
        let mut headers: Vec<&str> = self.axis_names.iter().map(String::as_str).collect();
        headers.push("cycles");
        headers.extend(["elem lat", "p95", "fiber lat", "p95"]);
        if baseline.is_some() {
            headers.push("speedup");
        }
        headers.push("fmax (MHz)");
        let mut aligns = vec![Align::Left; self.axis_names.len()];
        aligns.resize(headers.len(), Align::Right);
        let mut table = Table::new(&headers).aligns(&aligns);
        for run in &self.runs {
            let mut row: Vec<String> = self
                .axis_names
                .iter()
                .map(|n| run.axis(n).unwrap_or("-").to_string())
                .collect();
            row.push(run.report.total_cycles.to_string());
            row.extend(run.report.latency_cells());
            if let Some((axis, value)) = baseline {
                row.push(match self.speedup_over_baseline(run, axis, value) {
                    Some(s) => format!("{s:.2}x"),
                    None => "-".to_string(),
                });
            }
            row.push(format!("{:.0}", run.fmax_mhz));
            table.row(&row);
        }
        table
    }

    /// JSON-lines: one compact record per run, grid order.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for run in &self.runs {
            out.push_str(&run.to_json().to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Write [`RunSet::to_jsonl`] to `path`.
    pub fn write_jsonl(&self, path: &Path) -> io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::{Scenario, Sweep};

    fn tiny_runset() -> RunSet {
        Sweep::new(SystemConfig::config_b(), Scenario::random([48, 4_000, 6_000], 350, 5))
            .axis("system", &["ip-only", "proposed"])
            .axis("dma.n_buffers", &["1", "4"])
            .threads(2)
            .run()
            .unwrap()
    }

    #[test]
    fn get_baseline_and_speedup() {
        let rs = tiny_runset();
        assert_eq!(rs.len(), 4);
        let prop = rs.get(&[("system", "proposed"), ("dma.n_buffers", "4")]).unwrap();
        let base = rs.baseline_for(prop, "system", "ip-only").unwrap();
        assert_eq!(base.axis("system"), Some("ip-only"));
        assert_eq!(base.axis("dma.n_buffers"), Some("4"), "other axes must match");
        let s = rs.speedup_over_baseline(prop, "system", "ip-only").unwrap();
        let expect = base.report.total_cycles as f64 / prop.report.total_cycles as f64;
        assert!((s - expect).abs() < 1e-12, "speedup must pair the right baseline");
        let own = rs.speedup_over_baseline(base, "system", "ip-only").unwrap();
        assert!((own - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_has_axes_cycles_and_speedup_columns() {
        let rs = tiny_runset();
        let rendered = rs.to_table(Some(("system", "ip-only"))).render();
        assert!(rendered.contains("system"));
        assert!(rendered.contains("dma.n_buffers"));
        assert!(rendered.contains("cycles"));
        assert!(rendered.contains("speedup"));
        assert!(rendered.contains("1.00x"));
        // Latency mean + p95 columns ride next to cycles.
        assert!(rendered.contains("elem lat"));
        assert!(rendered.contains("fiber lat"));
        assert!(rendered.contains("p95"));
        let run = &rs.runs[0];
        for cell in run.report.latency_cells() {
            assert!(rendered.contains(&cell), "missing latency cell {cell:?}");
        }
    }

    #[test]
    fn jsonl_round_trips_with_schema_fields() {
        let rs = tiny_runset();
        let jsonl = rs.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), rs.len());
        for (line, run) in lines.iter().zip(&rs.runs) {
            let rec = Json::parse(line).unwrap();
            assert_eq!(rec.get("label").unwrap().as_str(), Some(run.label().as_str()));
            let axes = rec.get("axes").unwrap();
            assert_eq!(
                axes.get("system").unwrap().as_str(),
                Some(run.axis("system").unwrap())
            );
            assert_eq!(
                rec.get("total_cycles").unwrap().as_usize(),
                Some(run.report.total_cycles as usize)
            );
            let report = rec.get("report").unwrap();
            assert_eq!(
                report.get("total_cycles").unwrap().as_usize(),
                Some(run.report.total_cycles as usize)
            );
            assert!(rec.get("config").unwrap().get("kind").is_some());
            assert!(rec.get("fmax_mhz").unwrap().as_f64().is_some());
        }
    }
}
