//! Sweep: a declarative cartesian grid over configuration and scenario
//! axes, executed by a multi-threaded runner with deterministic result
//! order (grid order, independent of thread count).
//!
//! Every `simulate` call is independent, so the fig4/channels-style
//! grids are embarrassingly parallel: workers pull grid points from an
//! atomic cursor and write into per-point slots. Distinct trace sources
//! (deduplicated by scenario key — source + geometry) are resolved once
//! up front and shared read-only across workers; each worker opens its
//! own cursors, so streams never contend.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::config::{FabricType, SystemConfig, SystemKind};
use crate::resource::max_frequency_mhz;
use crate::sim::{simulate, MemorySystem, TelemetryOutput};
use crate::tensor::Mode;
use crate::trace::TraceSource;
use crate::util::json::Json;
use crate::util::NameParseError;

use super::runset::{axes_label, Run, RunSet};
use super::{preset, Scenario};

/// One grid dimension: one config/scenario key (or several zipped keys
/// that advance together) and the value tuples it takes.
#[derive(Debug, Clone)]
struct Axis {
    keys: Vec<String>,
    values: Vec<Vec<String>>,
}

/// One fully-resolved grid point, ready to simulate.
#[derive(Debug, Clone)]
pub struct Point {
    /// `(axis key, value)` in axis-declaration order.
    pub axes: Vec<(String, String)>,
    pub cfg: SystemConfig,
    pub scenario: Scenario,
}

/// A declarative experiment grid over a base config + scenario.
///
/// Axis keys are applied in declaration order to a fresh clone of the
/// base pair for every grid point:
///
/// * `preset` — replace the whole config (`a` / `b`); declare it first.
/// * `system` — derive a §V-B baseline variant (`ip-only`, `cache-only`,
///   `dma-only`, `proposed`).
/// * `dataset`, `scale`, `mode` — scenario knobs (which tensor — a
///   synthetic name or a `.tns` path — at what scale, which MTTKRP
///   mode).
/// * `fabric` — compute-fabric type (sets both the scenario trace shape
///   and `pe.fabric`).
/// * anything else — a [`SystemConfig::apply_override`] key, including
///   the `channels` / `topology` / `link-width` / `lmb-banks` /
///   `reply-network` shorthands and the cluster axes (`nodes`,
///   `inter-topology`, `cluster.link_bytes`, ...) — multi-node points
///   run through [`crate::cluster`] and return the flattened report.
#[derive(Debug, Clone)]
pub struct Sweep {
    base: SystemConfig,
    scenario: Scenario,
    axes: Vec<Axis>,
    threads: usize,
    telemetry_dir: Option<PathBuf>,
    resume_from: Option<PathBuf>,
}

/// Worker count the runner defaults to (the machine's parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Sweep {
    pub fn new(base: SystemConfig, scenario: Scenario) -> Sweep {
        Sweep {
            base,
            scenario,
            axes: Vec::new(),
            threads: default_threads(),
            telemetry_dir: None,
            resume_from: None,
        }
    }

    /// Resume an interrupted sweep: grid points whose label already
    /// appears as a `label` field in the JSON-lines file at `path` are
    /// skipped, and [`Sweep::run`] returns only the newly executed runs
    /// (append them to the same file to complete it). A missing file
    /// skips nothing; an unreadable or non-JSONL file is an error —
    /// silently re-running everything against a corrupt output would
    /// duplicate records.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Sweep {
        self.resume_from = Some(path.into());
        self
    }

    /// Write per-run telemetry artifacts into `dir` (created on demand):
    /// `trace-<n>-<label>.json` / `timeline-<n>-<label>.jsonl` for every
    /// grid point whose *resolved* config enables the matching product —
    /// so a `telemetry.trace` axis traces exactly the points that ask
    /// for it. Points with telemetry off write nothing and simulate on
    /// the untouched fast path.
    pub fn telemetry_dir(mut self, dir: impl Into<PathBuf>) -> Sweep {
        self.telemetry_dir = Some(dir.into());
        self
    }

    /// Add a cartesian axis: `key` takes each of `values` in turn.
    pub fn axis<S: AsRef<str>>(mut self, key: &str, values: &[S]) -> Sweep {
        self.axes.push(Axis {
            keys: vec![key.to_string()],
            values: values.iter().map(|v| vec![v.as_ref().to_string()]).collect(),
        });
        self
    }

    /// Add a zipped axis: the keys advance together through the value
    /// tuples (one grid dimension), e.g. paired
    /// `cache.lines`/`cache.associativity` geometries.
    pub fn zip_axis(mut self, keys: &[&str], values: &[&[&str]]) -> Sweep {
        for row in values {
            assert_eq!(row.len(), keys.len(), "zip_axis value tuple width");
        }
        self.axes.push(Axis {
            keys: keys.iter().map(|k| k.to_string()).collect(),
            values: values
                .iter()
                .map(|row| row.iter().map(|v| v.to_string()).collect())
                .collect(),
        });
        self
    }

    /// Worker-thread count (results are deterministic regardless).
    pub fn threads(mut self, n: usize) -> Sweep {
        self.threads = n.max(1);
        self
    }

    /// Flattened axis key names, in declaration order.
    pub fn axis_names(&self) -> Vec<String> {
        self.axes.iter().flat_map(|a| a.keys.iter().cloned()).collect()
    }

    /// Resolve every grid point (row-major: the first axis varies
    /// slowest). Fails fast on unknown/duplicate keys, bad values, or
    /// invalid configs.
    pub fn grid(&self) -> Result<Vec<Point>, String> {
        let names = self.axis_names();
        for (i, name) in names.iter().enumerate() {
            if names[..i].contains(name) {
                return Err(format!("duplicate axis key {name:?}"));
            }
        }
        let counts: Vec<usize> = self.axes.iter().map(|a| a.values.len()).collect();
        for (axis, &n) in self.axes.iter().zip(&counts) {
            if n == 0 {
                return Err(format!("axis {:?} has no values", axis.keys.join("+")));
            }
        }
        let total: usize = counts.iter().product();
        let mut points = Vec::with_capacity(total);
        for flat in 0..total {
            let mut idx = flat;
            let mut sel = vec![0usize; self.axes.len()];
            for ai in (0..self.axes.len()).rev() {
                sel[ai] = idx % counts[ai];
                idx /= counts[ai];
            }
            let mut cfg = self.base.clone();
            let mut scenario = self.scenario.clone();
            let mut axes_kv = Vec::new();
            for (axis, &vi) in self.axes.iter().zip(&sel) {
                for (key, value) in axis.keys.iter().zip(&axis.values[vi]) {
                    apply_axis(&mut cfg, &mut scenario, key, value)
                        .map_err(|e| format!("axis {key}={value}: {e}"))?;
                    axes_kv.push((key.clone(), value.clone()));
                }
            }
            // One source of truth each way: the scenario decides the
            // fabric type, the config decides the front-end geometry.
            cfg.pe.fabric = scenario.fabric;
            scenario.sync_geometry(&cfg);
            cfg.validate().map_err(|e| format!("grid point {axes_kv:?}: {e}"))?;
            points.push(Point { axes: axes_kv, cfg, scenario });
        }
        Ok(points)
    }

    /// Execute the grid and collect a [`RunSet`] in grid order. With
    /// [`Sweep::resume_from`], already-recorded grid points are skipped
    /// and only the new runs are returned.
    pub fn run(&self) -> Result<RunSet, String> {
        let mut points = self.grid()?;
        if let Some(path) = &self.resume_from {
            let done = completed_labels(path)?;
            points.retain(|p| !done.contains(&axes_label(&p.axes, &p.cfg.label)));
        }
        if points.is_empty() {
            return Ok(RunSet { axis_names: self.axis_names(), runs: Vec::new() });
        }
        // Resolve each distinct trace source once, before spawning
        // workers: source construction can fail (missing/garbled `.tns`
        // files) and the error must propagate instead of poisoning a
        // worker. Grid points sharing a scenario key share the source;
        // every run opens its own cursors.
        let mut sources: HashMap<String, Arc<dyn TraceSource>> = HashMap::new();
        for p in &points {
            let key = p.scenario.key();
            if !sources.contains_key(&key) {
                let src = p
                    .scenario
                    .trace_source()
                    .map_err(|e| format!("grid point {:?}: {e}", p.axes))?;
                sources.insert(key, src);
            }
        }
        let slots: Vec<OnceLock<Run>> = (0..points.len()).map(|_| OnceLock::new()).collect();
        // Side channel for telemetry artifacts: workers stash outputs
        // here, the calling thread does all file IO after the joins.
        let tel_slots: Vec<OnceLock<Option<TelemetryOutput>>> =
            (0..points.len()).map(|_| OnceLock::new()).collect();
        let want_telemetry = self.telemetry_dir.is_some();
        let cursor = AtomicUsize::new(0);
        // `grid` yields ≥ 1 point (an empty axis list is a single run).
        let workers = self.threads.clamp(1, points.len());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let p = &points[i];
                    let src = &sources[&p.scenario.key()];
                    let name = src.name().to_string();
                    let (report, tel) = if p.cfg.cluster.nodes > 1 {
                        // Multi-node points run through the cluster layer
                        // and flatten to a single report (per-node cycle
                        // telemetry is not plumbed through sweeps — use
                        // `run_cluster` directly for the full breakdown).
                        let cl = crate::cluster::simulate_cluster(&p.cfg, src);
                        (cl.into_report(), None)
                    } else if want_telemetry && p.cfg.telemetry.enabled() {
                        let mut sys = MemorySystem::new(&p.cfg, src);
                        let report = sys.run(&name);
                        (report, Some(sys.take_telemetry(&name)))
                    } else {
                        (simulate(&p.cfg, src), None)
                    };
                    tel_slots[i].set(tel).expect("each telemetry slot is filled once");
                    let run = Run {
                        axes: p.axes.clone(),
                        fmax_mhz: max_frequency_mhz(&p.cfg),
                        cfg: p.cfg.clone(),
                        report,
                    };
                    slots[i].set(run).expect("each slot is filled once");
                });
            }
        });
        let runs: Vec<Run> = slots
            .into_iter()
            .map(|s| s.into_inner().expect("worker filled every slot"))
            .collect();
        if let Some(dir) = &self.telemetry_dir {
            let outputs = tel_slots
                .into_iter()
                .map(|s| s.into_inner().expect("worker filled every telemetry slot"));
            write_telemetry_artifacts(dir, &runs, outputs)?;
        }
        Ok(RunSet { axis_names: self.axis_names(), runs })
    }
}

/// Filesystem-safe run label: alphanumerics kept, runs of anything else
/// collapsed to single dashes (`system=proposed scale=0.01` →
/// `system-proposed-scale-0-01`).
fn slug(label: &str) -> String {
    let mut out = String::with_capacity(label.len());
    for c in label.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else if !out.ends_with('-') {
            out.push('-');
        }
    }
    out.trim_matches('-').to_string()
}

/// Write each run's telemetry products (if any) under `dir`.
fn write_telemetry_artifacts(
    dir: &Path,
    runs: &[Run],
    outputs: impl Iterator<Item = Option<TelemetryOutput>>,
) -> Result<(), String> {
    std::fs::create_dir_all(dir)
        .map_err(|e| format!("telemetry dir {}: {e}", dir.display()))?;
    for (i, (run, out)) in runs.iter().zip(outputs).enumerate() {
        let Some(out) = out else { continue };
        let name = slug(&run.label());
        if let Some(trace) = &out.trace {
            let path = dir.join(format!("trace-{i:03}-{name}.json"));
            std::fs::write(&path, trace.to_string_compact())
                .map_err(|e| format!("{}: {e}", path.display()))?;
        }
        if !out.timeline.is_empty() {
            let mut body = String::new();
            for row in &out.timeline {
                body.push_str(&row.to_string_compact());
                body.push('\n');
            }
            let path = dir.join(format!("timeline-{i:03}-{name}.jsonl"));
            std::fs::write(&path, body).map_err(|e| format!("{}: {e}", path.display()))?;
        }
    }
    Ok(())
}

/// Labels already recorded in a JSON-lines results file (resume filter).
/// A missing file is an empty set; a present-but-corrupt file is an
/// error.
fn completed_labels(path: &Path) -> Result<HashSet<String>, String> {
    let body = match std::fs::read_to_string(path) {
        Ok(body) => body,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(HashSet::new()),
        Err(e) => return Err(format!("resume file {}: {e}", path.display())),
    };
    let mut done = HashSet::new();
    for (i, line) in body.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = Json::parse(line)
            .map_err(|e| format!("resume file {} line {}: {e}", path.display(), i + 1))?;
        if let Some(label) = rec.get("label").and_then(Json::as_str) {
            done.insert(label.to_string());
        }
    }
    Ok(done)
}

/// Apply one axis assignment to the (config, scenario) pair.
fn apply_axis(
    cfg: &mut SystemConfig,
    scenario: &mut Scenario,
    key: &str,
    value: &str,
) -> Result<(), String> {
    match key {
        "preset" => {
            *cfg = preset(value)?;
            scenario.set_fabric(cfg.pe.fabric);
        }
        "system" => {
            let kind: SystemKind = value.parse().map_err(|e: NameParseError| e.to_string())?;
            *cfg = cfg.as_baseline(kind);
        }
        "dataset" => scenario.set_dataset(value)?,
        "scale" => {
            let scale: f64 = value.parse().map_err(|e| format!("scale {value:?}: {e}"))?;
            super::scenario::check_scale(scale)?;
            scenario.set_scale(scale);
        }
        "mode" => {
            let mode: Mode = value.parse().map_err(|e: NameParseError| e.to_string())?;
            scenario.set_mode(mode);
        }
        "fabric" | "pe.fabric" => {
            let fabric: FabricType =
                value.parse().map_err(|e: NameParseError| e.to_string())?;
            scenario.set_fabric(fabric);
            cfg.pe.fabric = fabric;
        }
        _ => cfg.apply_override(key, value)?,
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;

    fn tiny_scenario() -> Scenario {
        Scenario::random([48, 4_000, 6_000], 400, 11)
    }

    #[test]
    fn grid_is_row_major_and_resolves_axes() {
        let sweep = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .axis("system", &["ip-only", "proposed"])
            .axis("channels", &["1", "2"]);
        let grid = sweep.grid().unwrap();
        assert_eq!(grid.len(), 4);
        let kv: Vec<_> = grid
            .iter()
            .map(|p| (p.axes[0].1.as_str(), p.axes[1].1.as_str()))
            .collect();
        assert_eq!(
            kv,
            [("ip-only", "1"), ("ip-only", "2"), ("proposed", "1"), ("proposed", "2")]
        );
        assert_eq!(grid[0].cfg.kind, SystemKind::IpOnly);
        assert_eq!(grid[3].cfg.kind, SystemKind::Proposed);
        assert_eq!(grid[3].cfg.interconnect.channels, 2);
    }

    #[test]
    fn zip_axis_advances_keys_together() {
        let sweep = Sweep::new(SystemConfig::config_a(), tiny_scenario())
            .zip_axis(&["cache.lines", "cache.associativity"], &[&["4096", "1"], &["8192", "2"]]);
        let grid = sweep.grid().unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].cfg.cache.lines, 4096);
        assert_eq!(grid[0].cfg.cache.associativity, 1);
        assert_eq!(grid[1].cfg.cache.lines, 8192);
        assert_eq!(grid[1].cfg.cache.associativity, 2);
        assert_eq!(sweep.axis_names(), ["cache.lines", "cache.associativity"]);
    }

    #[test]
    fn scenario_axes_shape_the_workload() {
        let base = SystemConfig::config_b();
        let sweep = Sweep::new(base, Scenario::synth01(0.0005))
            .axis("fabric", &["type1", "type2"])
            .axis("mode", &["i", "j"]);
        let grid = sweep.grid().unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].cfg.pe.fabric, FabricType::Type1);
        assert_eq!(grid[0].scenario.fabric, FabricType::Type1);
        assert_eq!(grid[0].scenario.mode, Mode::I);
        assert_eq!(grid[1].scenario.mode, Mode::J);
        assert_eq!(grid[3].cfg.pe.fabric, FabricType::Type2);
        // Keys separate the distinct workloads (fabric and mode both
        // shape the trace) and match where the grid points agree.
        assert_ne!(grid[0].scenario.key(), grid[1].scenario.key());
        assert_ne!(grid[0].scenario.key(), grid[2].scenario.key());
    }

    #[test]
    fn bad_axes_fail_fast() {
        let s = tiny_scenario();
        let base = SystemConfig::config_b();
        let try_axis = |key: &str, val: &str| {
            Sweep::new(base.clone(), s.clone()).axis(key, &[val]).grid()
        };
        assert!(try_axis("system", "warp-drive").is_err());
        assert!(try_axis("bogus.key", "1").is_err());
        assert!(try_axis("mode", "q").is_err());
        assert!(try_axis("scale", "2.0").is_err());
        // Invalid resolved config (3 channels is not a power of two).
        assert!(try_axis("channels", "3").is_err());
    }

    #[test]
    fn duplicate_axis_keys_are_rejected() {
        let err = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .axis("channels", &["1"])
            .axis("channels", &["2"])
            .grid()
            .unwrap_err();
        assert!(err.contains("duplicate axis"), "{err}");
        let err = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .zip_axis(&["cache.lines", "cache.lines"], &[&["2048", "4096"]])
            .grid()
            .unwrap_err();
        assert!(err.contains("duplicate axis"), "{err}");
    }

    #[test]
    fn empty_grid_is_a_single_point() {
        let sweep = Sweep::new(SystemConfig::config_b(), tiny_scenario());
        let grid = sweep.grid().unwrap();
        assert_eq!(grid.len(), 1);
        assert!(grid[0].axes.is_empty());
    }

    #[test]
    fn telemetry_dir_writes_artifacts_for_enabled_points_only() {
        let dir = std::env::temp_dir().join(format!("memsys-sweep-tel-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let rs = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .zip_axis(
                &["telemetry.trace", "telemetry.timeline"],
                &[&["off", "off"], &["on", "on"]],
            )
            .axis("telemetry.window", &["100"])
            .threads(2)
            .telemetry_dir(&dir)
            .run()
            .unwrap();
        assert_eq!(rs.len(), 2);
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        // Only grid point 1 (telemetry on) produced artifacts.
        assert_eq!(names.len(), 2, "{names:?}");
        assert!(names[0].starts_with("timeline-001-") && names[0].ends_with(".jsonl"));
        assert!(names[1].starts_with("trace-001-") && names[1].ends_with(".json"));
        let trace = crate::util::json::Json::parse(
            &std::fs::read_to_string(dir.join(&names[1])).unwrap(),
        )
        .unwrap();
        assert!(!trace.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        // Telemetry never perturbs the simulation itself.
        let plain = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .zip_axis(
                &["telemetry.trace", "telemetry.timeline"],
                &[&["off", "off"], &["on", "on"]],
            )
            .axis("telemetry.window", &["100"])
            .threads(1)
            .run()
            .unwrap();
        assert_eq!(plain.runs[0].report.diff(&plain.runs[1].report), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_skips_grid_cells_already_in_the_output_file() {
        let dir = std::env::temp_dir().join(format!("memsys-resume-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("sweep.jsonl");
        let sweep = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .axis("lmb_banks", &["1", "2"])
            .axis("channels", &["1", "2"])
            .threads(2);
        let full = sweep.clone().run().unwrap();
        assert_eq!(full.len(), 4);
        full.write_jsonl(&out).unwrap();
        // Complete file: nothing left to run.
        let none = sweep.clone().resume_from(&out).run().unwrap();
        assert!(none.is_empty());
        assert_eq!(none.axis_names, sweep.axis_names());
        // Partial file (one record removed): exactly that cell re-runs,
        // with the same label and report as the uninterrupted sweep.
        let target = full.runs[2].label();
        let body = std::fs::read_to_string(&out).unwrap();
        let kept: Vec<&str> = body
            .lines()
            .filter(|l| {
                Json::parse(l).unwrap().get("label").unwrap().as_str()
                    != Some(target.as_str())
            })
            .collect();
        assert_eq!(kept.len(), 3);
        std::fs::write(&out, kept.join("\n") + "\n").unwrap();
        let partial = sweep.clone().resume_from(&out).run().unwrap();
        assert_eq!(partial.len(), 1);
        assert_eq!(partial.runs[0].label(), target);
        assert_eq!(
            partial.runs[0].report.diff(&full.runs[2].report),
            None,
            "resumed cell must reproduce the uninterrupted run"
        );
        // Missing file: a fresh sweep runs everything.
        let fresh = sweep.clone().resume_from(dir.join("absent.jsonl")).run().unwrap();
        assert_eq!(fresh.len(), 4);
        // Corrupt file: error, not silent duplication.
        std::fs::write(&out, "not json\n").unwrap();
        assert!(sweep.resume_from(&out).run().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slug_flattens_labels() {
        assert_eq!(slug("system=proposed scale=0.01"), "system-proposed-scale-0-01");
        assert_eq!(slug("config-b"), "config-b");
    }

    #[test]
    fn cluster_axes_flow_through_overrides_and_geometry() {
        use crate::config::InterTopologyKind;
        let sweep = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .axis("nodes", &["1", "2"])
            .axis("inter-topology", &["ring", "mesh"]);
        let grid = sweep.grid().unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].cfg.cluster.nodes, 1);
        assert_eq!(grid[2].cfg.cluster.nodes, 2);
        assert_eq!(grid[1].cfg.cluster.topology, InterTopologyKind::Mesh);
        // Stream geometry scales with the node count (one window of
        // n_pes streams per node).
        assert_eq!(grid[0].scenario.n_pes, grid[0].cfg.pe.n_pes);
        assert_eq!(grid[2].scenario.n_pes, 2 * grid[2].cfg.pe.n_pes);
        assert_ne!(grid[0].scenario.key(), grid[2].scenario.key());
    }

    #[test]
    fn topology_shorthand_axis_applies() {
        let sweep = Sweep::new(SystemConfig::config_b(), tiny_scenario())
            .axis("channels", &["2"])
            .axis("topology", &["ring"]);
        let grid = sweep.grid().unwrap();
        assert_eq!(grid[0].cfg.interconnect.topology, TopologyKind::Ring);
        assert_eq!(grid[0].cfg.interconnect.channels, 2);
    }
}
