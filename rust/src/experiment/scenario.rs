//! Scenario: a declarative description of *what* is simulated — dataset
//! (name / scale / seed), MTTKRP mode, compute-fabric type, and PE-front-
//! end geometry — which lazily produces a cached [`Workload`].
//!
//! The builder replaces the hand-rolled six-positional-argument
//! `workload_from_tensor` call every driver used to repeat; geometry
//! (PE count, rank, DRAM row alignment) is normally copied from a
//! [`SystemConfig`] via [`Scenario::for_config`] so the workload always
//! matches the system it is replayed on.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use crate::config::{FabricType, SystemConfig};
use crate::tensor::gen::{self, GenParams};
use crate::tensor::io::{read_tns, scan_tns};
use crate::tensor::{CooTensor, Mode};
use crate::trace::{
    workload_from_tensor, CooStreamSource, TnsStreamSource, TraceSource, Workload,
};
use crate::util::rng::Rng;

/// Where the scenario's tensor comes from.
#[derive(Debug, Clone)]
pub enum TensorSource {
    /// A named Table III dataset (`synth01` / `synth02`), generated at
    /// the scenario's scale.
    Synth { name: String },
    /// Uniform-random COO (tests and microbenches).
    Random { dims: [u64; 3], nnz: usize, seed: u64 },
    /// A pre-built tensor.
    Owned(Arc<CooTensor>),
    /// A FROSTT `.tns` file, streamed from disk without materializing
    /// when already sorted along the scenario's mode.
    TnsFile { path: PathBuf },
}

/// Datasets [`Scenario::dataset`] resolves by name.
pub const DATASETS: [&str; 2] = ["synth01", "synth02"];

/// Single source of truth for the valid dataset-scale range.
pub(crate) fn check_scale(scale: f64) -> Result<(), String> {
    if scale <= 0.0 || scale > 1.0 {
        return Err(format!("dataset scale {scale} must be in (0, 1]"));
    }
    Ok(())
}

/// Builder for one simulation scenario; produces a cached [`Workload`].
///
/// Cloning is cheap and carries the caches: a clone whose knobs are not
/// changed shares the already-built tensor/workload `Arc`s.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub(crate) source: TensorSource,
    /// Dataset scale in (0, 1] (`Synth` sources only).
    pub(crate) scale: f64,
    /// Generator seed override (`Synth` sources only).
    pub(crate) seed: Option<u64>,
    pub(crate) mode: Mode,
    pub(crate) fabric: FabricType,
    pub(crate) n_pes: usize,
    pub(crate) rank: usize,
    pub(crate) row_align: u64,
    tensor_cache: OnceLock<Arc<CooTensor>>,
    workload_cache: OnceLock<Arc<Workload>>,
    source_cache: OnceLock<Result<Arc<dyn TraceSource>, String>>,
}

impl Scenario {
    fn from_source(source: TensorSource) -> Scenario {
        Scenario {
            source,
            scale: 1.0,
            seed: None,
            mode: Mode::I,
            fabric: FabricType::Type2,
            n_pes: 4,
            rank: 32,
            row_align: 8192,
            tensor_cache: OnceLock::new(),
            workload_cache: OnceLock::new(),
            source_cache: OnceLock::new(),
        }
    }

    /// A named dataset (see [`DATASETS`]) at `scale`, or a `.tns` file
    /// path (whose geometry is fixed by the file — `scale` is ignored).
    pub fn dataset(name: &str, scale: f64) -> Result<Scenario, String> {
        if name.ends_with(".tns") {
            return Ok(Scenario::tns_file(name));
        }
        if !DATASETS.contains(&name) {
            return Err(format!(
                "unknown dataset {name:?} (expected {DATASETS:?} or a .tns path)"
            ));
        }
        check_scale(scale)?;
        let mut s = Scenario::from_source(TensorSource::Synth { name: name.to_string() });
        s.set_scale(scale);
        Ok(s)
    }

    /// Paper Synth-01 at `scale`.
    pub fn synth01(scale: f64) -> Scenario {
        Scenario::dataset("synth01", scale).unwrap()
    }

    /// Paper Synth-02 at `scale`.
    pub fn synth02(scale: f64) -> Scenario {
        Scenario::dataset("synth02", scale).unwrap()
    }

    /// A uniform-random tensor (tests / microbenches).
    pub fn random(dims: [u64; 3], nnz: usize, seed: u64) -> Scenario {
        Scenario::from_source(TensorSource::Random { dims, nnz, seed })
    }

    /// Wrap an existing tensor.
    pub fn from_tensor(t: CooTensor) -> Scenario {
        Scenario::from_source(TensorSource::Owned(Arc::new(t)))
    }

    /// A FROSTT `.tns` file as the dataset. When the file is already
    /// sorted along the scenario's mode (FROSTT files are mode-`i`
    /// sorted), [`Scenario::trace_source`] streams it straight from disk
    /// in bounded memory; otherwise it is loaded and re-sorted in memory
    /// once. Errors (missing file, parse failures) surface when the
    /// trace source or tensor is first built.
    pub fn tns_file(path: impl Into<PathBuf>) -> Scenario {
        Scenario::from_source(TensorSource::TnsFile { path: path.into() })
    }

    // --- builder knobs (each invalidates the affected caches) ---------

    /// MTTKRP mode (which factor matrix is produced). Default `i`.
    pub fn mode(mut self, mode: Mode) -> Scenario {
        self.set_mode(mode);
        self
    }

    /// Compute-fabric type; decides the trace shape. Default `type2`.
    pub fn fabric(mut self, fabric: FabricType) -> Scenario {
        self.set_fabric(fabric);
        self
    }

    /// Number of PE front ends. Default 4.
    pub fn n_pes(mut self, n: usize) -> Scenario {
        if self.n_pes != n {
            self.n_pes = n;
            self.invalidate_workload();
        }
        self
    }

    /// Rank R (elements per factor fiber). Default 32.
    pub fn rank(mut self, rank: usize) -> Scenario {
        if self.rank != rank {
            self.rank = rank;
            self.invalidate_workload();
        }
        self
    }

    /// DRAM-row alignment of the address-map regions. Default 8192.
    pub fn row_align(mut self, bytes: u64) -> Scenario {
        if self.row_align != bytes {
            self.row_align = bytes;
            self.invalidate_workload();
        }
        self
    }

    /// Dataset scale in (0, 1] (`Synth` sources only).
    pub fn scale(mut self, scale: f64) -> Scenario {
        check_scale(scale).unwrap();
        self.set_scale(scale);
        self
    }

    /// Generator seed override (`Synth` sources only).
    pub fn seed(mut self, seed: u64) -> Scenario {
        if self.seed != Some(seed) {
            self.seed = Some(seed);
            self.invalidate_tensor();
        }
        self
    }

    /// Copy fabric type and front-end geometry from a system config so
    /// the workload matches the system it will be replayed on.
    pub fn for_config(mut self, cfg: &SystemConfig) -> Scenario {
        self.set_fabric(cfg.pe.fabric);
        self.sync_geometry(cfg);
        self
    }

    // --- in-place mutators (sweep axis application) --------------------

    pub(crate) fn set_dataset(&mut self, name: &str) -> Result<(), String> {
        // Anything ending in `.tns` is a file path; everything else must
        // be a known synthetic dataset name.
        if name.ends_with(".tns") {
            let path = PathBuf::from(name);
            if !matches!(&self.source, TensorSource::TnsFile { path: p } if *p == path) {
                self.source = TensorSource::TnsFile { path };
                self.invalidate_tensor();
            }
            return Ok(());
        }
        if !DATASETS.contains(&name) {
            return Err(format!(
                "unknown dataset {name:?} (expected {DATASETS:?} or a .tns path)"
            ));
        }
        if !matches!(&self.source, TensorSource::Synth { name: n } if n == name) {
            self.source = TensorSource::Synth { name: name.to_string() };
            self.invalidate_tensor();
        }
        Ok(())
    }

    pub(crate) fn set_scale(&mut self, scale: f64) {
        if self.scale != scale {
            self.scale = scale;
            self.invalidate_tensor();
        }
    }

    pub(crate) fn set_mode(&mut self, mode: Mode) {
        if self.mode != mode {
            self.mode = mode;
            self.invalidate_workload();
        }
    }

    pub(crate) fn set_fabric(&mut self, fabric: FabricType) {
        if self.fabric != fabric {
            self.fabric = fabric;
            self.invalidate_workload();
        }
    }

    /// Align PE count, rank and row alignment with `cfg` (the tensor and
    /// its cache survive; the workload is rebuilt only on change). A
    /// multi-node cluster needs one stream per PE *per node* — the
    /// cluster layer slices the `n_pes x nodes` streams back into
    /// per-node windows — so the stream count scales with
    /// `cluster.nodes` (x1 with the single-node default).
    pub(crate) fn sync_geometry(&mut self, cfg: &SystemConfig) {
        let streams = cfg.pe.n_pes * cfg.cluster.nodes;
        if self.n_pes != streams
            || self.rank != cfg.pe.rank
            || self.row_align != cfg.dram.row_bytes
        {
            self.n_pes = streams;
            self.rank = cfg.pe.rank;
            self.row_align = cfg.dram.row_bytes;
            self.invalidate_workload();
        }
    }

    fn invalidate_workload(&mut self) {
        self.workload_cache = OnceLock::new();
        self.source_cache = OnceLock::new();
    }

    fn invalidate_tensor(&mut self) {
        self.tensor_cache = OnceLock::new();
        self.invalidate_workload();
    }

    // --- products ------------------------------------------------------

    /// Dataset name ("synth01", "random", the owned tensor's name, or a
    /// `.tns` file's stem).
    pub fn dataset_name(&self) -> String {
        match &self.source {
            TensorSource::Synth { name } => name.clone(),
            TensorSource::Random { .. } => "random".to_string(),
            TensorSource::Owned(t) => t.name.clone(),
            TensorSource::TnsFile { path } => path
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_else(|| "tns".into()),
        }
    }

    /// Deduplication key: everything that shapes the workload. Two
    /// scenarios with equal keys produce identical workloads (except for
    /// distinct [`TensorSource::Owned`] tensors that share name, dims and
    /// nnz — sweeps never vary owned tensors, so this cannot happen
    /// within one sweep).
    pub fn key(&self) -> String {
        let src = match &self.source {
            TensorSource::Synth { name } => {
                format!("{name}@{}+{:?}", self.scale, self.seed)
            }
            TensorSource::Random { dims, nnz, seed } => {
                format!("random-{}x{}x{}-n{nnz}-s{seed}", dims[0], dims[1], dims[2])
            }
            TensorSource::Owned(t) => {
                format!("owned-{}-{:?}-n{}", t.name, t.dims, t.nnz())
            }
            TensorSource::TnsFile { path } => {
                format!("tns-{}", path.display())
            }
        };
        format!(
            "{src}|mode-{}|{}|pes{}|r{}|row{}",
            self.mode.name(),
            self.fabric.name(),
            self.n_pes,
            self.rank,
            self.row_align
        )
    }

    /// The scenario's tensor (built once, then cached).
    pub fn tensor(&self) -> Arc<CooTensor> {
        if let TensorSource::Owned(t) = &self.source {
            return t.clone();
        }
        self.tensor_cache.get_or_init(|| Arc::new(self.generate_tensor())).clone()
    }

    fn generate_tensor(&self) -> CooTensor {
        match &self.source {
            TensorSource::Synth { name } => {
                // Same spec + params as `gen::synth_01` / `gen::synth_02`.
                let (spec, mut params) = match name.as_str() {
                    "synth02" => (
                        gen::SYNTH_02,
                        GenParams { skew: 0.8, cluster_frac: 0.2, ..GenParams::default() },
                    ),
                    _ => (gen::SYNTH_01, GenParams::default()),
                };
                if let Some(seed) = self.seed {
                    params.seed = seed;
                }
                gen::generate(&spec.scaled(self.scale), &params)
            }
            TensorSource::Random { dims, nnz, seed } => {
                let mut rng = Rng::new(*seed);
                CooTensor::random(&mut rng, *dims, *nnz)
            }
            TensorSource::Owned(_) => unreachable!("owned tensors are returned directly"),
            TensorSource::TnsFile { path } => read_tns(path, None)
                .unwrap_or_else(|e| panic!("reading {}: {e}", path.display())),
        }
    }

    /// The fully materialized per-PE request streams (built once, then
    /// cached; clones share the cache until a knob changes). This is the
    /// regression oracle — use [`Scenario::trace_source`] to run in
    /// bounded memory.
    pub fn workload(&self) -> Arc<Workload> {
        self.workload_cache
            .get_or_init(|| {
                let t = self.tensor();
                Arc::new(workload_from_tensor(
                    &t,
                    self.mode,
                    self.fabric,
                    self.n_pes,
                    self.rank,
                    self.row_align,
                ))
            })
            .clone()
    }

    /// A streaming [`TraceSource`] for this scenario (built once, then
    /// cached). `.tns` files already sorted along the scenario's mode
    /// stream straight from disk without materializing anything; all
    /// other sources stream lazily from the (cached) in-memory tensor.
    /// Either way the per-run workload-side footprint is bounded by
    /// [`crate::trace::WORK_CHUNK`] items per PE stream, not by nnz.
    pub fn trace_source(&self) -> Result<Arc<dyn TraceSource>, String> {
        self.source_cache.get_or_init(|| self.build_source()).clone()
    }

    fn build_source(&self) -> Result<Arc<dyn TraceSource>, String> {
        if let TensorSource::TnsFile { path } = &self.source {
            let scan = scan_tns(path).map_err(|e| e.to_string())?;
            if scan.nnz > 0 && scan.sorted[self.mode.index()] {
                let src = TnsStreamSource::from_scan(
                    path,
                    &scan,
                    self.mode,
                    self.fabric,
                    self.n_pes,
                    self.rank,
                    self.row_align,
                )
                .map_err(|e| e.to_string())?;
                return Ok(Arc::new(src));
            }
            // Not sorted along this mode: materialize once, re-sort in
            // memory, and stream from there (propagating read errors
            // instead of panicking through `tensor()`).
            let t = match self.tensor_cache.get() {
                Some(t) => t.clone(),
                None => {
                    let t = Arc::new(read_tns(path, None).map_err(|e| e.to_string())?);
                    self.tensor_cache.get_or_init(|| t).clone()
                }
            };
            return Ok(Arc::new(CooStreamSource::new(
                t,
                self.mode,
                self.fabric,
                self.n_pes,
                self.rank,
                self.row_align,
            )));
        }
        Ok(Arc::new(CooStreamSource::new(
            self.tensor(),
            self.mode,
            self.fabric,
            self.n_pes,
            self.rank,
            self.row_align,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_cached_and_clones_share_it() {
        let s = Scenario::random([32, 500, 800], 300, 7);
        let a = s.workload();
        let b = s.workload();
        assert!(Arc::ptr_eq(&a, &b), "second build must hit the cache");
        let c = s.clone().workload();
        assert!(Arc::ptr_eq(&a, &c), "clones share the cached workload");
    }

    #[test]
    fn knob_changes_invalidate_the_right_caches() {
        let s = Scenario::random([32, 500, 800], 300, 7);
        let t = s.tensor();
        let w = s.workload();
        // Mode change rebuilds the workload but keeps the tensor.
        let s2 = s.clone().mode(Mode::J);
        assert!(Arc::ptr_eq(&t, &s2.tensor()));
        assert!(!Arc::ptr_eq(&w, &s2.workload()));
        // No-op setter keeps both caches.
        let s3 = s.clone().mode(Mode::I).n_pes(4);
        assert!(Arc::ptr_eq(&w, &s3.workload()));
    }

    #[test]
    fn synth_scenarios_match_the_gen_shortcuts() {
        let t = Scenario::synth01(0.0005).tensor();
        assert_eq!(*t, gen::synth_01(0.0005));
        let t2 = Scenario::synth02(0.0002).tensor();
        assert_eq!(*t2, gen::synth_02(0.0002));
        assert!(Scenario::dataset("synth03", 0.1).is_err());
    }

    #[test]
    fn for_config_copies_fabric_and_geometry() {
        let cfg = SystemConfig::config_a();
        let s = Scenario::synth01(0.001).for_config(&cfg);
        assert_eq!(s.fabric, FabricType::Type1);
        assert_eq!(s.n_pes, cfg.pe.n_pes);
        assert_eq!(s.rank, cfg.pe.rank);
        assert_eq!(s.row_align, cfg.dram.row_bytes);
        let w = s.workload();
        assert_eq!(w.fabric, FabricType::Type1);
        assert_eq!(w.pe_traces.len(), 1, "Type-1 has one shared front end");
    }

    #[test]
    fn tns_scenarios_stream_or_fall_back() {
        use crate::tensor::io::write_tns;
        let mut rng = Rng::new(31);
        let mut t = CooTensor::random(&mut rng, [8, 30, 40], 120);
        t.sort_mode(Mode::I);
        let dir = std::env::temp_dir().join(format!("memsys-scn-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scn.tns");
        write_tns(&t, &path).unwrap();
        let s = Scenario::tns_file(&path);
        assert_eq!(s.dataset_name(), "scn");
        let src = s.trace_source().unwrap();
        assert_eq!(src.nnz(), t.nnz());
        let again = s.trace_source().unwrap();
        assert!(
            std::ptr::eq(
                Arc::as_ptr(&src) as *const (),
                Arc::as_ptr(&again) as *const ()
            ),
            "trace source is cached"
        );
        // Mode J: the file is i-sorted, so the source falls back to
        // loading + re-sorting in memory — still a valid stream.
        let sj = s.clone().mode(Mode::J);
        let srcj = sj.trace_source().unwrap();
        assert_eq!(srcj.nnz(), t.nnz());
        assert_ne!(s.key(), sj.key());
        // Missing files error instead of panicking.
        assert!(Scenario::tns_file("/nonexistent/x.tns").trace_source().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keys_distinguish_workload_shaping_knobs() {
        let s = Scenario::synth01(0.001);
        assert_ne!(s.key(), s.clone().mode(Mode::J).key());
        assert_ne!(s.key(), s.clone().fabric(FabricType::Type1).key());
        assert_ne!(s.key(), s.clone().scale(0.002).key());
        assert_ne!(s.key(), s.clone().seed(9).key());
        assert_ne!(s.key(), Scenario::synth02(0.001).key());
        assert_eq!(s.key(), s.clone().key());
    }
}
