//! First-class experiment API — the one way drivers (CLI subcommands,
//! benches, examples, integration tests) compose the simulator.
//!
//! Three layers, mirroring how the paper's design is "configured during
//! the synthesis step" and evaluated as a grid of variants (Fig. 4):
//!
//! * [`Scenario`] — *what* is simulated: dataset (name/scale/seed),
//!   MTTKRP mode, fabric type, PE geometry → a cached [`crate::trace::Workload`].
//! * [`Sweep`] — *which variants*: a declarative cartesian grid over
//!   named config axes (`system`, `preset`, `channels`, `topology`, any
//!   `apply_override` key) and scenario axes (`dataset`, `scale`,
//!   `mode`, `fabric`), executed by a multi-threaded runner with
//!   deterministic (grid-order) results.
//! * [`RunSet`] — *the results*: baseline/speedup lookups, ASCII table
//!   rendering, and JSON-lines serialization for machine consumers.
//!
//! The flow below is a *runnable* doc-test (`cargo test` compiles and
//! executes it on a tiny random tensor — the paper-scale equivalent
//! swaps in `Scenario::synth01(scale)`):
//!
//! ```
//! use mttkrp_memsys::config::SystemConfig;
//! use mttkrp_memsys::experiment::{Scenario, Sweep};
//!
//! // 1. Scenario — *what* is simulated (tensor, mode, fabric, geometry).
//! let base = SystemConfig::config_b();
//! let scenario = Scenario::random([32, 2_000, 3_000], 120, 7).for_config(&base);
//!
//! // 2. Sweep — *which variants*: a cartesian grid over named axes,
//! //    run in parallel with deterministic (grid-order) results.
//! let runs = Sweep::new(base, scenario)
//!     .axis("system", &["ip-only", "proposed"])
//!     .axis("lmb_banks", &["1", "2"])
//!     .threads(2)
//!     .run()
//!     .unwrap();
//!
//! // 3. RunSet — the results: lookups, speedups, tables, JSON-lines.
//! assert_eq!(runs.len(), 4);
//! let ip = runs.get(&[("system", "ip-only"), ("lmb_banks", "1")]).unwrap();
//! let prop = runs.get(&[("system", "proposed"), ("lmb_banks", "1")]).unwrap();
//! assert!(prop.report.speedup_over(&ip.report) > 0.0);
//! let table = runs.to_table(Some(("system", "ip-only"))).render();
//! assert!(table.contains("lmb_banks"));
//! ```

mod runset;
mod scenario;
mod sweep;

pub use runset::{Run, RunSet};
pub use scenario::{Scenario, TensorSource, DATASETS};
pub use sweep::{default_threads, Point, Sweep};

use crate::cluster::ClusterReport;
use crate::config::SystemConfig;
use crate::sim::SimReport;

/// Resolve a paper preset by name (`a`/`config-a`, `b`/`config-b`).
pub fn preset(name: &str) -> Result<SystemConfig, String> {
    match name {
        "a" | "config-a" => Ok(SystemConfig::config_a()),
        "b" | "config-b" => Ok(SystemConfig::config_b()),
        other => Err(format!("unknown preset {other:?} (expected a|b)")),
    }
}

/// Simulate a single (config, scenario) pair — the degenerate sweep.
/// Runs from the scenario's streaming trace source (bounded memory);
/// panics on a broken dataset source, like the workload path used to.
///
/// With `cfg.cluster.nodes > 1` the run is a sharded multi-accelerator
/// cluster (see [`crate::cluster`]) and the returned report is the
/// flattened cluster view; use [`run_cluster`] to keep the per-node
/// breakdown. With the single-node default this is exactly
/// [`crate::sim::simulate`].
pub fn run_one(cfg: &SystemConfig, scenario: &Scenario) -> SimReport {
    if cfg.cluster.nodes > 1 {
        return run_cluster(cfg, scenario).into_report();
    }
    let src = scenario
        .trace_source()
        .unwrap_or_else(|e| panic!("building trace source: {e}"));
    crate::sim::simulate(cfg, &src)
}

/// Simulate a (config, scenario) pair as an accelerator cluster and keep
/// the full cluster result: per-node reports, makespan decomposition
/// (compute / local memory / communication) and inter-node network
/// counters. Works for any node count — with one node the communication
/// phase is empty and [`ClusterReport::into_report`] returns the plain
/// run verbatim.
pub fn run_cluster(cfg: &SystemConfig, scenario: &Scenario) -> ClusterReport {
    let src = scenario
        .trace_source()
        .unwrap_or_else(|e| panic!("building trace source: {e}"));
    crate::cluster::simulate_cluster(cfg, &src)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemKind;

    #[test]
    fn preset_resolution() {
        assert_eq!(preset("a").unwrap().label, "config-a");
        assert_eq!(preset("config-b").unwrap().label, "config-b");
        assert!(preset("c").is_err());
    }

    #[test]
    fn run_one_dispatches_to_the_cluster_layer() {
        let mut cfg = SystemConfig::config_b();
        cfg.cluster.nodes = 2;
        let scenario = Scenario::random([40, 3_000, 5_000], 400, 3).for_config(&cfg);
        let cl = run_cluster(&cfg, &scenario);
        assert_eq!(cl.nodes, 2);
        assert_eq!(cl.node_reports.len(), 2);
        // run_one returns the same cluster run, flattened.
        let flat = run_one(&cfg, &scenario);
        assert_eq!(flat.total_cycles, cl.total_cycles);
        assert_eq!(flat.nnz, cl.nnz());
    }

    #[test]
    fn run_one_equals_an_axis_less_sweep() {
        let cfg = SystemConfig::config_b().as_baseline(SystemKind::DmaOnly);
        let scenario = Scenario::random([40, 3_000, 5_000], 300, 3).for_config(&cfg);
        let single = run_one(&cfg, &scenario);
        let sweep = Sweep::new(cfg, scenario).threads(1).run().unwrap();
        assert_eq!(sweep.len(), 1);
        assert_eq!(sweep.runs[0].report.total_cycles, single.total_cycles);
        assert_eq!(sweep.runs[0].report.accesses, single.accesses);
    }
}
