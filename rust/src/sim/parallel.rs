//! Shard workers for the parallel in-run engine (`--sim-threads`).
//!
//! The run loop in [`super::system`] stays the single source of truth
//! for simulated behavior; this module only provides the plumbing that
//! lets some of its *embarrassingly parallel* phases run on worker
//! threads:
//!
//! * **DRAM channel ticks** — each controller owns its banks, queue and
//!   in-flight set; channels only meet again at the fabric, so the run
//!   loop detaches them ([`super::fabric::Fabric::take_channels`]),
//!   shards them round-robin across workers, and re-absorbs each
//!   channel's completions *in channel index order* — the exact merge
//!   the serial loop performs.
//! * **PE window fill / retire** — admission and retirement touch only
//!   the front end they run on; telemetry retire markers are replayed
//!   by the coordinator in PE index order from the returned counts.
//!
//! Everything else (LMB ticks minting request ids, the shared issue
//! budget, fabric routing) stays serial on the coordinating thread, so
//! the parallel engine is *deterministic by construction*: the report
//! and every telemetry artifact are byte-identical at any thread count
//! (property-tested in `tests/integration_engine.rs`).
//!
//! The crate is dependency-free, so the pool is built from
//! `std::thread::scope` + `std::sync::mpsc` alone. Workers spin briefly
//! on `try_recv` (the per-visited-cycle round trip is far shorter than
//! a park/unpark) and fall back to `yield_now` so an idle pool cannot
//! monopolize the host.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use super::dram::DramChannel;
use super::pe::PeFrontEnd;
use super::telemetry::Telemetry;
use super::{Cycle, MemResp};

/// One phase of sharded work shipped to a worker. Component ownership
/// *moves* through the channel and comes back in the reply — no locks,
/// no sharing, no unsafe.
pub enum ShardTask {
    /// Tick these detached DRAM channels at `now` (activity-gated like
    /// the serial engine), collecting each channel's completions
    /// separately so the coordinator can merge in channel order.
    Channels { now: Cycle, channels: Vec<(usize, DramChannel)> },
    /// Admit pending stream work into these front ends' windows.
    Fill { pes: Vec<(usize, PeFrontEnd)> },
    /// Retire finished slots at `now`, reporting per-front-end counts
    /// for the coordinator's in-order telemetry replay.
    Retire { now: Cycle, pes: Vec<(usize, PeFrontEnd)> },
}

/// A completed [`ShardTask`], returning the moved components.
pub enum ShardDone {
    Channels { channels: Vec<(usize, DramChannel, Vec<MemResp>)> },
    Fill { pes: Vec<(usize, PeFrontEnd)> },
    Retire { pes: Vec<(usize, PeFrontEnd, u64)> },
}

/// Execute one shard of work. Shared by workers and the coordinator
/// (which always processes one shard inline instead of idling at the
/// barrier). `tel` must be a disabled collector: the sharded paths are
/// only taken when request tracing is off, and the DRAM trace hooks are
/// single-branch no-ops on a disabled collector, so behavior matches
/// the serial engine exactly.
pub fn run_task(task: ShardTask, tel: &mut Telemetry) -> ShardDone {
    match task {
        ShardTask::Channels { now, channels } => {
            let mut out = Vec::with_capacity(channels.len());
            for (idx, mut dram) in channels {
                let mut resps = Vec::new();
                if dram.needs_tick(now) {
                    dram.tick_traced(now, &mut resps, tel, idx);
                }
                out.push((idx, dram, resps));
            }
            ShardDone::Channels { channels: out }
        }
        ShardTask::Fill { pes } => {
            let mut out = Vec::with_capacity(pes.len());
            for (idx, mut pe) in pes {
                if pe.needs_fill() {
                    pe.fill_window();
                }
                out.push((idx, pe));
            }
            ShardDone::Fill { pes: out }
        }
        ShardTask::Retire { now, pes } => {
            let mut out = Vec::with_capacity(pes.len());
            for (idx, mut pe) in pes {
                let n = pe.retire(now);
                out.push((idx, pe, n));
            }
            ShardDone::Retire { pes: out }
        }
    }
}

/// Deal `items` round-robin into `shards` piles, each entry tagged with
/// its original index so the coordinator can merge results back in
/// index order (the assignment itself is timing-inert — every sharded
/// phase is component-local).
pub fn shard_round_robin<T>(items: Vec<T>, shards: usize) -> Vec<Vec<(usize, T)>> {
    let mut parts: Vec<Vec<(usize, T)>> = (0..shards).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        parts[i % shards].push((i, item));
    }
    parts
}

/// The coordinator's handle on the worker threads: one task/done
/// channel pair per worker. Dropping the pool closes the task channels,
/// which ends every worker loop — `run_parallel` relies on that for
/// scope teardown.
pub struct ShardPool {
    to_workers: Vec<Sender<ShardTask>>,
    from_workers: Vec<Receiver<ShardDone>>,
}

/// A worker thread's ends of the channel pair.
pub struct WorkerEnd {
    tasks: Receiver<ShardTask>,
    done: Sender<ShardDone>,
}

impl ShardPool {
    /// Build the channel pairs for `workers` worker threads. The caller
    /// spawns one [`worker_loop`] per returned [`WorkerEnd`] inside a
    /// `std::thread::scope`.
    pub fn new(workers: usize) -> (ShardPool, Vec<WorkerEnd>) {
        let mut to_workers = Vec::with_capacity(workers);
        let mut from_workers = Vec::with_capacity(workers);
        let mut ends = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (task_tx, task_rx) = channel();
            let (done_tx, done_rx) = channel();
            to_workers.push(task_tx);
            from_workers.push(done_rx);
            ends.push(WorkerEnd { tasks: task_rx, done: done_tx });
        }
        (ShardPool { to_workers, from_workers }, ends)
    }

    pub fn n_workers(&self) -> usize {
        self.to_workers.len()
    }

    /// Ship one shard to worker `w`.
    pub fn send(&self, w: usize, task: ShardTask) {
        self.to_workers[w]
            .send(task)
            .expect("shard worker hung up mid-run");
    }

    /// Barrier half: wait for worker `w`'s result.
    pub fn recv(&self, w: usize) -> ShardDone {
        spin_recv(&self.from_workers[w]).expect("shard worker hung up mid-run")
    }
}

/// Worker body: serve shard tasks until the pool (sender) is dropped.
pub fn worker_loop(end: WorkerEnd) {
    let mut tel = Telemetry::disabled();
    while let Some(task) = spin_recv(&end.tasks) {
        if end.done.send(run_task(task, &mut tel)).is_err() {
            break;
        }
    }
}

/// Latency-oriented receive: spin briefly (the per-cycle round trip is
/// sub-microsecond when the pool is hot), then yield to the scheduler
/// so idle workers don't burn a core. `None` when the peer hung up.
fn spin_recv<T>(rx: &Receiver<T>) -> Option<T> {
    let mut spins: u32 = 0;
    loop {
        match rx.try_recv() {
            Ok(v) => return Some(v),
            Err(TryRecvError::Disconnected) => return None,
            Err(TryRecvError::Empty) => {
                spins = spins.saturating_add(1);
                if spins < 1 << 12 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Access, AccessClass, NnzWork, PeTrace, WORK_CHUNK};

    fn front_end(pe: usize, items: usize) -> PeFrontEnd {
        let a = |addr| Access { class: AccessClass::TensorElem, addr, bytes: 16 };
        let work = (0..items as u64)
            .map(|z| NnzWork {
                elem: a(z * 16),
                fibers: [a(0x1000 + z * 64), a(0x2000 + z * 64)],
                store: None,
            })
            .collect();
        PeFrontEnd::from_trace(PeTrace { pe, work }, 0, 8, 2, 4)
    }

    #[test]
    fn pool_round_trips_fill_shards() {
        let (pool, ends) = ShardPool::new(2);
        std::thread::scope(|s| {
            for end in ends {
                s.spawn(move || worker_loop(end));
            }
            pool.send(0, ShardTask::Fill { pes: vec![(0, front_end(0, WORK_CHUNK))] });
            pool.send(1, ShardTask::Fill { pes: vec![(1, front_end(1, 3))] });
            for w in [0, 1] {
                match pool.recv(w) {
                    ShardDone::Fill { pes } => {
                        for (_, pe) in pes {
                            assert!(pe.can_issue(), "fill admitted work");
                        }
                    }
                    _ => panic!("mismatched phase reply"),
                }
            }
            drop(pool); // hang up so the scope can join the workers
        });
    }

    #[test]
    fn run_task_inline_matches_worker_semantics() {
        let mut tel = Telemetry::disabled();
        let done = run_task(ShardTask::Retire { now: 0, pes: vec![(0, front_end(0, 2))] }, &mut tel);
        match done {
            ShardDone::Retire { pes } => {
                assert_eq!(pes.len(), 1);
                let (idx, _, retired) = &pes[0];
                assert_eq!((*idx, *retired), (0, 0), "nothing issued, nothing retires");
            }
            _ => panic!("mismatched phase reply"),
        }
    }
}
