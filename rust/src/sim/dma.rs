//! DMA Engine (Fig. 2) — streaming fiber transfers between PEs and
//! external memory.
//!
//! "It has several DMA buffers inside. Therefore, it can support multiple
//! fiber reads and writes simultaneously. The number of DMA buffers is
//! proportional to the number of PEs connected to the same LMB." (§IV-A)
//!
//! Each buffer owns one in-flight fiber transfer. Transfers are
//! beat-aligned: when a request is shorter than the interface width the
//! transferred tail is garbage — the overhead the paper charges against
//! the DMA-only baseline ("there can be garbage data in DMA transactions
//! when the length of the data requests is shorter than the width of the
//! memory interface IP", §V-D).

use std::collections::VecDeque;

use crate::config::DmaConfig;
use crate::util::round_up;

use super::dram::IdGen;
use super::{Cycle, MemReq, ReqId};

/// Caller-side identifier for a DMA transfer.
pub type DmaToken = u64;

#[derive(Debug, Clone, Copy)]
struct Transfer {
    token: DmaToken,
    req_id: ReqId,
}

/// DMA statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DmaStats {
    pub loads: u64,
    pub stores: u64,
    pub requested_bytes: u64,
    pub transferred_bytes: u64,
    pub queue_stalls: u64,
}

impl DmaStats {
    /// Fraction of moved bytes that were alignment garbage.
    pub fn garbage_ratio(&self) -> f64 {
        if self.transferred_bytes == 0 {
            0.0
        } else {
            1.0 - self.requested_bytes as f64 / self.transferred_bytes as f64
        }
    }
}

/// The DMA engine of one LMB.
///
/// Each buffer sustains a pipelined stream of descriptors: while one
/// burst's data drains into the buffer, the next command is already in
/// flight (double buffering in hardware). `pipeline_depth` bounds the
/// outstanding bursts per buffer — 1 models the DMA-only baseline's
/// "single DMA request at a time" engines; the proposed system uses the
/// buffer's double-buffered depth.
pub struct DmaEngine {
    n_buffers: usize,
    beat_bytes: u64,
    /// Max bytes a single buffer moves per request; longer fibers are
    /// split into multiple buffer-sized bursts.
    buffer_bytes: u64,
    /// Outstanding bursts allowed per buffer.
    pipeline_depth: usize,
    /// Transfers waiting for a free slot (request + write flag).
    queue: VecDeque<(DmaToken, u64, u32, bool)>,
    /// Requests ready to be offered to the router.
    outbox: VecDeque<MemReq>,
    /// In-flight transfers by (buffer × pipeline) slot.
    active: Vec<Option<Transfer>>,
    /// Free entries of `active` (slot allocation without scanning).
    free_slots: Vec<u32>,
    /// Occupied entries of `active` (idle checks without scanning).
    busy: usize,
    port: usize,
    pub stats: DmaStats,
}

impl DmaEngine {
    pub fn new(cfg: &DmaConfig, beat_bytes: u64, port: usize) -> DmaEngine {
        Self::with_pipeline(cfg, beat_bytes, port, 4)
    }

    /// Explicit per-buffer pipeline depth (1 = serialized baseline).
    pub fn with_pipeline(
        cfg: &DmaConfig,
        beat_bytes: u64,
        port: usize,
        pipeline_depth: usize,
    ) -> DmaEngine {
        let depth = pipeline_depth.max(1);
        let slots = cfg.n_buffers * depth;
        DmaEngine {
            n_buffers: cfg.n_buffers,
            beat_bytes,
            buffer_bytes: cfg.buffer_bytes.max(beat_bytes),
            pipeline_depth: depth,
            queue: VecDeque::new(),
            outbox: VecDeque::new(),
            active: vec![None; slots],
            // Reversed so pop() hands out low slots first (the order the
            // old linear scan produced; slot choice is timing-inert).
            free_slots: (0..slots as u32).rev().collect(),
            busy: 0,
            port,
            stats: DmaStats::default(),
        }
    }

    /// Accept a fiber transfer if the engine queue has room; PEs retry on
    /// `false`. Queue depth = one pending request per active slot.
    pub fn submit(&mut self, token: DmaToken, addr: u64, bytes: u32, is_write: bool) -> bool {
        if self.queue.len() >= self.n_buffers * self.pipeline_depth {
            self.stats.queue_stalls += 1;
            return false;
        }
        self.queue.push_back((token, addr, bytes, is_write));
        true
    }

    /// Move queued transfers into free buffers, minting DRAM requests.
    pub fn tick(&mut self, ids: &mut IdGen) {
        while !self.queue.is_empty() {
            let Some(slot) = self.free_slots.pop() else {
                break;
            };
            let slot = slot as usize;
            debug_assert!(self.active[slot].is_none());
            let (token, addr, bytes, is_write) = self.queue.pop_front().unwrap();
            // Beat-align the burst (garbage on both ends if unaligned).
            let start = addr - addr % self.beat_bytes;
            let end = round_up(addr + bytes as u64, self.beat_bytes);
            let burst = end - start;
            debug_assert!(
                burst <= self.buffer_bytes,
                "fiber burst {burst} exceeds DMA buffer {} — raise dma.buffer_bytes \
                 or lower pe.rank",
                self.buffer_bytes
            );
            let id = ids.next();
            self.active[slot] = Some(Transfer { token, req_id: id });
            self.busy += 1;
            self.outbox.push_back(MemReq {
                id,
                addr: start,
                bytes: burst as u32,
                is_write,
                port: self.port,
            });
            if is_write {
                self.stats.stores += 1;
            } else {
                self.stats.loads += 1;
            }
            self.stats.requested_bytes += bytes as u64;
            self.stats.transferred_bytes += burst;
        }
    }

    /// Next DRAM request to route (router pulls one per cycle).
    pub fn pop_request(&mut self) -> Option<MemReq> {
        self.outbox.pop_front()
    }

    /// Move every minted request into `out` (the LMB outbox), keeping
    /// both queues' storage.
    pub fn drain_requests_into(&mut self, out: &mut VecDeque<MemReq>) {
        out.append(&mut self.outbox);
    }

    pub fn has_requests(&self) -> bool {
        !self.outbox.is_empty()
    }

    /// Transfers waiting for a free buffer slot.
    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    /// DRAM completed request `id`: free its buffer, return the token and
    /// completion cycle (buffer→PE drain is folded into the DRAM beats).
    pub fn on_complete(&mut self, id: ReqId, done_at: Cycle) -> Option<(DmaToken, Cycle)> {
        for (i, slot) in self.active.iter_mut().enumerate() {
            if let Some(t) = slot {
                if t.req_id == id {
                    let token = t.token;
                    *slot = None;
                    self.busy -= 1;
                    self.free_slots.push(i as u32);
                    return Some((token, done_at));
                }
            }
        }
        None
    }

    pub fn busy_buffers(&self) -> usize {
        self.busy
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.outbox.is_empty() && self.busy == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dma(n: usize) -> (DmaEngine, IdGen) {
        dma_depth(n, 1)
    }

    fn dma_depth(n: usize, depth: usize) -> (DmaEngine, IdGen) {
        let cfg = DmaConfig {
            n_buffers: n,
            buffer_bytes: 256,
        };
        (
            DmaEngine::with_pipeline(&cfg, 64, 0, depth),
            IdGen::default(),
        )
    }

    #[test]
    fn submit_issue_complete() {
        let (mut d, mut ids) = dma(2);
        assert!(d.submit(1, 128, 128, false));
        d.tick(&mut ids);
        let req = d.pop_request().unwrap();
        assert_eq!(req.addr, 128);
        assert_eq!(req.bytes, 128);
        assert!(!req.is_write);
        assert_eq!(d.busy_buffers(), 1);
        let (token, at) = d.on_complete(req.id, 77).unwrap();
        assert_eq!(token, 1);
        assert_eq!(at, 77);
        assert!(d.is_idle());
    }

    #[test]
    fn parallel_buffers_overlap() {
        let (mut d, mut ids) = dma(4);
        for t in 0..4u64 {
            assert!(d.submit(t, t * 4096, 128, false));
        }
        d.tick(&mut ids);
        assert_eq!(d.busy_buffers(), 4);
        let mut reqs = Vec::new();
        while let Some(r) = d.pop_request() {
            reqs.push(r);
        }
        assert_eq!(reqs.len(), 4, "all four issue without waiting");
    }

    #[test]
    fn single_buffer_serializes() {
        // The DMA-only baseline: "a DMA engine can load/store a single DMA
        // request at a time".
        let (mut d, mut ids) = dma(1);
        assert!(d.submit(1, 0, 64, false));
        d.tick(&mut ids);
        assert!(d.submit(2, 4096, 64, false)); // queued behind buffer
        d.tick(&mut ids);
        assert_eq!(d.busy_buffers(), 1);
        let r1 = d.pop_request().unwrap();
        assert!(d.pop_request().is_none(), "second must wait for buffer");
        d.on_complete(r1.id, 50).unwrap();
        d.tick(&mut ids);
        assert!(d.pop_request().is_some());
    }

    #[test]
    fn queue_backpressure() {
        let (mut d, _ids) = dma(1);
        assert!(d.submit(1, 0, 64, false));
        assert!(!d.submit(2, 64, 64, false), "queue depth = n_buffers");
        assert_eq!(d.stats.queue_stalls, 1);
    }

    #[test]
    fn garbage_accounting_on_short_unaligned_requests() {
        let (mut d, mut ids) = dma(2);
        // A 16 B element via DMA: 64 B transferred, 75% garbage.
        assert!(d.submit(1, 16, 16, false));
        d.tick(&mut ids);
        let r = d.pop_request().unwrap();
        assert_eq!(r.addr, 0);
        assert_eq!(r.bytes, 64);
        assert!((d.stats.garbage_ratio() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn pipelined_buffers_allow_deeper_overlap() {
        let (mut d, mut ids) = dma_depth(2, 4);
        for t in 0..8u64 {
            assert!(d.submit(t, t * 4096, 128, false), "slot {t}");
        }
        assert!(!d.submit(99, 0, 64, false), "9th exceeds 2×4 slots");
        d.tick(&mut ids);
        assert_eq!(d.busy_buffers(), 8);
    }

    #[test]
    fn store_flag_propagates() {
        let (mut d, mut ids) = dma(1);
        assert!(d.submit(9, 256, 128, true));
        d.tick(&mut ids);
        let r = d.pop_request().unwrap();
        assert!(r.is_write);
        assert_eq!(d.stats.stores, 1);
        assert_eq!(d.stats.loads, 0);
    }
}
