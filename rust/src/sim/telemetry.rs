//! Cycle-level telemetry: request-lifecycle tracing and the windowed
//! time-series (`TelemetryConfig`, off by default).
//!
//! Two products, both opt-in and both **observation-only** — every hook
//! below mutates only this struct, never simulator state, so enabling
//! telemetry cannot perturb a run (pinned by the engine-equivalence
//! matrix in `tests/integration_engine.rs`):
//!
//! * **Request-lifecycle traces** (`telemetry.trace`): per-request spans
//!   across the pipeline stages — PE issue → LMB bank select + RR
//!   outcome → fabric transport → DRAM queue/service → reply traversal →
//!   retire — exported as Chrome trace-event JSON loadable in Perfetto /
//!   `chrome://tracing`. Timestamps are simulated cycles. 1-in-N
//!   sampling (`telemetry.sample`) keeps full-scale runs bounded: every
//!   `sample`-th PE access and every `sample`-th DRAM transaction opens
//!   spans; the rest cost one counter bump.
//! * **Windowed time-series** (`telemetry.timeline`): once per elapsed
//!   `telemetry.window` cycles the run loop hands over a [`TimelineSnap`]
//!   of the cumulative per-component counters; the recorded row carries
//!   the *deltas* since the previous row plus instantaneous queue
//!   depths — one JSONL line per window for phase/heatmap analysis.
//!
//! With everything off, each hook is a single predictable branch; the
//! run loop's structure is otherwise untouched, and disabled-telemetry
//! reports stay bit-identical to the pre-telemetry simulator.
//!
//! Span ↔ component map (process/track ids in the exported trace):
//!
//! | pid | tid | span | opened … closed |
//! |-----|-----|------|------------------|
//! | 0 "accesses" | PE index | `elem`/`fib1`/`fib2`/`store` | PE issue … last part delivered (args: LMB bank + RR outcome for element loads) |
//! | 0 "accesses" | PE index | `retire` (instant) | slots retired this cycle |
//! | 1 "memory" | channel | `fabric` | fabric ingress … DRAM controller enqueue |
//! | 1 "memory" | node | `hop` / `reply.hop` (instants) | one store-and-forward link traversal |
//! | 1 "memory" | channel | `dram.queue` | controller enqueue … bank issue |
//! | 1 "memory" | channel | `dram.service` | bank issue … data beats done (args: row hit/miss/conflict) |
//! | 1 "memory" | channel | `reply` | service done … reply-network delivery (reply network on only) |

use std::collections::BTreeMap;

use crate::config::SystemConfig;
use crate::util::json::Json;

use super::{Cycle, ReqId};

/// Access-class span names, indexed by `ACC_*` (`sim::pe`).
const CLASS_NAMES: [&str; 4] = ["elem", "fib1", "fib2", "store"];

/// An open per-access span, keyed by the packed `(pe, slot, acc)` token
/// (unique while the access is in flight).
#[derive(Debug, Clone)]
struct AccessSpan {
    class: usize,
    issued_at: Cycle,
    /// LMB bank that fronted the address (element loads only).
    bank: Option<usize>,
    /// RR outcome: `hit` / `forward` / `absorb` (element loads only).
    outcome: Option<&'static str>,
}

/// An open DRAM-transaction span chain, keyed by request id.
#[derive(Debug, Clone)]
struct MemSpan {
    port: usize,
    /// Cycle the request entered the fabric's ingress queue.
    enqueued_at: Cycle,
    /// Channel it was delivered to (known at controller enqueue).
    ch: Option<usize>,
    /// Cycle its data beats finished (pre-reply-network `done_at`).
    service_done: Option<Cycle>,
}

/// Cumulative counter snapshot the run loop hands to
/// [`Telemetry::timeline_record`] once per elapsed window. All fields
/// are running totals unless marked instantaneous; the recorded row
/// stores deltas against the previous snapshot.
#[derive(Debug, Clone, Default)]
pub struct TimelineSnap {
    /// Requests resident per DRAM channel (queue + in flight) — instantaneous.
    pub channel_occupancy: Vec<u64>,
    pub channel_reads: Vec<u64>,
    pub channel_writes: Vec<u64>,
    pub channel_busy_bus: Vec<u64>,
    pub fabric_forwarded: u64,
    pub fabric_backpressure: u64,
    pub fabric_hops: u64,
    /// Per-request-link forwarded counts (same order as the link stats).
    pub link_forwarded: Vec<u64>,
    pub reply_delivered: u64,
    /// Per-LMB cache hits/misses summed over its banks.
    pub lmb_hits: Vec<u64>,
    pub lmb_misses: Vec<u64>,
    pub rr_served: Vec<u64>,
    pub rr_absorbed: Vec<u64>,
    pub rr_forwarded: Vec<u64>,
    pub pe_retired: u64,
    pub pe_issued: u64,
    pub pe_stalls: u64,
    /// Fabric ingress depth per port — instantaneous.
    pub ingress_depths: Vec<u64>,
    /// Pending PE deliveries in the run loop's calendar — instantaneous.
    pub pending_deliveries: u64,
    /// Pending cache-line events in the run loop's calendar — instantaneous.
    pub pending_line_events: u64,
}

/// Everything a telemetry-enabled run produced, handed out by
/// [`crate::sim::MemorySystem::take_telemetry`].
#[derive(Debug, Clone, Default)]
pub struct TelemetryOutput {
    /// Chrome trace-event document (`{"traceEvents": [...], "meta": ...}`),
    /// present when `telemetry.trace` was on.
    pub trace: Option<Json>,
    /// One JSON object per elapsed timeline window, present (possibly
    /// empty for very short runs) when `telemetry.timeline` was on.
    pub timeline: Vec<Json>,
}

/// Telemetry collector owned by the memory system. All hooks are
/// `#[inline]` early-returns when their product is off.
#[derive(Debug)]
pub struct Telemetry {
    trace_on: bool,
    timeline_on: bool,
    sample: u64,
    window: Cycle,
    reply_network: bool,
    label: String,
    // --- trace state ---
    /// PE accesses issued so far (sampling denominator).
    issue_seq: u64,
    access_open: BTreeMap<u64, AccessSpan>,
    mem_open: BTreeMap<ReqId, MemSpan>,
    events: Vec<Json>,
    // --- timeline state ---
    next_window_end: Cycle,
    last_row_cycle: Option<Cycle>,
    prev: Option<TimelineSnap>,
    rows: Vec<Json>,
}

impl Telemetry {
    pub fn new(cfg: &SystemConfig) -> Telemetry {
        Telemetry {
            trace_on: cfg.telemetry.trace,
            timeline_on: cfg.telemetry.timeline,
            sample: cfg.telemetry.sample.max(1),
            window: cfg.telemetry.window.max(1),
            reply_network: cfg.interconnect.reply_network,
            label: cfg.label.clone(),
            issue_seq: 0,
            access_open: BTreeMap::new(),
            mem_open: BTreeMap::new(),
            events: Vec::new(),
            next_window_end: cfg.telemetry.window.max(1),
            last_row_cycle: None,
            prev: None,
            rows: Vec::new(),
        }
    }

    /// A collector with every product off — allocation-free; used by the
    /// untraced component entry points (unit tests, standalone drivers).
    pub fn disabled() -> Telemetry {
        Telemetry {
            trace_on: false,
            timeline_on: false,
            sample: 1,
            window: 1,
            reply_network: false,
            label: String::new(),
            issue_seq: 0,
            access_open: BTreeMap::new(),
            mem_open: BTreeMap::new(),
            events: Vec::new(),
            next_window_end: Cycle::MAX,
            last_row_cycle: None,
            prev: None,
            rows: Vec::new(),
        }
    }

    /// Request-lifecycle tracing active?
    #[inline]
    pub fn tracing(&self) -> bool {
        self.trace_on
    }

    /// Timeline recording active?
    #[inline]
    pub fn timelining(&self) -> bool {
        self.timeline_on
    }

    // --- access spans (PE side) -----------------------------------------

    /// A PE access was issued into the memory system. Opens a span for
    /// every `sample`-th issue (in global issue order, which both run
    /// engines produce identically).
    #[inline]
    pub fn access_issued(&mut self, token: u64, class: usize, now: Cycle) {
        if !self.trace_on {
            return;
        }
        let seq = self.issue_seq;
        self.issue_seq += 1;
        if seq % self.sample != 0 {
            return;
        }
        self.access_open.insert(
            token,
            AccessSpan { class, issued_at: now, bank: None, outcome: None },
        );
    }

    /// Annotate an open element-load span with its LMB bank and RR
    /// outcome (`hit` / `forward` / `absorb`).
    #[inline]
    pub fn access_probe(&mut self, token: u64, bank: usize, outcome: &'static str) {
        if !self.trace_on {
            return;
        }
        if let Some(s) = self.access_open.get_mut(&token) {
            s.bank = Some(bank);
            s.outcome = Some(outcome);
        }
    }

    /// The access's last outstanding part was delivered: close the span.
    #[inline]
    pub fn access_done(&mut self, token: u64, at: Cycle) {
        if !self.trace_on {
            return;
        }
        let Some(s) = self.access_open.remove(&token) else {
            return;
        };
        let (pe, _slot, _acc) = super::pe::unpack_token(token);
        let mut args = Vec::new();
        if let Some(b) = s.bank {
            args.push(("bank", Json::num(b as f64)));
        }
        if let Some(o) = s.outcome {
            args.push(("rr", Json::str(o)));
        }
        let name = CLASS_NAMES[s.class.min(3)];
        self.events.push(span_event(name, 0, pe as u64, s.issued_at, at, args));
    }

    /// A PE retired `count` slots this cycle (instant marker).
    #[inline]
    pub fn retired(&mut self, pe: usize, count: u64, now: Cycle) {
        if !self.trace_on {
            return;
        }
        self.events.push(instant_event(
            "retire",
            0,
            pe as u64,
            now,
            vec![("count", Json::num(count as f64))],
        ));
    }

    // --- memory spans (fabric + DRAM side) ------------------------------

    /// A `MemReq` entered the fabric's ingress queue. Opens a span chain
    /// for every `sample`-th request id (ids are minted identically by
    /// both run engines).
    #[inline]
    pub fn mem_enqueued(&mut self, id: ReqId, port: usize, now: Cycle) {
        if !self.trace_on {
            return;
        }
        if id % self.sample != 0 {
            return;
        }
        self.mem_open.insert(
            id,
            MemSpan { port, enqueued_at: now, ch: None, service_done: None },
        );
    }

    /// A tracked request crossed one store-and-forward link.
    #[inline]
    pub fn mem_hop(&mut self, id: ReqId, from: usize, to: usize, now: Cycle) {
        if !self.trace_on || !self.mem_open.contains_key(&id) {
            return;
        }
        self.events.push(instant_event(
            "hop",
            1,
            from as u64,
            now,
            vec![("id", Json::num(id as f64)), ("to", Json::num(to as f64))],
        ));
    }

    /// A tracked request was handed to channel `ch`'s DRAM controller:
    /// closes the `fabric` transport span.
    #[inline]
    pub fn mem_delivered(&mut self, id: ReqId, ch: usize, now: Cycle) {
        if !self.trace_on {
            return;
        }
        let Some(s) = self.mem_open.get_mut(&id) else {
            return;
        };
        s.ch = Some(ch);
        let (enq, port) = (s.enqueued_at, s.port);
        self.events.push(span_event(
            "fabric",
            1,
            ch as u64,
            enq,
            now,
            vec![("id", Json::num(id as f64)), ("port", Json::num(port as f64))],
        ));
    }

    /// A tracked request was issued to a DRAM bank: closes `dram.queue`
    /// (controller enqueue → bank issue) and records `dram.service`
    /// (bank issue → data beats done, with the row-buffer outcome).
    #[inline]
    pub fn mem_service(
        &mut self,
        id: ReqId,
        ch: usize,
        enq_at: Cycle,
        start: Cycle,
        done_at: Cycle,
        row: &'static str,
    ) {
        if !self.trace_on {
            return;
        }
        let Some(s) = self.mem_open.get_mut(&id) else {
            return;
        };
        s.ch = Some(ch);
        s.service_done = Some(done_at);
        self.events.push(span_event(
            "dram.queue",
            1,
            ch as u64,
            enq_at,
            start,
            vec![("id", Json::num(id as f64))],
        ));
        self.events.push(span_event(
            "dram.service",
            1,
            ch as u64,
            start,
            done_at,
            vec![("id", Json::num(id as f64)), ("row", Json::str(row))],
        ));
    }

    /// A tracked reply crossed one reply link (reply network on).
    #[inline]
    pub fn mem_reply_hop(&mut self, id: ReqId, from: usize, to: usize, now: Cycle) {
        if !self.trace_on || !self.mem_open.contains_key(&id) {
            return;
        }
        self.events.push(instant_event(
            "reply.hop",
            1,
            from as u64,
            now,
            vec![("id", Json::num(id as f64)), ("to", Json::num(to as f64))],
        ));
    }

    /// The completion surfaced to the run loop (`done_at` is the final,
    /// possibly reply-network-rewritten cycle): closes the span chain,
    /// emitting the `reply` traversal span when the reply network is on.
    #[inline]
    pub fn mem_complete(&mut self, id: ReqId, done_at: Cycle) {
        if !self.trace_on {
            return;
        }
        let Some(s) = self.mem_open.remove(&id) else {
            return;
        };
        if !self.reply_network {
            return;
        }
        if let (Some(ch), Some(sd)) = (s.ch, s.service_done) {
            self.events.push(span_event(
                "reply",
                1,
                ch as u64,
                sd,
                done_at,
                vec![
                    ("id", Json::num(id as f64)),
                    ("port", Json::num(s.port as f64)),
                ],
            ));
        }
    }

    // --- timeline -------------------------------------------------------

    /// Has the current window elapsed? One branch when the timeline is
    /// off; the run loop checks this once per visited cycle.
    #[inline]
    pub fn timeline_due(&self, now: Cycle) -> bool {
        self.timeline_on && now >= self.next_window_end
    }

    /// End of the current timeline window, when the timeline product is
    /// on. The run loop's advance step records a row for every boundary
    /// a skip-ahead jump crosses — stamped at the boundary cycle with
    /// the pre-jump counters (nothing changes across a jumped stretch by
    /// construction), which keeps timeline artifacts byte-identical
    /// between the engines without forcing extra visited cycles.
    #[inline]
    pub fn next_window_boundary(&self) -> Option<Cycle> {
        self.timeline_on.then_some(self.next_window_end)
    }

    /// Record one timeline row at `now` from the cumulative snapshot:
    /// stores deltas against the previous row (instantaneous fields pass
    /// through). Idempotent per cycle so the end-of-run flush cannot
    /// duplicate a boundary row.
    pub fn timeline_record(&mut self, now: Cycle, snap: TimelineSnap) {
        if !self.timeline_on || self.last_row_cycle == Some(now) {
            return;
        }
        let prev = self.prev.take().unwrap_or_default();
        let d = |cur: u64, prev: u64| Json::num(cur.saturating_sub(prev) as f64);
        let at = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);

        let channels: Vec<Json> = (0..snap.channel_reads.len())
            .map(|i| {
                Json::obj(vec![
                    ("occupancy", Json::num(at(&snap.channel_occupancy, i) as f64)),
                    ("reads", d(at(&snap.channel_reads, i), at(&prev.channel_reads, i))),
                    ("writes", d(at(&snap.channel_writes, i), at(&prev.channel_writes, i))),
                    (
                        "busy_bus",
                        d(at(&snap.channel_busy_bus, i), at(&prev.channel_busy_bus, i)),
                    ),
                ])
            })
            .collect();
        let links: Vec<Json> = (0..snap.link_forwarded.len())
            .map(|i| d(at(&snap.link_forwarded, i), at(&prev.link_forwarded, i)))
            .collect();
        let lmbs: Vec<Json> = (0..snap.lmb_hits.len())
            .map(|i| {
                Json::obj(vec![
                    ("hits", d(at(&snap.lmb_hits, i), at(&prev.lmb_hits, i))),
                    ("misses", d(at(&snap.lmb_misses, i), at(&prev.lmb_misses, i))),
                    ("rr_served", d(at(&snap.rr_served, i), at(&prev.rr_served, i))),
                    ("rr_absorbed", d(at(&snap.rr_absorbed, i), at(&prev.rr_absorbed, i))),
                    ("rr_forwarded", d(at(&snap.rr_forwarded, i), at(&prev.rr_forwarded, i))),
                ])
            })
            .collect();
        let row = Json::obj(vec![
            ("cycle", Json::num(now as f64)),
            ("channels", Json::arr(channels)),
            (
                "fabric",
                Json::obj(vec![
                    ("forwarded", d(snap.fabric_forwarded, prev.fabric_forwarded)),
                    ("backpressure", d(snap.fabric_backpressure, prev.fabric_backpressure)),
                    ("hops", d(snap.fabric_hops, prev.fabric_hops)),
                    ("links", Json::arr(links)),
                ]),
            ),
            (
                "reply",
                Json::obj(vec![("delivered", d(snap.reply_delivered, prev.reply_delivered))]),
            ),
            ("lmbs", Json::arr(lmbs)),
            (
                "pe",
                Json::obj(vec![
                    ("retired", d(snap.pe_retired, prev.pe_retired)),
                    ("issued", d(snap.pe_issued, prev.pe_issued)),
                    ("stalls", d(snap.pe_stalls, prev.pe_stalls)),
                ]),
            ),
            (
                "depths",
                Json::obj(vec![
                    (
                        "ingress",
                        Json::arr(
                            snap.ingress_depths.iter().map(|&v| Json::num(v as f64)).collect(),
                        ),
                    ),
                    ("deliveries", Json::num(snap.pending_deliveries as f64)),
                    ("line_events", Json::num(snap.pending_line_events as f64)),
                ]),
            ),
        ]);
        self.rows.push(row);
        self.last_row_cycle = Some(now);
        self.next_window_end = (now / self.window + 1) * self.window;
        self.prev = Some(snap);
    }

    // --- export ---------------------------------------------------------

    /// Drain everything recorded into a [`TelemetryOutput`]. `workload`
    /// labels the trace's metadata block.
    pub fn take_output(&mut self, workload: &str) -> TelemetryOutput {
        let timeline = std::mem::take(&mut self.rows);
        let trace = if self.trace_on {
            let mut events = vec![
                process_name_event(0, "accesses"),
                process_name_event(1, "memory"),
            ];
            events.append(&mut self.events);
            Some(Json::obj(vec![
                ("meta", Json::obj(vec![
                    ("label", Json::str(self.label.clone())),
                    ("workload", Json::str(workload)),
                    ("reply_network", Json::Bool(self.reply_network)),
                    ("sample", Json::num(self.sample as f64)),
                    ("window", Json::num(self.window as f64)),
                ])),
                ("traceEvents", Json::arr(events)),
            ]))
        } else {
            None
        };
        TelemetryOutput { trace, timeline }
    }
}

/// A complete ("X") Chrome trace event; `ts`/`dur` are simulated cycles.
fn span_event(
    name: &str,
    pid: u64,
    tid: u64,
    start: Cycle,
    end: Cycle,
    args: Vec<(&str, Json)>,
) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ph", Json::str("X")),
        ("ts", Json::num(start as f64)),
        ("dur", Json::num(end.saturating_sub(start) as f64)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(args)),
    ])
}

/// An instant ("i") Chrome trace event at thread scope.
fn instant_event(name: &str, pid: u64, tid: u64, at: Cycle, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ph", Json::str("i")),
        ("ts", Json::num(at as f64)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("s", Json::str("t")),
        ("args", Json::obj(args)),
    ])
}

/// A "process_name" metadata ("M") event naming one trace process row.
fn process_name_event(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::pe::pack_token;

    fn traced_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::config_a();
        cfg.telemetry.trace = true;
        cfg
    }

    #[test]
    fn disabled_hooks_record_nothing() {
        let mut t = Telemetry::disabled();
        t.access_issued(pack_token(0, 1, 0), 0, 5);
        t.access_done(pack_token(0, 1, 0), 50);
        t.mem_enqueued(0, 0, 1);
        t.mem_delivered(0, 0, 2);
        t.mem_complete(0, 40);
        t.retired(0, 3, 60);
        t.timeline_record(10_000, TimelineSnap::default());
        let out = t.take_output("w");
        assert!(out.trace.is_none());
        assert!(out.timeline.is_empty());
        assert!(!t.timeline_due(u64::MAX), "disabled timeline never fires");
    }

    #[test]
    fn access_span_lifecycle_produces_complete_event() {
        let mut t = Telemetry::new(&traced_cfg());
        let tok = pack_token(2, 7, 0);
        t.access_issued(tok, 0, 100);
        t.access_probe(tok, 3, "forward");
        t.access_done(tok, 180);
        let out = t.take_output("w").trace.unwrap();
        let evs = out.get("traceEvents").unwrap().as_arr().unwrap();
        let span = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("elem"))
            .expect("elem span present");
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(100.0));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(80.0));
        assert_eq!(span.get("tid").unwrap().as_usize(), Some(2));
        let args = span.get("args").unwrap();
        assert_eq!(args.get("bank").unwrap().as_usize(), Some(3));
        assert_eq!(args.get("rr").unwrap().as_str(), Some("forward"));
        // Metadata names both process rows.
        assert_eq!(
            evs.iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
                .count(),
            2
        );
        assert_eq!(out.get("meta").unwrap().get("workload").unwrap().as_str(), Some("w"));
    }

    #[test]
    fn memory_span_chain_covers_every_stage() {
        let mut cfg = traced_cfg();
        cfg.interconnect.reply_network = true;
        let mut t = Telemetry::new(&cfg);
        t.mem_enqueued(8, 1, 10);
        t.mem_hop(8, 0, 1, 11);
        t.mem_delivered(8, 1, 12);
        t.mem_service(8, 1, 12, 15, 60, "miss");
        t.mem_reply_hop(8, 1, 0, 61);
        t.mem_complete(8, 63);
        let out = t.take_output("w").trace.unwrap();
        let evs = out.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> =
            evs.iter().filter_map(|e| e.get("name").and_then(Json::as_str)).collect();
        for want in ["fabric", "hop", "dram.queue", "dram.service", "reply.hop", "reply"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        let service = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("dram.service"))
            .unwrap();
        assert_eq!(service.get("dur").unwrap().as_f64(), Some(45.0));
        assert_eq!(service.get("args").unwrap().get("row").unwrap().as_str(), Some("miss"));
    }

    #[test]
    fn reply_span_absent_with_reply_network_off() {
        let mut t = Telemetry::new(&traced_cfg());
        t.mem_enqueued(4, 0, 0);
        t.mem_delivered(4, 0, 1);
        t.mem_service(4, 0, 1, 2, 30, "hit");
        t.mem_complete(4, 30);
        let out = t.take_output("w").trace.unwrap();
        let evs = out.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(
            !evs.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("reply")),
            "no reply span when the reply network is off"
        );
    }

    #[test]
    fn sampling_drops_all_but_every_nth() {
        let mut cfg = traced_cfg();
        cfg.telemetry.sample = 4;
        let mut t = Telemetry::new(&cfg);
        for i in 0..16u64 {
            let tok = pack_token(0, i as usize, 0);
            t.access_issued(tok, 0, i);
            t.access_done(tok, i + 10);
        }
        let out = t.take_output("w").trace.unwrap();
        let spans = out
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .count();
        assert_eq!(spans, 4, "16 issues at 1-in-4 sampling");
    }

    #[test]
    fn timeline_rows_are_deltas_with_instant_depths() {
        let mut cfg = SystemConfig::config_a();
        cfg.telemetry.timeline = true;
        cfg.telemetry.window = 100;
        let mut t = Telemetry::new(&cfg);
        assert!(!t.timeline_due(99));
        assert!(t.timeline_due(100));
        let snap = |reads: u64, occ: u64| TimelineSnap {
            channel_occupancy: vec![occ],
            channel_reads: vec![reads],
            channel_writes: vec![0],
            channel_busy_bus: vec![0],
            pe_retired: reads * 2,
            ..TimelineSnap::default()
        };
        t.timeline_record(100, snap(40, 7));
        assert!(!t.timeline_due(150));
        assert!(t.timeline_due(200));
        t.timeline_record(200, snap(100, 3));
        t.timeline_record(200, snap(100, 3)); // same-cycle flush: no dup
        let rows = t.take_output("w").timeline;
        assert_eq!(rows.len(), 2);
        let ch0 = |r: &Json| r.get("channels").unwrap().as_arr().unwrap()[0].clone();
        assert_eq!(ch0(&rows[0]).get("reads").unwrap().as_usize(), Some(40));
        assert_eq!(ch0(&rows[1]).get("reads").unwrap().as_usize(), Some(60), "delta vs prev");
        assert_eq!(ch0(&rows[1]).get("occupancy").unwrap().as_usize(), Some(3), "instantaneous");
        assert_eq!(rows[1].get("pe").unwrap().get("retired").unwrap().as_usize(), Some(120));
        assert_eq!(rows[0].get("cycle").unwrap().as_usize(), Some(100));
    }
}
