//! Local Memory Block (LMB) — "the basic building blocks of our proposed
//! memory system. A LMB has a Request Reductor, non-blocking cache, and a
//! DMA Engine. Each LMB connects to one or more PEs." (§IV)
//!
//! This module composes the three units and owns the LMB's request
//! traffic toward the router. The *routing policy* — which access class
//! takes which path — lives here too:
//!
//! * proposed system: elements → RR→cache, fibers/stores → DMA;
//! * cache-only baseline: everything → cache (fibers split into lines,
//!   conventional MSHR semantics, stores write-through);
//! * DMA-only baseline: everything → DMA (elements become beat-sized
//!   bursts with garbage).

use std::collections::VecDeque;

use crate::config::{SystemConfig, SystemKind};
#[allow(unused_imports)]
use crate::config::FabricType;

use super::cache::{Cache, CacheAccess, WaiterToken};
use super::dma::DmaEngine;
use super::dram::IdGen;
use super::request_reductor::{RequestReductor, RrResult};
use super::stats::LmbStats;
use super::{Cycle, MemReq, ReqId};

pub use super::Delivery;

/// Outcome of presenting an access to the LMB.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LmbOutcome {
    /// Completion time already known (temp-buffer or cache hit).
    Ready { at: Cycle },
    /// In flight; a [`Delivery`] will surface later.
    Pending,
    /// Structural stall — caller retries next cycle.
    Stall,
}

/// A cache line headed to the RR at a known future cycle (cache hits).
#[derive(Debug, Clone, Copy)]
pub struct LineEvent {
    pub lmb: usize,
    pub line: u64,
    pub at: Cycle,
}

/// One Local Memory Block.
pub struct Lmb {
    pub idx: usize,
    kind: SystemKind,
    pub cache: Cache,
    pub rr: RequestReductor,
    pub dma: DmaEngine,
    /// Fill/write requests waiting to enter the router.
    outbox: VecDeque<MemReq>,
    /// RR line loads the cache was too blocked to take.
    retry_lines: VecDeque<u64>,
    /// Reusable buffer for cache-fill waiter release (hot path).
    fill_scratch: Vec<WaiterToken>,
    line_bytes: u64,
}

impl Lmb {
    pub fn new(cfg: &SystemConfig, idx: usize) -> Lmb {
        let pes_per_lmb = cfg.pes_per_lmb();
        // The DMA-only baseline keeps the same engines; its §V-D cost is
        // what DMA cannot do — exploit temporal locality, and avoid
        // garbage on sub-beat requests — not reduced concurrency.
        let dma_depth = 4;
        Lmb {
            idx,
            kind: cfg.kind,
            cache: Cache::new(&cfg.cache, idx),
            rr: RequestReductor::new(&cfg.rr, cfg.cache.line_bytes(), pes_per_lmb),
            dma: DmaEngine::with_pipeline(&cfg.dma, cfg.dram.beat_bytes(), idx, dma_depth),
            outbox: VecDeque::new(),
            retry_lines: VecDeque::new(),
            fill_scratch: Vec::new(),
            line_bytes: cfg.cache.line_bytes(),
        }
    }

    /// Element load on the proposed path (RR → cache).
    pub fn element_load(
        &mut self,
        addr: u64,
        token: u64,
        now: Cycle,
        ids: &mut IdGen,
        line_events: &mut Vec<LineEvent>,
    ) -> LmbOutcome {
        debug_assert_eq!(self.kind, SystemKind::Proposed);
        match self.rr.element_load(addr, token, now) {
            RrResult::Served { ready_at } => LmbOutcome::Ready { at: ready_at },
            RrResult::Absorbed => LmbOutcome::Pending,
            RrResult::Stall => LmbOutcome::Stall,
            RrResult::ForwardLine { line } => {
                self.line_to_cache(line, now, ids, line_events);
                LmbOutcome::Pending
            }
        }
    }

    /// Present an RR line request to the cache (used for both the fast
    /// path and stalled retries).
    fn line_to_cache(
        &mut self,
        line: u64,
        now: Cycle,
        ids: &mut IdGen,
        line_events: &mut Vec<LineEvent>,
    ) {
        match self.cache.load(line * self.line_bytes, line, now, ids) {
            CacheAccess::Hit { ready_at } => line_events.push(LineEvent {
                lmb: self.idx,
                line,
                at: ready_at,
            }),
            CacheAccess::Miss { fill_req } => self.outbox.push_back(fill_req),
            CacheAccess::Merged => {} // already pending in the cache
            CacheAccess::Blocked => self.retry_lines.push_back(line),
        }
    }

    /// Direct cache load (cache-only baseline): `token` is a PE token.
    pub fn cache_load_direct(
        &mut self,
        addr: u64,
        token: u64,
        now: Cycle,
        ids: &mut IdGen,
    ) -> LmbOutcome {
        debug_assert_eq!(self.kind, SystemKind::CacheOnly);
        match self.cache.load(addr, token, now, ids) {
            CacheAccess::Hit { ready_at } => LmbOutcome::Ready { at: ready_at },
            CacheAccess::Miss { fill_req } => {
                self.outbox.push_back(fill_req);
                LmbOutcome::Pending
            }
            CacheAccess::Merged => LmbOutcome::Pending,
            CacheAccess::Blocked => LmbOutcome::Stall,
        }
    }

    /// Fiber transfer via the DMA engine (proposed + both fiber paths of
    /// the DMA-only baseline).
    pub fn dma_transfer(
        &mut self,
        addr: u64,
        bytes: u32,
        token: u64,
        is_write: bool,
    ) -> LmbOutcome {
        if self.dma.submit(token, addr, bytes, is_write) {
            LmbOutcome::Pending
        } else {
            LmbOutcome::Stall
        }
    }

    /// Write-through store used by the cache-only baseline (no allocate).
    pub fn store_through(&mut self, addr: u64, bytes: u32, ids: &mut IdGen) -> ReqId {
        let id = ids.next();
        self.outbox.push_back(MemReq {
            id,
            addr: addr - addr % self.line_bytes.min(64),
            bytes,
            is_write: true,
            port: self.idx,
        });
        id
    }

    /// Per-cycle housekeeping: move DMA queue into buffers, retry blocked
    /// RR lines.
    pub fn tick(&mut self, now: Cycle, ids: &mut IdGen, line_events: &mut Vec<LineEvent>) {
        self.dma.tick(ids);
        self.dma.drain_requests_into(&mut self.outbox);
        // One blocked RR line retried per cycle (single cache port).
        if let Some(line) = self.retry_lines.pop_front() {
            self.line_to_cache(line, now, ids, line_events);
        }
    }

    /// Would [`Lmb::tick`] do anything right now — queued DMA transfers
    /// to place, minted DMA requests to drain, or a blocked RR line to
    /// retry? When false, a tick is a provable no-op (no state change,
    /// no statistics) and the event-driven run loop skips this LMB.
    pub fn needs_tick(&self) -> bool {
        self.dma.has_queued() || self.dma.has_requests() || !self.retry_lines.is_empty()
    }

    /// A cache line reached the RR: release waiters into `deliveries`.
    pub fn line_ready_into(&mut self, line: u64, now: Cycle, deliveries: &mut Vec<Delivery>) {
        self.rr.line_arrived_into(line, now, deliveries);
    }

    /// A DRAM completion for this port. Appends PE deliveries to
    /// `deliveries` (and, on the proposed path, RR line events for
    /// freshly filled lines to `line_events`) — allocation-free.
    pub fn on_dram_completion(
        &mut self,
        id: ReqId,
        done_at: Cycle,
        line_events: &mut Vec<LineEvent>,
        deliveries: &mut Vec<Delivery>,
    ) {
        // DMA transfer?
        if let Some((token, at)) = self.dma.on_complete(id, done_at) {
            deliveries.push(Delivery { token, at });
            return;
        }
        // Cache fill?
        self.fill_scratch.clear();
        if let Some(line) = self.cache.fill_into(id, &mut self.fill_scratch) {
            match self.kind {
                SystemKind::Proposed => {
                    // Waiters are RR line tokens — deliver the line to the
                    // RR after the cache pipeline.
                    for &w in &self.fill_scratch {
                        debug_assert_eq!(w, line);
                        line_events.push(LineEvent {
                            lmb: self.idx,
                            line: w,
                            at: done_at + 3,
                        });
                    }
                }
                SystemKind::CacheOnly => {
                    for &token in &self.fill_scratch {
                        deliveries.push(Delivery {
                            token,
                            at: done_at + 3,
                        });
                    }
                }
                _ => unreachable!("cache unused in {:?}", self.kind),
            }
        }
    }

    /// Next outgoing request toward the router, if any.
    pub fn pop_request(&mut self) -> Option<MemReq> {
        self.outbox.pop_front()
    }

    pub fn has_requests(&self) -> bool {
        !self.outbox.is_empty()
    }

    pub fn quiescent(&self) -> bool {
        self.outbox.is_empty()
            && self.retry_lines.is_empty()
            && self.cache.quiescent()
            && self.dma.is_idle()
            && self.rr.outstanding() == 0
    }

    pub fn stats(&self) -> LmbStats {
        LmbStats {
            cache: self.cache.stats.clone(),
            rr: self.rr.stats.clone(),
            dma: self.dma.stats.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lmb(kind: SystemKind) -> (Lmb, IdGen) {
        let mut cfg = SystemConfig::config_a();
        cfg.kind = kind;
        (Lmb::new(&cfg, 0), IdGen::default())
    }

    #[test]
    fn proposed_element_flow_via_rr_cache_dram() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        let mut evs = Vec::new();
        // First element: RR forwards, cache misses → request in outbox.
        assert_eq!(
            l.element_load(0, 1, 0, &mut ids, &mut evs),
            LmbOutcome::Pending
        );
        let req = l.pop_request().expect("fill request");
        assert_eq!(req.bytes, 64);
        // Second element of the same line: absorbed by RRSH.
        assert_eq!(
            l.element_load(16, 2, 1, &mut ids, &mut evs),
            LmbOutcome::Pending
        );
        // DRAM completes → line event → RR release.
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 100, &mut evs, &mut d);
        assert!(d.is_empty());
        assert_eq!(evs.len(), 1);
        let mut deliveries = Vec::new();
        l.line_ready_into(evs[0].line, evs[0].at, &mut deliveries);
        assert_eq!(deliveries.len(), 2);
        assert!(deliveries.iter().any(|d| d.token == 1));
        assert!(deliveries.iter().any(|d| d.token == 2));
        // Third element of that line: temp-buffer hit.
        match l.element_load(32, 3, 200, &mut ids, &mut evs) {
            LmbOutcome::Ready { at } => assert!(at > 200),
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn dma_path_and_completion() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        let mut evs = Vec::new();
        assert_eq!(
            l.dma_transfer(0x10080, 128, 7, false),
            LmbOutcome::Pending
        );
        l.tick(0, &mut ids, &mut evs);
        let req = l.pop_request().expect("dma burst");
        assert_eq!(req.addr, 0x10080);
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 55, &mut evs, &mut d);
        assert_eq!(d, vec![Delivery { token: 7, at: 55 }]);
    }

    #[test]
    fn dma_only_backpressures_at_capacity() {
        let (mut l, mut ids) = lmb(SystemKind::DmaOnly);
        let mut evs = Vec::new();
        // 4 buffers × pipeline depth 4 → 16 accepted, 17th stalls.
        for t in 0..16 {
            assert_eq!(l.dma_transfer(t * 64, 64, t, false), LmbOutcome::Pending);
        }
        assert_eq!(l.dma_transfer(4096, 64, 99, false), LmbOutcome::Stall);
        l.tick(0, &mut ids, &mut evs);
        assert!(l.pop_request().is_some());
    }

    #[test]
    fn cache_only_direct_loads() {
        let (mut l, mut ids) = lmb(SystemKind::CacheOnly);
        assert_eq!(l.cache_load_direct(0, 9, 0, &mut ids), LmbOutcome::Pending);
        let req = l.pop_request().unwrap();
        let mut evs = Vec::new();
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 80, &mut evs, &mut d);
        assert_eq!(d, vec![Delivery { token: 9, at: 83 }]);
        // Now hits.
        match l.cache_load_direct(16, 10, 90, &mut ids) {
            LmbOutcome::Ready { at } => assert_eq!(at, 93),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn store_through_issues_write() {
        let (mut l, mut ids) = lmb(SystemKind::CacheOnly);
        l.store_through(0x30000, 128, &mut ids);
        let req = l.pop_request().unwrap();
        assert!(req.is_write);
        assert_eq!(req.bytes, 128);
    }

    #[test]
    fn needs_tick_tracks_housekeeping_work() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        let mut evs = Vec::new();
        assert!(!l.needs_tick(), "fresh LMB has no housekeeping");
        // A queued DMA transfer makes the LMB tick-active...
        assert_eq!(l.dma_transfer(0, 64, 1, false), LmbOutcome::Pending);
        assert!(l.needs_tick());
        // ...and once the tick placed it (queue + DMA outbox drained into
        // the LMB outbox), housekeeping is idle again even though a
        // request waits for the router.
        l.tick(0, &mut ids, &mut evs);
        assert!(!l.needs_tick());
        assert!(l.has_requests());
    }

    #[test]
    fn quiescent_tracks_all_subunits() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        assert!(l.quiescent());
        let mut evs = Vec::new();
        l.element_load(0, 1, 0, &mut ids, &mut evs);
        assert!(!l.quiescent());
    }
}
