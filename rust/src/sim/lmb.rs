//! Local Memory Block (LMB) — "the basic building blocks of our proposed
//! memory system. A LMB has a Request Reductor, non-blocking cache, and a
//! DMA Engine. Each LMB connects to one or more PEs." (§IV)
//!
//! This module composes the three units and owns the LMB's request
//! traffic toward the router. The *routing policy* — which access class
//! takes which path — lives here too:
//!
//! * proposed system: elements → RR→cache, fibers/stores → DMA;
//! * cache-only baseline: everything → cache (fibers split into lines,
//!   conventional MSHR semantics, stores write-through);
//! * DMA-only baseline: everything → DMA (elements become beat-sized
//!   bursts with garbage).
//!
//! # Per-channel banks
//!
//! The cache + Request Reductor pair is instantiated once per **bank**
//! ([`crate::config::SystemConfig::lmb_banks`], default 1). Banks are
//! selected by the same [`ChannelMap`] interleaving the DRAM side uses
//! (same granularity), so with `lmb_banks == interconnect.channels` bank
//! *b* caches exactly the addresses that live on DRAM channel *b* — the
//! "per-channel LMB banks" layout. Cache lines, MSHR entries and RRSH
//! entries are sharded across banks (total capacity constant); each bank
//! has its own cache port, so blocked RR lines retry one per bank per
//! cycle. Bank caches index their sets by the **bank-local** address
//! (the interleave bits squeezed out — the same dense view each DRAM
//! channel gets), so every sharded set stays reachable; fill requests
//! keep global addresses, and RR line tokens are global line numbers
//! end to end. The DMA engine stays un-banked — fiber bursts are long
//! streams that span interleave granules and already pipeline across
//! channels.
//!
//! Key invariant: with `lmb_banks = 1` the bank map is the identity and
//! the single bank carries the full configured geometry, so the banked
//! LMB is **bit-identical** to the pre-bank one by construction
//! (regression-pinned by `tests/integration_fabric.rs`).

use std::collections::VecDeque;

use crate::config::{SystemConfig, SystemKind};
#[allow(unused_imports)]
use crate::config::FabricType;

use super::cache::{Cache, CacheAccess, WaiterToken};
use super::dma::DmaEngine;
use super::dram::{ChannelMap, IdGen};
use super::request_reductor::{RequestReductor, RrResult};
use super::stats::{LmbBankStats, LmbStats};
use super::{Cycle, MemReq, ReqId};

pub use super::Delivery;

/// Outcome of presenting an access to the LMB.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LmbOutcome {
    /// Completion time already known (temp-buffer or cache hit).
    Ready { at: Cycle },
    /// In flight; a [`Delivery`] will surface later.
    Pending,
    /// Structural stall — caller retries next cycle.
    Stall,
}

/// A cache line headed to the RR at a known future cycle (cache hits).
#[derive(Debug, Clone, Copy)]
pub struct LineEvent {
    pub lmb: usize,
    pub line: u64,
    pub at: Cycle,
}

/// One cache + Request-Reductor bank of an LMB (the sharded unit).
pub struct LmbBank {
    pub cache: Cache,
    pub rr: RequestReductor,
    /// RR line loads the bank's cache was too blocked to take.
    retry_lines: VecDeque<u64>,
}

/// One Local Memory Block.
pub struct Lmb {
    pub idx: usize,
    kind: SystemKind,
    /// Cache + RR banks (`lmb_banks` of them; 1 = the paper's LMB).
    banks: Vec<LmbBank>,
    /// Address → bank, the DRAM side's interleaving reused verbatim.
    bank_map: ChannelMap,
    pub dma: DmaEngine,
    /// Fill/write requests waiting to enter the router.
    outbox: VecDeque<MemReq>,
    /// Reusable buffer for cache-fill waiter release (hot path).
    fill_scratch: Vec<WaiterToken>,
    line_bytes: u64,
    line_shift: u32,
}

impl Lmb {
    pub fn new(cfg: &SystemConfig, idx: usize) -> Lmb {
        let pes_per_lmb = cfg.pes_per_lmb();
        // The DMA-only baseline keeps the same engines; its §V-D cost is
        // what DMA cannot do — exploit temporal locality, and avoid
        // garbage on sub-beat requests — not reduced concurrency.
        let dma_depth = 4;
        let bank_cache = cfg.bank_cache();
        let bank_rr = cfg.bank_rr();
        let banks = (0..cfg.lmb_banks)
            .map(|_| LmbBank {
                cache: Cache::new(&bank_cache, idx),
                rr: RequestReductor::new(&bank_rr, cfg.cache.line_bytes(), pes_per_lmb),
                retry_lines: VecDeque::new(),
            })
            .collect();
        Lmb {
            idx,
            kind: cfg.kind,
            banks,
            bank_map: ChannelMap::new(cfg.lmb_banks, cfg.interconnect.interleave_bytes),
            dma: DmaEngine::with_pipeline(&cfg.dma, cfg.dram.beat_bytes(), idx, dma_depth),
            outbox: VecDeque::new(),
            fill_scratch: Vec::new(),
            line_bytes: cfg.cache.line_bytes(),
            line_shift: crate::util::log2(cfg.cache.line_bytes()),
        }
    }

    /// Bank fronting `addr` (identity with one bank). Banks never split a
    /// cache line: config validation pins `interleave_bytes >= line`.
    #[inline]
    fn bank_of(&self, addr: u64) -> usize {
        self.bank_map.decode(addr).0
    }

    /// Bank fronting cache line `line` (lines are globally numbered —
    /// banks see full addresses, so the line number maps back uniquely).
    #[inline]
    fn bank_of_line(&self, line: u64) -> usize {
        self.bank_of(line << self.line_shift)
    }

    /// Bank-local address: the global address with the bank-select bits
    /// squeezed out (identity with one bank). Bank caches index their
    /// sets with this — exactly as each DRAM channel sees a dense
    /// channel-local address space — so a bank's sharded sets stay fully
    /// reachable even though its global addresses share fixed
    /// interleave bits.
    #[inline]
    fn local_addr(&self, addr: u64) -> u64 {
        self.bank_map.decode(addr).1
    }

    /// Bank-local line number of a global line.
    #[inline]
    fn local_line_of(&self, line: u64) -> u64 {
        self.local_addr(line << self.line_shift) >> self.line_shift
    }

    /// Number of cache + RR banks.
    pub fn n_banks(&self) -> usize {
        self.banks.len()
    }

    /// Element load on the proposed path (RR → cache), routed to the
    /// address's bank.
    pub fn element_load(
        &mut self,
        addr: u64,
        token: u64,
        now: Cycle,
        ids: &mut IdGen,
        line_events: &mut Vec<LineEvent>,
    ) -> LmbOutcome {
        self.element_load_probed(addr, token, now, ids, line_events).0
    }

    /// [`Lmb::element_load`] that also reports which bank fronted the
    /// address and the RR outcome kind (`hit` / `absorb` / `forward` /
    /// `stall`) — the telemetry probe. Behavior is identical.
    pub fn element_load_probed(
        &mut self,
        addr: u64,
        token: u64,
        now: Cycle,
        ids: &mut IdGen,
        line_events: &mut Vec<LineEvent>,
    ) -> (LmbOutcome, usize, &'static str) {
        debug_assert_eq!(self.kind, SystemKind::Proposed);
        let bank = self.bank_of(addr);
        match self.banks[bank].rr.element_load(addr, token, now) {
            RrResult::Served { ready_at } => (LmbOutcome::Ready { at: ready_at }, bank, "hit"),
            RrResult::Absorbed => (LmbOutcome::Pending, bank, "absorb"),
            RrResult::Stall => (LmbOutcome::Stall, bank, "stall"),
            RrResult::ForwardLine { line } => {
                self.line_to_cache(bank, line, now, ids, line_events);
                (LmbOutcome::Pending, bank, "forward")
            }
        }
    }

    /// Present an RR line request to one bank's cache (used for both the
    /// fast path and stalled retries). The cache indexes by the
    /// bank-local address; the fill request keeps the global address
    /// (the fabric decodes the DRAM channel from it). Waiter tokens stay
    /// global line numbers throughout.
    fn line_to_cache(
        &mut self,
        bank: usize,
        line: u64,
        now: Cycle,
        ids: &mut IdGen,
        line_events: &mut Vec<LineEvent>,
    ) {
        let local = self.local_line_of(line) * self.line_bytes;
        let b = &mut self.banks[bank];
        match b.cache.load(local, line, now, ids) {
            CacheAccess::Hit { ready_at } => line_events.push(LineEvent {
                lmb: self.idx,
                line,
                at: ready_at,
            }),
            CacheAccess::Miss { mut fill_req } => {
                fill_req.addr = line * self.line_bytes;
                self.outbox.push_back(fill_req);
            }
            CacheAccess::Merged => {} // already pending in the cache
            CacheAccess::Blocked => b.retry_lines.push_back(line),
        }
    }

    /// Direct cache load (cache-only baseline): `token` is a PE token.
    /// Indexes by the bank-local address; the fill keeps the global one.
    pub fn cache_load_direct(
        &mut self,
        addr: u64,
        token: u64,
        now: Cycle,
        ids: &mut IdGen,
    ) -> LmbOutcome {
        debug_assert_eq!(self.kind, SystemKind::CacheOnly);
        let bank = self.bank_of(addr);
        let local = self.local_addr(addr);
        match self.banks[bank].cache.load(local, token, now, ids) {
            CacheAccess::Hit { ready_at } => LmbOutcome::Ready { at: ready_at },
            CacheAccess::Miss { mut fill_req } => {
                fill_req.addr = addr - addr % self.line_bytes;
                self.outbox.push_back(fill_req);
                LmbOutcome::Pending
            }
            CacheAccess::Merged => LmbOutcome::Pending,
            CacheAccess::Blocked => LmbOutcome::Stall,
        }
    }

    /// Fiber transfer via the DMA engine (proposed + both fiber paths of
    /// the DMA-only baseline).
    pub fn dma_transfer(
        &mut self,
        addr: u64,
        bytes: u32,
        token: u64,
        is_write: bool,
    ) -> LmbOutcome {
        if self.dma.submit(token, addr, bytes, is_write) {
            LmbOutcome::Pending
        } else {
            LmbOutcome::Stall
        }
    }

    /// Write-through store used by the cache-only baseline (no allocate).
    pub fn store_through(&mut self, addr: u64, bytes: u32, ids: &mut IdGen) -> ReqId {
        let id = ids.next();
        self.outbox.push_back(MemReq {
            id,
            addr: addr - addr % self.line_bytes.min(64),
            bytes,
            is_write: true,
            port: self.idx,
        });
        id
    }

    /// Per-cycle housekeeping: move DMA queue into buffers, retry blocked
    /// RR lines (one per bank per cycle — one cache port per bank).
    pub fn tick(&mut self, now: Cycle, ids: &mut IdGen, line_events: &mut Vec<LineEvent>) {
        self.dma.tick(ids);
        self.dma.drain_requests_into(&mut self.outbox);
        for bank in 0..self.banks.len() {
            if let Some(line) = self.banks[bank].retry_lines.pop_front() {
                self.line_to_cache(bank, line, now, ids, line_events);
            }
        }
    }

    /// Would [`Lmb::tick`] do anything right now — queued DMA transfers
    /// to place, minted DMA requests to drain, or a blocked RR line to
    /// retry in any bank? When false, a tick is a provable no-op (no
    /// state change, no statistics) and the event-driven run loop skips
    /// this LMB.
    pub fn needs_tick(&self) -> bool {
        self.dma.has_queued()
            || self.dma.has_requests()
            || self.banks.iter().any(|b| !b.retry_lines.is_empty())
    }

    /// A cache line reached its RR: release waiters into `deliveries`.
    pub fn line_ready_into(&mut self, line: u64, now: Cycle, deliveries: &mut Vec<Delivery>) {
        let bank = self.bank_of_line(line);
        self.banks[bank].rr.line_arrived_into(line, now, deliveries);
    }

    /// A DRAM completion for this port. Appends PE deliveries to
    /// `deliveries` (and, on the proposed path, RR line events for
    /// freshly filled lines to `line_events`) — allocation-free. Request
    /// ids are unique, so at most one bank's MSHR claims the fill.
    pub fn on_dram_completion(
        &mut self,
        id: ReqId,
        done_at: Cycle,
        line_events: &mut Vec<LineEvent>,
        deliveries: &mut Vec<Delivery>,
    ) {
        // DMA transfer?
        if let Some((token, at)) = self.dma.on_complete(id, done_at) {
            deliveries.push(Delivery { token, at });
            return;
        }
        // Cache fill? (scan the banks; ids are unique across them)
        self.fill_scratch.clear();
        let Some(line) = self
            .banks
            .iter_mut()
            .find_map(|b| b.cache.fill_into(id, &mut self.fill_scratch))
        else {
            return;
        };
        match self.kind {
            SystemKind::Proposed => {
                // Waiters are RR line tokens (global line numbers);
                // `line` is the cache's bank-local key. Deliver the line
                // to the RR after the cache pipeline.
                for &w in &self.fill_scratch {
                    debug_assert_eq!(self.local_line_of(w), line);
                    line_events.push(LineEvent {
                        lmb: self.idx,
                        line: w,
                        at: done_at + 3,
                    });
                }
            }
            SystemKind::CacheOnly => {
                for &token in &self.fill_scratch {
                    deliveries.push(Delivery {
                        token,
                        at: done_at + 3,
                    });
                }
            }
            _ => unreachable!("cache unused in {:?}", self.kind),
        }
    }

    /// Next outgoing request toward the router, if any.
    pub fn pop_request(&mut self) -> Option<MemReq> {
        self.outbox.pop_front()
    }

    pub fn has_requests(&self) -> bool {
        !self.outbox.is_empty()
    }

    pub fn quiescent(&self) -> bool {
        self.outbox.is_empty()
            && self.dma.is_idle()
            && self.banks.iter().all(|b| {
                b.retry_lines.is_empty() && b.cache.quiescent() && b.rr.outstanding() == 0
            })
    }

    pub fn stats(&self) -> LmbStats {
        let mut cache = super::cache::CacheStats::default();
        let mut rr = super::request_reductor::RrStats::default();
        let mut banks = Vec::with_capacity(self.banks.len());
        for b in &self.banks {
            cache.merge(&b.cache.stats);
            rr.merge(&b.rr.stats);
            banks.push(LmbBankStats {
                cache: b.cache.stats.clone(),
                rr: b.rr.stats.clone(),
            });
        }
        LmbStats {
            cache,
            rr,
            dma: self.dma.stats.clone(),
            banks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lmb(kind: SystemKind) -> (Lmb, IdGen) {
        let mut cfg = SystemConfig::config_a();
        cfg.kind = kind;
        (Lmb::new(&cfg, 0), IdGen::default())
    }

    fn lmb_banked(kind: SystemKind, banks: usize) -> (Lmb, IdGen) {
        let mut cfg = SystemConfig::config_a();
        cfg.kind = kind;
        cfg.lmb_banks = banks;
        cfg.validate().unwrap();
        (Lmb::new(&cfg, 0), IdGen::default())
    }

    #[test]
    fn proposed_element_flow_via_rr_cache_dram() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        let mut evs = Vec::new();
        // First element: RR forwards, cache misses → request in outbox.
        assert_eq!(
            l.element_load(0, 1, 0, &mut ids, &mut evs),
            LmbOutcome::Pending
        );
        let req = l.pop_request().expect("fill request");
        assert_eq!(req.bytes, 64);
        // Second element of the same line: absorbed by RRSH.
        assert_eq!(
            l.element_load(16, 2, 1, &mut ids, &mut evs),
            LmbOutcome::Pending
        );
        // DRAM completes → line event → RR release.
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 100, &mut evs, &mut d);
        assert!(d.is_empty());
        assert_eq!(evs.len(), 1);
        let mut deliveries = Vec::new();
        l.line_ready_into(evs[0].line, evs[0].at, &mut deliveries);
        assert_eq!(deliveries.len(), 2);
        assert!(deliveries.iter().any(|d| d.token == 1));
        assert!(deliveries.iter().any(|d| d.token == 2));
        // Third element of that line: temp-buffer hit.
        match l.element_load(32, 3, 200, &mut ids, &mut evs) {
            LmbOutcome::Ready { at } => assert!(at > 200),
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn banked_elements_route_to_their_interleave_bank() {
        // 4 banks over the default 4096 B granule: granule g → bank g%4.
        let (mut l, mut ids) = lmb_banked(SystemKind::Proposed, 4);
        assert_eq!(l.n_banks(), 4);
        let mut evs = Vec::new();
        for g in 0..4u64 {
            assert_eq!(
                l.element_load(g * 4096, 100 + g, 0, &mut ids, &mut evs),
                LmbOutcome::Pending
            );
        }
        let stats = l.stats();
        assert_eq!(stats.banks.len(), 4);
        for (b, s) in stats.banks.iter().enumerate() {
            assert_eq!(s.rr.forwarded, 1, "bank {b} must see exactly its granule");
        }
        // Aggregate view folds the banks.
        assert_eq!(stats.rr.forwarded, 4);
        assert_eq!(stats.cache.primary_misses, 4);
        // Four independent fill requests, one per bank.
        let mut n = 0;
        while l.pop_request().is_some() {
            n += 1;
        }
        assert_eq!(n, 4);
    }

    #[test]
    fn banked_fill_and_line_release_find_the_right_bank() {
        let (mut l, mut ids) = lmb_banked(SystemKind::Proposed, 2);
        let mut evs = Vec::new();
        // Granule 1 (bank 1): miss + one absorbed waiter.
        let addr = 4096;
        assert_eq!(l.element_load(addr, 7, 0, &mut ids, &mut evs), LmbOutcome::Pending);
        assert_eq!(l.element_load(addr + 16, 8, 0, &mut ids, &mut evs), LmbOutcome::Pending);
        let req = l.pop_request().expect("bank-1 fill");
        assert_eq!(req.addr, addr);
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 50, &mut evs, &mut d);
        assert_eq!(evs.len(), 1, "one line event for the filled line");
        let mut deliveries = Vec::new();
        l.line_ready_into(evs[0].line, evs[0].at, &mut deliveries);
        assert_eq!(deliveries.len(), 2);
        let stats = l.stats();
        assert_eq!(stats.banks[0].rr.forwarded, 0);
        assert_eq!(stats.banks[1].rr.forwarded, 1);
        assert_eq!(stats.banks[1].rr.absorbed, 1);
        assert_eq!(stats.banks[1].cache.fills, 1);
    }

    #[test]
    fn bank_caches_index_by_local_address_so_all_sets_are_reachable() {
        // 4 banks on config-a: per-bank cache is 2048 lines / 2-way =
        // 1024 sets. Bank 0 sees only every 4th interleave granule, so
        // under *global* line indexing two of the 10 set bits would be
        // constant and 3/4 of the bank's sets unreachable (the 1024
        // lines below would pile 4-deep onto 256 sets and thrash the
        // 2 ways). With bank-local indexing they are set-dense: 1024
        // lines → 1024 distinct sets, no evictions, every re-probe hits.
        let (mut l, mut ids) = lmb_banked(SystemKind::CacheOnly, 4);
        let mut evs = Vec::new();
        let mut d = Vec::new();
        let addrs: Vec<u64> = (0..16u64)
            .flat_map(|g| (0..64u64).map(move |j| g * 4 * 4096 + j * 64))
            .collect(); // granule 4g → bank 0; local lines are dense
        for (i, &addr) in addrs.iter().enumerate() {
            match l.cache_load_direct(addr, i as u64, 0, &mut ids) {
                LmbOutcome::Pending => {
                    let req = l.pop_request().unwrap();
                    assert_eq!(req.addr, addr, "fill must carry the global address");
                    l.on_dram_completion(req.id, 10, &mut evs, &mut d);
                }
                other => panic!("first touch of {addr:#x} must miss, got {other:?}"),
            }
        }
        let stats = l.stats();
        assert_eq!(stats.banks[0].cache.fills, 1024);
        assert_eq!(stats.cache.evictions, 0, "1024 set-dense lines must not evict");
        // Every line is now resident.
        for &addr in &addrs {
            match l.cache_load_direct(addr, 9999, 20, &mut ids) {
                LmbOutcome::Ready { .. } => {}
                other => panic!("re-probe of {addr:#x} must hit, got {other:?}"),
            }
        }
        assert_eq!(l.stats().cache.hits, 1024);
    }

    #[test]
    fn single_bank_carries_full_geometry() {
        // banks=1 is the regression anchor: identity map, full cache.
        let cfg = SystemConfig::config_a();
        let l = Lmb::new(&cfg, 0);
        assert_eq!(l.n_banks(), 1);
        assert_eq!(l.bank_of(0), 0);
        assert_eq!(l.bank_of(u64::MAX >> 16), 0);
    }

    #[test]
    fn dma_path_and_completion() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        let mut evs = Vec::new();
        assert_eq!(
            l.dma_transfer(0x10080, 128, 7, false),
            LmbOutcome::Pending
        );
        l.tick(0, &mut ids, &mut evs);
        let req = l.pop_request().expect("dma burst");
        assert_eq!(req.addr, 0x10080);
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 55, &mut evs, &mut d);
        assert_eq!(d, vec![Delivery { token: 7, at: 55 }]);
    }

    #[test]
    fn dma_only_backpressures_at_capacity() {
        let (mut l, mut ids) = lmb(SystemKind::DmaOnly);
        let mut evs = Vec::new();
        // 4 buffers × pipeline depth 4 → 16 accepted, 17th stalls.
        for t in 0..16 {
            assert_eq!(l.dma_transfer(t * 64, 64, t, false), LmbOutcome::Pending);
        }
        assert_eq!(l.dma_transfer(4096, 64, 99, false), LmbOutcome::Stall);
        l.tick(0, &mut ids, &mut evs);
        assert!(l.pop_request().is_some());
    }

    #[test]
    fn cache_only_direct_loads() {
        let (mut l, mut ids) = lmb(SystemKind::CacheOnly);
        assert_eq!(l.cache_load_direct(0, 9, 0, &mut ids), LmbOutcome::Pending);
        let req = l.pop_request().unwrap();
        let mut evs = Vec::new();
        let mut d = Vec::new();
        l.on_dram_completion(req.id, 80, &mut evs, &mut d);
        assert_eq!(d, vec![Delivery { token: 9, at: 83 }]);
        // Now hits.
        match l.cache_load_direct(16, 10, 90, &mut ids) {
            LmbOutcome::Ready { at } => assert_eq!(at, 93),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn store_through_issues_write() {
        let (mut l, mut ids) = lmb(SystemKind::CacheOnly);
        l.store_through(0x30000, 128, &mut ids);
        let req = l.pop_request().unwrap();
        assert!(req.is_write);
        assert_eq!(req.bytes, 128);
    }

    #[test]
    fn needs_tick_tracks_housekeeping_work() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        let mut evs = Vec::new();
        assert!(!l.needs_tick(), "fresh LMB has no housekeeping");
        // A queued DMA transfer makes the LMB tick-active...
        assert_eq!(l.dma_transfer(0, 64, 1, false), LmbOutcome::Pending);
        assert!(l.needs_tick());
        // ...and once the tick placed it (queue + DMA outbox drained into
        // the LMB outbox), housekeeping is idle again even though a
        // request waits for the router.
        l.tick(0, &mut ids, &mut evs);
        assert!(!l.needs_tick());
        assert!(l.has_requests());
    }

    #[test]
    fn quiescent_tracks_all_subunits() {
        let (mut l, mut ids) = lmb(SystemKind::Proposed);
        assert!(l.quiescent());
        let mut evs = Vec::new();
        l.element_load(0, 1, 0, &mut ids, &mut evs);
        assert!(!l.quiescent());
    }
}
