//! Request Router (§IV-D): "(a) receive memory requests from different
//! LMB units and forward them to the DRAM interface IP, (b) forward the
//! data coming from external memory to the LMB units."
//!
//! Round-robin arbitration over the LMB ports, one command per user-clock
//! cycle into the memory controller (matching the single MIG command
//! channel), with backpressure when the controller queue is full.

use std::collections::VecDeque;

use super::dram::Dram;
use super::{Cycle, MemReq, MemResp};

/// Router statistics.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub forwarded: u64,
    pub backpressure_cycles: u64,
    pub per_port_forwarded: Vec<u64>,
}

/// The request router between LMBs and the DRAM interface IP.
pub struct Router {
    /// Per-port ingress queues (filled by LMBs / direct PE ports).
    ingress: Vec<VecDeque<MemReq>>,
    /// Round-robin pointer.
    rr_next: usize,
    /// Commands the router may forward per cycle (MIG: 1).
    cmds_per_cycle: usize,
    pub stats: RouterStats,
}

impl Router {
    pub fn new(n_ports: usize, cmds_per_cycle: usize) -> Router {
        Router {
            ingress: (0..n_ports).map(|_| VecDeque::new()).collect(),
            rr_next: 0,
            cmds_per_cycle: cmds_per_cycle.max(1),
            stats: RouterStats {
                per_port_forwarded: vec![0; n_ports],
                ..RouterStats::default()
            },
        }
    }

    pub fn n_ports(&self) -> usize {
        self.ingress.len()
    }

    /// Enqueue a request from port `req.port`.
    pub fn push(&mut self, req: MemReq) {
        debug_assert!(req.port < self.ingress.len());
        self.ingress[req.port].push_back(req);
    }

    /// Ingress occupancy of one port (for LMB backpressure decisions).
    pub fn port_depth(&self, port: usize) -> usize {
        self.ingress[port].len()
    }

    /// Forward up to `cmds_per_cycle` requests into the DRAM controller,
    /// round-robin across ports.
    pub fn tick(&mut self, dram: &mut Dram, now: Cycle) {
        let n = self.ingress.len();
        let mut forwarded = 0;
        let mut scanned = 0;
        while forwarded < self.cmds_per_cycle && scanned < n {
            let port = (self.rr_next + scanned) % n;
            if let Some(req) = self.ingress[port].front() {
                if !dram.can_accept() {
                    self.stats.backpressure_cycles += 1;
                    return;
                }
                let req = *req;
                self.ingress[port].pop_front();
                dram.push(req, now);
                self.stats.forwarded += 1;
                self.stats.per_port_forwarded[port] += 1;
                forwarded += 1;
                // Advance RR past the port we just served.
                self.rr_next = (port + 1) % n;
                scanned = 0;
                continue;
            }
            scanned += 1;
        }
    }

    /// Split DRAM completions back out by port (the data return path).
    pub fn route_completions(
        completions: Vec<MemResp>,
        per_port: &mut [Vec<MemResp>],
    ) {
        for resp in completions {
            per_port[resp.port].push(resp);
        }
    }

    pub fn is_idle(&self) -> bool {
        self.ingress.iter().all(VecDeque::is_empty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramConfig;

    fn req(id: u64, port: usize) -> MemReq {
        MemReq {
            id,
            addr: id * 64,
            bytes: 64,
            is_write: false,
            port,
        }
    }

    #[test]
    fn round_robin_fairness() {
        let mut r = Router::new(4, 1);
        let mut dram = Dram::new(&DramConfig::mig_u250());
        // Port 0 floods; ports 1-3 each submit one.
        for i in 0..8 {
            r.push(req(100 + i, 0));
        }
        for p in 1..4 {
            r.push(req(p as u64, p));
        }
        // After 4 cycles of arbitration every port got a turn.
        for c in 0..4 {
            r.tick(&mut dram, c);
        }
        assert_eq!(r.stats.forwarded, 4);
        for p in 0..4 {
            assert!(
                r.stats.per_port_forwarded[p] >= 1,
                "port {p} starved: {:?}",
                r.stats.per_port_forwarded
            );
        }
    }

    #[test]
    fn backpressure_when_dram_full() {
        let cfg = DramConfig {
            max_outstanding: 2,
            ..DramConfig::mig_u250()
        };
        let mut dram = Dram::new(&cfg);
        let mut r = Router::new(1, 1);
        for i in 0..4 {
            r.push(req(i, 0));
        }
        r.tick(&mut dram, 0);
        r.tick(&mut dram, 1);
        r.tick(&mut dram, 2); // controller full
        assert_eq!(r.stats.forwarded, 2);
        assert!(r.stats.backpressure_cycles >= 1);
        assert_eq!(r.port_depth(0), 2);
    }

    #[test]
    fn completion_routing_by_port() {
        let completions = vec![
            MemResp {
                id: 1,
                port: 0,
                done_at: 5,
            },
            MemResp {
                id: 2,
                port: 1,
                done_at: 6,
            },
            MemResp {
                id: 3,
                port: 0,
                done_at: 7,
            },
        ];
        let mut per_port = vec![Vec::new(), Vec::new()];
        Router::route_completions(completions, &mut per_port);
        assert_eq!(per_port[0].len(), 2);
        assert_eq!(per_port[1].len(), 1);
        assert_eq!(per_port[1][0].id, 2);
    }

    #[test]
    fn multi_cmd_router_forwards_more() {
        let mut r = Router::new(2, 2);
        let mut dram = Dram::new(&DramConfig::mig_u250());
        r.push(req(1, 0));
        r.push(req(2, 1));
        r.tick(&mut dram, 0);
        assert_eq!(r.stats.forwarded, 2);
        assert!(r.is_idle());
    }
}
