//! System composition + run loop (paper Fig. 1): PE front ends → LMBs
//! (or baseline paths) → request router → DRAM interface, simulated to
//! completion of the whole request stream.
//!
//! Full request lifecycle (see `docs/ARCHITECTURE.md` for the walkthrough):
//! address → LMB bank (cache/RR or DMA) → forward fabric → DRAM channel
//! → reply network (when [`crate::config::InterconnectConfig::reply_network`]
//! is on; combinational return otherwise) → LMB bank / direct map → PE
//! retire. The run loop below only ever sees ports — banking lives inside
//! [`Lmb`], the response path inside [`Fabric`].
//!
//! The four §V-B variants share every component model; they differ only
//! in how accesses are routed:
//!
//! | variant    | tensor elements      | fibers (loads/stores)    |
//! |------------|----------------------|--------------------------|
//! | proposed   | RR → cache           | DMA (n parallel buffers) |
//! | ip-only    | direct to controller | direct to controller     |
//! | cache-only | cache (+MSHR)        | cache, line-split (+MSHR); stores write-through |
//! | dma-only   | DMA (1-deep, garbage)| DMA (1-deep)             |
//!
//! # The two engines
//!
//! [`MemorySystem::run`] is the **event-driven engine** every driver
//! uses; [`MemorySystem::run_reference`] is the original poll-everything
//! loop, kept as the correctness oracle. Both execute the *same* loop
//! body (`run_impl`) over the *same* sequence of visited
//! cycles — the event engine only adds per-component **activity gates**,
//! each of which skips a step exactly when that step would be a provable
//! no-op (no state change *and* no statistics, stall counters included):
//!
//! * DRAM channels are only ticked when they have queued work or a
//!   completion due ([`super::dram::Dram::needs_tick`]);
//! * LMB housekeeping only visits LMBs with queued DMA transfers or
//!   blocked line retries ([`Lmb::needs_tick`]);
//! * fabric transport only runs while requests are resident in the
//!   fabric ([`super::Fabric::has_traffic`]);
//! * PE issue only visits front ends that could admit or issue an
//!   access ([`super::pe::PeFrontEnd::can_issue`]), and retirement
//!   returns in O(1) until the earliest compute-done cycle;
//! * the (pure) termination predicate is only re-evaluated on cycles
//!   where state changed.
//!
//! Timed events live in calendar queues — the `deliveries` and
//! `line_events` binary heaps plus each channel's tracked
//! earliest-completion / next-schedulable cycle — which both engines
//! already use to fast-forward over globally idle stretches
//! (`next_event_time`). Because stall statistics accrue
//! once per *visited* cycle, the visited-cycle sequence itself must not
//! change: the event engine therefore keeps the reference time-advance
//! rule verbatim and takes its ~order-of-magnitude host-time win purely
//! from not touching quiescent components while *other* components are
//! busy. `tests/integration_engine.rs` (and the in-module test below)
//! assert full [`SimReport`] equality between the engines across all
//! four variants, both fabric types and all three topologies.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::config::{FabricType, SystemConfig, SystemKind};
use crate::trace::{AccessClass, TraceSource};

use super::dram::IdGen;
use super::fabric::Fabric;
use super::lmb::{LineEvent, Lmb, LmbOutcome};
use super::pe::{pack_token, unpack_token, PeFrontEnd};
use super::stats::{PeAggStats, SimReport};
use super::telemetry::{Telemetry, TelemetryOutput, TimelineSnap};
use super::{Cycle, Delivery, MemReq, ReqId};

/// In-progress multi-part issue (cache-only fiber line splitting).
#[derive(Debug, Clone, Copy)]
struct PartialIssue {
    slot: usize,
    acc: usize,
    next_addr: u64,
    end_addr: u64,
    is_store: bool,
}

/// Outstanding direct-to-controller requests: request id → PE token.
///
/// The live set is tiny — bounded by the direct-issue limit (ip-only)
/// or the controller/port queue depths (cache-only stores) — and ids
/// are minted monotonically, so an insertion-ordered vec with binary
/// search beats a `HashMap`: no hashing, no per-entry allocation, and
/// removal is a short shift.
#[derive(Debug, Default)]
struct DirectMap {
    entries: Vec<(ReqId, u64)>,
}

impl DirectMap {
    fn insert(&mut self, id: ReqId, token: u64) {
        debug_assert!(
            match self.entries.last() {
                Some(&(last, _)) => last < id,
                None => true,
            },
            "request ids must be inserted in mint order"
        );
        self.entries.push((id, token));
    }

    fn remove(&mut self, id: ReqId) -> Option<u64> {
        let i = self.entries.binary_search_by_key(&id, |&(k, _)| k).ok()?;
        Some(self.entries.remove(i).1)
    }

    fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The composed memory system under simulation.
pub struct MemorySystem {
    cfg: SystemConfig,
    /// Interconnect fabric + the DRAM channels behind it.
    fabric: Fabric,
    lmbs: Vec<Lmb>,
    pes: Vec<PeFrontEnd>,
    partials: Vec<Option<PartialIssue>>,
    ids: IdGen,
    /// Requests issued directly to the controller (ip-only; cache-only
    /// stores).
    direct: DirectMap,
    /// (ready_at, token) — PE access parts with known completion times.
    deliveries: BinaryHeap<Reverse<(Cycle, u64)>>,
    /// (at, lmb, line) — cache lines en route to a Request Reductor.
    line_events: BinaryHeap<Reverse<(Cycle, usize, u64)>>,
    /// Max ingress depth per router port before LMBs hold requests.
    port_cap: usize,
    /// Outstanding direct requests per port (ip-only decoupling limit).
    direct_outstanding: Vec<usize>,
    /// Running total of `direct_outstanding` (the ip-only limit check
    /// runs per issued access — no per-access port scan).
    direct_total: usize,
    direct_limit: usize,
    accesses_served: u64,
    requested_bytes: u64,
    /// Reusable sinks for the allocation-free component APIs.
    scratch_events: Vec<LineEvent>,
    scratch_deliveries: Vec<Delivery>,
    /// Observation-only telemetry collector (`cfg.telemetry`; every hook
    /// is a single branch when off).
    telemetry: Telemetry,
    /// Bank + RR outcome of the last dispatched element load, staged for
    /// the access span (set only while tracing).
    elem_probe: Option<(usize, &'static str)>,
}

impl MemorySystem {
    /// Build a system for `cfg` and attach one PE front end per source
    /// stream. Any [`TraceSource`] plugs in here — the materialized
    /// [`Workload`](crate::trace::Workload) oracle, a lazy
    /// `CooStreamSource`, or a `TnsStreamSource` reading straight from
    /// disk; report-identity across them is a hard invariant.
    pub fn new<S: TraceSource + ?Sized>(cfg: &SystemConfig, source: &S) -> MemorySystem {
        cfg.validate().expect("invalid system config");
        let n_fronts = source.n_streams();
        // Port topology: ip-only gives each front end its own controller
        // port; the LMB variants use one port per LMB.
        let n_ports = match cfg.kind {
            SystemKind::IpOnly => n_fronts,
            _ => cfg.n_lmbs,
        };
        let lmbs = match cfg.kind {
            SystemKind::IpOnly => Vec::new(),
            _ => (0..cfg.n_lmbs).map(|i| Lmb::new(cfg, i)).collect(),
        };
        let pes = (0..n_fronts)
            .map(|s| {
                let pe = source.stream_pe(s);
                let port = match cfg.kind {
                    SystemKind::IpOnly => pe % n_ports,
                    _ => pe % cfg.n_lmbs,
                };
                // Type-1's single front end stands for the whole fabric:
                // give it the aggregate window and issue width.
                let (window, width) = match source.fabric() {
                    FabricType::Type1 => (
                        cfg.pe.max_inflight * cfg.pe.n_pes,
                        3, // shared TLU + MLU + MSU issue in parallel
                    ),
                    FabricType::Type2 => (cfg.pe.max_inflight, 2),
                };
                PeFrontEnd::new(
                    pe,
                    source.stream_len(s),
                    source.open(s),
                    port,
                    window,
                    width,
                    cfg.pe.compute_cycles_per_nnz,
                )
            })
            .collect::<Vec<_>>();
        let n_pes = pes.len();
        MemorySystem {
            fabric: Fabric::new(n_ports, &cfg.interconnect, &cfg.dram),
            lmbs,
            pes,
            partials: vec![None; n_pes],
            ids: IdGen::default(),
            direct: DirectMap::default(),
            deliveries: BinaryHeap::new(),
            line_events: BinaryHeap::new(),
            port_cap: 16,
            direct_outstanding: vec![0; n_ports],
            direct_total: 0,
            // Naive direct connection: the commercial IP exposes a single
            // command interface; a simple fabric-side master keeps only a
            // handful of reads outstanding (no reordering, no coalescing).
            // Type-2's independent per-PE masters squeeze out a little
            // more MLP than Type-1's three shared units, but the limit is
            // GLOBAL — they all share the one controller interface.
            direct_limit: match source.fabric() {
                FabricType::Type1 => 5,
                FabricType::Type2 => 7,
            },
            accesses_served: 0,
            requested_bytes: 0,
            scratch_events: Vec::new(),
            scratch_deliveries: Vec::new(),
            telemetry: Telemetry::new(cfg),
            elem_probe: None,
            cfg: cfg.clone(),
        }
    }

    /// Drain the telemetry recorded by the last run (`workload` labels
    /// the trace metadata). Empty output unless `cfg.telemetry` enabled
    /// a product.
    pub fn take_telemetry(&mut self, workload: &str) -> TelemetryOutput {
        self.telemetry.take_output(workload)
    }

    /// Run to completion with the event-driven engine; returns the
    /// report. Report-identical to [`MemorySystem::run_reference`]
    /// (modulo `host_seconds`), only faster.
    pub fn run(&mut self, workload_name: &str) -> SimReport {
        self.run_impl(workload_name, true)
    }

    /// Run to completion with the original poll-everything loop — the
    /// correctness oracle the event-driven engine is checked against.
    pub fn run_reference(&mut self, workload_name: &str) -> SimReport {
        self.run_impl(workload_name, false)
    }

    /// The shared loop body. `event_driven` enables the activity gates;
    /// with it false every component is polled on every visited cycle
    /// (the seed behavior). Each gate must only ever skip a provable
    /// no-op — see the module docs for the per-gate argument.
    fn run_impl(&mut self, workload_name: &str, event_driven: bool) -> SimReport {
        let host_t0 = Instant::now();
        let mut now: Cycle = 0;
        let total_accesses: u64 = self
            .pes
            .iter()
            .map(|p| p.total_work() as u64 * 4)
            .sum::<u64>();
        // Generous deadlock watchdog (saturating: scaled-up workloads
        // must clamp at u64::MAX rather than wrap to a tiny bound).
        let watchdog = total_accesses.saturating_mul(2_000).saturating_add(10_000_000);
        let mut completions = Vec::new();
        let mut line_evs = Vec::new();
        loop {
            let mut progress = false;

            // 1. DRAM completions (all channels with schedulable or due
            //    work; channel order — hence completion order — is the
            //    same in both engines). With the reply network on these
            //    are the replies whose fabric traversal finished, their
            //    done_at rewritten to the delivery cycle.
            completions.clear();
            if event_driven {
                self.fabric.tick_memory_gated_traced(now, &mut completions, &mut self.telemetry);
            } else {
                self.fabric.tick_memory_traced(now, &mut completions, &mut self.telemetry);
            }
            for resp in completions.drain(..) {
                progress = true;
                self.telemetry.mem_complete(resp.id, resp.done_at);
                if let Some(token) = self.direct.remove(resp.id) {
                    self.direct_outstanding[resp.port] -= 1;
                    self.direct_total -= 1;
                    self.deliveries.push(Reverse((resp.done_at + 1, token)));
                    continue;
                }
                line_evs.clear();
                self.scratch_deliveries.clear();
                self.lmbs[resp.port].on_dram_completion(
                    resp.id,
                    resp.done_at,
                    &mut line_evs,
                    &mut self.scratch_deliveries,
                );
                for d in self.scratch_deliveries.drain(..) {
                    self.deliveries.push(Reverse((d.at, d.token)));
                }
                for ev in line_evs.drain(..) {
                    self.line_events.push(Reverse((ev.at, ev.lmb, ev.line)));
                }
            }

            // 2. Cache lines reaching their RR.
            while let Some(&Reverse((at, lmb, line))) = self.line_events.peek() {
                if at > now {
                    break;
                }
                self.line_events.pop();
                progress = true;
                self.scratch_deliveries.clear();
                self.lmbs[lmb].line_ready_into(line, at, &mut self.scratch_deliveries);
                for d in self.scratch_deliveries.drain(..) {
                    self.deliveries.push(Reverse((d.at, d.token)));
                }
            }

            // 3. PE access-part completions.
            while let Some(&Reverse((at, token))) = self.deliveries.peek() {
                if at > now {
                    break;
                }
                self.deliveries.pop();
                progress = true;
                let (pe, slot, acc) = unpack_token(token);
                if self.pes[pe].part_done(slot, acc, at.max(now)) {
                    self.accesses_served += 1;
                    self.telemetry.access_done(token, at.max(now));
                }
            }

            // 4. LMB housekeeping (DMA buffer fills, blocked-line
            //    retries) — only LMBs with pending housekeeping work.
            line_evs.clear();
            for lmb in &mut self.lmbs {
                if event_driven && !lmb.needs_tick() {
                    continue;
                }
                lmb.tick(now, &mut self.ids, &mut line_evs);
            }
            for ev in line_evs.drain(..) {
                self.line_events.push(Reverse((ev.at, ev.lmb, ev.line)));
            }

            // 5. LMB outboxes → fabric (bounded ingress per port). The
            //    `has_requests` loop condition is itself the activity
            //    test — idle LMBs cost one boolean check.
            for li in 0..self.lmbs.len() {
                while self.lmbs[li].has_requests()
                    && self.fabric.port_depth(li) < self.port_cap
                {
                    let req = self.lmbs[li].pop_request().unwrap();
                    self.telemetry.mem_enqueued(req.id, req.port, now);
                    self.fabric.push(req);
                    progress = true;
                }
            }

            // 6. Fabric transport: egress into the channel controllers +
            //    one store-and-forward hop per link — skipped outright
            //    while no request is resident in the fabric.
            if !event_driven || self.fabric.has_traffic() {
                progress |= self.fabric.route_traced(now, &mut self.telemetry);
            }

            // 7. PE issue + retire — only front ends that could issue
            //    (pending access, admittable work, or an open line-split
            //    partial); stalled heads stay "issuable" so their
            //    per-visited-cycle retry cadence — and thus every stall
            //    counter — matches the reference loop exactly.
            for pe_idx in 0..self.pes.len() {
                let issuable = !event_driven
                    || self.partials[pe_idx].is_some()
                    || self.pes[pe_idx].can_issue();
                if issuable && self.issue_pe(pe_idx, now) {
                    progress = true;
                }
                let n_retired = self.pes[pe_idx].retire(now);
                if n_retired > 0 {
                    progress = true;
                    self.telemetry.retired(self.pes[pe_idx].pe, n_retired, now);
                }
            }

            // 7b. Telemetry timeline: record one row per elapsed window
            //     (observation only — reads counters, mutates nothing).
            if self.telemetry.timeline_due(now) {
                let snap = self.timeline_snap();
                self.telemetry.timeline_record(now, snap);
            }

            // 8. Termination. `finished` is a pure state predicate and
            //    every completing transition sets `progress`, so the
            //    event engine only re-evaluates it when state changed.
            if (!event_driven || progress || now == 0) && self.finished() {
                break;
            }

            // 9. Advance time — identical in both engines (the visited-
            //    cycle sequence is part of the observable behavior):
            //    next cycle on progress, else jump to the next scheduled
            //    event (DRAM completion, delivery, line event, the next
            //    time a queued DRAM request can issue, or — line/ring —
            //    the next fabric hop).
            if progress {
                now += 1;
            } else {
                match self.next_event_time(now) {
                    Some(c) if c > now => now = c,
                    // Nothing scheduled but not finished → structural
                    // stall that resolves on retry next cycle.
                    _ => now += 1,
                }
            }
            assert!(
                now < watchdog,
                "simulation deadlock: cycle {now}, {} accesses served of {}",
                self.accesses_served,
                total_accesses
            );
        }

        // Final timeline row at the makespan cycle (idempotent — cannot
        // duplicate a row already taken at `now`).
        if self.telemetry.timelining() {
            let snap = self.timeline_snap();
            self.telemetry.timeline_record(now, snap);
        }

        let mut latency: [crate::sim::pe::LatencyStats; 4] = Default::default();
        let mut pe_agg = PeAggStats::default();
        for front in &self.pes {
            for (agg, l) in latency.iter_mut().zip(&front.stats.latency) {
                agg.merge(l);
            }
            pe_agg.retired += front.stats.retired;
            pe_agg.issued_accesses += front.stats.issued_accesses;
            pe_agg.stall_cycles += front.stats.stall_cycles;
        }
        SimReport {
            label: self.cfg.label.clone(),
            workload: workload_name.to_string(),
            latency,
            pe: pe_agg,
            total_cycles: now,
            nnz: self.pes.iter().map(|p| p.total_work() as u64).sum(),
            accesses: self.accesses_served,
            requested_bytes: self.requested_bytes,
            dram: self.fabric.aggregate_dram_stats(),
            channels: self.fabric.channel_stats(),
            fabric: self.fabric.stats.clone(),
            link_width: self.fabric.link_width(),
            lmbs: self.lmbs.iter().map(Lmb::stats).collect(),
            host_seconds: host_t0.elapsed().as_secs_f64(),
        }
    }

    /// Cumulative-counter snapshot for one telemetry timeline row
    /// (read-only; runs once per elapsed window, never on the hot path).
    fn timeline_snap(&self) -> TimelineSnap {
        let channels = self.fabric.channel_stats();
        let mut snap = TimelineSnap {
            channel_occupancy: self.fabric.channel_occupancy(),
            channel_reads: channels.iter().map(|c| c.reads).collect(),
            channel_writes: channels.iter().map(|c| c.writes).collect(),
            channel_busy_bus: channels.iter().map(|c| c.busy_bus_cycles).collect(),
            fabric_forwarded: self.fabric.stats.forwarded,
            fabric_backpressure: self.fabric.stats.backpressure_cycles,
            fabric_hops: self.fabric.stats.hops,
            link_forwarded: self.fabric.stats.links.iter().map(|l| l.forwarded).collect(),
            reply_delivered: self.fabric.stats.reply.delivered,
            ingress_depths: (0..self.fabric.n_ports())
                .map(|p| self.fabric.port_depth(p) as u64)
                .collect(),
            pending_deliveries: self.deliveries.len() as u64,
            pending_line_events: self.line_events.len() as u64,
            ..TimelineSnap::default()
        };
        for lmb in &self.lmbs {
            let s = lmb.stats();
            snap.lmb_hits.push(s.cache.hits);
            snap.lmb_misses.push(s.cache.primary_misses);
            snap.rr_served.push(s.rr.served_temp);
            snap.rr_absorbed.push(s.rr.absorbed);
            snap.rr_forwarded.push(s.rr.forwarded);
        }
        for pe in &self.pes {
            snap.pe_retired += pe.stats.retired;
            snap.pe_issued += pe.stats.issued_accesses;
            snap.pe_stalls += pe.stats.stall_cycles;
        }
        snap
    }

    /// Earliest future cycle anything is scheduled to happen — the fold
    /// over the event calendar both engines use to fast-forward across
    /// globally idle stretches.
    fn next_event_time(&self, now: Cycle) -> Option<Cycle> {
        [
            self.deliveries.peek().map(|Reverse((c, _))| *c),
            self.line_events.peek().map(|Reverse((c, _, _))| *c),
            self.fabric.next_completion(),
            self.fabric.next_schedule_time(now),
            self.fabric.next_transit_time(now),
        ]
        .into_iter()
        .flatten()
        .min()
    }

    fn finished(&self) -> bool {
        self.pes.iter().all(PeFrontEnd::done)
            && self.fabric.is_idle()
            && self.deliveries.is_empty()
            && self.line_events.is_empty()
            && self.lmbs.iter().all(Lmb::quiescent)
            && self.direct.is_empty()
    }

    /// Issue up to `issue_width` access (parts) for one PE. Returns true
    /// if anything was issued.
    fn issue_pe(&mut self, pe_idx: usize, now: Cycle) -> bool {
        self.pes[pe_idx].fill_window();
        let width = self.pes[pe_idx].issue_width;
        let mut issued_any = false;
        let mut budget = width;
        while budget > 0 {
            // Continue a partial (line-split) issue first.
            if let Some(p) = self.partials[pe_idx] {
                match self.issue_partial(pe_idx, p, now) {
                    IssueStep::Advanced => {
                        issued_any = true;
                        budget -= 1;
                        continue;
                    }
                    IssueStep::Stalled => break,
                    IssueStep::Done => {
                        self.partials[pe_idx] = None;
                        continue;
                    }
                }
            }
            let Some((slot, acc, access)) = self.pes[pe_idx].next_unissued() else {
                break;
            };
            let token = pack_token(self.pes[pe_idx].pe, slot, acc);
            self.requested_bytes += access.bytes as u64;
            let outcome = self.dispatch(pe_idx, slot, acc, access, token, now);
            let probe = self.elem_probe.take();
            match outcome {
                DispatchResult::Issued { parts } => {
                    self.pes[pe_idx].mark_issued_at(slot, acc, parts, now);
                    self.telemetry.access_issued(token, acc, now);
                    if let Some((bank, rr)) = probe {
                        self.telemetry.access_probe(token, bank, rr);
                    }
                    issued_any = true;
                    budget -= 1;
                }
                DispatchResult::Split => {
                    // mark_issued already done inside dispatch (cache-only
                    // fibers); the partial continues next loop turn.
                    self.telemetry.access_issued(token, acc, now);
                    issued_any = true;
                    budget -= 1;
                }
                DispatchResult::Stall => {
                    self.requested_bytes -= access.bytes as u64;
                    self.pes[pe_idx].stats.stall_cycles += 1;
                    break; // head-of-line: wait for the hazard to clear
                }
            }
        }
        issued_any
    }

    /// Route one access according to the system variant.
    fn dispatch(
        &mut self,
        pe_idx: usize,
        slot: usize,
        acc: usize,
        access: crate::trace::Access,
        token: u64,
        now: Cycle,
    ) -> DispatchResult {
        let port = self.pes[pe_idx].port;
        match self.cfg.kind {
            SystemKind::Proposed => match access.class {
                AccessClass::TensorElem => {
                    self.scratch_events.clear();
                    let (r, bank, rr) = self.lmbs[port].element_load_probed(
                        access.addr,
                        token,
                        now,
                        &mut self.ids,
                        &mut self.scratch_events,
                    );
                    if self.telemetry.tracing() {
                        self.elem_probe = Some((bank, rr));
                    }
                    for ev in self.scratch_events.drain(..) {
                        self.line_events.push(Reverse((ev.at, ev.lmb, ev.line)));
                    }
                    self.outcome_to_result(r, token, 1)
                }
                AccessClass::FiberLoad | AccessClass::FiberStore => {
                    let r = self.lmbs[port].dma_transfer(
                        access.addr,
                        access.bytes,
                        token,
                        access.class.is_write(),
                    );
                    self.outcome_to_result(r, token, 1)
                }
            },
            SystemKind::DmaOnly => {
                // Everything via DMA, garbage and serialization included.
                let r = self.lmbs[port].dma_transfer(
                    access.addr,
                    access.bytes,
                    token,
                    access.class.is_write(),
                );
                self.outcome_to_result(r, token, 1)
            }
            SystemKind::CacheOnly => match access.class {
                AccessClass::FiberStore => {
                    // Write-through, no allocate.
                    let id =
                        self.lmbs[port].store_through(access.addr, access.bytes, &mut self.ids);
                    self.direct.insert(id, token);
                    self.direct_outstanding[port] += 1;
                    self.direct_total += 1;
                    DispatchResult::Issued { parts: 1 }
                }
                _ => {
                    // Loads split into cache lines; first line issued now,
                    // the rest via the partial mechanism.
                    let line_bytes = self.cfg.cache.line_bytes();
                    let start = access.addr - access.addr % line_bytes;
                    let end = crate::util::round_up(access.addr + access.bytes as u64, line_bytes);
                    let parts = ((end - start) / line_bytes) as u16;
                    self.pes[pe_idx].mark_issued_at(slot, acc, parts, now);
                    self.partials[pe_idx] = Some(PartialIssue {
                        slot,
                        acc,
                        next_addr: start,
                        end_addr: end,
                        is_store: false,
                    });
                    DispatchResult::Split
                }
            },
            SystemKind::IpOnly => {
                // Naive direct connection: full-width transfers, few
                // outstanding per port (the limit is maintained as a
                // running total — no per-access port scan).
                if self.direct_total >= self.direct_limit
                    || self.fabric.port_depth(port) >= self.port_cap
                {
                    return DispatchResult::Stall;
                }
                let beat = self.cfg.dram.beat_bytes();
                let start = access.addr - access.addr % beat;
                let end = crate::util::round_up(access.addr + access.bytes as u64, beat);
                let id = self.ids.next();
                self.telemetry.mem_enqueued(id, port, now);
                self.fabric.push(MemReq {
                    id,
                    addr: start,
                    bytes: (end - start) as u32,
                    is_write: access.class.is_write(),
                    port,
                });
                self.direct.insert(id, token);
                self.direct_outstanding[port] += 1;
                self.direct_total += 1;
                DispatchResult::Issued { parts: 1 }
            }
        }
    }

    fn outcome_to_result(&mut self, r: LmbOutcome, token: u64, parts: u16) -> DispatchResult {
        match r {
            LmbOutcome::Ready { at } => {
                self.deliveries.push(Reverse((at, token)));
                DispatchResult::Issued { parts }
            }
            LmbOutcome::Pending => DispatchResult::Issued { parts },
            LmbOutcome::Stall => DispatchResult::Stall,
        }
    }

    /// Issue the next line of a split (cache-only) access.
    fn issue_partial(&mut self, pe_idx: usize, p: PartialIssue, now: Cycle) -> IssueStep {
        if p.next_addr >= p.end_addr {
            return IssueStep::Done;
        }
        let port = self.pes[pe_idx].port;
        let token = pack_token(self.pes[pe_idx].pe, p.slot, p.acc);
        debug_assert!(!p.is_store);
        match self.lmbs[port].cache_load_direct(p.next_addr, token, now, &mut self.ids) {
            LmbOutcome::Ready { at } => {
                self.deliveries.push(Reverse((at, token)));
            }
            LmbOutcome::Pending => {}
            LmbOutcome::Stall => return IssueStep::Stalled,
        }
        let line_bytes = self.cfg.cache.line_bytes();
        self.partials[pe_idx] = Some(PartialIssue {
            next_addr: p.next_addr + line_bytes,
            ..p
        });
        IssueStep::Advanced
    }
}

enum DispatchResult {
    Issued { parts: u16 },
    Split,
    Stall,
}

enum IssueStep {
    Advanced,
    Stalled,
    Done,
}

/// Convenience: build + run in one call (event-driven engine). Accepts
/// any [`TraceSource`] — materialized workload or streaming.
pub fn simulate<S: TraceSource + ?Sized>(cfg: &SystemConfig, source: &S) -> SimReport {
    let name = source.name().to_string();
    MemorySystem::new(cfg, source).run(&name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{CooTensor, Mode};
    use crate::trace::{workload_from_tensor, Workload};
    use crate::util::rng::Rng;

    fn small_workload(fabric: FabricType, n_pes: usize) -> Workload {
        // Hyper-sparse like the paper's Table III tensors: J and K are
        // much larger than the cache, so factor fibers have no temporal
        // locality (the regime the LMB design targets).
        let mut rng = Rng::new(90);
        let t = CooTensor::random(&mut rng, [96, 40_000, 60_000], 3000);
        workload_from_tensor(&t, Mode::I, fabric, n_pes, 32, 8192)
    }

    fn cfg_for(kind: SystemKind, fabric: FabricType) -> SystemConfig {
        let mut c = match fabric {
            FabricType::Type1 => SystemConfig::config_a(),
            FabricType::Type2 => SystemConfig::config_b(),
        };
        c = c.as_baseline(kind);
        if kind == SystemKind::Proposed {
            c.label = c.label.replace("-proposed", "");
        }
        c
    }

    #[test]
    fn all_variants_complete_and_serve_every_access_type2() {
        let w = small_workload(FabricType::Type2, 4);
        let expected: u64 = w
            .pe_traces
            .iter()
            .map(|p| p.n_accesses() as u64)
            .sum();
        for kind in SystemKind::ALL {
            let cfg = cfg_for(kind, FabricType::Type2);
            let report = simulate(&cfg, &w);
            assert_eq!(
                report.accesses, expected,
                "{:?} lost accesses",
                kind
            );
            assert!(report.total_cycles > 0);
        }
    }

    #[test]
    fn all_variants_complete_type1() {
        let w = small_workload(FabricType::Type1, 4);
        for kind in SystemKind::ALL {
            let cfg = cfg_for(kind, FabricType::Type1);
            let report = simulate(&cfg, &w);
            assert!(report.total_cycles > 0, "{kind:?} did not run");
            assert_eq!(report.nnz, w.nnz as u64);
        }
    }

    #[test]
    fn event_engine_is_report_identical_to_reference_loop() {
        for fabric in [FabricType::Type1, FabricType::Type2] {
            let w = small_workload(fabric, 4);
            for kind in SystemKind::ALL {
                let cfg = cfg_for(kind, fabric);
                let event = MemorySystem::new(&cfg, &w).run(&w.name);
                let reference = MemorySystem::new(&cfg, &w).run_reference(&w.name);
                assert_eq!(
                    event.diff(&reference),
                    None,
                    "{fabric:?}/{kind:?}: engines diverged"
                );
            }
        }
    }

    #[test]
    fn banked_lmbs_with_reply_network_complete_and_agree_across_engines() {
        let w = small_workload(FabricType::Type2, 4);
        let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
        let mut cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        cfg.lmb_banks = 2;
        cfg.interconnect.channels = 2;
        cfg.interconnect.reply_network = true;
        cfg.validate().unwrap();
        let event = MemorySystem::new(&cfg, &w).run(&w.name);
        let reference = MemorySystem::new(&cfg, &w).run_reference(&w.name);
        assert_eq!(event.diff(&reference), None, "banked+reply engines diverged");
        assert_eq!(event.accesses, expected);
        // Reply traffic is real: one delivery per DRAM transaction.
        assert_eq!(
            event.fabric.reply.delivered,
            event.dram.reads + event.dram.writes
        );
        // Both banks of every LMB saw element traffic.
        for l in &event.lmbs {
            assert_eq!(l.banks.len(), 2);
            for (b, s) in l.banks.iter().enumerate() {
                assert!(s.rr.forwarded > 0, "bank {b} idle");
            }
        }
    }

    #[test]
    fn reply_network_never_makes_the_system_faster() {
        let w = small_workload(FabricType::Type2, 4);
        let base = cfg_for(SystemKind::Proposed, FabricType::Type2);
        let free = simulate(&base, &w);
        let mut modeled_cfg = base.clone();
        modeled_cfg.interconnect.reply_network = true;
        let modeled = simulate(&modeled_cfg, &w);
        assert!(
            modeled.total_cycles >= free.total_cycles,
            "modeling the return path cannot speed things up: {} < {}",
            modeled.total_cycles,
            free.total_cycles
        );
        assert_eq!(modeled.accesses, free.accesses);
    }

    #[test]
    fn proposed_beats_ip_only() {
        let w = small_workload(FabricType::Type2, 4);
        let prop = simulate(&cfg_for(SystemKind::Proposed, FabricType::Type2), &w);
        let ip = simulate(&cfg_for(SystemKind::IpOnly, FabricType::Type2), &w);
        let speedup = prop.speedup_over(&ip);
        assert!(
            speedup > 1.5,
            "proposed should clearly beat ip-only, got {speedup:.2}×"
        );
    }

    #[test]
    fn proposed_beats_cache_only_and_dma_only() {
        let w = small_workload(FabricType::Type2, 4);
        let prop = simulate(&cfg_for(SystemKind::Proposed, FabricType::Type2), &w);
        let cache = simulate(&cfg_for(SystemKind::CacheOnly, FabricType::Type2), &w);
        let dma = simulate(&cfg_for(SystemKind::DmaOnly, FabricType::Type2), &w);
        assert!(
            prop.total_cycles < cache.total_cycles,
            "proposed {} !< cache-only {}",
            prop.total_cycles,
            cache.total_cycles
        );
        assert!(
            prop.total_cycles < dma.total_cycles,
            "proposed {} !< dma-only {}",
            prop.total_cycles,
            dma.total_cycles
        );
    }

    #[test]
    fn dram_traffic_accounting_is_consistent() {
        let w = small_workload(FabricType::Type2, 4);
        let cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        let r = simulate(&cfg, &w);
        // DRAM moved at least the requested payload (alignment can only
        // add bytes) and cache reuse can only remove element re-reads.
        assert!(r.dram.read_bytes + r.dram.write_bytes > 0);
        // Stores: every output fiber goes to memory exactly once.
        let store_bytes: u64 = w
            .pe_traces
            .iter()
            .flat_map(|p| &p.work)
            .filter_map(|x| x.store.map(|s| s.bytes as u64))
            .sum();
        assert!(r.dram.write_bytes >= store_bytes);
    }

    #[test]
    fn cache_hit_rate_is_high_for_element_stream() {
        let w = small_workload(FabricType::Type2, 4);
        let cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        let r = simulate(&cfg, &w);
        // RRSH + temp buffer absorb most element traffic; what reaches
        // the cache is mostly unique lines, but RR-level reuse must be
        // visible in the report.
        let rr_served: u64 = r
            .lmbs
            .iter()
            .map(|l| l.rr.served_temp + l.rr.absorbed)
            .sum();
        assert!(
            rr_served > 0,
            "request reductor should absorb element reuse"
        );
    }
}
