//! System composition + run loop (paper Fig. 1): PE front ends → LMBs
//! (or baseline paths) → request router → DRAM interface, simulated to
//! completion of the whole request stream.
//!
//! Full request lifecycle (see `docs/ARCHITECTURE.md` for the walkthrough):
//! address → LMB bank (cache/RR or DMA) → forward fabric → DRAM channel
//! → reply network (when [`crate::config::InterconnectConfig::reply_network`]
//! is on; combinational return otherwise) → LMB bank / direct map → PE
//! retire. The run loop below only ever sees ports — banking lives inside
//! [`Lmb`], the response path inside [`Fabric`].
//!
//! The four §V-B variants share every component model; they differ only
//! in how accesses are routed:
//!
//! | variant    | tensor elements      | fibers (loads/stores)    |
//! |------------|----------------------|--------------------------|
//! | proposed   | RR → cache           | DMA (n parallel buffers) |
//! | ip-only    | direct to controller | direct to controller     |
//! | cache-only | cache (+MSHR)        | cache, line-split (+MSHR); stores write-through |
//! | dma-only   | DMA (1-deep, garbage)| DMA (1-deep)             |
//!
//! # The two engines
//!
//! [`MemorySystem::run`] is the **event-driven engine** every driver
//! uses; [`MemorySystem::run_reference`] is the original poll-everything
//! loop, kept as the correctness oracle. Both execute the *same* loop
//! body (`run_impl`); the event engine layers on three mechanisms, each
//! of which may only elide or reorder a provable no-op:
//!
//! * **Activity gates** — per-component skips of steps that would
//!   change no state and no statistic: DRAM channels without queued or
//!   due work ([`super::dram::Dram::needs_tick`]), LMBs with no
//!   housekeeping ([`Lmb::needs_tick`]), an empty fabric
//!   ([`super::Fabric::has_traffic`]), front ends that could not admit
//!   ([`super::pe::PeFrontEnd::needs_fill`]) or issue
//!   ([`super::pe::PeFrontEnd::can_issue`]), and a termination
//!   predicate only re-evaluated on cycles where state changed.
//!
//! * **Skip-ahead** — instead of stepping `now + 1`, jump straight to
//!   the earliest calendar entry (delivery / line-event heap heads,
//!   DRAM earliest-completion and next-schedulable cycles, fabric
//!   transit, PE earliest-retire) unless some component is *primed* to
//!   act on the very next cycle (`wants_next_cycle`): resident fabric
//!   traffic, LMB housekeeping or queued requests, an open line-split
//!   partial, a front end with issuable work, or a head in a *sticky*
//!   stall. Sticky stalls (every LMB path: RR probe clocks, cache
//!   LRU/blocked counters, DMA queue-stall counters) mutate state on
//!   each retry, so the engine revisits every cycle while one is open
//!   — exactly like the reference loop; pure stalls (the ip-only limit
//!   checks) mutate nothing and are skippable. Stall time itself is
//!   accounted as episode durations
//!   ([`super::pe::PeFrontEnd::stall_since`]: first-stall cycle to
//!   dispatch cycle), which both engines compute identically because
//!   episode endpoints are mutation cycles both always visit. Timeline
//!   telemetry stays byte-identical because the advance step records a
//!   row for every window boundary a jump crosses, stamped at the
//!   boundary with the pre-jump counters — nothing can change inside a
//!   jumped stretch, or the jump would have been invalid.
//!
//! * **Sharded ticking** (`sim_threads > 1`, [`super::parallel`]) —
//!   DRAM-channel ticks and PE window fill/retire run on scoped worker
//!   threads, synchronized at a per-visited-cycle barrier and merged
//!   in component index order, which reproduces the serial engine's
//!   completion and telemetry order bit-for-bit. Request-id minting
//!   (LMB ticks), PE issue (shared direct-issue budget, shared
//!   LMB/fabric queues) and fabric routing stay on the coordinating
//!   thread — their order *is* observable behavior.
//!
//! `tests/integration_engine.rs` (and the in-module tests below) assert
//! full [`SimReport`] equality between the engines — and across thread
//! counts, telemetry artifacts included — over all four variants, both
//! fabric types and all three topologies.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::config::{FabricType, SystemConfig, SystemKind};
use crate::trace::{AccessClass, TraceSource};

use super::dram::{DramChannel, IdGen};
use super::fabric::Fabric;
use super::lmb::{LineEvent, Lmb, LmbOutcome};
use super::parallel::{run_task, shard_round_robin, worker_loop, ShardDone, ShardPool, ShardTask};
use super::pe::{pack_token, unpack_token, PeFrontEnd};
use super::stats::{PeAggStats, SimReport};
use super::telemetry::{Telemetry, TelemetryOutput, TimelineSnap};
use super::{Cycle, Delivery, MemReq, MemResp, ReqId};

/// In-progress multi-part issue (cache-only fiber line splitting).
#[derive(Debug, Clone, Copy)]
struct PartialIssue {
    slot: usize,
    acc: usize,
    next_addr: u64,
    end_addr: u64,
    is_store: bool,
}

/// Outstanding direct-to-controller requests: request id → PE token.
///
/// The live set is tiny — bounded by the direct-issue limit (ip-only)
/// or the controller/port queue depths (cache-only stores) — and ids
/// are minted monotonically, so an insertion-ordered vec with binary
/// search beats a `HashMap`: no hashing, no per-entry allocation, and
/// removal is a short shift.
#[derive(Debug, Default)]
struct DirectMap {
    entries: Vec<(ReqId, u64)>,
}

impl DirectMap {
    fn insert(&mut self, id: ReqId, token: u64) {
        debug_assert!(
            match self.entries.last() {
                Some(&(last, _)) => last < id,
                None => true,
            },
            "request ids must be inserted in mint order"
        );
        self.entries.push((id, token));
    }

    fn remove(&mut self, id: ReqId) -> Option<u64> {
        let i = self.entries.binary_search_by_key(&id, |&(k, _)| k).ok()?;
        Some(self.entries.remove(i).1)
    }

    fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The composed memory system under simulation.
pub struct MemorySystem {
    cfg: SystemConfig,
    /// Interconnect fabric + the DRAM channels behind it.
    fabric: Fabric,
    lmbs: Vec<Lmb>,
    pes: Vec<PeFrontEnd>,
    partials: Vec<Option<PartialIssue>>,
    ids: IdGen,
    /// Requests issued directly to the controller (ip-only; cache-only
    /// stores).
    direct: DirectMap,
    /// (ready_at, token) — PE access parts with known completion times.
    deliveries: BinaryHeap<Reverse<(Cycle, u64)>>,
    /// (at, lmb, line) — cache lines en route to a Request Reductor.
    line_events: BinaryHeap<Reverse<(Cycle, usize, u64)>>,
    /// Max ingress depth per router port before LMBs hold requests.
    port_cap: usize,
    /// Outstanding direct requests per port (ip-only decoupling limit).
    direct_outstanding: Vec<usize>,
    /// Running total of `direct_outstanding` (the ip-only limit check
    /// runs per issued access — no per-access port scan).
    direct_total: usize,
    direct_limit: usize,
    accesses_served: u64,
    requested_bytes: u64,
    /// Reusable sinks for the allocation-free component APIs.
    scratch_events: Vec<LineEvent>,
    scratch_deliveries: Vec<Delivery>,
    /// Observation-only telemetry collector (`cfg.telemetry`; every hook
    /// is a single branch when off).
    telemetry: Telemetry,
    /// Bank + RR outcome of the last dispatched element load, staged for
    /// the access span (set only while tracing).
    elem_probe: Option<(usize, &'static str)>,
    /// Per-front-end head-stall kind from the most recent issue attempt
    /// — drives the skip-ahead advance rule (see `wants_next_cycle`).
    head_stall: Vec<HeadStall>,
}

impl MemorySystem {
    /// Build a system for `cfg` and attach one PE front end per source
    /// stream. Any [`TraceSource`] plugs in here — the materialized
    /// [`Workload`](crate::trace::Workload) oracle, a lazy
    /// `CooStreamSource`, or a `TnsStreamSource` reading straight from
    /// disk; report-identity across them is a hard invariant.
    pub fn new<S: TraceSource + ?Sized>(cfg: &SystemConfig, source: &S) -> MemorySystem {
        cfg.validate().expect("invalid system config");
        let n_fronts = source.n_streams();
        // Port topology: ip-only gives each front end its own controller
        // port; the LMB variants use one port per LMB.
        let n_ports = match cfg.kind {
            SystemKind::IpOnly => n_fronts,
            _ => cfg.n_lmbs,
        };
        let lmbs = match cfg.kind {
            SystemKind::IpOnly => Vec::new(),
            _ => (0..cfg.n_lmbs).map(|i| Lmb::new(cfg, i)).collect(),
        };
        let pes = (0..n_fronts)
            .map(|s| {
                let pe = source.stream_pe(s);
                let port = match cfg.kind {
                    SystemKind::IpOnly => pe % n_ports,
                    _ => pe % cfg.n_lmbs,
                };
                // Type-1's single front end stands for the whole fabric:
                // give it the aggregate window and issue width.
                let (window, width) = match source.fabric() {
                    FabricType::Type1 => (
                        cfg.pe.max_inflight * cfg.pe.n_pes,
                        3, // shared TLU + MLU + MSU issue in parallel
                    ),
                    FabricType::Type2 => (cfg.pe.max_inflight, 2),
                };
                PeFrontEnd::new(
                    pe,
                    source.stream_len(s),
                    source.open(s),
                    port,
                    window,
                    width,
                    cfg.pe.compute_cycles_per_nnz,
                )
            })
            .collect::<Vec<_>>();
        let n_pes = pes.len();
        MemorySystem {
            fabric: Fabric::new(n_ports, &cfg.interconnect, &cfg.dram),
            lmbs,
            pes,
            partials: vec![None; n_pes],
            ids: IdGen::default(),
            direct: DirectMap::default(),
            deliveries: BinaryHeap::new(),
            line_events: BinaryHeap::new(),
            port_cap: 16,
            direct_outstanding: vec![0; n_ports],
            direct_total: 0,
            // Naive direct connection: the commercial IP exposes a single
            // command interface; a simple fabric-side master keeps only a
            // handful of reads outstanding (no reordering, no coalescing).
            // Type-2's independent per-PE masters squeeze out a little
            // more MLP than Type-1's three shared units, but the limit is
            // GLOBAL — they all share the one controller interface.
            direct_limit: match source.fabric() {
                FabricType::Type1 => 5,
                FabricType::Type2 => 7,
            },
            accesses_served: 0,
            requested_bytes: 0,
            scratch_events: Vec::new(),
            scratch_deliveries: Vec::new(),
            telemetry: Telemetry::new(cfg),
            elem_probe: None,
            head_stall: vec![HeadStall::Clear; n_pes],
            cfg: cfg.clone(),
        }
    }

    /// Drain the telemetry recorded by the last run (`workload` labels
    /// the trace metadata). Empty output unless `cfg.telemetry` enabled
    /// a product.
    pub fn take_telemetry(&mut self, workload: &str) -> TelemetryOutput {
        self.telemetry.take_output(workload)
    }

    /// Run to completion with the event-driven engine; returns the
    /// report. Report-identical to [`MemorySystem::run_reference`]
    /// (modulo the host-side fields), only faster. With
    /// `cfg.sim_threads > 1` the component-local phases run on scoped
    /// shard workers — still bit-identical at any thread count (the
    /// merges are deterministic; see [`super::parallel`]).
    pub fn run(&mut self, workload_name: &str) -> SimReport {
        if self.cfg.sim_threads > 1 {
            return self.run_sharded(workload_name);
        }
        self.run_impl(workload_name, true, None)
    }

    /// Run to completion with the original poll-everything loop — the
    /// correctness oracle the event-driven engine is checked against.
    /// Always single-threaded.
    pub fn run_reference(&mut self, workload_name: &str) -> SimReport {
        self.run_impl(workload_name, false, None)
    }

    /// The event engine with `sim_threads - 1` scoped shard workers
    /// (`std::thread::scope` only — the crate stays dependency-free).
    fn run_sharded(&mut self, workload_name: &str) -> SimReport {
        let (pool, ends) = ShardPool::new(self.cfg.sim_threads - 1);
        std::thread::scope(|s| {
            for end in ends {
                s.spawn(move || worker_loop(end));
            }
            let report = self.run_impl(workload_name, true, Some(&pool));
            drop(pool); // hang up the task channels so the workers exit
            report
        })
    }

    /// The shared loop body. `event_driven` enables the activity gates
    /// and skip-ahead; with it false every component is polled on every
    /// visited cycle and time only jumps across globally idle stretches
    /// (the seed behavior). `pool` (event engine only) shards the
    /// component-local phases across workers. Each gate, jump and shard
    /// merge must preserve observable behavior exactly — see the module
    /// docs for the per-mechanism argument.
    fn run_impl(
        &mut self,
        workload_name: &str,
        event_driven: bool,
        pool: Option<&ShardPool>,
    ) -> SimReport {
        debug_assert!(pool.is_none() || event_driven, "reference loop is never sharded");
        let host_t0 = Instant::now();
        let mut now: Cycle = 0;
        let total_accesses: u64 = self
            .pes
            .iter()
            .map(|p| p.total_work() as u64 * 4)
            .sum::<u64>();
        // Generous deadlock watchdog on *visited iterations* (skip-ahead
        // makes `now` jump legitimately, so wall-cycle bounds would be
        // meaningless; a deadlock shows up as iterations without
        // progress). Saturating: scaled-up workloads must clamp at
        // u64::MAX rather than wrap to a tiny bound.
        let watchdog = total_accesses.saturating_mul(2_000).saturating_add(10_000_000);
        let mut visited: u64 = 0;
        let mut completions = Vec::new();
        let mut line_evs = Vec::new();
        loop {
            visited += 1;
            let mut progress = false;

            // 1. DRAM completions (all channels with schedulable or due
            //    work; channel order — hence completion order — is the
            //    same in both engines and at any thread count). With the
            //    reply network on these are the replies whose fabric
            //    traversal finished, their done_at rewritten to the
            //    delivery cycle. Sharded across the pool when at least
            //    two channels have work and request tracing is off (the
            //    DRAM trace hooks fire inside the tick; workers carry
            //    disabled collectors).
            completions.clear();
            match pool {
                Some(pool)
                    if !self.telemetry.tracing()
                        && self.fabric.channels_needing_tick(now) >= 2 =>
                {
                    self.tick_memory_sharded(now, &mut completions, pool);
                }
                _ if event_driven => {
                    self.fabric.tick_memory_gated_traced(now, &mut completions, &mut self.telemetry);
                }
                _ => {
                    self.fabric.tick_memory_traced(now, &mut completions, &mut self.telemetry);
                }
            }
            for resp in completions.drain(..) {
                progress = true;
                self.telemetry.mem_complete(resp.id, resp.done_at);
                if let Some(token) = self.direct.remove(resp.id) {
                    self.direct_outstanding[resp.port] -= 1;
                    self.direct_total -= 1;
                    self.deliveries.push(Reverse((resp.done_at + 1, token)));
                    continue;
                }
                line_evs.clear();
                self.scratch_deliveries.clear();
                self.lmbs[resp.port].on_dram_completion(
                    resp.id,
                    resp.done_at,
                    &mut line_evs,
                    &mut self.scratch_deliveries,
                );
                for d in self.scratch_deliveries.drain(..) {
                    self.deliveries.push(Reverse((d.at, d.token)));
                }
                for ev in line_evs.drain(..) {
                    self.line_events.push(Reverse((ev.at, ev.lmb, ev.line)));
                }
            }

            // 2. Cache lines reaching their RR.
            while let Some(&Reverse((at, lmb, line))) = self.line_events.peek() {
                if at > now {
                    break;
                }
                self.line_events.pop();
                progress = true;
                self.scratch_deliveries.clear();
                self.lmbs[lmb].line_ready_into(line, at, &mut self.scratch_deliveries);
                for d in self.scratch_deliveries.drain(..) {
                    self.deliveries.push(Reverse((d.at, d.token)));
                }
            }

            // 3. PE access-part completions.
            while let Some(&Reverse((at, token))) = self.deliveries.peek() {
                if at > now {
                    break;
                }
                self.deliveries.pop();
                progress = true;
                let (pe, slot, acc) = unpack_token(token);
                if self.pes[pe].part_done(slot, acc, at.max(now)) {
                    self.accesses_served += 1;
                    self.telemetry.access_done(token, at.max(now));
                }
            }

            // 4. LMB housekeeping (DMA buffer fills, blocked-line
            //    retries) — only LMBs with pending housekeeping work.
            line_evs.clear();
            for lmb in &mut self.lmbs {
                if event_driven && !lmb.needs_tick() {
                    continue;
                }
                lmb.tick(now, &mut self.ids, &mut line_evs);
            }
            for ev in line_evs.drain(..) {
                self.line_events.push(Reverse((ev.at, ev.lmb, ev.line)));
            }

            // 5. LMB outboxes → fabric (bounded ingress per port). The
            //    `has_requests` loop condition is itself the activity
            //    test — idle LMBs cost one boolean check.
            for li in 0..self.lmbs.len() {
                while self.lmbs[li].has_requests()
                    && self.fabric.port_depth(li) < self.port_cap
                {
                    let req = self.lmbs[li].pop_request().unwrap();
                    self.telemetry.mem_enqueued(req.id, req.port, now);
                    self.fabric.push(req);
                    progress = true;
                }
            }

            // 6. Fabric transport: egress into the channel controllers +
            //    one store-and-forward hop per link — skipped outright
            //    while no request is resident in the fabric.
            if !event_driven || self.fabric.has_traffic() {
                progress |= self.fabric.route_traced(now, &mut self.telemetry);
            }

            // 7a. Window admission. Fill is front-end-local and stamps
            //     no cycles (admitted items queue *behind* a stalled
            //     head), so it can run sharded — and hoisted out of the
            //     per-PE issue call without observable difference.
            match pool {
                Some(pool) if self.pes.iter().filter(|p| p.needs_fill()).count() >= 2 => {
                    self.fill_windows_sharded(pool);
                }
                _ => {
                    for pe in &mut self.pes {
                        if !event_driven || pe.needs_fill() {
                            pe.fill_window();
                        }
                    }
                }
            }

            // 7b. Issue — serial and in PE index order in every
            //     configuration: it mints request ids, spends the shared
            //     direct-issue budget and pushes into shared LMB/fabric
            //     queues, so its order *is* observable behavior. Only
            //     front ends that could issue are visited (pending
            //     access or an open line-split partial); stalled heads
            //     stay "issuable" so sticky retries keep their
            //     reference-loop cadence.
            for pe_idx in 0..self.pes.len() {
                let issuable = !event_driven
                    || self.partials[pe_idx].is_some()
                    || self.pes[pe_idx].can_issue();
                if issuable && self.issue_pe(pe_idx, now) {
                    progress = true;
                }
            }

            // 7c. Retire — front-end-local, O(1) until the earliest
            //     compute-done cycle; sharded when at least two front
            //     ends are due. Telemetry retire markers replay in PE
            //     index order on either path.
            match pool {
                Some(pool)
                    if self
                        .pes
                        .iter()
                        .filter(|p| p.next_retire().is_some_and(|c| c <= now))
                        .count()
                        >= 2 =>
                {
                    for (pe, n_retired) in self.retire_sharded(now, pool) {
                        progress = true;
                        self.telemetry.retired(pe, n_retired, now);
                    }
                }
                _ => {
                    for pe_idx in 0..self.pes.len() {
                        let n_retired = self.pes[pe_idx].retire(now);
                        if n_retired > 0 {
                            progress = true;
                            self.telemetry.retired(self.pes[pe_idx].pe, n_retired, now);
                        }
                    }
                }
            }

            // 7d. Telemetry timeline: record one row per elapsed window
            //     (observation only — reads counters, mutates nothing).
            if self.telemetry.timeline_due(now) {
                let snap = self.timeline_snap();
                self.telemetry.timeline_record(now, snap);
            }

            // 8. Termination. `finished` is a pure state predicate and
            //    every completing transition sets `progress`, so the
            //    event engine only re-evaluates it when state changed.
            if (!event_driven || progress || now == 0) && self.finished() {
                break;
            }

            // 9. Advance time. The reference loop steps `now + 1` after
            //    every progress cycle and otherwise jumps to the
            //    calendar head. The event engine proves, before taking
            //    the post-progress step, that some component is primed
            //    for the very next cycle (`wants_next_cycle`) — when
            //    none is, every cycle up to the calendar head is a
            //    no-op in the reference loop too (it would visit one
            //    more no-progress cycle, then take the same jump), so
            //    skipping straight there is unobservable.
            let step = if event_driven {
                progress && self.wants_next_cycle()
            } else {
                progress
            };
            let target = if step {
                now + 1
            } else {
                match self.next_event_time(now) {
                    Some(c) if c > now => c,
                    // Nothing scheduled but not finished → structural
                    // stall that resolves on retry next cycle.
                    _ => now + 1,
                }
            };
            // Timeline rows for window boundaries the jump crosses,
            // stamped at the boundary with the current counters — the
            // frozen snapshot is exactly what a visit at the boundary
            // would have recorded, since nothing can change inside a
            // jumped stretch. (One branch per iteration when the
            // timeline is off or no boundary is crossed.)
            while let Some(b) = self.telemetry.next_window_boundary() {
                if b >= target {
                    break;
                }
                let snap = self.timeline_snap();
                self.telemetry.timeline_record(b, snap);
            }
            now = target;
            assert!(
                visited < watchdog,
                "simulation deadlock: {visited} visited iterations at cycle {now}, \
                 {} accesses served of {}",
                self.accesses_served,
                total_accesses
            );
        }

        // Final timeline row at the makespan cycle (idempotent — cannot
        // duplicate a row already taken at `now`).
        if self.telemetry.timelining() {
            let snap = self.timeline_snap();
            self.telemetry.timeline_record(now, snap);
        }

        let mut latency: [crate::sim::pe::LatencyStats; 4] = Default::default();
        let mut pe_agg = PeAggStats::default();
        for front in &self.pes {
            for (agg, l) in latency.iter_mut().zip(&front.stats.latency) {
                agg.merge(l);
            }
            pe_agg.retired += front.stats.retired;
            pe_agg.issued_accesses += front.stats.issued_accesses;
            pe_agg.stall_cycles += front.stats.stall_cycles;
        }
        SimReport {
            label: self.cfg.label.clone(),
            workload: workload_name.to_string(),
            latency,
            pe: pe_agg,
            total_cycles: now,
            nnz: self.pes.iter().map(|p| p.total_work() as u64).sum(),
            accesses: self.accesses_served,
            requested_bytes: self.requested_bytes,
            dram: self.fabric.aggregate_dram_stats(),
            channels: self.fabric.channel_stats(),
            fabric: self.fabric.stats.clone(),
            link_width: self.fabric.link_width(),
            lmbs: self.lmbs.iter().map(Lmb::stats).collect(),
            visited_cycles: visited,
            host_seconds: host_t0.elapsed().as_secs_f64(),
        }
    }

    /// Is any component primed to act on the very next cycle in a way
    /// the event calendar cannot see? Consulted by the event engine
    /// after a progress cycle, before taking the reference loop's
    /// unconditional `now + 1` step: resident fabric traffic, LMB
    /// housekeeping or held requests, an open line-split partial, a
    /// sticky-stalled head (its retry mutates state every cycle), or a
    /// non-stalled front end with issuable work (including an issue
    /// budget cut short this cycle). Pure-stalled heads are excluded on
    /// purpose — their retries mutate nothing, and the hazard they wait
    /// on clears only through calendar-visible events.
    fn wants_next_cycle(&self) -> bool {
        self.fabric.has_traffic()
            || self.lmbs.iter().any(|l| l.needs_tick() || l.has_requests())
            || (0..self.pes.len()).any(|i| {
                self.partials[i].is_some()
                    || match self.head_stall[i] {
                        HeadStall::Sticky => true,
                        HeadStall::Pure => false,
                        HeadStall::Clear => self.pes[i].can_issue(),
                    }
            })
    }

    // --- sharded phases (`sim_threads > 1`) -----------------------------

    /// Phase-1 DRAM tick across the pool: detach the channel
    /// controllers, tick one shard inline while the workers tick
    /// theirs, then absorb every channel's completions in channel index
    /// order — the exact merge [`Fabric::tick_channels`] performs
    /// serially, so everything downstream is bit-identical.
    fn tick_memory_sharded(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        pool: &ShardPool,
    ) {
        self.fabric.drain_due_replies(now, completions);
        let channels = self.fabric.take_channels();
        let n = channels.len();
        let mut parts = shard_round_robin(channels, pool.n_workers() + 1);
        let own = parts.pop().expect("coordinator shard");
        let mut sent = Vec::with_capacity(parts.len());
        for (w, part) in parts.into_iter().enumerate() {
            if !part.is_empty() {
                pool.send(w, ShardTask::Channels { now, channels: part });
                sent.push(w);
            }
        }
        let mut slots: Vec<Option<(DramChannel, Vec<MemResp>)>> = (0..n).map(|_| None).collect();
        let mut tel = Telemetry::disabled();
        let place = |slots: &mut Vec<Option<(DramChannel, Vec<MemResp>)>>, done: ShardDone| {
            match done {
                ShardDone::Channels { channels } => {
                    for (i, dram, resps) in channels {
                        slots[i] = Some((dram, resps));
                    }
                }
                _ => unreachable!("phase reply mismatch"),
            }
        };
        place(
            &mut slots,
            run_task(ShardTask::Channels { now, channels: own }, &mut tel),
        );
        for w in sent {
            place(&mut slots, pool.recv(w));
        }
        let mut restored = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            let (dram, mut resps) = slot.expect("every channel comes back");
            self.fabric.absorb_channel_completions(i, &mut resps, completions);
            restored.push(dram);
        }
        self.fabric.put_channels(restored);
    }

    /// Phase-7a window fill across the pool. Fill is front-end-local,
    /// so only the reassembly order (PE index) is observable — and it
    /// is restored explicitly.
    fn fill_windows_sharded(&mut self, pool: &ShardPool) {
        let pes = std::mem::take(&mut self.pes);
        let n = pes.len();
        let mut parts = shard_round_robin(pes, pool.n_workers() + 1);
        let own = parts.pop().expect("coordinator shard");
        let mut sent = Vec::with_capacity(parts.len());
        for (w, part) in parts.into_iter().enumerate() {
            if !part.is_empty() {
                pool.send(w, ShardTask::Fill { pes: part });
                sent.push(w);
            }
        }
        let mut slots: Vec<Option<PeFrontEnd>> = (0..n).map(|_| None).collect();
        let mut tel = Telemetry::disabled();
        let place = |slots: &mut Vec<Option<PeFrontEnd>>, done: ShardDone| match done {
            ShardDone::Fill { pes } => {
                for (i, pe) in pes {
                    slots[i] = Some(pe);
                }
            }
            _ => unreachable!("phase reply mismatch"),
        };
        place(&mut slots, run_task(ShardTask::Fill { pes: own }, &mut tel));
        for w in sent {
            place(&mut slots, pool.recv(w));
        }
        self.pes = slots
            .into_iter()
            .map(|s| s.expect("every front end comes back"))
            .collect();
    }

    /// Phase-7c retire across the pool. Returns `(pe label, count)` for
    /// front ends that retired, in PE index order, for the
    /// coordinator's telemetry replay.
    fn retire_sharded(&mut self, now: Cycle, pool: &ShardPool) -> Vec<(usize, u64)> {
        let pes = std::mem::take(&mut self.pes);
        let n = pes.len();
        let mut parts = shard_round_robin(pes, pool.n_workers() + 1);
        let own = parts.pop().expect("coordinator shard");
        let mut sent = Vec::with_capacity(parts.len());
        for (w, part) in parts.into_iter().enumerate() {
            if !part.is_empty() {
                pool.send(w, ShardTask::Retire { now, pes: part });
                sent.push(w);
            }
        }
        let mut slots: Vec<Option<(PeFrontEnd, u64)>> = (0..n).map(|_| None).collect();
        let mut tel = Telemetry::disabled();
        let place = |slots: &mut Vec<Option<(PeFrontEnd, u64)>>, done: ShardDone| match done {
            ShardDone::Retire { pes } => {
                for (i, pe, count) in pes {
                    slots[i] = Some((pe, count));
                }
            }
            _ => unreachable!("phase reply mismatch"),
        };
        place(&mut slots, run_task(ShardTask::Retire { now, pes: own }, &mut tel));
        for w in sent {
            place(&mut slots, pool.recv(w));
        }
        let mut retired = Vec::new();
        self.pes = slots
            .into_iter()
            .map(|s| {
                let (pe, count) = s.expect("every front end comes back");
                if count > 0 {
                    retired.push((pe.pe, count));
                }
                pe
            })
            .collect();
        retired
    }

    /// Cumulative-counter snapshot for one telemetry timeline row
    /// (read-only; runs once per elapsed window, never on the hot path).
    fn timeline_snap(&self) -> TimelineSnap {
        let channels = self.fabric.channel_stats();
        let mut snap = TimelineSnap {
            channel_occupancy: self.fabric.channel_occupancy(),
            channel_reads: channels.iter().map(|c| c.reads).collect(),
            channel_writes: channels.iter().map(|c| c.writes).collect(),
            channel_busy_bus: channels.iter().map(|c| c.busy_bus_cycles).collect(),
            fabric_forwarded: self.fabric.stats.forwarded,
            fabric_backpressure: self.fabric.stats.backpressure_cycles,
            fabric_hops: self.fabric.stats.hops,
            link_forwarded: self.fabric.stats.links.iter().map(|l| l.forwarded).collect(),
            reply_delivered: self.fabric.stats.reply.delivered,
            ingress_depths: (0..self.fabric.n_ports())
                .map(|p| self.fabric.port_depth(p) as u64)
                .collect(),
            pending_deliveries: self.deliveries.len() as u64,
            pending_line_events: self.line_events.len() as u64,
            ..TimelineSnap::default()
        };
        for lmb in &self.lmbs {
            let s = lmb.stats();
            snap.lmb_hits.push(s.cache.hits);
            snap.lmb_misses.push(s.cache.primary_misses);
            snap.rr_served.push(s.rr.served_temp);
            snap.rr_absorbed.push(s.rr.absorbed);
            snap.rr_forwarded.push(s.rr.forwarded);
        }
        for pe in &self.pes {
            snap.pe_retired += pe.stats.retired;
            snap.pe_issued += pe.stats.issued_accesses;
            snap.pe_stalls += pe.stats.stall_cycles;
        }
        snap
    }

    /// Earliest future cycle anything is scheduled to happen — the fold
    /// over the event calendar both engines use to fast-forward across
    /// globally idle stretches.
    fn next_event_time(&self, now: Cycle) -> Option<Cycle> {
        [
            self.deliveries.peek().map(|Reverse((c, _))| *c),
            self.line_events.peek().map(|Reverse((c, _, _))| *c),
            self.fabric.next_completion(),
            self.fabric.next_schedule_time(now),
            self.fabric.next_transit_time(now),
            self.pes.iter().filter_map(PeFrontEnd::next_retire).min(),
        ]
        .into_iter()
        .flatten()
        .min()
    }

    fn finished(&self) -> bool {
        self.pes.iter().all(PeFrontEnd::done)
            && self.fabric.is_idle()
            && self.deliveries.is_empty()
            && self.line_events.is_empty()
            && self.lmbs.iter().all(Lmb::quiescent)
            && self.direct.is_empty()
    }

    /// Issue up to `issue_width` access (parts) for one PE. Returns true
    /// if anything was issued.
    fn issue_pe(&mut self, pe_idx: usize, now: Cycle) -> bool {
        let width = self.pes[pe_idx].issue_width;
        let mut issued_any = false;
        let mut budget = width;
        while budget > 0 {
            // Continue a partial (line-split) issue first.
            if let Some(p) = self.partials[pe_idx] {
                match self.issue_partial(pe_idx, p, now) {
                    IssueStep::Advanced => {
                        self.close_head_stall(pe_idx, now);
                        issued_any = true;
                        budget -= 1;
                        continue;
                    }
                    IssueStep::Stalled => {
                        // All line-split stalls are LMB-side: retries
                        // clock the cache every cycle.
                        self.open_head_stall(pe_idx, HeadStall::Sticky, now);
                        break;
                    }
                    IssueStep::Done => {
                        self.partials[pe_idx] = None;
                        continue;
                    }
                }
            }
            let Some((slot, acc, access)) = self.pes[pe_idx].next_unissued() else {
                break;
            };
            let token = pack_token(self.pes[pe_idx].pe, slot, acc);
            self.requested_bytes += access.bytes as u64;
            let outcome = self.dispatch(pe_idx, slot, acc, access, token, now);
            let probe = self.elem_probe.take();
            match outcome {
                DispatchResult::Issued { parts } => {
                    self.pes[pe_idx].mark_issued_at(slot, acc, parts, now);
                    self.close_head_stall(pe_idx, now);
                    self.telemetry.access_issued(token, acc, now);
                    if let Some((bank, rr)) = probe {
                        self.telemetry.access_probe(token, bank, rr);
                    }
                    issued_any = true;
                    budget -= 1;
                }
                DispatchResult::Split => {
                    // mark_issued already done inside dispatch (cache-only
                    // fibers); the partial continues next loop turn.
                    self.close_head_stall(pe_idx, now);
                    self.telemetry.access_issued(token, acc, now);
                    issued_any = true;
                    budget -= 1;
                }
                DispatchResult::Stall { sticky } => {
                    self.requested_bytes -= access.bytes as u64;
                    let kind = if sticky { HeadStall::Sticky } else { HeadStall::Pure };
                    self.open_head_stall(pe_idx, kind, now);
                    break; // head-of-line: wait for the hazard to clear
                }
            }
        }
        issued_any
    }

    /// Record that `pe_idx`'s head access failed to dispatch this
    /// cycle. Opens a stall episode (first failing cycle) if none is
    /// running and remembers the stall *kind* for the skip-ahead rule:
    /// sticky retries mutate component state every visited cycle, so
    /// the event engine must keep visiting; pure retries are no-ops, so
    /// it may jump.
    fn open_head_stall(&mut self, pe_idx: usize, kind: HeadStall, now: Cycle) {
        debug_assert!(kind != HeadStall::Clear);
        self.head_stall[pe_idx] = kind;
        let pe = &mut self.pes[pe_idx];
        if pe.stall_since.is_none() {
            pe.stall_since = Some(now);
        }
    }

    /// The head finally dispatched: close any open stall episode,
    /// accruing its *duration* (first-stall cycle to this dispatch
    /// cycle) into `stall_cycles`. Durations depend only on simulated
    /// time — never on which cycles the engine visited — which keeps
    /// the counter engine-invariant under skip-ahead.
    fn close_head_stall(&mut self, pe_idx: usize, now: Cycle) {
        self.head_stall[pe_idx] = HeadStall::Clear;
        let pe = &mut self.pes[pe_idx];
        if let Some(since) = pe.stall_since.take() {
            pe.stats.stall_cycles += now - since;
        }
    }

    /// Route one access according to the system variant.
    fn dispatch(
        &mut self,
        pe_idx: usize,
        slot: usize,
        acc: usize,
        access: crate::trace::Access,
        token: u64,
        now: Cycle,
    ) -> DispatchResult {
        let port = self.pes[pe_idx].port;
        match self.cfg.kind {
            SystemKind::Proposed => match access.class {
                AccessClass::TensorElem => {
                    self.scratch_events.clear();
                    let (r, bank, rr) = self.lmbs[port].element_load_probed(
                        access.addr,
                        token,
                        now,
                        &mut self.ids,
                        &mut self.scratch_events,
                    );
                    if self.telemetry.tracing() {
                        self.elem_probe = Some((bank, rr));
                    }
                    for ev in self.scratch_events.drain(..) {
                        self.line_events.push(Reverse((ev.at, ev.lmb, ev.line)));
                    }
                    self.outcome_to_result(r, token, 1)
                }
                AccessClass::FiberLoad | AccessClass::FiberStore => {
                    let r = self.lmbs[port].dma_transfer(
                        access.addr,
                        access.bytes,
                        token,
                        access.class.is_write(),
                    );
                    self.outcome_to_result(r, token, 1)
                }
            },
            SystemKind::DmaOnly => {
                // Everything via DMA, garbage and serialization included.
                let r = self.lmbs[port].dma_transfer(
                    access.addr,
                    access.bytes,
                    token,
                    access.class.is_write(),
                );
                self.outcome_to_result(r, token, 1)
            }
            SystemKind::CacheOnly => match access.class {
                AccessClass::FiberStore => {
                    // Write-through, no allocate.
                    let id =
                        self.lmbs[port].store_through(access.addr, access.bytes, &mut self.ids);
                    self.direct.insert(id, token);
                    self.direct_outstanding[port] += 1;
                    self.direct_total += 1;
                    DispatchResult::Issued { parts: 1 }
                }
                _ => {
                    // Loads split into cache lines; first line issued now,
                    // the rest via the partial mechanism.
                    let line_bytes = self.cfg.cache.line_bytes();
                    let start = access.addr - access.addr % line_bytes;
                    let end = crate::util::round_up(access.addr + access.bytes as u64, line_bytes);
                    let parts = ((end - start) / line_bytes) as u16;
                    self.pes[pe_idx].mark_issued_at(slot, acc, parts, now);
                    self.partials[pe_idx] = Some(PartialIssue {
                        slot,
                        acc,
                        next_addr: start,
                        end_addr: end,
                        is_store: false,
                    });
                    DispatchResult::Split
                }
            },
            SystemKind::IpOnly => {
                // Naive direct connection: full-width transfers, few
                // outstanding per port (the limit is maintained as a
                // running total — no per-access port scan).
                if self.direct_total >= self.direct_limit
                    || self.fabric.port_depth(port) >= self.port_cap
                {
                    // Limit checks only — the retry mutates nothing, so
                    // the event engine may skip ahead over this stall.
                    return DispatchResult::Stall { sticky: false };
                }
                let beat = self.cfg.dram.beat_bytes();
                let start = access.addr - access.addr % beat;
                let end = crate::util::round_up(access.addr + access.bytes as u64, beat);
                let id = self.ids.next();
                self.telemetry.mem_enqueued(id, port, now);
                self.fabric.push(MemReq {
                    id,
                    addr: start,
                    bytes: (end - start) as u32,
                    is_write: access.class.is_write(),
                    port,
                });
                self.direct.insert(id, token);
                self.direct_outstanding[port] += 1;
                self.direct_total += 1;
                DispatchResult::Issued { parts: 1 }
            }
        }
    }

    fn outcome_to_result(&mut self, r: LmbOutcome, token: u64, parts: u16) -> DispatchResult {
        match r {
            LmbOutcome::Ready { at } => {
                self.deliveries.push(Reverse((at, token)));
                DispatchResult::Issued { parts }
            }
            LmbOutcome::Pending => DispatchResult::Issued { parts },
            // Every LMB stall path (RR bank probe, cache lookup, DMA
            // queue) counts per-attempt stats and clocks LRU/RR state on
            // each retry — sticky, so the event engine keeps visiting.
            LmbOutcome::Stall => DispatchResult::Stall { sticky: true },
        }
    }

    /// Issue the next line of a split (cache-only) access.
    fn issue_partial(&mut self, pe_idx: usize, p: PartialIssue, now: Cycle) -> IssueStep {
        if p.next_addr >= p.end_addr {
            return IssueStep::Done;
        }
        let port = self.pes[pe_idx].port;
        let token = pack_token(self.pes[pe_idx].pe, p.slot, p.acc);
        debug_assert!(!p.is_store);
        match self.lmbs[port].cache_load_direct(p.next_addr, token, now, &mut self.ids) {
            LmbOutcome::Ready { at } => {
                self.deliveries.push(Reverse((at, token)));
            }
            LmbOutcome::Pending => {}
            LmbOutcome::Stall => return IssueStep::Stalled,
        }
        let line_bytes = self.cfg.cache.line_bytes();
        self.partials[pe_idx] = Some(PartialIssue {
            next_addr: p.next_addr + line_bytes,
            ..p
        });
        IssueStep::Advanced
    }
}

enum DispatchResult {
    Issued { parts: u16 },
    Split,
    /// Head-of-line hazard. `sticky` distinguishes stalls whose retry
    /// mutates component state every attempt (all LMB paths: RR probe
    /// clocks + stat counters, cache LRU clock, DMA queue stalls) from
    /// pure limit checks (IP-only outstanding/port caps) that are
    /// attempt-count-invariant — the skip-ahead rule in
    /// [`MemorySystem::wants_next_cycle`] hinges on the difference.
    Stall { sticky: bool },
}

enum IssueStep {
    Advanced,
    Stalled,
    Done,
}

/// Skip-ahead classification of a front end's head-of-line state,
/// refreshed on every issue attempt (see [`DispatchResult::Stall`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HeadStall {
    /// No open stall: the head dispatched on its last attempt (or was
    /// never attempted).
    Clear,
    /// Stalled on a pure limit check; retries mutate nothing, so the
    /// engine may jump to the next calendar event.
    Pure,
    /// Stalled on a mutating retry path; the engine must visit every
    /// cycle until the head dispatches so per-attempt state matches the
    /// reference loop exactly.
    Sticky,
}

/// Convenience: build + run in one call (event-driven engine). Accepts
/// any [`TraceSource`] — materialized workload or streaming.
pub fn simulate<S: TraceSource + ?Sized>(cfg: &SystemConfig, source: &S) -> SimReport {
    let name = source.name().to_string();
    MemorySystem::new(cfg, source).run(&name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{CooTensor, Mode};
    use crate::trace::{workload_from_tensor, Workload};
    use crate::util::rng::Rng;

    fn small_workload(fabric: FabricType, n_pes: usize) -> Workload {
        // Hyper-sparse like the paper's Table III tensors: J and K are
        // much larger than the cache, so factor fibers have no temporal
        // locality (the regime the LMB design targets).
        let mut rng = Rng::new(90);
        let t = CooTensor::random(&mut rng, [96, 40_000, 60_000], 3000);
        workload_from_tensor(&t, Mode::I, fabric, n_pes, 32, 8192)
    }

    fn cfg_for(kind: SystemKind, fabric: FabricType) -> SystemConfig {
        let mut c = match fabric {
            FabricType::Type1 => SystemConfig::config_a(),
            FabricType::Type2 => SystemConfig::config_b(),
        };
        c = c.as_baseline(kind);
        if kind == SystemKind::Proposed {
            c.label = c.label.replace("-proposed", "");
        }
        c
    }

    #[test]
    fn all_variants_complete_and_serve_every_access_type2() {
        let w = small_workload(FabricType::Type2, 4);
        let expected: u64 = w
            .pe_traces
            .iter()
            .map(|p| p.n_accesses() as u64)
            .sum();
        for kind in SystemKind::ALL {
            let cfg = cfg_for(kind, FabricType::Type2);
            let report = simulate(&cfg, &w);
            assert_eq!(
                report.accesses, expected,
                "{:?} lost accesses",
                kind
            );
            assert!(report.total_cycles > 0);
        }
    }

    #[test]
    fn all_variants_complete_type1() {
        let w = small_workload(FabricType::Type1, 4);
        for kind in SystemKind::ALL {
            let cfg = cfg_for(kind, FabricType::Type1);
            let report = simulate(&cfg, &w);
            assert!(report.total_cycles > 0, "{kind:?} did not run");
            assert_eq!(report.nnz, w.nnz as u64);
        }
    }

    #[test]
    fn event_engine_is_report_identical_to_reference_loop() {
        for fabric in [FabricType::Type1, FabricType::Type2] {
            let w = small_workload(fabric, 4);
            for kind in SystemKind::ALL {
                let cfg = cfg_for(kind, fabric);
                let event = MemorySystem::new(&cfg, &w).run(&w.name);
                let reference = MemorySystem::new(&cfg, &w).run_reference(&w.name);
                assert_eq!(
                    event.diff(&reference),
                    None,
                    "{fabric:?}/{kind:?}: engines diverged"
                );
            }
        }
    }

    #[test]
    fn sharded_engine_is_bit_identical_at_any_thread_count() {
        let w = small_workload(FabricType::Type2, 4);
        let mut cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        cfg.interconnect.channels = 2; // give the channel shards real work
        cfg.validate().unwrap();
        let base = MemorySystem::new(&cfg, &w).run(&w.name);
        for threads in [2, 4] {
            let mut c = cfg.clone();
            c.sim_threads = threads;
            let sharded = MemorySystem::new(&c, &w).run(&w.name);
            assert_eq!(sharded.diff(&base), None, "sim_threads={threads} diverged");
        }
    }

    #[test]
    fn banked_lmbs_with_reply_network_complete_and_agree_across_engines() {
        let w = small_workload(FabricType::Type2, 4);
        let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
        let mut cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        cfg.lmb_banks = 2;
        cfg.interconnect.channels = 2;
        cfg.interconnect.reply_network = true;
        cfg.validate().unwrap();
        let event = MemorySystem::new(&cfg, &w).run(&w.name);
        let reference = MemorySystem::new(&cfg, &w).run_reference(&w.name);
        assert_eq!(event.diff(&reference), None, "banked+reply engines diverged");
        assert_eq!(event.accesses, expected);
        // Reply traffic is real: one delivery per DRAM transaction.
        assert_eq!(
            event.fabric.reply.delivered,
            event.dram.reads + event.dram.writes
        );
        // Both banks of every LMB saw element traffic.
        for l in &event.lmbs {
            assert_eq!(l.banks.len(), 2);
            for (b, s) in l.banks.iter().enumerate() {
                assert!(s.rr.forwarded > 0, "bank {b} idle");
            }
        }
    }

    #[test]
    fn reply_network_never_makes_the_system_faster() {
        let w = small_workload(FabricType::Type2, 4);
        let base = cfg_for(SystemKind::Proposed, FabricType::Type2);
        let free = simulate(&base, &w);
        let mut modeled_cfg = base.clone();
        modeled_cfg.interconnect.reply_network = true;
        let modeled = simulate(&modeled_cfg, &w);
        assert!(
            modeled.total_cycles >= free.total_cycles,
            "modeling the return path cannot speed things up: {} < {}",
            modeled.total_cycles,
            free.total_cycles
        );
        assert_eq!(modeled.accesses, free.accesses);
    }

    #[test]
    fn proposed_beats_ip_only() {
        let w = small_workload(FabricType::Type2, 4);
        let prop = simulate(&cfg_for(SystemKind::Proposed, FabricType::Type2), &w);
        let ip = simulate(&cfg_for(SystemKind::IpOnly, FabricType::Type2), &w);
        let speedup = prop.speedup_over(&ip);
        assert!(
            speedup > 1.5,
            "proposed should clearly beat ip-only, got {speedup:.2}×"
        );
    }

    #[test]
    fn proposed_beats_cache_only_and_dma_only() {
        let w = small_workload(FabricType::Type2, 4);
        let prop = simulate(&cfg_for(SystemKind::Proposed, FabricType::Type2), &w);
        let cache = simulate(&cfg_for(SystemKind::CacheOnly, FabricType::Type2), &w);
        let dma = simulate(&cfg_for(SystemKind::DmaOnly, FabricType::Type2), &w);
        assert!(
            prop.total_cycles < cache.total_cycles,
            "proposed {} !< cache-only {}",
            prop.total_cycles,
            cache.total_cycles
        );
        assert!(
            prop.total_cycles < dma.total_cycles,
            "proposed {} !< dma-only {}",
            prop.total_cycles,
            dma.total_cycles
        );
    }

    #[test]
    fn dram_traffic_accounting_is_consistent() {
        let w = small_workload(FabricType::Type2, 4);
        let cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        let r = simulate(&cfg, &w);
        // DRAM moved at least the requested payload (alignment can only
        // add bytes) and cache reuse can only remove element re-reads.
        assert!(r.dram.read_bytes + r.dram.write_bytes > 0);
        // Stores: every output fiber goes to memory exactly once.
        let store_bytes: u64 = w
            .pe_traces
            .iter()
            .flat_map(|p| &p.work)
            .filter_map(|x| x.store.map(|s| s.bytes as u64))
            .sum();
        assert!(r.dram.write_bytes >= store_bytes);
    }

    #[test]
    fn cache_hit_rate_is_high_for_element_stream() {
        let w = small_workload(FabricType::Type2, 4);
        let cfg = cfg_for(SystemKind::Proposed, FabricType::Type2);
        let r = simulate(&cfg, &w);
        // RRSH + temp buffer absorb most element traffic; what reaches
        // the cache is mostly unique lines, but RR-level reuse must be
        // visible in the report.
        let rr_served: u64 = r
            .lmbs
            .iter()
            .map(|l| l.rr.served_temp + l.rr.absorbed)
            .sum();
        assert!(
            rr_served > 0,
            "request reductor should absorb element reuse"
        );
    }
}
