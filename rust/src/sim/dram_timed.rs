//! Command-level DDR4 channel backend (`dram.model = timed`).
//!
//! Where the lumped [`super::dram::Dram`] folds bank timing into
//! `t_row_hit`/`t_row_miss` latencies, this backend replays the explicit
//! command schedule the controller would emit per bank:
//!
//! * **ACT** — opening a row costs `t_rcd` before the column command;
//! * **PRE** — closing a conflicting row costs `t_rp`, and may not cut
//!   the row's `t_ras` minimum-open window short;
//! * **RD/WR** — the column command returns data after `t_cas` (reads)
//!   or `t_cwl` (writes); back-to-back columns on one open row pipeline
//!   at `t_ccd`;
//! * **REF** — every `t_refi` cycles a refresh steals `t_rfc` cycles
//!   from *every* bank and closes all open rows (so row hits can turn
//!   into misses across a boundary);
//! * **turnaround** — flipping the data-bus direction inserts `t_wtr`
//!   (write→read) or `t_rtw` (read→write) between column commands.
//!
//! Everything above the command layer is kept identical to the lumped
//! model on purpose: the same FR-FCFS-lite pick loop, the same
//! `t_controller` front-end, the same shared-data-bus beat serialization
//! and `bus_admission_factor` guard, and the same event-engine gate
//! contract (`needs_tick` true whenever `tick` would act;
//! `next_schedule_time` early-but-never-late). That is what makes the
//! degenerate-timing configuration (`t_rcd = t_rp = 0`, refresh off,
//! turnaround 0, `t_cas = t_cwl = t_ras`) *bit-identical* to a lumped
//! channel with `t_row_hit = t_row_miss = t_cas, t_precharge = 0` — the
//! conformance property `tests/integration_dram.rs` pins.
//!
//! Refresh is applied lazily: elapsed tREFI boundaries are caught up at
//! the top of `schedule`, but only when the queue is non-empty. The
//! guard is load-bearing for engine equivalence — the reference loop
//! calls `tick` every cycle while the event engine skips provable
//! no-ops, so a mutation during an empty-queue call would diverge the
//! two engines' refresh accounting. With the guard, both engines process
//! exactly the same boundary set at the same points in the issue order,
//! and the catch-up result (`busy_until = max(busy_until, boundary) +
//! t_rfc`) is independent of which cycle actually executes it.

use std::collections::VecDeque;

use crate::config::DramConfig;
use crate::util::log2;

use super::dram::{DramModel, DramStats};
use super::telemetry::Telemetry;
use super::{Cycle, MemReq, MemResp};

/// Per-bank command state.
#[derive(Debug, Clone, Copy, Default)]
struct TimedBank {
    open_row: Option<u64>,
    /// Bank command machine busy through this cycle.
    busy_until: Cycle,
    /// Cycle of the last ACT — a PRE may not land before `act_at + t_ras`.
    act_at: Cycle,
}

#[derive(Debug, Clone, Copy)]
struct Inflight {
    req: MemReq,
    done_at: Cycle,
}

/// The command-level DRAM channel model.
pub struct TimedDram {
    cfg: DramConfig,
    banks: Vec<TimedBank>,
    /// Requests accepted but not yet scheduled onto banks.
    queue: VecDeque<(MemReq, Cycle)>,
    /// Requests with a computed completion time.
    inflight: Vec<Inflight>,
    /// Min `done_at` over `inflight` (`Cycle::MAX` when empty).
    earliest_done: Cycle,
    /// Data bus reserved through this cycle.
    bus_free_at: Cycle,
    /// Next un-processed tREFI boundary (refresh catch-up cursor).
    next_refresh: Cycle,
    /// Direction of the last column command (`true` = write); decides
    /// whether tWTR/tRTW applies to the next one.
    last_dir: Option<bool>,
    /// End of the last column command's data window (turnaround anchor).
    last_col_end: Cycle,
    stats: DramStats,
    bank_shift: u32,
    bank_mask: u64,
    row_shift: u32,
}

impl TimedDram {
    pub fn new(cfg: &DramConfig) -> TimedDram {
        TimedDram {
            banks: vec![TimedBank::default(); cfg.banks],
            queue: VecDeque::new(),
            inflight: Vec::new(),
            earliest_done: Cycle::MAX,
            bus_free_at: 0,
            next_refresh: cfg.t_refi,
            last_dir: None,
            last_col_end: 0,
            stats: DramStats::default(),
            // ROW-BANK-COLUMN order, exactly as the lumped model.
            bank_shift: log2(cfg.row_bytes),
            bank_mask: cfg.banks as u64 - 1,
            row_shift: log2(cfg.row_bytes) + log2(cfg.banks as u64),
            cfg: cfg.clone(),
        }
    }

    #[inline]
    fn bank_of(&self, addr: u64) -> usize {
        ((addr >> self.bank_shift) & self.bank_mask) as usize
    }

    #[inline]
    fn row_of(&self, addr: u64) -> u64 {
        addr >> self.row_shift
    }

    /// The bus-admission horizon. The lumped model uses
    /// `factor * t_row_miss`; the command-level analog of one row-miss
    /// service is `t_rcd + t_cas`, so the calibrated default
    /// (24 + 28 = 52) books the bus exactly as far ahead as lumped does.
    #[inline]
    fn bus_horizon(&self) -> u64 {
        self.cfg.bus_admission_factor * (self.cfg.t_rcd + self.cfg.t_cas)
    }

    pub fn can_accept(&self) -> bool {
        self.queue.len() + self.inflight.len() < self.cfg.max_outstanding
    }

    pub fn occupancy(&self) -> usize {
        self.queue.len() + self.inflight.len()
    }

    pub fn push(&mut self, req: MemReq, now: Cycle) {
        debug_assert!(self.can_accept());
        debug_assert!(req.bytes > 0);
        self.queue.push_back((req, now));
    }

    pub fn tick(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        self.tick_traced(now, completions, &mut Telemetry::disabled(), 0);
    }

    pub fn tick_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
        ch: usize,
    ) {
        self.schedule(now, tel, ch);
        if self.earliest_done > now {
            return;
        }
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done_at <= now {
                let fin = self.inflight.swap_remove(i);
                completions.push(MemResp {
                    id: fin.req.id,
                    port: fin.req.port,
                    done_at: fin.done_at,
                });
            } else {
                i += 1;
            }
        }
        self.earliest_done = self
            .inflight
            .iter()
            .map(|f| f.done_at)
            .min()
            .unwrap_or(Cycle::MAX);
    }

    pub fn next_event(&self) -> Option<Cycle> {
        if self.inflight.is_empty() {
            None
        } else {
            Some(self.earliest_done)
        }
    }

    pub fn needs_tick(&self, now: Cycle) -> bool {
        !self.queue.is_empty() || self.earliest_done <= now
    }

    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    /// Mirror of the lumped gate. Computed against the *pre-catch-up*
    /// bank state, which can only under-estimate (refresh extends
    /// `busy_until`) — an early wakeup re-runs `schedule`, which first
    /// applies the catch-up and then recomputes; a late one is
    /// impossible.
    pub fn next_schedule_time(&self, now: Cycle) -> Option<Cycle> {
        if self.queue.is_empty() {
            return None;
        }
        let bus_gate = self.bus_free_at.saturating_sub(self.bus_horizon());
        let mut t = Cycle::MAX;
        for (req, _) in &self.queue {
            let bank = &self.banks[self.bank_of(req.addr)];
            t = t.min(bank.busy_until.max(bus_gate));
        }
        Some(t.max(now + 1))
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.inflight.is_empty()
    }

    pub fn stats(&self) -> &DramStats {
        &self.stats
    }

    /// FR-FCFS-lite, identical pick rule to the lumped model: row hits
    /// first, then oldest, only on free banks, bounded by the bus window.
    fn schedule(&mut self, now: Cycle, tel: &mut Telemetry, ch: usize) {
        if self.queue.is_empty() {
            // Do NOT catch up refresh here: the reference loop reaches
            // this point every cycle while the event engine skips, so a
            // mutation on the empty-queue path would diverge the engines
            // (see the module doc). Deferring it is timing-neutral — the
            // catch-up result is the same whenever it runs before the
            // next issue.
            return;
        }
        if self.cfg.refresh {
            while self.next_refresh <= now {
                let boundary = self.next_refresh;
                for bank in &mut self.banks {
                    // REF hits all banks: wait out any command in
                    // flight, steal tRFC, close the row.
                    bank.busy_until = bank.busy_until.max(boundary) + self.cfg.t_rfc;
                    bank.open_row = None;
                }
                self.stats.refreshes += 1;
                self.stats.refresh_steal_cycles += self.cfg.t_rfc * self.banks.len() as u64;
                self.next_refresh += self.cfg.t_refi;
            }
        }
        while !self.queue.is_empty() {
            let mut pick: Option<usize> = None;
            for (qi, (req, _)) in self.queue.iter().enumerate() {
                let bank = self.banks[self.bank_of(req.addr)];
                if bank.busy_until > now {
                    continue;
                }
                let is_hit = bank.open_row == Some(self.row_of(req.addr));
                if is_hit {
                    pick = Some(qi);
                    break;
                }
                if pick.is_none() {
                    pick = Some(qi);
                }
            }
            let Some(qi) = pick else { break };
            if self.bus_free_at > now + self.bus_horizon() {
                break;
            }
            let (req, enq_at) = self.queue.remove(qi).unwrap();
            self.issue(req, enq_at, now, tel, ch);
        }
    }

    /// Compute the command schedule for one transaction and book the
    /// bank + bus. All times are exact command cycles; the golden
    /// fixtures below assert them number by number.
    fn issue(&mut self, req: MemReq, enq_at: Cycle, now: Cycle, tel: &mut Telemetry, ch: usize) {
        let beat = self.cfg.beat_bytes();
        let beats = crate::util::ceil_div(req.bytes as u64, beat).max(1);
        let bank_idx = self.bank_of(req.addr);
        let row = self.row_of(req.addr);
        let cas_lat = if req.is_write {
            self.cfg.t_cwl
        } else {
            self.cfg.t_cas
        };
        let bank = &mut self.banks[bank_idx];
        let start = now.max(bank.busy_until);
        let was_hit = bank.open_row == Some(row);
        // Command chain up to the column command (RD/WR at `col_at`).
        let (mut col_at, row_kind) = match bank.open_row {
            Some(r) if r == row => {
                self.stats.row_hits += 1;
                (start, "hit")
            }
            Some(_) => {
                self.stats.row_conflicts += 1;
                // PRE may not close the row before tRAS expires.
                let pre_at = start.max(bank.act_at + self.cfg.t_ras);
                let act_at = pre_at + self.cfg.t_rp;
                bank.act_at = act_at;
                (act_at + self.cfg.t_rcd, "conflict")
            }
            None => {
                self.stats.row_misses += 1;
                bank.act_at = start;
                (start + self.cfg.t_rcd, "miss")
            }
        };
        // Bus turnaround: a direction flip separates the two column
        // commands by tWTR (W→R) / tRTW (R→W). Gated on `gap > 0` so a
        // zero-turnaround config is exactly turnaround-free — another
        // bank's later `last_col_end` must not leak a delay in.
        if let Some(last_write) = self.last_dir {
            if last_write != req.is_write {
                let gap = if req.is_write {
                    self.cfg.t_rtw
                } else {
                    self.cfg.t_wtr
                };
                if gap > 0 {
                    let gated = col_at.max(self.last_col_end + gap);
                    self.stats.turnaround_cycles += gated - col_at;
                    col_at = gated;
                }
            }
        }
        self.last_dir = Some(req.is_write);
        self.last_col_end = col_at + cas_lat;
        bank.open_row = Some(row);
        // Bank occupancy: hits pipeline at tCCD; an activate ties the
        // bank up until its column data window.
        bank.busy_until = col_at + if was_hit { self.cfg.t_ccd } else { cas_lat };
        let ready = col_at + self.cfg.t_controller + cas_lat;
        // Data beats serialize on the shared bus, as in the lumped model.
        let data_start = ready.max(self.bus_free_at);
        let done_at = data_start + beats;
        self.earliest_done = self.earliest_done.min(done_at);
        self.bus_free_at = done_at;
        self.stats.busy_bus_cycles += beats;
        self.stats.total_queue_wait += now.saturating_sub(enq_at);
        if req.is_write {
            self.stats.writes += 1;
            self.stats.write_bytes += req.bytes as u64;
        } else {
            self.stats.reads += 1;
            self.stats.read_bytes += req.bytes as u64;
        }
        tel.mem_service(req.id, ch, enq_at, now, done_at, row_kind);
        self.inflight.push(Inflight { req, done_at });
    }
}

impl DramModel for TimedDram {
    fn can_accept(&self) -> bool {
        TimedDram::can_accept(self)
    }

    fn occupancy(&self) -> usize {
        TimedDram::occupancy(self)
    }

    fn push(&mut self, req: MemReq, now: Cycle) {
        TimedDram::push(self, req, now)
    }

    fn tick_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
        ch: usize,
    ) {
        TimedDram::tick_traced(self, now, completions, tel, ch)
    }

    fn next_event(&self) -> Option<Cycle> {
        TimedDram::next_event(self)
    }

    fn needs_tick(&self, now: Cycle) -> bool {
        TimedDram::needs_tick(self, now)
    }

    fn has_queued(&self) -> bool {
        TimedDram::has_queued(self)
    }

    fn next_schedule_time(&self, now: Cycle) -> Option<Cycle> {
        TimedDram::next_schedule_time(self, now)
    }

    fn is_idle(&self) -> bool {
        TimedDram::is_idle(self)
    }

    fn stats(&self) -> &DramStats {
        TimedDram::stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramModelKind;
    use crate::sim::dram::Dram;
    use crate::sim::ReqId;
    use crate::util::rng::Rng;

    /// Timed defaults with refresh off — every golden fixture states its
    /// own refresh/turnaround knobs explicitly.
    fn timed_cfg() -> DramConfig {
        DramConfig {
            model: DramModelKind::Timed,
            refresh: false,
            ..DramConfig::mig_u250()
        }
    }

    fn req(id: ReqId, addr: u64, bytes: u32, is_write: bool) -> MemReq {
        MemReq {
            id,
            addr,
            bytes,
            is_write,
            port: 0,
        }
    }

    fn run_until_done(d: &mut TimedDram, horizon: Cycle) -> Vec<MemResp> {
        let mut out = Vec::new();
        for c in 0..horizon {
            d.tick(c, &mut out);
            if d.is_idle() {
                break;
            }
        }
        out
    }

    fn done_of(out: &[MemResp], id: ReqId) -> Cycle {
        out.iter().find(|r| r.id == id).expect("completion").done_at
    }

    /// Address of `row` in bank 0 (ROW-BANK-COLUMN, 16 banks x 8 KiB
    /// rows): row bits sit above bank bits.
    fn bank0_row(cfg: &DramConfig, row: u64) -> u64 {
        row * cfg.row_bytes * cfg.banks as u64
    }

    // ---- Golden command-timing fixtures (hand-computed cycles) ----

    #[test]
    fn golden_act_rd_pre_sequence_cycle_by_cycle() {
        // Defaults: t_rcd=24 t_rp=12 t_cas=28 t_ras=56 t_ccd=4
        // t_controller=8, 64 B = 1 beat, turnaround irrelevant (reads
        // only), refresh off.
        let cfg = timed_cfg();
        let mut d = TimedDram::new(&cfg);
        // r1: ACT row0 -> RD. r2: row0 hit. r3: row1 conflict (PRE+ACT).
        d.push(req(1, bank0_row(&cfg, 0), 64, false), 0);
        d.push(req(2, bank0_row(&cfg, 0) + 64, 64, false), 0);
        d.push(req(3, bank0_row(&cfg, 1), 64, false), 0);
        let out = run_until_done(&mut d, 10_000);
        assert_eq!(out.len(), 3);
        // r1 (bank empty): ACT@0, RD@24 (tRCD), data ready 24+8+28=60,
        // 1 beat -> done 61. Bank busy through 24+28=52.
        assert_eq!(done_of(&out, 1), 61);
        // r2 (row hit): issues when the bank frees at 52, RD@52, ready
        // 52+8+28=88, bus free since 61 -> done 89. Bank busy 52+4=56.
        assert_eq!(done_of(&out, 2), 89);
        // r3 (conflict): issues at 56; PRE must wait for tRAS of the
        // ACT@0 -> PRE@max(56, 0+56)=56, ACT@68 (tRP=12), RD@92
        // (tRCD=24), ready 92+8+28=128 -> done 129.
        assert_eq!(done_of(&out, 3), 129);
        assert_eq!(d.stats.row_misses, 1);
        assert_eq!(d.stats.row_hits, 1);
        assert_eq!(d.stats.row_conflicts, 1);
        assert_eq!(d.stats.refreshes, 0);
        assert_eq!(d.stats.turnaround_cycles, 0);
    }

    #[test]
    fn golden_pre_waits_out_tras() {
        // Stretch tRAS to 200: the conflict's PRE may not land before
        // ACT@0 + 200 even though the bank frees at 52.
        let cfg = DramConfig {
            t_ras: 200,
            ..timed_cfg()
        };
        let mut d = TimedDram::new(&cfg);
        d.push(req(1, bank0_row(&cfg, 0), 64, false), 0);
        d.push(req(2, bank0_row(&cfg, 1), 64, false), 0);
        let out = run_until_done(&mut d, 10_000);
        assert_eq!(done_of(&out, 1), 61);
        // Bank free at 52 -> PRE@max(52, 0+200)=200, ACT@212, RD@236,
        // ready 236+8+28=272 -> done 273.
        assert_eq!(done_of(&out, 2), 273);
    }

    #[test]
    fn golden_refresh_steals_exactly_trfc_at_the_trefi_boundary() {
        // tREFI=100, tRFC=50. r1 opens row0 (done 61, bank busy to 52).
        // r2 arrives at 150, after the boundary at 100: the catch-up
        // extends every bank to max(busy, 100)+50 = 150 and closes the
        // row, so r2 — a row hit without refresh — re-activates:
        // ACT@150, RD@174, ready 174+8+28=210 -> done 211.
        let cfg = DramConfig {
            refresh: true,
            t_refi: 100,
            t_rfc: 50,
            ..timed_cfg()
        };
        let mut d = TimedDram::new(&cfg);
        d.push(req(1, bank0_row(&cfg, 0), 64, false), 0);
        let mut out = Vec::new();
        for c in 0..2_000 {
            if c == 150 {
                d.push(req(2, bank0_row(&cfg, 0) + 64, 64, false), c);
            }
            d.tick(c, &mut out);
            if c > 150 && d.is_idle() {
                break;
            }
        }
        assert_eq!(done_of(&out, 1), 61);
        assert_eq!(done_of(&out, 2), 211);
        // Exactly one boundary processed, stealing tRFC on all 16 banks;
        // the re-activation shows up as a second row miss.
        assert_eq!(d.stats.refreshes, 1);
        assert_eq!(d.stats.refresh_steal_cycles, 50 * cfg.banks as u64);
        assert_eq!(d.stats.row_misses, 2);
        assert_eq!(d.stats.row_hits, 0);
    }

    #[test]
    fn golden_write_to_read_turnaround() {
        // tWTR=8: WR row0 (CWL=28, col@24, data window ends 52), then a
        // row-hit RD at 52 is pushed to col@max(52, 52+8)=60 -> ready
        // 60+8+28=96 -> done 97 (without tWTR it would be 89).
        let cfg = timed_cfg(); // t_wtr=8 from the preset
        let mut d = TimedDram::new(&cfg);
        d.push(req(1, bank0_row(&cfg, 0), 64, true), 0);
        d.push(req(2, bank0_row(&cfg, 0) + 64, 64, false), 0);
        let out = run_until_done(&mut d, 10_000);
        assert_eq!(done_of(&out, 1), 61);
        assert_eq!(done_of(&out, 2), 97);
        assert_eq!(d.stats.turnaround_cycles, 8);
    }

    #[test]
    fn golden_read_to_write_turnaround() {
        // tRTW=6, symmetric case: RD then row-hit WR at 52 pushed to
        // col@58 -> ready 58+8+28=94 -> done 95.
        let cfg = timed_cfg(); // t_rtw=6 from the preset
        let mut d = TimedDram::new(&cfg);
        d.push(req(1, bank0_row(&cfg, 0), 64, false), 0);
        d.push(req(2, bank0_row(&cfg, 0) + 64, 64, true), 0);
        let out = run_until_done(&mut d, 10_000);
        assert_eq!(done_of(&out, 1), 61);
        assert_eq!(done_of(&out, 2), 95);
        assert_eq!(d.stats.turnaround_cycles, 6);
    }

    // ---- Channel-level equivalence against the lumped model ----

    /// The degenerate pair from the conformance contract: timed with
    /// tRCD=tRP=0, refresh off, turnaround 0, tCAS=tCWL=tRAS=L is
    /// bit-identical to lumped with t_row_hit=t_row_miss=L,
    /// t_precharge=0.
    fn degenerate_pair(l: u64) -> (DramConfig, DramConfig) {
        let lumped = DramConfig {
            t_row_hit: l,
            t_row_miss: l,
            t_precharge: 0,
            ..DramConfig::mig_u250()
        };
        let timed = DramConfig {
            model: DramModelKind::Timed,
            t_rcd: 0,
            t_rp: 0,
            t_cas: l,
            t_cwl: l,
            t_ras: l,
            t_wtr: 0,
            t_rtw: 0,
            refresh: false,
            ..lumped.clone()
        };
        (lumped, timed)
    }

    /// The calibrated pair: timed with the preset's DDR4 numbers minus
    /// refresh/turnaround/tRAS-slack reproduces the lumped preset
    /// exactly (hit 28 = tCAS, miss 52 = tRCD+tCAS, conflict 64 =
    /// tRP+tRCD+tCAS; bus horizon 4x52 both ways).
    fn calibrated_pair() -> (DramConfig, DramConfig) {
        let lumped = DramConfig::mig_u250();
        let timed = DramConfig {
            model: DramModelKind::Timed,
            t_ras: lumped.t_rcd + lumped.t_cas,
            t_cwl: lumped.t_cas,
            t_wtr: 0,
            t_rtw: 0,
            refresh: false,
            ..lumped.clone()
        };
        (lumped, timed)
    }

    /// Drive both backends with an identical randomized request stream,
    /// ticking every cycle, and demand identical completion times and
    /// stats.
    fn assert_backends_identical(lumped_cfg: &DramConfig, timed_cfg: &DramConfig, seed: u64) {
        lumped_cfg.validate().expect("lumped cfg");
        timed_cfg.validate().expect("timed cfg");
        let mut lumped = Dram::new(lumped_cfg);
        let mut timed = TimedDram::new(timed_cfg);
        let mut rng = Rng::new(seed);
        let n = 300u64;
        let mut pushed = 0u64;
        let mut out_l = Vec::new();
        let mut out_t = Vec::new();
        let mut c: Cycle = 0;
        while (out_l.len() as u64) < n {
            // Bursty arrivals over a mix of streams and scatters.
            while pushed < n && lumped.can_accept() && timed.can_accept() && rng.gen_bool(0.7) {
                let addr = match pushed % 3 {
                    0 => pushed * 64,                          // stream
                    1 => (pushed * 1_048_576) % (1 << 30),     // scatter
                    _ => (pushed % 7) * 8192 * 16 + pushed * 8, // few rows
                };
                let is_write = rng.gen_bool(0.3);
                pushed += 1;
                lumped.push(req(pushed, addr, 64, is_write), c);
                timed.push(req(pushed, addr, 64, is_write), c);
            }
            lumped.tick(c, &mut out_l);
            timed.tick(c, &mut out_t);
            c += 1;
            assert!(c < 1_000_000, "runaway");
        }
        for _ in 0..5_000 {
            lumped.tick(c, &mut out_l);
            timed.tick(c, &mut out_t);
            c += 1;
            if lumped.is_idle() && timed.is_idle() {
                break;
            }
        }
        let key = |r: &MemResp| (r.id, r.done_at);
        let mut a: Vec<_> = out_l.iter().map(key).collect();
        let mut b: Vec<_> = out_t.iter().map(key).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "completion schedules diverged (seed {seed})");
        assert_eq!(lumped.stats, *timed.stats(), "stats diverged (seed {seed})");
    }

    #[test]
    fn degenerate_timings_are_bit_identical_to_lumped() {
        for (l, seed) in [(28u64, 1u64), (52, 2), (1, 3)] {
            let (lumped, timed) = degenerate_pair(l);
            assert_backends_identical(&lumped, &timed, seed);
        }
    }

    #[test]
    fn calibrated_timings_reproduce_the_lumped_preset() {
        let (lumped, timed) = calibrated_pair();
        for seed in [11u64, 12, 13] {
            assert_backends_identical(&lumped, &timed, seed);
        }
    }

    #[test]
    fn refresh_preserves_counts_and_only_adds_cycles() {
        // Same stream with refresh on vs off: identical access counters,
        // identical row-outcome totals (hits may convert to misses), and
        // a last completion that can only move later.
        let run = |refresh: bool| {
            let cfg = DramConfig {
                refresh,
                t_refi: 500,
                t_rfc: 40,
                ..timed_cfg()
            };
            let mut d = TimedDram::new(&cfg);
            let mut out = Vec::new();
            let mut pushed = 0u64;
            let mut c: Cycle = 0;
            while out.len() < 200 {
                while pushed < 200 && d.can_accept() {
                    d.push(req(pushed + 1, (pushed % 16) * 64, 64, pushed % 5 == 0), c);
                    pushed += 1;
                }
                d.tick(c, &mut out);
                c += 1;
                assert!(c < 1_000_000, "runaway");
            }
            let makespan = out.iter().map(|r| r.done_at).max().unwrap();
            (makespan, d.stats.clone())
        };
        let (span_off, off) = run(false);
        let (span_on, on) = run(true);
        assert!(on.refreshes > 0, "the stream must cross tREFI boundaries");
        assert_eq!(on.reads, off.reads);
        assert_eq!(on.writes, off.writes);
        assert_eq!(on.read_bytes, off.read_bytes);
        assert_eq!(on.write_bytes, off.write_bytes);
        assert_eq!(
            on.row_hits + on.row_misses + on.row_conflicts,
            off.row_hits + off.row_misses + off.row_conflicts,
            "row outcomes must be conserved in total"
        );
        assert!(
            span_on >= span_off,
            "refresh may only add cycles: on {span_on} < off {span_off}"
        );
    }

    #[test]
    fn event_gates_match_the_lumped_contract() {
        let cfg = timed_cfg();
        let mut d = TimedDram::new(&cfg);
        assert!(d.is_idle());
        assert_eq!(d.next_event(), None);
        assert_eq!(d.next_schedule_time(0), None);
        assert!(!d.needs_tick(0));
        d.push(req(1, 0, 64, false), 0);
        assert!(d.needs_tick(0));
        assert!(d.next_schedule_time(0).unwrap() >= 1, "strictly future");
        let mut out = Vec::new();
        d.tick(0, &mut out);
        // Issued: the completion event is exact (61), and the gate skips
        // straight to it.
        assert_eq!(d.next_event(), Some(61));
        assert!(!d.needs_tick(60));
        assert!(d.needs_tick(61));
        d.tick(61, &mut out);
        assert_eq!(out.len(), 1);
        assert!(d.is_idle());
    }
}
