//! Non-blocking set-associative cache (§IV-B).
//!
//! * 3-stage hit pipeline ("Our non-blocking cache uses a 3-stage pipeline
//!   to achieve high frequency").
//! * Line width = memory-interface data width (512 bit = 64 B) — "We keep
//!   the cache-line width similar to the data width of DRAM Interface IP".
//! * Whole cache-*lines* are returned toward the Request Reductor; the RR
//!   fans individual elements out to PEs (§IV-B).
//! * Misses allocate [`super::mshr`] entries; the *conventional* MSHR used by the
//!   cache-only baseline has a bounded secondary-miss capacity, which is
//!   exactly the bottleneck §V-D blames for the cache-only system's loss
//!   ("conventional MSHR can not handle a large number of secondary cache
//!   misses without losing the performance").
//! * Loads only: MTTKRP never reads back what it stores during one mode's
//!   sweep (input structures are read-only, §IV), so stores are
//!   write-through/no-allocate and bypass the tag array.

use crate::config::CacheConfig;
use crate::util::log2;

use super::dram::IdGen;
use super::mshr::{Mshr, MshrOutcome};
use super::{Cycle, MemReq, ReqId};

/// Result of a load presented to the cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheAccess {
    /// In the array; data available after the hit pipeline.
    Hit { ready_at: Cycle },
    /// Primary miss: `fill_req` must be forwarded to the router/DRAM.
    Miss { fill_req: MemReq },
    /// Secondary miss merged into an existing MSHR entry.
    Merged,
    /// Structural stall (MSHR full / secondary cap reached). Retry later.
    Blocked,
}

/// Cache statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub primary_misses: u64,
    pub merged_misses: u64,
    pub blocked: u64,
    pub evictions: u64,
    pub fills: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.primary_misses + self.merged_misses
    }

    /// Fold another bank's counters into this one (per-LMB aggregate
    /// over its cache banks).
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.primary_misses += other.primary_misses;
        self.merged_misses += other.merged_misses;
        self.blocked += other.blocked;
        self.evictions += other.evictions;
        self.fills += other.fills;
    }

    pub fn hit_rate(&self) -> f64 {
        let a = self.accesses();
        if a == 0 {
            0.0
        } else {
            self.hits as f64 / a as f64
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct Way {
    tag: u64,
    valid: bool,
    /// LRU stamp (higher = more recent).
    lru: u64,
}

/// Set-associative, non-blocking, load-only cache.
pub struct Cache {
    cfg: CacheConfig,
    ways: Vec<Way>, // sets × assoc, row-major by set
    set_mask: u64,
    /// Tag extraction shift (`log2(sets)`), hoisted out of the per-access
    /// probe path.
    set_shift: u32,
    line_shift: u32,
    lru_clock: u64,
    mshr: Mshr,
    pub stats: CacheStats,
    /// Port id used for fill requests (the LMB index).
    port: usize,
}

/// Token identifying a waiter blocked on a line fill (caller-defined).
pub type WaiterToken = u64;

impl Cache {
    pub fn new(cfg: &CacheConfig, port: usize) -> Cache {
        let sets = cfg.sets();
        Cache {
            ways: vec![Way::default(); cfg.lines],
            set_mask: sets as u64 - 1,
            set_shift: log2(sets as u64),
            line_shift: log2(cfg.line_bytes()),
            lru_clock: 0,
            mshr: Mshr::new(cfg.mshr_entries, cfg.mshr_secondary_cap),
            stats: CacheStats::default(),
            cfg: cfg.clone(),
            port,
        }
    }

    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    pub fn line_bytes(&self) -> u64 {
        self.cfg.line_bytes()
    }

    /// Present a load for `addr`; `token` identifies the waiter to release
    /// when the line arrives (unused on hits).
    pub fn load(
        &mut self,
        addr: u64,
        token: WaiterToken,
        now: Cycle,
        ids: &mut IdGen,
    ) -> CacheAccess {
        let line = self.line_of(addr);
        let set = (line & self.set_mask) as usize;
        let tag = line >> self.set_shift;
        self.lru_clock += 1;
        // Tag probe.
        let base = set * self.cfg.associativity;
        for w in 0..self.cfg.associativity {
            let way = &mut self.ways[base + w];
            if way.valid && way.tag == tag {
                way.lru = self.lru_clock;
                self.stats.hits += 1;
                return CacheAccess::Hit {
                    ready_at: now + self.cfg.pipeline_stages,
                };
            }
        }
        // Miss path → MSHR.
        match self.mshr.lookup_or_allocate(line, token) {
            MshrOutcome::Allocated(id_slot) => {
                self.stats.primary_misses += 1;
                let id = ids.next();
                self.mshr.set_req_id(id_slot, id);
                CacheAccess::Miss {
                    fill_req: MemReq {
                        id,
                        addr: line << self.line_shift,
                        bytes: self.cfg.line_bytes() as u32,
                        is_write: false,
                        port: self.port,
                    },
                }
            }
            MshrOutcome::Merged => {
                self.stats.merged_misses += 1;
                CacheAccess::Merged
            }
            MshrOutcome::Full => {
                self.stats.blocked += 1;
                CacheAccess::Blocked
            }
        }
    }

    /// A line fill returned from DRAM: install it, free the MSHR entry,
    /// and append the tokens waiting on it to `waiters` (data is
    /// forwarded to the RR / PEs `pipeline_stages` later; the caller
    /// applies that). Returns the filled line. The MSHR entry's waiter
    /// storage is recycled, so the fill path never allocates.
    pub fn fill_into(&mut self, req_id: ReqId, waiters: &mut Vec<WaiterToken>) -> Option<u64> {
        let line = self.mshr.complete_into(req_id, waiters)?;
        self.install(line);
        self.stats.fills += 1;
        Some(line)
    }

    fn install(&mut self, line: u64) {
        let set = (line & self.set_mask) as usize;
        let tag = line >> self.set_shift;
        let base = set * self.cfg.associativity;
        self.lru_clock += 1;
        // Prefer an invalid way; otherwise evict LRU.
        let mut victim = base;
        let mut best_lru = u64::MAX;
        for w in 0..self.cfg.associativity {
            let way = &self.ways[base + w];
            if !way.valid {
                victim = base + w;
                break;
            }
            if way.lru < best_lru {
                best_lru = way.lru;
                victim = base + w;
            }
        }
        if self.ways[victim].valid {
            self.stats.evictions += 1;
        }
        self.ways[victim] = Way {
            tag,
            valid: true,
            lru: self.lru_clock,
        };
    }

    /// True if no misses are outstanding.
    pub fn quiescent(&self) -> bool {
        self.mshr.is_empty()
    }

    /// Outstanding primary misses.
    pub fn outstanding(&self) -> usize {
        self.mshr.occupancy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(assoc: usize, lines: usize) -> (Cache, IdGen) {
        let cfg = CacheConfig {
            associativity: assoc,
            lines,
            line_bits: 512,
            pipeline_stages: 3,
            mshr_entries: 4,
            mshr_secondary_cap: 2,
        };
        (Cache::new(&cfg, 0), IdGen::default())
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let (mut c, mut ids) = cache(2, 64);
        let r = c.load(0x1000, 1, 0, &mut ids);
        let CacheAccess::Miss { fill_req } = r else {
            panic!("expected miss, got {r:?}")
        };
        assert_eq!(fill_req.addr, 0x1000);
        assert_eq!(fill_req.bytes, 64);
        let mut waiters = Vec::new();
        let line = c.fill_into(fill_req.id, &mut waiters).unwrap();
        assert_eq!(line, c.line_of(0x1000));
        assert_eq!(waiters, vec![1]);
        // Same line (different offset) now hits through the 3-stage pipe.
        match c.load(0x1008, 2, 10, &mut ids) {
            CacheAccess::Hit { ready_at } => assert_eq!(ready_at, 13),
            other => panic!("expected hit, got {other:?}"),
        }
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.primary_misses, 1);
    }

    #[test]
    fn secondary_miss_merges_until_cap() {
        let (mut c, mut ids) = cache(2, 64);
        let CacheAccess::Miss { fill_req } = c.load(0x2000, 1, 0, &mut ids) else {
            panic!()
        };
        // cap = 2 secondary waiters.
        assert_eq!(c.load(0x2010, 2, 0, &mut ids), CacheAccess::Merged);
        assert_eq!(c.load(0x2020, 3, 0, &mut ids), CacheAccess::Merged);
        assert_eq!(c.load(0x2030, 4, 0, &mut ids), CacheAccess::Blocked);
        let mut waiters = Vec::new();
        c.fill_into(fill_req.id, &mut waiters).unwrap();
        assert_eq!(waiters, vec![1, 2, 3]);
        assert_eq!(c.stats.merged_misses, 2);
        assert_eq!(c.stats.blocked, 1);
    }

    #[test]
    fn mshr_full_blocks_new_primary_misses() {
        let (mut c, mut ids) = cache(2, 64);
        for i in 0..4u64 {
            assert!(matches!(
                c.load(0x10_000 + i * 64, i, 0, &mut ids),
                CacheAccess::Miss { .. }
            ));
        }
        assert_eq!(c.load(0x20_000, 99, 0, &mut ids), CacheAccess::Blocked);
        assert_eq!(c.outstanding(), 4);
    }

    #[test]
    fn lru_eviction_within_set() {
        // Direct-mapped 4-line cache: sets 0..3, line i maps to set i%4.
        let (mut c, mut ids) = cache(1, 4);
        let CacheAccess::Miss { fill_req: f1 } = c.load(0, 1, 0, &mut ids) else {
            panic!()
        };
        let mut waiters = Vec::new();
        c.fill_into(f1.id, &mut waiters).unwrap();
        assert!(matches!(c.load(0, 2, 1, &mut ids), CacheAccess::Hit { .. }));
        // Same set (line 4 * 64 bytes * 4 sets apart), evicts line 0.
        let conflict_addr = 4 * 64;
        let CacheAccess::Miss { fill_req: f2 } = c.load(conflict_addr, 3, 2, &mut ids) else {
            panic!()
        };
        waiters.clear();
        c.fill_into(f2.id, &mut waiters).unwrap();
        assert_eq!(c.stats.evictions, 1);
        // Original line is gone.
        assert!(matches!(c.load(0, 4, 3, &mut ids), CacheAccess::Miss { .. }));
    }

    #[test]
    fn two_way_set_keeps_both_lines() {
        let (mut c, mut ids) = cache(2, 8); // 4 sets × 2 ways
        let a = 0u64;
        let b = 4 * 64; // same set, different tag
        let mut waiters = Vec::new();
        for (addr, tok) in [(a, 1u64), (b, 2)] {
            if let CacheAccess::Miss { fill_req } = c.load(addr, tok, 0, &mut ids) {
                c.fill_into(fill_req.id, &mut waiters).unwrap();
            }
        }
        assert!(matches!(c.load(a, 3, 5, &mut ids), CacheAccess::Hit { .. }));
        assert!(matches!(c.load(b, 4, 6, &mut ids), CacheAccess::Hit { .. }));
        assert_eq!(c.stats.evictions, 0);
    }

    #[test]
    fn hit_rate_accounting() {
        let (mut c, mut ids) = cache(2, 64);
        let CacheAccess::Miss { fill_req } = c.load(0, 1, 0, &mut ids) else {
            panic!()
        };
        c.fill_into(fill_req.id, &mut Vec::new()).unwrap();
        for i in 0..3 {
            assert!(matches!(
                c.load(i * 8, 10 + i, 1, &mut ids),
                CacheAccess::Hit { .. }
            ));
        }
        assert!((c.stats.hit_rate() - 0.75).abs() < 1e-9);
        assert!(c.quiescent());
    }
}
