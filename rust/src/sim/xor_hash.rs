//! XOR-based hash table (R. Zhang et al., HPEC'20 — the paper's RRSH
//! substrate, chosen "considering its high throughput and scalability").
//!
//! Hardware model: two banked sub-tables, each indexed by an XOR-fold of
//! the key (two independent fold patterns). An insert takes the first
//! free of the two candidate slots; with both occupied the pipeline
//! stalls (no eviction chains — this is a hardware table, not software
//! cuckoo). Lookup probes both slots in parallel (1 cycle each in HW).

/// Slot state of one sub-table entry.
#[derive(Debug, Clone)]
struct Slot<V> {
    key: u64,
    value: V,
    valid: bool,
}

/// Outcome of an insert attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    Inserted,
    /// Key already present (caller should update via `get_mut`).
    Exists,
    /// Both candidate slots occupied — structural stall.
    Conflict,
}

/// Two-choice XOR-hashed table with `2 × half` slots.
pub struct XorHashTable<V> {
    half: usize,
    mask: u64,
    t0: Vec<Slot<V>>,
    t1: Vec<Slot<V>>,
    len: usize,
    pub stat_conflicts: u64,
}

impl<V: Default + Clone> XorHashTable<V> {
    /// `capacity` is the total number of entries (split into two banks);
    /// must be a power of two ≥ 2.
    pub fn new(capacity: usize) -> XorHashTable<V> {
        assert!(capacity >= 2 && capacity.is_power_of_two());
        let half = capacity / 2;
        let empty = Slot {
            key: 0,
            value: V::default(),
            valid: false,
        };
        XorHashTable {
            half,
            mask: half as u64 - 1,
            t0: vec![empty.clone(); half],
            t1: vec![empty; half],
            len: 0,
            stat_conflicts: 0,
        }
    }

    /// XOR-fold hash #0: fold 16-bit chunks.
    #[inline]
    fn h0(&self, key: u64) -> usize {
        let f = key ^ (key >> 16) ^ (key >> 32) ^ (key >> 48);
        (f & self.mask) as usize
    }

    /// XOR-fold hash #1: different fold pattern (11/22/33-bit shears) so
    /// the two banks fail independently.
    #[inline]
    fn h1(&self, key: u64) -> usize {
        let f = key ^ (key >> 11) ^ (key >> 22) ^ (key >> 33) ^ 0x5bd1e995;
        (f & self.mask) as usize
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.half * 2
    }

    /// Look up `key`.
    pub fn get(&self, key: u64) -> Option<&V> {
        let s0 = &self.t0[self.h0(key)];
        if s0.valid && s0.key == key {
            return Some(&s0.value);
        }
        let s1 = &self.t1[self.h1(key)];
        if s1.valid && s1.key == key {
            return Some(&s1.value);
        }
        None
    }

    /// Mutable lookup.
    pub fn get_mut(&mut self, key: u64) -> Option<&mut V> {
        let i0 = self.h0(key);
        if self.t0[i0].valid && self.t0[i0].key == key {
            return Some(&mut self.t0[i0].value);
        }
        let i1 = self.h1(key);
        if self.t1[i1].valid && self.t1[i1].key == key {
            return Some(&mut self.t1[i1].value);
        }
        None
    }

    /// Insert `key → value` if absent.
    pub fn insert(&mut self, key: u64, value: V) -> InsertOutcome {
        self.try_insert_with(key, move || value)
    }

    /// Insert `key` with a lazily-built value: `make` runs only when a
    /// free slot exists, so callers can keep pooled storage (e.g. the
    /// RRSH's recycled waiter lists) out of the `Conflict` path.
    pub fn try_insert_with(&mut self, key: u64, make: impl FnOnce() -> V) -> InsertOutcome {
        if self.get(key).is_some() {
            return InsertOutcome::Exists;
        }
        let i0 = self.h0(key);
        if !self.t0[i0].valid {
            self.t0[i0] = Slot {
                key,
                value: make(),
                valid: true,
            };
            self.len += 1;
            return InsertOutcome::Inserted;
        }
        let i1 = self.h1(key);
        if !self.t1[i1].valid {
            self.t1[i1] = Slot {
                key,
                value: make(),
                valid: true,
            };
            self.len += 1;
            return InsertOutcome::Inserted;
        }
        self.stat_conflicts += 1;
        InsertOutcome::Conflict
    }

    /// Remove `key`, returning its value.
    pub fn remove(&mut self, key: u64) -> Option<V> {
        let i0 = self.h0(key);
        if self.t0[i0].valid && self.t0[i0].key == key {
            self.t0[i0].valid = false;
            self.len -= 1;
            return Some(std::mem::take(&mut self.t0[i0].value));
        }
        let i1 = self.h1(key);
        if self.t1[i1].valid && self.t1[i1].key == key {
            self.t1[i1].valid = false;
            self.len -= 1;
            return Some(std::mem::take(&mut self.t1[i1].value));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn insert_get_remove() {
        let mut t: XorHashTable<u32> = XorHashTable::new(16);
        assert_eq!(t.insert(100, 1), InsertOutcome::Inserted);
        assert_eq!(t.insert(100, 2), InsertOutcome::Exists);
        assert_eq!(t.get(100), Some(&1));
        *t.get_mut(100).unwrap() = 7;
        assert_eq!(t.remove(100), Some(7));
        assert_eq!(t.get(100), None);
        assert!(t.is_empty());
    }

    #[test]
    fn fills_to_reasonable_load_factor() {
        let mut t: XorHashTable<u64> = XorHashTable::new(1024);
        let mut rng = Rng::new(70);
        let mut inserted = 0;
        for _ in 0..1024 {
            let key = rng.next_u64() >> 8;
            match t.insert(key, key) {
                InsertOutcome::Inserted => inserted += 1,
                InsertOutcome::Exists | InsertOutcome::Conflict => {}
            }
        }
        // Two-choice hashing sustains a decent load factor before
        // conflicts dominate.
        assert!(
            inserted > 512,
            "only {inserted} of 1024 random keys inserted"
        );
        assert_eq!(t.len(), inserted);
    }

    #[test]
    fn conflict_reported_when_both_slots_busy() {
        let mut t: XorHashTable<u32> = XorHashTable::new(2); // 1+1 slots
        // Fill both banks with whatever keys land there.
        let mut filled = Vec::new();
        for key in 0..64u64 {
            if t.insert(key, 0) == InsertOutcome::Inserted {
                filled.push(key);
                if filled.len() == 2 {
                    break;
                }
            }
        }
        assert_eq!(filled.len(), 2);
        // Now every new key must conflict (or already exist).
        let mut conflicts = 0;
        for key in 100..164u64 {
            if t.insert(key, 0) == InsertOutcome::Conflict {
                conflicts += 1;
            }
        }
        assert!(conflicts > 0);
        assert_eq!(t.stat_conflicts, conflicts);
    }

    #[test]
    fn values_survive_many_random_ops() {
        let mut t: XorHashTable<u64> = XorHashTable::new(256);
        let mut shadow = std::collections::HashMap::new();
        let mut rng = Rng::new(71);
        for _ in 0..2000 {
            let key = rng.gen_range(512);
            if rng.gen_bool(0.5) {
                if t.insert(key, key * 3) == InsertOutcome::Inserted {
                    shadow.insert(key, key * 3);
                }
            } else {
                let got = t.remove(key);
                let want = shadow.remove(&key);
                assert_eq!(got, want, "remove({key}) mismatch");
            }
        }
        for (k, v) in &shadow {
            assert_eq!(t.get(*k), Some(v), "key {k} lost");
        }
    }
}
