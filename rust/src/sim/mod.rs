//! Cycle-level simulator of the paper's memory system and its baselines.
//!
//! Component map (paper figure → module):
//!
//! * Fig. 1 overall architecture → [`system`] (composition + run loop)
//! * Fig. 1 "Request Router"    → [`router`] (single-channel reference)
//! * interconnect fabric        → [`fabric`] (multi-channel
//!   generalization of the router: [`fabric::Topology`] crossbar / line /
//!   ring over N interleaved DRAM channels with per-link bandwidth
//!   tracking; `channels = 1` + crossbar replays [`router`] exactly;
//!   opt-in reply network models the response path hop-accurately too)
//! * Fig. 1 "LMB"               → [`lmb`] (shardable into per-channel
//!   cache + RR banks via the `lmb_banks` config key; 1 = the paper's
//!   monolithic LMB)
//! * Fig. 2 "DMA Engine"        → [`dma`]
//! * Fig. 3 "Request Reductor"  → [`request_reductor`] ([`temp_buffer`]
//!   CAM stage + [`rrsh`] stage over an [`xor_hash`] table)
//! * §IV-B non-blocking cache   → [`cache`] (+ conventional [`mshr`] for
//!   the cache-only baseline)
//! * DRAM interface IP + DDR4   → [`dram`] (one instance per channel;
//!   [`dram::ChannelMap`] interleaves the physical address space). Two
//!   timing backends share the [`dram::DramModel`] seam, selected per
//!   config by `dram.model`: the lumped default, and the command-level
//!   [`dram_timed`] (explicit ACT/RD/WR/PRE/REF with
//!   tRCD/tRP/tCAS/tCWL/tRAS, tREFI/tRFC refresh, tWTR/tRTW turnaround)
//! * compute fabrics (Type-1/2) → [`pe`]
//!
//! One simulated cycle = one user-clock cycle of the memory interface IP
//! (300 MHz). The simulator is request-accurate: every element load,
//! fiber load/store and DRAM transaction is an explicit object with issue
//! and completion cycles; `total memory access time` (the paper's Fig. 4
//! metric) is the makespan of the whole request stream.
//!
//! Two run loops share every component model (see [`system`]):
//!
//! * [`MemorySystem::run`] — the event-driven engine: timed events live
//!   in calendar queues, per-cycle work only visits components with
//!   pending work (active-set gating), and time advances straight to
//!   the next scheduled event whenever nothing is primed for the very
//!   next cycle (skip-ahead). With `sim_threads > 1` it additionally
//!   shards DRAM-channel ticking and PE window fill/retire across
//!   worker threads ([`parallel`]), merged deterministically. This is
//!   the engine every driver uses.
//! * [`MemorySystem::run_reference`] — the original poll-everything
//!   loop, kept as the correctness oracle. The two are report-identical
//!   by construction (each gate skips only provable no-ops, each jump
//!   only provably idle stretches); `tests/integration_engine.rs`
//!   enforces it — and thread-count invariance — across all variants,
//!   fabrics and topologies.
//!
//! Drivers (CLI, benches, examples, integration tests) do not call
//! [`simulate`] with hand-rolled workloads; they compose scenarios and
//! grids through [`crate::experiment`] (Scenario → Sweep → RunSet),
//! which handles workload caching, parallel execution, and result
//! serialization.

pub mod cache;
pub mod dma;
pub mod dram;
pub mod dram_timed;
pub mod fabric;
pub mod lmb;
pub mod mshr;
pub mod parallel;
pub mod pe;
pub mod request_reductor;
pub mod router;
pub mod rrsh;
pub mod stats;
pub mod system;
pub mod telemetry;
pub mod temp_buffer;
pub mod xor_hash;

pub use fabric::{Fabric, FabricStats, LinkStats, ReplyStats};
pub use stats::SimReport;
pub use system::{simulate, MemorySystem};
pub use telemetry::{Telemetry, TelemetryOutput, TimelineSnap};

/// Simulated clock cycle.
pub type Cycle = u64;

/// Identifier of a DRAM-level transaction.
pub type ReqId = u64;

/// A DRAM-level memory transaction (what crosses the request router).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemReq {
    pub id: ReqId,
    /// Byte address (beat-aligned by the issuing component).
    pub addr: u64,
    /// Transfer size in bytes (multiple of the beat size).
    pub bytes: u32,
    pub is_write: bool,
    /// Which LMB (or direct port) issued it — routing key for the reply.
    pub port: usize,
}

/// Completion notice delivered back through the router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemResp {
    pub id: ReqId,
    pub port: usize,
    pub done_at: Cycle,
}

/// A completed PE-visible access part: `token` identifies the waiting
/// (pe, slot, access) and `at` the cycle its data is available. Producers
/// (LMBs, the Request Reductor) append these to caller-owned sinks; the
/// run loop moves them into its delivery calendar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Delivery {
    pub token: u64,
    pub at: Cycle,
}
