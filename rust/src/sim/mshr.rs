//! Conventional Miss Status Holding Registers.
//!
//! This is the structure the paper's cache-only baseline relies on and the
//! RRSH replaces: a small fully-associative table of outstanding line
//! fills, each tracking a bounded list of secondary waiters. When either
//! the table or a waiter list is full the cache must stall — the exact
//! failure mode §V-D describes for MTTKRP fiber streams.

use super::cache::WaiterToken;
use super::ReqId;

/// Outcome of presenting a miss to the MSHR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MshrOutcome {
    /// New entry allocated (slot index returned) — issue the fill.
    Allocated(usize),
    /// Joined an existing entry as a secondary miss.
    Merged,
    /// Table or waiter list full — structural stall.
    Full,
}

#[derive(Debug, Clone)]
struct Entry {
    line: u64,
    req_id: ReqId,
    waiters: Vec<WaiterToken>,
    valid: bool,
}

/// A conventional MSHR file.
pub struct Mshr {
    entries: Vec<Entry>,
    secondary_cap: usize,
    occupancy: usize,
}

impl Mshr {
    pub fn new(n_entries: usize, secondary_cap: usize) -> Mshr {
        Mshr {
            entries: (0..n_entries)
                .map(|_| Entry {
                    line: 0,
                    req_id: 0,
                    waiters: Vec::new(),
                    valid: false,
                })
                .collect(),
            secondary_cap,
            occupancy: 0,
        }
    }

    /// Present a missing `line`; `token` waits for its fill.
    pub fn lookup_or_allocate(&mut self, line: u64, token: WaiterToken) -> MshrOutcome {
        // Fully-associative lookup.
        let mut free = None;
        for (idx, e) in self.entries.iter_mut().enumerate() {
            if e.valid && e.line == line {
                // `waiters` holds the primary + secondaries; cap counts
                // secondaries only.
                if e.waiters.len() >= 1 + self.secondary_cap {
                    return MshrOutcome::Full;
                }
                e.waiters.push(token);
                return MshrOutcome::Merged;
            }
            if !e.valid && free.is_none() {
                free = Some(idx);
            }
        }
        match free {
            Some(idx) => {
                let e = &mut self.entries[idx];
                e.valid = true;
                e.line = line;
                e.req_id = 0;
                e.waiters.clear();
                e.waiters.push(token);
                self.occupancy += 1;
                MshrOutcome::Allocated(idx)
            }
            None => MshrOutcome::Full,
        }
    }

    /// Record the DRAM request id of a just-allocated entry.
    pub fn set_req_id(&mut self, slot: usize, id: ReqId) {
        debug_assert!(self.entries[slot].valid);
        self.entries[slot].req_id = id;
    }

    /// A fill completed: free the entry, append its waiters to `out`
    /// (in arrival order) and return the line. The entry keeps its
    /// waiter-list allocation for reuse, so steady-state completion is
    /// allocation-free.
    pub fn complete_into(&mut self, id: ReqId, out: &mut Vec<WaiterToken>) -> Option<u64> {
        for e in &mut self.entries {
            if e.valid && e.req_id == id {
                e.valid = false;
                self.occupancy -= 1;
                out.extend(e.waiters.drain(..));
                return Some(e.line);
            }
        }
        None
    }

    pub fn is_empty(&self) -> bool {
        self.occupancy == 0
    }

    pub fn occupancy(&self) -> usize {
        self.occupancy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_merge_complete_cycle() {
        let mut m = Mshr::new(2, 2);
        let MshrOutcome::Allocated(slot) = m.lookup_or_allocate(7, 100) else {
            panic!()
        };
        m.set_req_id(slot, 42);
        assert_eq!(m.lookup_or_allocate(7, 101), MshrOutcome::Merged);
        assert_eq!(m.occupancy(), 1);
        let mut waiters = Vec::new();
        let line = m.complete_into(42, &mut waiters).unwrap();
        assert_eq!(line, 7);
        assert_eq!(waiters, vec![100, 101]);
        assert!(m.is_empty());
    }

    #[test]
    fn secondary_cap_enforced() {
        let mut m = Mshr::new(1, 1);
        let MshrOutcome::Allocated(s) = m.lookup_or_allocate(3, 1) else {
            panic!()
        };
        m.set_req_id(s, 9);
        assert_eq!(m.lookup_or_allocate(3, 2), MshrOutcome::Merged);
        assert_eq!(m.lookup_or_allocate(3, 3), MshrOutcome::Full);
    }

    #[test]
    fn table_capacity_enforced() {
        let mut m = Mshr::new(2, 4);
        assert!(matches!(m.lookup_or_allocate(1, 1), MshrOutcome::Allocated(_)));
        assert!(matches!(m.lookup_or_allocate(2, 2), MshrOutcome::Allocated(_)));
        assert_eq!(m.lookup_or_allocate(3, 3), MshrOutcome::Full);
    }

    #[test]
    fn complete_unknown_id_is_none() {
        let mut m = Mshr::new(1, 1);
        let mut waiters = Vec::new();
        assert!(m.complete_into(5, &mut waiters).is_none());
        assert!(waiters.is_empty());
    }

    #[test]
    fn slots_recycle_after_completion() {
        let mut m = Mshr::new(1, 0);
        let MshrOutcome::Allocated(s) = m.lookup_or_allocate(1, 1) else {
            panic!()
        };
        m.set_req_id(s, 11);
        let mut waiters = Vec::new();
        m.complete_into(11, &mut waiters).unwrap();
        assert_eq!(waiters, vec![1]);
        assert!(matches!(m.lookup_or_allocate(2, 2), MshrOutcome::Allocated(_)));
    }
}
