//! Multi-channel interconnect fabric between the request ports (LMBs /
//! direct PE ports) and N independent DRAM channels.
//!
//! The paper's memory system funnels every LMB through one request
//! router into a single memory-interface IP ([`super::router`]). This
//! module generalizes that pipe into a routed fabric, the enabler for
//! HBM-style many-channel parts:
//!
//! * a [`Topology`] trait ([`Crossbar`], [`Line`], [`Ring`]) describing
//!   how ports reach channels;
//! * cycle-accurate store-and-forward transport: one cycle per hop,
//!   [`InterconnectConfig::link_width`] requests per directed link per
//!   cycle, bounded per-link queues with backpressure;
//! * channel interleaving of the physical address space via
//!   [`ChannelMap`] — each channel runs its own [`Dram`] model (banks,
//!   bus, controller queue), so aggregate bandwidth scales with
//!   `channels`.
//!
//! With `channels = 1` and the crossbar topology the fabric reduces
//! exactly — cycle for cycle — to the seed `Router -> Dram` pipe (the
//! egress arbitration below is the same round-robin loop), which keeps
//! the paper's Fig. 4 / Table II/III benches valid; a regression test
//! pins this equivalence against [`super::router::Router`] on a fixed
//! trace.
//!
//! # The reply network
//!
//! With [`InterconnectConfig::reply_network`] **off** (the default),
//! replies return directly to the issuing port on completion — as in the
//! seed router, whose data return path is combinational — and only the
//! request path is hop-accurate. That code path is untouched and remains
//! the bit-identical regression anchor.
//!
//! With it **on**, the response path becomes a first-class network:
//! every DRAM completion enters a per-node reply buffer and traverses
//! the topology *back* to the requesting port over dedicated reply
//! links — mirrors of the request links with their own
//! [`InterconnectConfig::link_width`] budgets, bounded queues,
//! backpressure, and [`LinkStats`] counters (labels `r:nA->nB`; the
//! crossbar's virtual return buses are `chC->pP`). Each port accepts at
//! most one reply per cycle (its return-data bus), so same-port
//! completion bursts serialize — the cost the free return path hides.
//! Reply-side counters live in [`ReplyStats`].

use std::collections::VecDeque;

use crate::config::{DramConfig, InterconnectConfig, TopologyKind};

use super::dram::{ChannelMap, DramChannel, DramStats};
use super::telemetry::Telemetry;
use super::{Cycle, MemReq, MemResp};

/// Static routing view of an interconnect topology over `nodes` fabric
/// nodes (one node per DRAM channel; ports attach round-robin).
pub trait Topology {
    fn name(&self) -> &'static str;

    /// Node where requests from `port` enter the fabric.
    fn ingress_node(&self, port: usize, nodes: usize) -> usize {
        port % nodes
    }

    /// Next node on the route from `at` toward `dest`, or `None` when
    /// the request is delivered locally (crossbars deliver everywhere).
    fn next_hop(&self, at: usize, dest: usize, nodes: usize) -> Option<usize>;

    /// All directed store-and-forward links (from, to).
    fn links(&self, nodes: usize) -> Vec<(usize, usize)>;

    /// Fabric hops from `port`'s ingress node to channel `dest`.
    fn route_hops(&self, port: usize, dest: usize, nodes: usize) -> usize {
        let mut at = self.ingress_node(port, nodes);
        let mut hops = 0;
        while let Some(next) = self.next_hop(at, dest, nodes) {
            at = next;
            hops += 1;
            assert!(hops <= nodes, "{}: routing loop {at}->{dest}", self.name());
        }
        hops
    }
}

/// Full crossbar: every port arbitrates at every channel in one cycle.
pub struct Crossbar;

impl Topology for Crossbar {
    fn name(&self) -> &'static str {
        "crossbar"
    }

    fn next_hop(&self, _at: usize, _dest: usize, _nodes: usize) -> Option<usize> {
        None
    }

    fn links(&self, _nodes: usize) -> Vec<(usize, usize)> {
        Vec::new()
    }
}

/// Nodes in a row; requests walk node-to-node toward their channel.
pub struct Line;

impl Topology for Line {
    fn name(&self) -> &'static str {
        "line"
    }

    fn next_hop(&self, at: usize, dest: usize, _nodes: usize) -> Option<usize> {
        match dest.cmp(&at) {
            std::cmp::Ordering::Equal => None,
            std::cmp::Ordering::Greater => Some(at + 1),
            std::cmp::Ordering::Less => Some(at - 1),
        }
    }

    fn links(&self, nodes: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..nodes.saturating_sub(1) {
            out.push((i, i + 1));
            out.push((i + 1, i));
        }
        out
    }
}

/// A line closed into a ring; requests take the shortest direction
/// (ties go clockwise).
pub struct Ring;

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn next_hop(&self, at: usize, dest: usize, nodes: usize) -> Option<usize> {
        if at == dest {
            return None;
        }
        let cw = (dest + nodes - at) % nodes;
        let ccw = (at + nodes - dest) % nodes;
        if cw <= ccw {
            Some((at + 1) % nodes)
        } else {
            Some((at + nodes - 1) % nodes)
        }
    }

    fn links(&self, nodes: usize) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for i in 0..nodes {
            let j = (i + 1) % nodes;
            if i == j {
                continue;
            }
            if !out.contains(&(i, j)) {
                out.push((i, j));
            }
            if !out.contains(&(j, i)) {
                out.push((j, i));
            }
        }
        out
    }
}

/// The static routing table for a topology kind.
pub fn topology_of(kind: TopologyKind) -> &'static dyn Topology {
    match kind {
        TopologyKind::Crossbar => &Crossbar,
        TopologyKind::Line => &Line,
        TopologyKind::Ring => &Ring,
    }
}

/// Per-directed-link counters. For the crossbar these are the virtual
/// port→channel links (bandwidth 1 request/cycle); for line/ring they
/// are the physical node→node links (bandwidth `link_width`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Human label, e.g. `p0->ch2` (crossbar) or `n1->n2` (line/ring).
    pub label: String,
    /// Requests that crossed this link.
    pub forwarded: u64,
    /// Cycles a ready request could not cross (link budget exhausted,
    /// downstream queue full, or — crossbar — channel controller full).
    pub stall_cycles: u64,
}

impl LinkStats {
    /// Fraction of the link's request bandwidth used over a run.
    pub fn utilization(&self, total_cycles: Cycle, link_width: usize) -> f64 {
        if total_cycles == 0 {
            0.0
        } else {
            self.forwarded as f64 / (total_cycles as f64 * link_width.max(1) as f64)
        }
    }
}

/// Reply-network statistics (all zero / empty when the reply network is
/// off — the return path is combinational then and has no counters).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplyStats {
    /// Replies delivered back to their requesting port.
    pub delivered: u64,
    /// Total reply-link traversals (0 for crossbar).
    pub hops: u64,
    /// Cycles a deliverable reply was held by an exhausted per-port
    /// return bus (crossbar arbitration contention).
    pub backpressure_cycles: u64,
    /// Per-reply-link counters (`r:nA->nB`, or `chC->pP` virtual return
    /// buses for the crossbar).
    pub links: Vec<LinkStats>,
}

/// Fabric-level statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FabricStats {
    /// Requests delivered into a DRAM channel controller.
    pub forwarded: u64,
    /// Cycles an egress arbiter was blocked by a full channel controller.
    pub backpressure_cycles: u64,
    /// Total store-and-forward link traversals (0 for crossbar).
    pub hops: u64,
    pub per_port_forwarded: Vec<u64>,
    pub per_channel_forwarded: Vec<u64>,
    pub links: Vec<LinkStats>,
    /// Response-path counters (see [`ReplyStats`]).
    pub reply: ReplyStats,
}

/// Where an egress arbiter may pull requests from at one fabric node.
#[derive(Debug, Clone, Copy)]
enum Source {
    /// Ingress queue of a port attached to this node (crossbar: every
    /// port is visible at every node).
    Port(usize),
    /// Arrival queue of an incoming link (by link id).
    Link(usize),
}

/// Where a reply arbiter may pull completions from at one fabric node
/// (line/ring reply transport).
#[derive(Debug, Clone, Copy)]
enum ReplySource {
    /// The node's own reply buffer (its channel's completions).
    Node,
    /// Arrival queue of an incoming reply link (by link id).
    Link(usize),
}

/// The interconnect fabric: ingress ports, routed transport, and N
/// independent DRAM channels.
pub struct Fabric {
    kind: TopologyKind,
    chmap: ChannelMap,
    channels: Vec<DramChannel>,
    /// Per-port ingress queues (filled by LMBs / direct PE ports).
    ingress: Vec<VecDeque<MemReq>>,
    /// Store-and-forward link queues, entries tagged with the cycle the
    /// hop completes (line/ring; empty for crossbar).
    links: Vec<VecDeque<(MemReq, Cycle)>>,
    /// Link id by (from, to) node pair.
    link_id: Vec<Vec<Option<usize>>>,
    /// Egress arbitration sources per node (line/ring).
    sources: Vec<Vec<Source>>,
    /// Per-channel egress round-robin pointer.
    rr_egress: Vec<usize>,
    /// Per-node hop round-robin pointer (line/ring).
    rr_hop: Vec<usize>,
    /// Commands each channel controller accepts per cycle (MIG: 1).
    cmds_per_cycle: usize,
    link_width: usize,
    link_queue_cap: usize,
    /// Requests resident in the ingress queues (maintained so idle/busy
    /// checks never scan).
    ingress_occupancy: usize,
    /// Requests resident in the store-and-forward link queues.
    link_occupancy: usize,
    /// Reusable per-link hop budget for [`Fabric::route`] (line/ring) —
    /// sized once per call without reallocating.
    hop_budget: Vec<usize>,
    /// Reply network on? (`false` keeps the combinational return path.)
    reply_enabled: bool,
    /// Per-node reply buffers: completions of node `n`'s channel wait
    /// here for the reply transport (unbounded — the channel's response
    /// FIFO; bandwidth is bounded at the links and port buses).
    reply_at_node: Vec<VecDeque<MemResp>>,
    /// In-transit replies per reply link, tagged with hop-arrival cycle
    /// (line/ring; empty for crossbar).
    reply_links: Vec<VecDeque<(MemResp, Cycle)>>,
    /// Reply arbitration sources per node (line/ring).
    reply_sources: Vec<Vec<ReplySource>>,
    /// Per-node reply delivery round-robin pointer (line/ring).
    rr_reply_egress: Vec<usize>,
    /// Per-node reply hop round-robin pointer (line/ring).
    rr_reply_hop: Vec<usize>,
    /// Per-port return-bus budget, reset each route call (crossbar).
    reply_port_budget: Vec<u8>,
    /// Rotating channel-scan start for crossbar reply arbitration —
    /// advanced only past a channel that actually delivered, so the
    /// event engine's skipped (no-op) route calls cannot diverge from
    /// the reference loop's.
    rr_reply_xbar: usize,
    /// Reusable per-reply-link hop budget (line/ring).
    reply_hop_budget: Vec<usize>,
    /// Replies that finished transport, `done_at` = delivery cycle.
    reply_out: VecDeque<MemResp>,
    /// Reusable completion sink for channel ticks (reply mode).
    reply_scratch: Vec<MemResp>,
    /// Replies resident in node buffers + reply links (idle/busy checks
    /// without scanning).
    reply_occupancy: usize,
    pub stats: FabricStats,
}

impl Fabric {
    pub fn new(n_ports: usize, ic: &InterconnectConfig, dram: &DramConfig) -> Fabric {
        ic.validate().expect("invalid interconnect config");
        let nodes = ic.channels;
        let topo = topology_of(ic.topology);
        let phys = topo.links(nodes);
        let mut link_id = vec![vec![None; nodes]; nodes];
        let mut link_stats = Vec::new();
        for (lid, &(from, to)) in phys.iter().enumerate() {
            link_id[from][to] = Some(lid);
            link_stats.push(LinkStats {
                label: format!("n{from}->n{to}"),
                ..LinkStats::default()
            });
        }
        if matches!(ic.topology, TopologyKind::Crossbar) {
            // Virtual port→channel links (direct arbitration, no queues).
            for p in 0..n_ports {
                for c in 0..nodes {
                    link_stats.push(LinkStats {
                        label: format!("p{p}->ch{c}"),
                        ..LinkStats::default()
                    });
                }
            }
        }
        // Egress sources per node: attached ports first (in port order),
        // then incoming links. With one node this is exactly the seed
        // router's port scan order.
        let mut sources = vec![Vec::new(); nodes];
        for p in 0..n_ports {
            sources[topo.ingress_node(p, nodes)].push(Source::Port(p));
        }
        for (lid, &(_, to)) in phys.iter().enumerate() {
            sources[to].push(Source::Link(lid));
        }
        // Reply network: dedicated reply links mirroring the physical
        // links (line/ring) or virtual per-port return buses (crossbar),
        // each with its own stats row.
        let mut reply_link_stats = Vec::new();
        let mut reply_sources = vec![Vec::new(); nodes];
        if ic.reply_network {
            match ic.topology {
                TopologyKind::Crossbar => {
                    for c in 0..nodes {
                        for p in 0..n_ports {
                            reply_link_stats.push(LinkStats {
                                label: format!("ch{c}->p{p}"),
                                ..LinkStats::default()
                            });
                        }
                    }
                }
                TopologyKind::Line | TopologyKind::Ring => {
                    for &(from, to) in &phys {
                        reply_link_stats.push(LinkStats {
                            label: format!("r:n{from}->n{to}"),
                            ..LinkStats::default()
                        });
                    }
                    // Reply sources per node: the node's own channel
                    // buffer first, then incoming reply links.
                    for (node, srcs) in reply_sources.iter_mut().enumerate() {
                        srcs.push(ReplySource::Node);
                        for (lid, &(_, to)) in phys.iter().enumerate() {
                            if to == node {
                                srcs.push(ReplySource::Link(lid));
                            }
                        }
                    }
                }
            }
        }
        let reply_sf = ic.reply_network && !matches!(ic.topology, TopologyKind::Crossbar);
        let n_reply_links = if reply_sf { phys.len() } else { 0 };
        Fabric {
            kind: ic.topology,
            chmap: ChannelMap::new(ic.channels, ic.interleave_bytes),
            channels: (0..ic.channels).map(|_| DramChannel::new(dram)).collect(),
            ingress: (0..n_ports).map(|_| VecDeque::new()).collect(),
            links: (0..phys.len()).map(|_| VecDeque::new()).collect(),
            link_id,
            sources,
            rr_egress: vec![0; nodes],
            rr_hop: vec![0; nodes],
            cmds_per_cycle: 1,
            link_width: ic.link_width,
            link_queue_cap: ic.link_queue,
            ingress_occupancy: 0,
            link_occupancy: 0,
            hop_budget: Vec::new(),
            reply_enabled: ic.reply_network,
            reply_at_node: (0..nodes).map(|_| VecDeque::new()).collect(),
            reply_links: (0..n_reply_links).map(|_| VecDeque::new()).collect(),
            reply_sources,
            rr_reply_egress: vec![0; nodes],
            rr_reply_hop: vec![0; nodes],
            reply_port_budget: vec![0; n_ports],
            rr_reply_xbar: 0,
            reply_hop_budget: Vec::new(),
            reply_out: VecDeque::new(),
            reply_scratch: Vec::new(),
            reply_occupancy: 0,
            stats: FabricStats {
                per_port_forwarded: vec![0; n_ports],
                per_channel_forwarded: vec![0; nodes],
                links: link_stats,
                reply: ReplyStats {
                    links: reply_link_stats,
                    ..ReplyStats::default()
                },
                ..FabricStats::default()
            },
        }
    }

    pub fn n_ports(&self) -> usize {
        self.ingress.len()
    }

    pub fn n_channels(&self) -> usize {
        self.channels.len()
    }

    /// Enqueue a request from port `req.port`.
    pub fn push(&mut self, req: MemReq) {
        debug_assert!(req.port < self.ingress.len());
        self.ingress[req.port].push_back(req);
        self.ingress_occupancy += 1;
    }

    /// Ingress occupancy of one port (for LMB backpressure decisions).
    pub fn port_depth(&self, port: usize) -> usize {
        self.ingress[port].len()
    }

    /// Advance every DRAM channel to `now`, collecting completions. With
    /// the reply network on, fresh completions enter the reply transport
    /// instead and `completions` receives the replies whose traversal
    /// finished by `now` (their `done_at` rewritten to the delivery
    /// cycle).
    pub fn tick_memory(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        self.tick_channels(now, completions, false, &mut Telemetry::disabled());
    }

    /// [`Fabric::tick_memory`] with a telemetry sink for the per-channel
    /// DRAM queue/service spans. Behavior is identical.
    pub fn tick_memory_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
    ) {
        self.tick_channels(now, completions, false, tel);
    }

    /// Event-driven variant of [`Fabric::tick_memory`]: only advance
    /// channels with schedulable or due work. Skipped channels are
    /// provable no-ops (empty queue, no completion due at `now`), and
    /// channel order — hence completion order — is preserved. Due reply
    /// deliveries drain unconditionally, exactly as in the ungated
    /// variant.
    pub fn tick_memory_gated(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        self.tick_channels(now, completions, true, &mut Telemetry::disabled());
    }

    /// [`Fabric::tick_memory_gated`] with a telemetry sink.
    pub fn tick_memory_gated_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
    ) {
        self.tick_channels(now, completions, true, tel);
    }

    fn tick_channels(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        gated: bool,
        tel: &mut Telemetry,
    ) {
        self.drain_due_replies(now, completions);
        for c in 0..self.channels.len() {
            if gated && !self.channels[c].needs_tick(now) {
                continue;
            }
            if self.reply_enabled {
                // Inline twin of `absorb_channel_completions` (which the
                // sharded engine uses on detached channels).
                self.reply_scratch.clear();
                self.channels[c].tick_traced(now, &mut self.reply_scratch, tel, c);
                for resp in self.reply_scratch.drain(..) {
                    self.reply_at_node[c].push_back(resp);
                    self.reply_occupancy += 1;
                }
            } else {
                self.channels[c].tick_traced(now, completions, tel, c);
            }
        }
    }

    // --- channel-shard support (parallel engine) ------------------------
    //
    // The sharded engine ticks the channel controllers on worker threads:
    // it detaches them with `take_channels`, ticks each shard against a
    // private completion sink, then re-absorbs every channel's output *in
    // channel index order* — the exact order `tick_channels` produces
    // serially, so completions (and therefore everything downstream) are
    // bit-identical at any thread count.

    /// Surface replies whose transport finished by `now` — the serial
    /// head of [`Fabric::tick_channels`] (they completed strictly before
    /// anything due at `now`), split out so the coordinating thread can
    /// run it before the channel shards tick.
    pub fn drain_due_replies(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        while let Some(resp) = self.reply_out.front() {
            if resp.done_at > now {
                break;
            }
            completions.push(self.reply_out.pop_front().unwrap());
        }
    }

    /// How many channels the gated tick would actually advance at `now` —
    /// the sharding-worthwhile test (one idle-channel scan, no mutation).
    pub fn channels_needing_tick(&self, now: Cycle) -> usize {
        self.channels.iter().filter(|d| d.needs_tick(now)).count()
    }

    /// Detach the DRAM channel controllers for shard-parallel ticking.
    /// The fabric must not be routed or ticked until [`Fabric::put_channels`]
    /// reinstalls them (the run loop does both within one phase).
    pub fn take_channels(&mut self) -> Vec<DramChannel> {
        std::mem::take(&mut self.channels)
    }

    /// Reinstall controllers detached by [`Fabric::take_channels`], in
    /// channel index order.
    pub fn put_channels(&mut self, channels: Vec<DramChannel>) {
        debug_assert!(self.channels.is_empty(), "channels already installed");
        self.channels = channels;
    }

    /// Merge one (detached) channel's tick output, exactly as the serial
    /// loop in [`Fabric::tick_channels`] does inline: with the reply
    /// network on, completions enter the channel node's reply buffer;
    /// otherwise they surface directly.
    pub fn absorb_channel_completions(
        &mut self,
        ch: usize,
        out: &mut Vec<MemResp>,
        completions: &mut Vec<MemResp>,
    ) {
        if self.reply_enabled {
            for resp in out.drain(..) {
                self.reply_at_node[ch].push_back(resp);
                self.reply_occupancy += 1;
            }
        } else {
            completions.append(out);
        }
    }

    /// Any requests or replies resident in the fabric (ingress queues,
    /// links, reply buffers)? When false, [`Fabric::route`] is a
    /// provable no-op.
    pub fn has_traffic(&self) -> bool {
        self.ingress_occupancy + self.link_occupancy + self.reply_occupancy > 0
    }

    /// Move requests — and, when modeled, replies — through the fabric
    /// for one cycle: egress into the channel controllers, one
    /// store-and-forward hop per link, then the mirror image on the
    /// reply side. Returns true if anything moved.
    pub fn route(&mut self, now: Cycle) -> bool {
        self.route_traced(now, &mut Telemetry::disabled())
    }

    /// [`Fabric::route`] with a telemetry sink for transport spans
    /// (controller delivery, store-and-forward hops, reply hops).
    /// Behavior is identical — telemetry is observation-only.
    pub fn route_traced(&mut self, now: Cycle, tel: &mut Telemetry) -> bool {
        let mut moved = match self.kind {
            TopologyKind::Crossbar => self.route_crossbar(now, tel),
            TopologyKind::Line | TopologyKind::Ring => self.route_store_forward(now, tel),
        };
        if self.reply_enabled {
            moved |= match self.kind {
                TopologyKind::Crossbar => self.route_reply_crossbar(now),
                TopologyKind::Line | TopologyKind::Ring => {
                    self.route_reply_store_forward(now, tel)
                }
            };
        }
        moved
    }

    /// Crossbar: per-channel round-robin over all port queues — the seed
    /// router's arbitration loop, one instance per channel.
    fn route_crossbar(&mut self, now: Cycle, tel: &mut Telemetry) -> bool {
        let n = self.ingress.len();
        let nch = self.channels.len();
        let mut moved = false;
        for c in 0..nch {
            let mut forwarded = 0;
            let mut scanned = 0;
            while forwarded < self.cmds_per_cycle && scanned < n {
                let port = (self.rr_egress[c] + scanned) % n;
                let Some(&req) = self.ingress[port].front() else {
                    scanned += 1;
                    continue;
                };
                let (ch, local) = self.chmap.decode(req.addr);
                if ch != c {
                    scanned += 1;
                    continue;
                }
                if !self.channels[c].can_accept() {
                    self.stats.backpressure_cycles += 1;
                    self.stats.links[port * nch + c].stall_cycles += 1;
                    break;
                }
                self.ingress[port].pop_front();
                self.ingress_occupancy -= 1;
                self.stats.links[port * nch + c].forwarded += 1;
                self.deliver(MemReq { addr: local, ..req }, c, now, tel);
                forwarded += 1;
                moved = true;
                // Advance RR past the port we just served.
                self.rr_egress[c] = (port + 1) % n;
                scanned = 0;
            }
        }
        moved
    }

    /// Line/ring: requests drain into their node's channel when they
    /// arrive, otherwise advance one link toward it (one cycle per hop,
    /// `link_width` per link per cycle, bounded queues).
    fn route_store_forward(&mut self, now: Cycle, tel: &mut Telemetry) -> bool {
        let nodes = self.channels.len();
        let topo = topology_of(self.kind);
        let mut moved = false;
        // Phase 1: egress at each node.
        for node in 0..nodes {
            let nsrc = self.sources[node].len();
            if nsrc == 0 {
                continue;
            }
            let mut forwarded = 0;
            let mut scanned = 0;
            while forwarded < self.cmds_per_cycle && scanned < nsrc {
                let si = (self.rr_egress[node] + scanned) % nsrc;
                let Some((req, dest)) = self.source_head(node, si, now) else {
                    scanned += 1;
                    continue;
                };
                if dest != node {
                    scanned += 1;
                    continue;
                }
                if !self.channels[node].can_accept() {
                    self.stats.backpressure_cycles += 1;
                    break;
                }
                self.pop_source(node, si);
                let (_, local) = self.chmap.decode(req.addr);
                self.deliver(MemReq { addr: local, ..req }, node, now, tel);
                forwarded += 1;
                moved = true;
                self.rr_egress[node] = (si + 1) % nsrc;
                scanned = 0;
            }
        }
        // Phase 2: hop in-transit requests one link forward.
        self.hop_budget.clear();
        self.hop_budget.resize(self.links.len(), self.link_width);
        for node in 0..nodes {
            let nsrc = self.sources[node].len();
            if nsrc == 0 {
                continue;
            }
            let start = self.rr_hop[node];
            let mut advanced = false;
            for k in 0..nsrc {
                let si = (start + k) % nsrc;
                let Some((req, dest)) = self.source_head(node, si, now) else {
                    continue;
                };
                if dest == node {
                    continue; // waiting on egress (channel backpressure)
                }
                let next = topo
                    .next_hop(node, dest, nodes)
                    .expect("non-local request must have a next hop");
                let lid = self.link_id[node][next].expect("route uses a physical link");
                if self.hop_budget[lid] == 0 || self.links[lid].len() >= self.link_queue_cap {
                    self.stats.links[lid].stall_cycles += 1;
                    continue;
                }
                self.pop_source(node, si);
                self.links[lid].push_back((req, now + 1));
                self.link_occupancy += 1;
                self.hop_budget[lid] -= 1;
                self.stats.links[lid].forwarded += 1;
                self.stats.hops += 1;
                tel.mem_hop(req.id, node, next, now);
                moved = true;
                if !advanced {
                    self.rr_hop[node] = (si + 1) % nsrc;
                    advanced = true;
                }
            }
        }
        moved
    }

    /// Head request of one egress source, with its destination node.
    /// Link entries become visible one cycle after the hop.
    fn source_head(&self, node: usize, si: usize, now: Cycle) -> Option<(MemReq, usize)> {
        match self.sources[node][si] {
            Source::Port(p) => {
                let req = *self.ingress[p].front()?;
                Some((req, self.chmap.decode(req.addr).0))
            }
            Source::Link(l) => match self.links[l].front() {
                Some(&(req, ready)) if ready <= now => Some((req, self.chmap.decode(req.addr).0)),
                _ => None,
            },
        }
    }

    fn pop_source(&mut self, node: usize, si: usize) {
        match self.sources[node][si] {
            Source::Port(p) => {
                self.ingress[p].pop_front();
                self.ingress_occupancy -= 1;
            }
            Source::Link(l) => {
                self.links[l].pop_front();
                self.link_occupancy -= 1;
            }
        }
    }

    /// Crossbar reply arbitration: each channel offers its FIFO head;
    /// each port accepts at most one reply per cycle over its virtual
    /// return bus (`chC->pP`). Channels are scanned round-robin (the
    /// mirror of the forward crossbar's `rr_egress`) so a contended
    /// port's bus is shared fairly instead of favoring low channel
    /// indices; a head held by an exhausted bus counts a stall on it.
    fn route_reply_crossbar(&mut self, now: Cycle) -> bool {
        let n_ports = self.ingress.len();
        let nch = self.reply_at_node.len();
        let mut moved = false;
        let mut advanced = false;
        self.reply_port_budget.fill(1);
        for k in 0..nch {
            let c = (self.rr_reply_xbar + k) % nch;
            let Some(&resp) = self.reply_at_node[c].front() else {
                continue;
            };
            let lid = c * n_ports + resp.port;
            if self.reply_port_budget[resp.port] == 0 {
                self.stats.reply.links[lid].stall_cycles += 1;
                self.stats.reply.backpressure_cycles += 1;
                continue;
            }
            self.reply_port_budget[resp.port] -= 1;
            self.reply_at_node[c].pop_front();
            self.reply_occupancy -= 1;
            self.stats.reply.links[lid].forwarded += 1;
            self.stats.reply.delivered += 1;
            self.reply_out.push_back(MemResp { done_at: now + 1, ..resp });
            moved = true;
            if !advanced {
                self.rr_reply_xbar = (c + 1) % nch;
                advanced = true;
            }
        }
        moved
    }

    /// Line/ring reply transport — the mirror image of
    /// [`Fabric::route_store_forward`]: replies drain to their port when
    /// they reach its ingress node (one per node per cycle), otherwise
    /// advance one reply link toward it (one cycle per hop, `link_width`
    /// per link per cycle, bounded queues with backpressure).
    fn route_reply_store_forward(&mut self, now: Cycle, tel: &mut Telemetry) -> bool {
        let nodes = self.channels.len();
        let topo = topology_of(self.kind);
        let mut moved = false;
        // Phase 1: delivery at each node.
        for node in 0..nodes {
            let nsrc = self.reply_sources[node].len();
            if nsrc == 0 {
                continue;
            }
            let mut delivered = 0;
            let mut scanned = 0;
            while delivered < self.cmds_per_cycle && scanned < nsrc {
                let si = (self.rr_reply_egress[node] + scanned) % nsrc;
                let Some((resp, dest)) = self.reply_source_head(node, si, now) else {
                    scanned += 1;
                    continue;
                };
                if dest != node {
                    scanned += 1;
                    continue;
                }
                self.pop_reply_source(node, si);
                self.stats.reply.delivered += 1;
                self.reply_out.push_back(MemResp { done_at: now + 1, ..resp });
                delivered += 1;
                moved = true;
                self.rr_reply_egress[node] = (si + 1) % nsrc;
                scanned = 0;
            }
        }
        // Phase 2: hop in-transit replies one reply link forward.
        self.reply_hop_budget.clear();
        self.reply_hop_budget.resize(self.reply_links.len(), self.link_width);
        for node in 0..nodes {
            let nsrc = self.reply_sources[node].len();
            if nsrc == 0 {
                continue;
            }
            let start = self.rr_reply_hop[node];
            let mut advanced = false;
            for k in 0..nsrc {
                let si = (start + k) % nsrc;
                let Some((resp, dest)) = self.reply_source_head(node, si, now) else {
                    continue;
                };
                if dest == node {
                    continue; // waiting on the delivery budget
                }
                let next = topo
                    .next_hop(node, dest, nodes)
                    .expect("non-local reply must have a next hop");
                let lid = self.link_id[node][next].expect("reply route uses a physical link");
                if self.reply_hop_budget[lid] == 0
                    || self.reply_links[lid].len() >= self.link_queue_cap
                {
                    self.stats.reply.links[lid].stall_cycles += 1;
                    continue;
                }
                self.pop_reply_source(node, si);
                self.reply_links[lid].push_back((resp, now + 1));
                self.reply_occupancy += 1;
                self.reply_hop_budget[lid] -= 1;
                self.stats.reply.links[lid].forwarded += 1;
                self.stats.reply.hops += 1;
                tel.mem_reply_hop(resp.id, node, next, now);
                moved = true;
                if !advanced {
                    self.rr_reply_hop[node] = (si + 1) % nsrc;
                    advanced = true;
                }
            }
        }
        moved
    }

    /// Head reply of one reply source, with its destination node (the
    /// requesting port's ingress node). Reply-link entries become
    /// visible one cycle after the hop.
    fn reply_source_head(&self, node: usize, si: usize, now: Cycle) -> Option<(MemResp, usize)> {
        let nodes = self.channels.len();
        let topo = topology_of(self.kind);
        match self.reply_sources[node][si] {
            ReplySource::Node => {
                let resp = *self.reply_at_node[node].front()?;
                Some((resp, topo.ingress_node(resp.port, nodes)))
            }
            ReplySource::Link(l) => match self.reply_links[l].front() {
                Some(&(resp, ready)) if ready <= now => {
                    Some((resp, topo.ingress_node(resp.port, nodes)))
                }
                _ => None,
            },
        }
    }

    fn pop_reply_source(&mut self, node: usize, si: usize) {
        match self.reply_sources[node][si] {
            ReplySource::Node => {
                self.reply_at_node[node].pop_front();
            }
            ReplySource::Link(l) => {
                self.reply_links[l].pop_front();
            }
        }
        self.reply_occupancy -= 1;
    }

    /// Hand a request (already rewritten to its channel-local address)
    /// to channel `ch`'s controller.
    fn deliver(&mut self, req: MemReq, ch: usize, now: Cycle, tel: &mut Telemetry) {
        self.stats.per_port_forwarded[req.port] += 1;
        self.stats.per_channel_forwarded[ch] += 1;
        self.stats.forwarded += 1;
        tel.mem_delivered(req.id, ch, now);
        self.channels[ch].push(req, now);
    }

    /// Earliest in-flight DRAM completion across all channels.
    pub fn next_completion(&self) -> Option<Cycle> {
        self.channels.iter().filter_map(DramChannel::next_event).min()
    }

    /// Earliest future cycle a queued DRAM request could issue, across
    /// all channels (run-loop idle fast-forward).
    pub fn next_schedule_time(&self, now: Cycle) -> Option<Cycle> {
        self.channels.iter().filter_map(|d| d.next_schedule_time(now)).min()
    }

    /// Earliest future cycle at which fabric transport itself can make
    /// progress. `None` for the crossbar with the reply network off
    /// (ingress→controller transfer is combinational within
    /// [`Fabric::route`], so the DRAM-side events fully cover its
    /// wakeups — exactly the seed router's candidates).
    pub fn next_transit_time(&self, now: Cycle) -> Option<Cycle> {
        let mut t: Option<Cycle> = None;
        let mut fold = |t: &mut Option<Cycle>, c: Cycle| {
            *t = Some(t.map_or(c, |x| x.min(c)));
        };
        if !matches!(self.kind, TopologyKind::Crossbar) {
            // Deliberately conservative: a non-empty ingress queue pins
            // the fast-forward to the next cycle even when the head is
            // blocked on a chain that bottoms out in a DRAM event
            // (already covered by the other candidates). Costs host time
            // in backpressured line/ring phases, never correctness.
            if self.ingress_occupancy > 0 {
                fold(&mut t, now + 1);
            }
            for l in &self.links {
                if let Some(&(_, ready)) = l.front() {
                    fold(&mut t, ready.max(now + 1));
                }
            }
        }
        // Reply side (same conservatism): anything resident in the reply
        // transport, or a finished reply awaiting its delivery cycle,
        // wants a visit next cycle.
        if self.reply_enabled && (self.reply_occupancy > 0 || !self.reply_out.is_empty()) {
            fold(&mut t, now + 1);
        }
        t
    }

    pub fn is_idle(&self) -> bool {
        self.ingress_occupancy == 0
            && self.link_occupancy == 0
            && self.reply_occupancy == 0
            && self.reply_out.is_empty()
            && self.channels.iter().all(DramChannel::is_idle)
    }

    /// Per-channel DRAM statistics snapshots.
    pub fn channel_stats(&self) -> Vec<DramStats> {
        self.channels.iter().map(|d| d.stats().clone()).collect()
    }

    /// Requests resident (queued + in flight) per channel — the
    /// instantaneous occupancy the telemetry timeline samples.
    pub fn channel_occupancy(&self) -> Vec<u64> {
        self.channels.iter().map(|d| d.occupancy() as u64).collect()
    }

    /// All channels folded into one aggregate (the seed report's view).
    pub fn aggregate_dram_stats(&self) -> DramStats {
        let mut agg = DramStats::default();
        for d in &self.channels {
            agg.merge(d.stats());
        }
        agg
    }

    /// Request bandwidth of one link, for utilization reporting.
    pub fn link_width(&self) -> usize {
        match self.kind {
            TopologyKind::Crossbar => 1,
            _ => self.link_width,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dram::Dram;
    use crate::sim::router::Router;

    fn req(id: u64, addr: u64, port: usize) -> MemReq {
        MemReq {
            id,
            addr,
            bytes: 64,
            is_write: false,
            port,
        }
    }

    fn ic(channels: usize, topology: TopologyKind) -> InterconnectConfig {
        InterconnectConfig {
            channels,
            topology,
            ..InterconnectConfig::single_channel()
        }
    }

    // --- route computation ---------------------------------------------

    #[test]
    fn crossbar_routes_are_direct() {
        let t = Crossbar;
        for at in 0..4 {
            for dest in 0..4 {
                assert_eq!(t.next_hop(at, dest, 4), None);
            }
        }
        assert!(t.links(4).is_empty());
        assert_eq!(t.route_hops(3, 2, 4), 0);
    }

    #[test]
    fn line_routes_walk_monotonically() {
        let t = Line;
        assert_eq!(t.next_hop(0, 3, 4), Some(1));
        assert_eq!(t.next_hop(1, 3, 4), Some(2));
        assert_eq!(t.next_hop(3, 0, 4), Some(2));
        assert_eq!(t.next_hop(2, 2, 4), None);
        // port p enters at node p % nodes; hops = |entry - dest|.
        assert_eq!(t.route_hops(0, 3, 4), 3);
        assert_eq!(t.route_hops(5, 0, 4), 1);
        assert_eq!(t.links(4).len(), 6); // 3 pairs, both directions
        assert_eq!(t.links(1).len(), 0);
    }

    #[test]
    fn ring_takes_shortest_direction() {
        let t = Ring;
        // 0 -> 3 on 4 nodes: counter-clockwise is 1 hop.
        assert_eq!(t.next_hop(0, 3, 4), Some(3));
        // 0 -> 1: clockwise 1 hop.
        assert_eq!(t.next_hop(0, 1, 4), Some(1));
        // Tie (0 -> 2 on 4 nodes) goes clockwise.
        assert_eq!(t.next_hop(0, 2, 4), Some(1));
        assert_eq!(t.route_hops(0, 3, 4), 1);
        assert_eq!(t.route_hops(0, 2, 4), 2);
        assert_eq!(t.links(4).len(), 8);
        assert_eq!(t.links(2).len(), 2);
        assert_eq!(t.links(1).len(), 0);
    }

    #[test]
    fn ring_routes_always_terminate() {
        for nodes in [1usize, 2, 4, 8] {
            for port in 0..8 {
                for dest in 0..nodes {
                    let hops = Ring.route_hops(port, dest, nodes);
                    assert!(hops <= nodes / 2, "ring hop count {hops} too long");
                }
            }
        }
    }

    // --- transport ------------------------------------------------------

    /// Drive arrivals through the seed Router -> Dram pipe with the
    /// system run-loop's ordering; returns sorted (id, done_at).
    fn drive_router(arrivals: &[(Cycle, MemReq)], n_ports: usize) -> Vec<(u64, Cycle)> {
        let mut dram = Dram::new(&DramConfig::mig_u250());
        let mut router = Router::new(n_ports, 1);
        let mut out = Vec::new();
        let mut completions = Vec::new();
        let mut i = 0;
        for now in 0..1_000_000u64 {
            completions.clear();
            dram.tick(now, &mut completions);
            out.extend(completions.iter().map(|c| (c.id, c.done_at)));
            while i < arrivals.len() && arrivals[i].0 <= now {
                router.push(arrivals[i].1);
                i += 1;
            }
            router.tick(&mut dram, now);
            if i == arrivals.len() && router.is_idle() && dram.is_idle() {
                break;
            }
        }
        assert_eq!(out.len(), arrivals.len(), "router run did not drain");
        out.sort_unstable();
        out
    }

    /// Same loop through the fabric.
    fn drive_fabric(
        arrivals: &[(Cycle, MemReq)],
        n_ports: usize,
        ic: &InterconnectConfig,
    ) -> (Vec<(u64, Cycle)>, FabricStats) {
        let mut fab = Fabric::new(n_ports, ic, &DramConfig::mig_u250());
        let mut out = Vec::new();
        let mut completions = Vec::new();
        let mut i = 0;
        for now in 0..1_000_000u64 {
            completions.clear();
            fab.tick_memory(now, &mut completions);
            out.extend(completions.iter().map(|c| (c.id, c.done_at)));
            while i < arrivals.len() && arrivals[i].0 <= now {
                fab.push(arrivals[i].1);
                i += 1;
            }
            fab.route(now);
            if i == arrivals.len() && fab.is_idle() {
                break;
            }
        }
        assert_eq!(out.len(), arrivals.len(), "fabric run did not drain");
        out.sort_unstable();
        (out, fab.stats)
    }

    /// A mixed trace: four ports, streams + scatters + a write burst.
    fn mixed_trace() -> Vec<(Cycle, MemReq)> {
        let mut tr = Vec::new();
        let mut id = 0u64;
        for g in 0..64u64 {
            for port in 0..4usize {
                id += 1;
                let addr = match port {
                    0 => g * 64,                               // stream
                    1 => (g * 1_048_576 + g * 64) % (1 << 30), // row scatter
                    2 => 262_144 + g * 4096,                   // granule hops
                    _ => 524_288 + (g % 8) * 64,               // reuse
                };
                let mut r = req(id, addr, port);
                r.is_write = port == 3 && g % 4 == 0;
                tr.push((g / 2, r));
            }
        }
        tr
    }

    #[test]
    fn single_channel_crossbar_is_bit_identical_to_seed_router() {
        let tr = mixed_trace();
        let want = drive_router(&tr, 4);
        let (got, stats) = drive_fabric(&tr, 4, &ic(1, TopologyKind::Crossbar));
        assert_eq!(got, want, "fabric must replay the seed router");
        assert_eq!(stats.forwarded, tr.len() as u64);
        assert_eq!(stats.hops, 0);
    }

    #[test]
    fn single_channel_line_and_ring_also_degenerate_to_seed_router() {
        // With one node there is nothing to hop: every topology must
        // collapse to the same arbitration loop.
        let tr = mixed_trace();
        let want = drive_router(&tr, 4);
        for topo in [TopologyKind::Line, TopologyKind::Ring] {
            let (got, stats) = drive_fabric(&tr, 4, &ic(1, topo));
            assert_eq!(got, want, "{topo:?} with 1 channel diverged from seed");
            assert_eq!(stats.hops, 0);
        }
    }

    #[test]
    fn interleaving_spreads_traffic_over_all_channels() {
        let tr = mixed_trace();
        let (done, stats) = drive_fabric(&tr, 4, &ic(4, TopologyKind::Crossbar));
        assert_eq!(done.len(), tr.len());
        for (c, n) in stats.per_channel_forwarded.iter().enumerate() {
            assert!(*n > 0, "channel {c} got no traffic");
        }
        let total: u64 = stats.per_channel_forwarded.iter().sum();
        assert_eq!(total, tr.len() as u64);
    }

    #[test]
    fn four_channels_beat_one_on_parallel_streams() {
        let tr = mixed_trace();
        let (one, _) = drive_fabric(&tr, 4, &ic(1, TopologyKind::Crossbar));
        let (four, _) = drive_fabric(&tr, 4, &ic(4, TopologyKind::Crossbar));
        let makespan = |v: &[(u64, Cycle)]| v.iter().map(|&(_, t)| t).max().unwrap();
        assert!(
            makespan(&four) < makespan(&one),
            "4-channel crossbar {} !< single channel {}",
            makespan(&four),
            makespan(&one)
        );
    }

    #[test]
    fn store_and_forward_hops_are_counted_and_delayed() {
        // 2-node line, port 0 at node 0 sends everything to channel 1:
        // every request crosses link n0->n1 exactly once.
        let icfg = ic(2, TopologyKind::Line);
        let tr: Vec<(Cycle, MemReq)> = (0..8u64)
            .map(|i| (0, req(i + 1, 4096 + i * 8192 * 2, 0))) // granule 1, 3, 5... all channel 1
            .collect();
        // granule of addr 4096+i*16384 with interleave 4096: (addr/4096) % 2 == 1.
        let (done, stats) = drive_fabric(&tr, 1, &icfg);
        assert_eq!(done.len(), 8);
        assert_eq!(stats.hops, 8);
        let fwd: u64 = stats
            .links
            .iter()
            .filter(|l| l.label == "n0->n1")
            .map(|l| l.forwarded)
            .sum();
        assert_eq!(fwd, 8);
        // And the hop adds at least one cycle versus a crossbar.
        let (xbar, _) = drive_fabric(&tr, 1, &ic(2, TopologyKind::Crossbar));
        let makespan = |v: &[(u64, Cycle)]| v.iter().map(|&(_, t)| t).max().unwrap();
        assert!(makespan(&done) > makespan(&xbar));
    }

    #[test]
    fn narrow_link_backpressures_and_still_drains() {
        // 4-node line, all traffic from port 0 (node 0) to channel 3:
        // three hops per request over width-1, depth-1 links.
        let icfg = InterconnectConfig {
            channels: 4,
            topology: TopologyKind::Line,
            link_width: 1,
            link_queue: 1,
            interleave_bytes: 4096,
            reply_network: false,
        };
        let tr: Vec<(Cycle, MemReq)> = (0..16u64)
            .map(|i| (0, req(i + 1, 3 * 4096 + i * 4 * 4096, 0))) // granule ≡ 3 (mod 4)
            .collect();
        let (done, stats) = drive_fabric(&tr, 1, &icfg);
        assert_eq!(done.len(), 16, "must drain despite backpressure");
        assert_eq!(stats.hops, 16 * 3);
        let stalls: u64 = stats.links.iter().map(|l| l.stall_cycles).sum();
        assert!(stalls > 0, "depth-1 links must report contention");
    }

    #[test]
    fn crossbar_reports_per_virtual_link_counters() {
        let tr = mixed_trace();
        let (_, stats) = drive_fabric(&tr, 4, &ic(2, TopologyKind::Crossbar));
        assert_eq!(stats.links.len(), 4 * 2);
        let total: u64 = stats.links.iter().map(|l| l.forwarded).sum();
        assert_eq!(total, tr.len() as u64);
        // Utilization is a sane fraction.
        for l in &stats.links {
            let u = l.utilization(10_000, 1);
            assert!((0.0..=1.0).contains(&u));
        }
    }

    // --- reply network ---------------------------------------------------

    fn ic_reply(channels: usize, topology: TopologyKind) -> InterconnectConfig {
        InterconnectConfig {
            reply_network: true,
            ..ic(channels, topology)
        }
    }

    #[test]
    fn reply_network_off_keeps_reply_stats_empty() {
        let tr = mixed_trace();
        let (_, stats) = drive_fabric(&tr, 4, &ic(2, TopologyKind::Crossbar));
        assert_eq!(stats.reply.delivered, 0);
        assert_eq!(stats.reply.hops, 0);
        assert!(stats.reply.links.is_empty(), "no reply links exist when off");
    }

    #[test]
    fn reply_network_delivers_every_completion_exactly_once() {
        let tr = mixed_trace();
        for topo in [TopologyKind::Crossbar, TopologyKind::Line, TopologyKind::Ring] {
            for channels in [1usize, 2, 4] {
                let (done, stats) = drive_fabric(&tr, 4, &ic_reply(channels, topo));
                assert_eq!(done.len(), tr.len(), "{topo:?}/{channels}ch lost replies");
                assert_eq!(stats.reply.delivered, tr.len() as u64);
                let link_fwd: u64 = stats
                    .reply
                    .links
                    .iter()
                    .filter(|l| l.label.starts_with("r:"))
                    .map(|l| l.forwarded)
                    .sum();
                assert_eq!(link_fwd, stats.reply.hops, "{topo:?} reply hop accounting");
            }
        }
    }

    #[test]
    fn reply_network_adds_at_least_one_cycle_per_completion() {
        // Same trace, same channel model: with the response path modeled
        // every completion reaches the port strictly later than the
        // combinational return, and never earlier.
        let tr = mixed_trace();
        for topo in TopologyKind::ALL {
            let (free, _) = drive_fabric(&tr, 4, &ic(2, topo));
            let (modeled, _) = drive_fabric(&tr, 4, &ic_reply(2, topo));
            for (&(id_f, t_f), &(id_m, t_m)) in free.iter().zip(&modeled) {
                assert_eq!(id_f, id_m);
                assert!(
                    t_m > t_f,
                    "{topo:?}: reply id {id_f} at {t_m} not after free-path {t_f}"
                );
            }
        }
    }

    #[test]
    fn reply_hops_mirror_the_return_route() {
        // 2-node line, port 0 at node 0, all traffic to channel 1: each
        // reply crosses reply link r:n1->n0 exactly once.
        let tr: Vec<(Cycle, MemReq)> = (0..8u64)
            .map(|i| (0, req(i + 1, 4096 + i * 8192 * 2, 0)))
            .collect();
        let (done, stats) = drive_fabric(&tr, 1, &ic_reply(2, TopologyKind::Line));
        assert_eq!(done.len(), 8);
        assert_eq!(stats.reply.hops, 8);
        let fwd: u64 = stats
            .reply
            .links
            .iter()
            .filter(|l| l.label == "r:n1->n0")
            .map(|l| l.forwarded)
            .sum();
        assert_eq!(fwd, 8);
    }

    #[test]
    fn crossbar_return_bus_serializes_same_port_replies() {
        // Two channels completing in lockstep for one port: the free
        // return path hands the port several completions per cycle, the
        // modeled per-port return bus takes exactly one reply per cycle
        // — and the rotating arbiter shares the bus across channels
        // instead of starving the higher channel index.
        let tr: Vec<(Cycle, MemReq)> = (0..32u64)
            .map(|i| (0, req(i + 1, i * 4096, 0))) // alternate channels
            .collect();
        let (free, _) = drive_fabric(&tr, 1, &ic(2, TopologyKind::Crossbar));
        let (done, stats) = drive_fabric(&tr, 1, &ic_reply(2, TopologyKind::Crossbar));
        assert_eq!(done.len(), 32);
        assert_eq!(stats.reply.delivered, 32);
        let dups = |v: &[(u64, Cycle)]| {
            let mut times: Vec<Cycle> = v.iter().map(|&(_, t)| t).collect();
            times.sort_unstable();
            times.windows(2).filter(|w| w[0] == w[1]).count()
        };
        assert!(dups(&free) > 0, "trace must produce same-cycle completions");
        assert_eq!(dups(&done), 0, "one reply per port per cycle");
        for l in &stats.reply.links {
            assert!(l.forwarded > 0, "starved return bus {}: {:?}", l.label, l);
        }
    }

    #[test]
    fn fabric_backpressures_ports_like_the_router() {
        let mut fab = Fabric::new(
            2,
            &ic(1, TopologyKind::Crossbar),
            &DramConfig {
                max_outstanding: 2,
                ..DramConfig::mig_u250()
            },
        );
        for i in 0..4 {
            fab.push(req(i + 1, i * 64, 0));
        }
        fab.route(0);
        fab.route(1);
        fab.route(2); // controller full
        assert_eq!(fab.stats.forwarded, 2);
        assert!(fab.stats.backpressure_cycles >= 1);
        assert_eq!(fab.port_depth(0), 2);
    }
}
