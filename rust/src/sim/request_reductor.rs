//! Request Reductor (RR) — Fig. 3: a 2-stage pipeline that converts
//! element-wise reads from PEs into cache-line accesses.
//!
//! Stage 1: probe the CAM [`TempBuffer`] of recently received lines —
//! hits are served locally without any cache traffic.
//! Stage 2: probe/update the [`Rrsh`] — requests to already-pending lines
//! are absorbed; new lines forward exactly one line request to the cache.
//!
//! When a cache reply (a whole line, §IV-B) comes back, the RR stores it
//! in the temporary buffer and fans the requested elements out to each
//! waiting PE.
//!
//! With `lmb_banks > 1` each [`super::lmb::Lmb`] instantiates one RR per
//! bank over a sharded RRSH (entries divided across banks; the CAM stays
//! per-bank — see [`crate::config::SystemConfig::bank_rr`]). A single RR
//! instance never sees addresses outside its bank's interleave granules,
//! so its behavior is unchanged; only the address stream it observes is.

use super::rrsh::{Rrsh, RrshOutcome, RrshToken};
use super::temp_buffer::TempBuffer;
use super::{Cycle, Delivery};
use crate::config::RrConfig;
use crate::util::log2;

/// Result of presenting an element load to the RR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RrResult {
    /// Served from the temporary buffer after the RR pipeline delay.
    Served { ready_at: Cycle },
    /// New pending line: the LMB must forward one line load to the cache.
    ForwardLine { line: u64 },
    /// Joined an existing pending line (no cache traffic).
    Absorbed,
    /// Structural stall (RRSH conflict/full); retry next cycle.
    Stall,
}

/// RR statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RrStats {
    pub served_temp: u64,
    pub forwarded: u64,
    pub absorbed: u64,
    pub stalls: u64,
}

impl RrStats {
    /// Fold another bank's counters into this one (per-LMB aggregate
    /// over its RR banks).
    pub fn merge(&mut self, other: &RrStats) {
        self.served_temp += other.served_temp;
        self.forwarded += other.forwarded;
        self.absorbed += other.absorbed;
        self.stalls += other.stalls;
    }
}

/// The Request Reductor unit.
pub struct RequestReductor {
    temp: TempBuffer,
    rrsh: Rrsh,
    pipeline: Cycle,
    line_shift: u32,
    /// Reusable buffer for RRSH waiter release (hot path, no allocation).
    waiter_scratch: Vec<RrshToken>,
    pub stats: RrStats,
}

impl RequestReductor {
    pub fn new(cfg: &RrConfig, line_bytes: u64, n_pes: usize) -> RequestReductor {
        let elems_per_line = (line_bytes / 16).max(1) as usize;
        RequestReductor {
            temp: TempBuffer::new(cfg.temp_buffer_entries),
            rrsh: Rrsh::new(cfg.rrsh_entries, n_pes, elems_per_line),
            pipeline: cfg.pipeline_stages,
            line_shift: log2(line_bytes),
            waiter_scratch: Vec::new(),
            stats: RrStats::default(),
        }
    }

    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    /// Present an element load from a PE.
    pub fn element_load(&mut self, addr: u64, token: RrshToken, now: Cycle) -> RrResult {
        let line = self.line_of(addr);
        // Stage 1: CAM probe.
        if self.temp.probe(line) {
            self.stats.served_temp += 1;
            return RrResult::Served {
                ready_at: now + self.pipeline,
            };
        }
        // Stage 2: RRSH.
        match self.rrsh.request(line, token) {
            RrshOutcome::Forward => {
                self.stats.forwarded += 1;
                RrResult::ForwardLine { line }
            }
            RrshOutcome::Absorbed => {
                self.stats.absorbed += 1;
                RrResult::Absorbed
            }
            RrshOutcome::Stall => {
                self.stats.stalls += 1;
                RrResult::Stall
            }
        }
    }

    /// A full cache line arrived from the cache: buffer it and release
    /// all waiters into `out` — one [`Delivery`] per waiter, fanned out
    /// one PE port per cycle after the pipeline delay. Appends to `out`
    /// without allocating.
    pub fn line_arrived_into(&mut self, line: u64, now: Cycle, out: &mut Vec<Delivery>) {
        self.temp.insert(line);
        self.waiter_scratch.clear();
        self.rrsh.complete_into(line, &mut self.waiter_scratch);
        for (i, &token) in self.waiter_scratch.iter().enumerate() {
            out.push(Delivery {
                token,
                at: now + self.pipeline + i as Cycle,
            });
        }
    }

    /// Lines still pending a cache reply.
    pub fn outstanding(&self) -> usize {
        self.rrsh.outstanding_lines()
    }

    pub fn temp_hit_rate(&self) -> f64 {
        self.temp.hit_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rr() -> RequestReductor {
        let cfg = RrConfig {
            rrsh_entries: 64,
            temp_buffer_entries: 8,
            pipeline_stages: 2,
        };
        RequestReductor::new(&cfg, 64, 4)
    }

    #[test]
    fn forward_absorb_release_cycle() {
        let mut r = rr();
        // Four elements of the same 64 B line.
        assert_eq!(r.element_load(0, 1, 0), RrResult::ForwardLine { line: 0 });
        assert_eq!(r.element_load(16, 2, 1), RrResult::Absorbed);
        assert_eq!(r.element_load(32, 3, 1), RrResult::Absorbed);
        let mut released = Vec::new();
        r.line_arrived_into(0, 10, &mut released);
        assert_eq!(released.len(), 3);
        // Fan-out: one PE port per cycle after the 2-stage pipeline.
        assert_eq!(released[0], Delivery { token: 1, at: 12 });
        assert_eq!(released[1], Delivery { token: 2, at: 13 });
        assert_eq!(released[2], Delivery { token: 3, at: 14 });
        // Element 4 of the line now hits the temp buffer.
        match r.element_load(48, 4, 20) {
            RrResult::Served { ready_at } => assert_eq!(ready_at, 22),
            other => panic!("expected Served, got {other:?}"),
        }
        assert_eq!(r.stats.forwarded, 1);
        assert_eq!(r.stats.absorbed, 2);
        assert_eq!(r.stats.served_temp, 1);
    }

    #[test]
    fn cache_traffic_reduction_factor() {
        // Sequential 16 B element stream: only 1 in 4 accesses should
        // reach the cache (the paper's "drastically reduces the memory
        // traffic" claim, quantified).
        let mut r = rr();
        let mut to_cache = 0;
        let mut released = Vec::new();
        for z in 0..4000u64 {
            let addr = z * 16;
            match r.element_load(addr, z, z) {
                RrResult::ForwardLine { line } => {
                    to_cache += 1;
                    // Immediate reply (hit in cache).
                    released.clear();
                    r.line_arrived_into(line, z, &mut released);
                }
                RrResult::Served { .. } => {}
                RrResult::Absorbed => {}
                RrResult::Stall => panic!("stall on sequential stream"),
            }
        }
        assert_eq!(to_cache, 1000);
        assert!(r.temp_hit_rate() > 0.7, "temp hit rate {}", r.temp_hit_rate());
    }

    #[test]
    fn outstanding_counts_pending_lines() {
        let mut r = rr();
        r.element_load(0, 1, 0);
        r.element_load(64, 2, 0);
        assert_eq!(r.outstanding(), 2);
        r.line_arrived_into(0, 5, &mut Vec::new());
        assert_eq!(r.outstanding(), 1);
    }
}
