//! Simulation report: the paper's metric (total memory access time) plus
//! per-component counters for analysis and ablations.

use crate::util::json::Json;

use super::cache::CacheStats;
use super::dma::DmaStats;
use super::dram::DramStats;
use super::pe::LatencyStats;
use super::request_reductor::RrStats;
use super::Cycle;

/// Per-LMB statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct LmbStats {
    pub cache: CacheStats,
    pub rr: RrStats,
    pub dma: DmaStats,
}

/// Complete result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// System label (e.g. "config-a" / "config-a-cache-only").
    pub label: String,
    /// Workload name (e.g. "synth01").
    pub workload: String,
    /// The paper's Fig. 4 metric: total memory access time in user-clock
    /// cycles (makespan from first issue to last completion).
    pub total_cycles: Cycle,
    /// Nonzeros processed.
    pub nnz: u64,
    /// PE-visible accesses served (elements + fibers + stores).
    pub accesses: u64,
    /// Bytes the PEs asked for (excl. alignment garbage).
    pub requested_bytes: u64,
    pub dram: DramStats,
    pub lmbs: Vec<LmbStats>,
    /// PE-observed latency per access slot: [element, fiber-load,
    /// fiber-load, store] — the paper's per-class "minimum latency" view.
    pub latency: [LatencyStats; 4],
    /// Wall-clock seconds the simulation itself took (host time).
    pub host_seconds: f64,
}

impl SimReport {
    /// Simulated memory bandwidth actually delivered (bytes/cycle).
    pub fn bytes_per_cycle(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            (self.dram.read_bytes + self.dram.write_bytes) as f64 / self.total_cycles as f64
        }
    }

    /// Nonzeros processed per cycle (the compute-side view).
    pub fn nnz_per_cycle(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.nnz as f64 / self.total_cycles as f64
        }
    }

    /// Speedup of this run relative to a baseline run on the same
    /// workload (baseline_cycles / self_cycles) — Fig. 4's y-axis.
    pub fn speedup_over(&self, baseline: &SimReport) -> f64 {
        assert_eq!(self.workload, baseline.workload, "speedup across workloads");
        if self.total_cycles == 0 {
            0.0
        } else {
            baseline.total_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Aggregate cache hit rate over all LMBs.
    pub fn cache_hit_rate(&self) -> f64 {
        let (mut h, mut a) = (0u64, 0u64);
        for l in &self.lmbs {
            h += l.cache.hits;
            a += l.cache.accesses();
        }
        if a == 0 {
            0.0
        } else {
            h as f64 / a as f64
        }
    }

    /// Mean PE-observed latency of element loads (cycles).
    pub fn elem_latency_mean(&self) -> f64 {
        self.latency[0].mean()
    }

    /// Mean PE-observed latency of fiber loads (cycles).
    pub fn fiber_latency_mean(&self) -> f64 {
        let (a, b) = (&self.latency[1], &self.latency[2]);
        let n = a.count + b.count;
        if n == 0 {
            0.0
        } else {
            (a.total + b.total) as f64 / n as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("elem_latency_mean", Json::num(self.elem_latency_mean())),
            ("fiber_latency_mean", Json::num(self.fiber_latency_mean())),
            ("workload", Json::str(self.workload.clone())),
            ("total_cycles", Json::num(self.total_cycles as f64)),
            ("nnz", Json::num(self.nnz as f64)),
            ("accesses", Json::num(self.accesses as f64)),
            ("requested_bytes", Json::num(self.requested_bytes as f64)),
            ("bytes_per_cycle", Json::num(self.bytes_per_cycle())),
            ("nnz_per_cycle", Json::num(self.nnz_per_cycle())),
            ("cache_hit_rate", Json::num(self.cache_hit_rate())),
            (
                "dram",
                Json::obj(vec![
                    ("reads", Json::num(self.dram.reads as f64)),
                    ("writes", Json::num(self.dram.writes as f64)),
                    ("read_bytes", Json::num(self.dram.read_bytes as f64)),
                    ("write_bytes", Json::num(self.dram.write_bytes as f64)),
                    ("row_hit_rate", Json::num(self.dram.row_hit_rate())),
                ]),
            ),
            ("host_seconds", Json::num(self.host_seconds)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cycles: Cycle) -> SimReport {
        SimReport {
            label: "x".into(),
            workload: "w".into(),
            total_cycles: cycles,
            nnz: 100,
            accesses: 400,
            requested_bytes: 6400,
            dram: DramStats {
                read_bytes: 5000,
                write_bytes: 1000,
                ..Default::default()
            },
            lmbs: vec![],
            latency: Default::default(),
            host_seconds: 0.0,
        }
    }

    #[test]
    fn derived_metrics() {
        let r = report(1000);
        assert!((r.bytes_per_cycle() - 6.0).abs() < 1e-12);
        assert!((r.nnz_per_cycle() - 0.1).abs() < 1e-12);
        let base = report(3500);
        assert!((base.speedup_over(&base) - 1.0).abs() < 1e-12);
        assert!((r.speedup_over(&base) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn json_round_trip_fields() {
        let j = report(10).to_json();
        assert_eq!(j.get("total_cycles").unwrap().as_usize(), Some(10));
        assert!(j.get("dram").unwrap().get("row_hit_rate").is_some());
    }
}
