//! Simulation report: the paper's metric (total memory access time) plus
//! per-component counters for analysis and ablations.

use crate::util::json::Json;

use super::cache::CacheStats;
use super::dma::DmaStats;
use super::dram::DramStats;
use super::fabric::FabricStats;
use super::pe::LatencyStats;
use super::request_reductor::RrStats;
use super::Cycle;

/// Per-bank statistics snapshot (one cache + RR bank of an LMB).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LmbBankStats {
    pub cache: CacheStats,
    pub rr: RrStats,
}

impl LmbBankStats {
    /// Element requests this bank handled (its share of the LMB's
    /// element stream — the per-bank utilization view).
    pub fn requests(&self) -> u64 {
        self.rr.served_temp + self.rr.forwarded + self.rr.absorbed + self.cache.accesses()
    }
}

/// Per-LMB statistics snapshot. `cache`/`rr` are the aggregates over the
/// LMB's banks (the pre-bank report view); `banks` holds the per-bank
/// breakdown (`lmb_banks` entries — one with the default config).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LmbStats {
    pub cache: CacheStats,
    pub rr: RrStats,
    pub dma: DmaStats,
    pub banks: Vec<LmbBankStats>,
}

/// Aggregate PE front-end counters (summed over all front ends). In the
/// report so the engine-equivalence oracle also covers the PE issue
/// path — `stall_cycles` in particular accrues stall-episode *durations*
/// (first-stall cycle to dispatch cycle, see
/// [`super::pe::PeFrontEnd::stall_since`]), a definition both engines
/// compute identically even when the event engine skips ahead.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PeAggStats {
    pub retired: u64,
    pub issued_accesses: u64,
    pub stall_cycles: u64,
}

/// Complete result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// System label (e.g. "config-a" / "config-a-cache-only").
    pub label: String,
    /// Workload name (e.g. "synth01").
    pub workload: String,
    /// The paper's Fig. 4 metric: total memory access time in user-clock
    /// cycles (makespan from first issue to last completion).
    pub total_cycles: Cycle,
    /// Nonzeros processed.
    pub nnz: u64,
    /// PE-visible accesses served (elements + fibers + stores).
    pub accesses: u64,
    /// Bytes the PEs asked for (excl. alignment garbage).
    pub requested_bytes: u64,
    /// Aggregate over all DRAM channels (the seed single-MIG view).
    pub dram: DramStats,
    /// Per-channel DRAM counters (one entry per interconnect channel).
    pub channels: Vec<DramStats>,
    /// Interconnect fabric counters (per-port, per-channel, per-link).
    pub fabric: FabricStats,
    /// Request bandwidth of one fabric link (for link utilization).
    pub link_width: usize,
    pub lmbs: Vec<LmbStats>,
    /// Aggregate PE front-end counters (issue/stall/retire).
    pub pe: PeAggStats,
    /// PE-observed latency per access slot: [element, fiber-load,
    /// fiber-load, store] — the paper's per-class "minimum latency" view.
    pub latency: [LatencyStats; 4],
    /// Run-loop iterations the engine actually executed (host-side cost
    /// metric). The event engine's skip-ahead makes this much smaller
    /// than `total_cycles`; the reference loop visits more cycles. Like
    /// `host_seconds` it describes the *simulator*, not the simulated
    /// machine, so it is excluded from [`SimReport::diff`].
    pub visited_cycles: u64,
    /// Wall-clock seconds the simulation itself took (host time).
    pub host_seconds: f64,
}

impl SimReport {
    /// First field (if any) on which two reports describe *different
    /// simulations*, as a human-readable description. `None` means the
    /// runs were behaviorally identical — every cycle count, access
    /// count and per-component counter matches. Host wall-clock time
    /// (`host_seconds`) is deliberately excluded: it is the only field
    /// the event-driven engine and the reference loop may differ on.
    pub fn diff(&self, other: &SimReport) -> Option<String> {
        // Exhaustive destructuring: adding a SimReport field without
        // deciding whether the engines must agree on it becomes a
        // compile error, not a silent hole in the equivalence oracle.
        let SimReport {
            label,
            workload,
            total_cycles,
            nnz,
            accesses,
            requested_bytes,
            dram,
            channels,
            fabric,
            link_width,
            lmbs,
            pe,
            latency,
            visited_cycles: _, // host-side loop-iteration count, engine-specific
            host_seconds: _,   // host wall-clock is allowed to differ
        } = self;
        macro_rules! cmp {
            ($field:ident) => {
                if *$field != other.$field {
                    return Some(format!(
                        "{}: {:?} != {:?}",
                        stringify!($field),
                        $field,
                        other.$field
                    ));
                }
            };
        }
        cmp!(label);
        cmp!(workload);
        cmp!(total_cycles);
        cmp!(nnz);
        cmp!(accesses);
        cmp!(requested_bytes);
        cmp!(dram);
        cmp!(channels);
        cmp!(fabric);
        cmp!(link_width);
        cmp!(lmbs);
        cmp!(pe);
        cmp!(latency);
        None
    }

    /// Simulated memory bandwidth actually delivered (bytes/cycle).
    pub fn bytes_per_cycle(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            (self.dram.read_bytes + self.dram.write_bytes) as f64 / self.total_cycles as f64
        }
    }

    /// Nonzeros processed per cycle (the compute-side view).
    pub fn nnz_per_cycle(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.nnz as f64 / self.total_cycles as f64
        }
    }

    /// Speedup of this run relative to a baseline run on the same
    /// workload (baseline_cycles / self_cycles) — Fig. 4's y-axis.
    pub fn speedup_over(&self, baseline: &SimReport) -> f64 {
        assert_eq!(self.workload, baseline.workload, "speedup across workloads");
        if self.total_cycles == 0 {
            0.0
        } else {
            baseline.total_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Aggregate cache hit rate over all LMBs.
    pub fn cache_hit_rate(&self) -> f64 {
        let (mut h, mut a) = (0u64, 0u64);
        for l in &self.lmbs {
            h += l.cache.hits;
            a += l.cache.accesses();
        }
        if a == 0 {
            0.0
        } else {
            h as f64 / a as f64
        }
    }

    /// Mean PE-observed latency of element loads (cycles).
    pub fn elem_latency_mean(&self) -> f64 {
        self.latency[0].mean()
    }

    /// p95 PE-observed latency of element loads (cycles, log2-bucketed
    /// nearest-rank estimate).
    pub fn elem_latency_p95(&self) -> u64 {
        self.latency[0].percentile(0.95)
    }

    /// p95 PE-observed latency of fiber loads (both fiber slots merged).
    pub fn fiber_latency_p95(&self) -> u64 {
        let mut merged = self.latency[1].clone();
        merged.merge(&self.latency[2]);
        merged.percentile(0.95)
    }

    /// The latency table cells shared by the sweep and fig4 ASCII views:
    /// `[elem mean, elem p95, fiber mean, fiber p95]` (cycles).
    pub fn latency_cells(&self) -> [String; 4] {
        [
            format!("{:.1}", self.elem_latency_mean()),
            self.elem_latency_p95().to_string(),
            format!("{:.1}", self.fiber_latency_mean()),
            self.fiber_latency_p95().to_string(),
        ]
    }

    /// Per-channel data-bus utilization (busy beats / makespan).
    pub fn channel_bus_utilization(&self) -> Vec<f64> {
        self.channels
            .iter()
            .map(|c| {
                if self.total_cycles == 0 {
                    0.0
                } else {
                    c.busy_bus_cycles as f64 / self.total_cycles as f64
                }
            })
            .collect()
    }

    /// Highest per-link request-bandwidth utilization in the fabric.
    pub fn max_link_utilization(&self) -> f64 {
        self.fabric
            .links
            .iter()
            .map(|l| l.utilization(self.total_cycles, self.link_width))
            .fold(0.0, f64::max)
    }

    /// Highest per-link utilization on the reply network (0.0 when the
    /// reply network is off — no reply links exist then).
    pub fn max_reply_link_utilization(&self) -> f64 {
        self.fabric
            .reply
            .links
            .iter()
            .map(|l| l.utilization(self.total_cycles, self.link_width))
            .fold(0.0, f64::max)
    }

    /// Mean PE-observed latency of fiber loads (cycles).
    pub fn fiber_latency_mean(&self) -> f64 {
        let (a, b) = (&self.latency[1], &self.latency[2]);
        let n = a.count + b.count;
        if n == 0 {
            0.0
        } else {
            (a.total + b.total) as f64 / n as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("elem_latency_mean", Json::num(self.elem_latency_mean())),
            ("fiber_latency_mean", Json::num(self.fiber_latency_mean())),
            ("workload", Json::str(self.workload.clone())),
            ("total_cycles", Json::num(self.total_cycles as f64)),
            ("nnz", Json::num(self.nnz as f64)),
            ("accesses", Json::num(self.accesses as f64)),
            ("requested_bytes", Json::num(self.requested_bytes as f64)),
            ("bytes_per_cycle", Json::num(self.bytes_per_cycle())),
            ("nnz_per_cycle", Json::num(self.nnz_per_cycle())),
            ("cache_hit_rate", Json::num(self.cache_hit_rate())),
            (
                "dram",
                Json::obj(vec![
                    ("reads", Json::num(self.dram.reads as f64)),
                    ("writes", Json::num(self.dram.writes as f64)),
                    ("read_bytes", Json::num(self.dram.read_bytes as f64)),
                    ("write_bytes", Json::num(self.dram.write_bytes as f64)),
                    ("row_hit_rate", Json::num(self.dram.row_hit_rate())),
                    ("refreshes", Json::num(self.dram.refreshes as f64)),
                    ("refresh_steal_cycles", Json::num(self.dram.refresh_steal_cycles as f64)),
                    ("turnaround_cycles", Json::num(self.dram.turnaround_cycles as f64)),
                ]),
            ),
            ("latency", self.latency_json()),
            ("channels", self.channels_json()),
            ("fabric", self.fabric_json()),
            ("lmbs", self.lmbs_json()),
            (
                "pe",
                Json::obj(vec![
                    ("retired", Json::num(self.pe.retired as f64)),
                    ("issued_accesses", Json::num(self.pe.issued_accesses as f64)),
                    ("stall_cycles", Json::num(self.pe.stall_cycles as f64)),
                ]),
            ),
            ("host_seconds", Json::num(self.host_seconds)),
        ])
    }

    /// Per-access-class latency distributions: count/mean/p50/p95/p99/max
    /// plus the occupied log2 histogram buckets (inclusive value ranges).
    fn latency_json(&self) -> Json {
        const CLASSES: [&str; 4] = ["elem", "fib1", "fib2", "store"];
        let rows = CLASSES
            .iter()
            .zip(&self.latency)
            .map(|(name, l)| {
                let buckets: Vec<Json> = l
                    .buckets
                    .iter()
                    .enumerate()
                    .filter(|&(_, &n)| n > 0)
                    .map(|(k, &n)| {
                        let (lo, hi) = LatencyStats::bucket_range(k);
                        Json::obj(vec![
                            ("lo", Json::num(lo as f64)),
                            ("hi", Json::num(hi as f64)),
                            ("count", Json::num(n as f64)),
                        ])
                    })
                    .collect();
                (
                    *name,
                    Json::obj(vec![
                        ("count", Json::num(l.count as f64)),
                        ("mean", Json::num(l.mean())),
                        ("p50", Json::num(l.percentile(0.50) as f64)),
                        ("p95", Json::num(l.percentile(0.95) as f64)),
                        ("p99", Json::num(l.percentile(0.99) as f64)),
                        ("max", Json::num(l.max as f64)),
                        ("buckets", Json::arr(buckets)),
                    ]),
                )
            })
            .collect();
        Json::obj(rows)
    }

    /// Per-channel DRAM counters + bus utilization as a JSON array.
    fn channels_json(&self) -> Json {
        let utils = self.channel_bus_utilization();
        let rows = self
            .channels
            .iter()
            .zip(utils)
            .map(|(c, util)| {
                Json::obj(vec![
                    ("reads", Json::num(c.reads as f64)),
                    ("writes", Json::num(c.writes as f64)),
                    ("read_bytes", Json::num(c.read_bytes as f64)),
                    ("write_bytes", Json::num(c.write_bytes as f64)),
                    ("row_hit_rate", Json::num(c.row_hit_rate())),
                    ("bus_utilization", Json::num(util)),
                ])
            })
            .collect();
        Json::arr(rows)
    }

    /// Interconnect counters, including per-link utilization on both the
    /// request and (when modeled) the reply side.
    fn fabric_json(&self) -> Json {
        let link_rows = |links: &[super::fabric::LinkStats]| -> Vec<Json> {
            links
                .iter()
                .map(|l| {
                    Json::obj(vec![
                        ("label", Json::str(l.label.clone())),
                        ("forwarded", Json::num(l.forwarded as f64)),
                        ("stall_cycles", Json::num(l.stall_cycles as f64)),
                        (
                            "utilization",
                            Json::num(l.utilization(self.total_cycles, self.link_width)),
                        ),
                    ])
                })
                .collect()
        };
        Json::obj(vec![
            ("forwarded", Json::num(self.fabric.forwarded as f64)),
            (
                "backpressure_cycles",
                Json::num(self.fabric.backpressure_cycles as f64),
            ),
            ("hops", Json::num(self.fabric.hops as f64)),
            ("links", Json::arr(link_rows(&self.fabric.links))),
            (
                "reply",
                Json::obj(vec![
                    ("delivered", Json::num(self.fabric.reply.delivered as f64)),
                    ("hops", Json::num(self.fabric.reply.hops as f64)),
                    (
                        "backpressure_cycles",
                        Json::num(self.fabric.reply.backpressure_cycles as f64),
                    ),
                    ("links", Json::arr(link_rows(&self.fabric.reply.links))),
                ]),
            ),
        ])
    }

    /// Per-LMB counters with the per-bank breakdown — the banked-layout
    /// view (`lmb_banks` entries per LMB; one with the default config).
    fn lmbs_json(&self) -> Json {
        let rows = self
            .lmbs
            .iter()
            .map(|l| {
                let total: u64 = l.banks.iter().map(LmbBankStats::requests).sum();
                let banks = l
                    .banks
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("cache_hits", Json::num(b.cache.hits as f64)),
                            (
                                "cache_misses",
                                Json::num(b.cache.primary_misses as f64),
                            ),
                            ("rr_forwarded", Json::num(b.rr.forwarded as f64)),
                            ("rr_absorbed", Json::num(b.rr.absorbed as f64)),
                            ("rr_served_temp", Json::num(b.rr.served_temp as f64)),
                            ("requests", Json::num(b.requests() as f64)),
                            (
                                "utilization",
                                Json::num(if total == 0 {
                                    0.0
                                } else {
                                    b.requests() as f64 / total as f64
                                }),
                            ),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("cache_hit_rate", Json::num(l.cache.hit_rate())),
                    ("rr_forwarded", Json::num(l.rr.forwarded as f64)),
                    ("rr_absorbed", Json::num(l.rr.absorbed as f64)),
                    ("dma_loads", Json::num(l.dma.loads as f64)),
                    ("dma_stores", Json::num(l.dma.stores as f64)),
                    ("banks", Json::arr(banks)),
                ])
            })
            .collect();
        Json::arr(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cycles: Cycle) -> SimReport {
        SimReport {
            label: "x".into(),
            workload: "w".into(),
            total_cycles: cycles,
            nnz: 100,
            accesses: 400,
            requested_bytes: 6400,
            dram: DramStats {
                read_bytes: 5000,
                write_bytes: 1000,
                ..Default::default()
            },
            channels: vec![
                DramStats {
                    read_bytes: 5000,
                    busy_bus_cycles: 250,
                    ..Default::default()
                },
                DramStats {
                    write_bytes: 1000,
                    busy_bus_cycles: 750,
                    ..Default::default()
                },
            ],
            fabric: FabricStats::default(),
            link_width: 1,
            lmbs: vec![],
            pe: PeAggStats::default(),
            latency: Default::default(),
            visited_cycles: 0,
            host_seconds: 0.0,
        }
    }

    #[test]
    fn derived_metrics() {
        let r = report(1000);
        assert!((r.bytes_per_cycle() - 6.0).abs() < 1e-12);
        assert!((r.nnz_per_cycle() - 0.1).abs() < 1e-12);
        let base = report(3500);
        assert!((base.speedup_over(&base) - 1.0).abs() < 1e-12);
        assert!((r.speedup_over(&base) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn json_round_trip_fields() {
        let j = report(10).to_json();
        assert_eq!(j.get("total_cycles").unwrap().as_usize(), Some(10));
        assert!(j.get("dram").unwrap().get("row_hit_rate").is_some());
        let chans = j.get("channels").unwrap().as_arr().unwrap();
        assert_eq!(chans.len(), 2);
        assert!(chans[0].get("bus_utilization").is_some());
        assert!(j.get("fabric").unwrap().get("links").is_some());
        // Reply + per-bank sections are always present (empty when off).
        let reply = j.get("fabric").unwrap().get("reply").unwrap();
        assert_eq!(reply.get("delivered").unwrap().as_usize(), Some(0));
        assert!(reply.get("links").is_some());
        assert!(j.get("lmbs").unwrap().as_arr().is_some());
    }

    #[test]
    fn latency_json_carries_percentiles_and_buckets() {
        let mut r = report(100);
        for lat in [10u64, 20, 30, 1000] {
            r.latency[0].record(lat);
        }
        r.latency[1].record(40);
        r.latency[2].record(4000);
        let j = r.to_json();
        let elem = j.get("latency").unwrap().get("elem").unwrap();
        assert_eq!(elem.get("count").unwrap().as_usize(), Some(4));
        assert_eq!(elem.get("max").unwrap().as_usize(), Some(1000));
        // rank-2 sample (20) sits in bucket [16, 31]; rank-4 (1000) in
        // [512, 1023], upper bound clamped to the observed max.
        assert_eq!(elem.get("p50").unwrap().as_usize(), Some(31));
        assert_eq!(elem.get("p95").unwrap().as_usize(), Some(1000));
        let buckets = elem.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 3, "occupied buckets only");
        let total: f64 = buckets.iter().map(|b| b.get("count").unwrap().as_f64().unwrap()).sum();
        assert_eq!(total, 4.0);
        // Empty class stays all-zero.
        let store = j.get("latency").unwrap().get("store").unwrap();
        assert_eq!(store.get("count").unwrap().as_usize(), Some(0));
        assert_eq!(store.get("p99").unwrap().as_usize(), Some(0));
        // Report-level helpers agree with the per-class view.
        assert_eq!(r.elem_latency_p95(), 1000);
        assert_eq!(r.fiber_latency_p95(), 4000, "fiber slots merge for p95");
    }

    #[test]
    fn latency_cells_pin_known_stream() {
        let mut r = report(100);
        for lat in [10u64, 20, 30, 1000] {
            r.latency[0].record(lat);
        }
        r.latency[1].record(40);
        r.latency[2].record(4000);
        // elem mean 1060/4 = 265.0, p95 = bucket [512,1023] clamped to
        // max 1000; fiber merges both slots: mean 4040/2, p95 = 4000.
        assert_eq!(
            r.latency_cells(),
            ["265.0", "1000", "2020.0", "4000"].map(String::from)
        );
        // Empty distributions render as zeros, never NaN.
        assert_eq!(
            report(1).latency_cells(),
            ["0.0", "0", "0.0", "0"].map(String::from)
        );
    }

    #[test]
    fn per_bank_json_carries_utilization() {
        let mut r = report(100);
        r.lmbs = vec![LmbStats {
            banks: vec![
                LmbBankStats {
                    rr: RrStats { forwarded: 3, absorbed: 9, ..Default::default() },
                    ..Default::default()
                },
                LmbBankStats {
                    rr: RrStats { forwarded: 1, absorbed: 3, ..Default::default() },
                    ..Default::default()
                },
            ],
            ..Default::default()
        }];
        let j = r.to_json();
        let lmbs = j.get("lmbs").unwrap().as_arr().unwrap();
        assert_eq!(lmbs.len(), 1);
        let banks = lmbs[0].get("banks").unwrap().as_arr().unwrap();
        assert_eq!(banks.len(), 2);
        assert_eq!(banks[0].get("requests").unwrap().as_usize(), Some(12));
        let u0 = banks[0].get("utilization").unwrap().as_f64().unwrap();
        let u1 = banks[1].get("utilization").unwrap().as_f64().unwrap();
        assert!((u0 - 0.75).abs() < 1e-12 && (u1 - 0.25).abs() < 1e-12);
    }

    #[test]
    fn per_channel_utilization_derives_from_makespan() {
        let r = report(1000);
        let util = r.channel_bus_utilization();
        assert_eq!(util.len(), 2);
        assert!((util[0] - 0.25).abs() < 1e-12);
        assert!((util[1] - 0.75).abs() < 1e-12);
        assert_eq!(r.max_link_utilization(), 0.0); // no links recorded
    }
}
