//! DRAM channel + memory-interface-IP front end.
//!
//! Models the Xilinx MIG-style controller the paper connects to (§V-A):
//! 512-bit data path, 31-bit addresses, banked DDR4 behind it. Timing is
//! folded to user-clock cycles (DESIGN.md §6):
//!
//! * per-request controller overhead `t_controller`;
//! * bank state: row hit (`t_row_hit`), row empty (`t_row_miss`), row
//!   conflict (`t_row_miss + t_precharge`);
//! * a shared data bus moving one beat (= data width) per cycle — the
//!   bandwidth ceiling;
//! * at most `max_outstanding` transactions in flight (controller queue).
//!
//! The scheduler is FR-FCFS-lite: among queued requests it prefers row
//! hits, then age — enough fidelity to reward streaming (DMA bursts) and
//! punish scattered element traffic, which is the effect Fig. 4 measures.

use std::collections::VecDeque;

use crate::config::{DramConfig, DramModelKind};
use crate::util::log2;

use super::telemetry::Telemetry;
use super::{Cycle, MemReq, MemResp, ReqId};

/// Per-bank open-row state.
#[derive(Debug, Clone, Copy, Default)]
struct Bank {
    open_row: Option<u64>,
    /// Bank busy until this cycle (row activation in progress).
    busy_until: Cycle,
}

/// DRAM timing + occupancy statistics.
///
/// The last three counters are produced only by the command-level
/// backend ([`super::dram_timed::TimedDram`]); the lumped model leaves
/// them at zero, which keeps lumped reports bit-identical to their
/// pre-trait shape.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub busy_bus_cycles: u64,
    pub total_queue_wait: u64,
    /// REF commands issued (one per elapsed tREFI boundary).
    pub refreshes: u64,
    /// Bank-cycles stolen by refresh (tRFC per bank per boundary).
    pub refresh_steal_cycles: u64,
    /// Column-command cycles added by tWTR/tRTW bus turnaround.
    pub turnaround_cycles: u64,
}

impl DramStats {
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses + self.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Fold another channel's counters into this one (fabric aggregate).
    pub fn merge(&mut self, other: &DramStats) {
        self.reads += other.reads;
        self.writes += other.writes;
        self.read_bytes += other.read_bytes;
        self.write_bytes += other.write_bytes;
        self.row_hits += other.row_hits;
        self.row_misses += other.row_misses;
        self.row_conflicts += other.row_conflicts;
        self.busy_bus_cycles += other.busy_bus_cycles;
        self.total_queue_wait += other.total_queue_wait;
        self.refreshes += other.refreshes;
        self.refresh_steal_cycles += other.refresh_steal_cycles;
        self.turnaround_cycles += other.turnaround_cycles;
    }
}

/// The backend-agnostic seam between the interconnect fabric and a DRAM
/// channel's timing model. Each method mirrors an event-engine gate of
/// the lumped [`Dram`]:
///
/// * [`DramModel::needs_tick`] must be true whenever `tick` at `now`
///   would do anything (schedule queued work or deliver a due
///   completion) — skipping a channel for which it is false must be a
///   provable no-op;
/// * [`DramModel::next_event`] is the earliest in-flight completion;
/// * [`DramModel::next_schedule_time`] may wake the engine *early*
///   (a revisit recomputes) but never late.
pub trait DramModel {
    /// Can the controller accept another request this cycle?
    fn can_accept(&self) -> bool;
    /// Number of requests currently queued or in flight.
    fn occupancy(&self) -> usize;
    /// Accept a request (caller must have checked `can_accept`).
    fn push(&mut self, req: MemReq, now: Cycle);
    /// Advance to `now`; deliver completions due at or before `now`.
    fn tick(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        self.tick_traced(now, completions, &mut Telemetry::disabled(), 0);
    }
    /// [`DramModel::tick`] with a telemetry sink (observation-only).
    fn tick_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
        ch: usize,
    );
    /// Earliest in-flight completion cycle; `None` if nothing in flight.
    fn next_event(&self) -> Option<Cycle>;
    /// Would `tick` do anything at `now`?
    fn needs_tick(&self, now: Cycle) -> bool;
    /// True if requests are waiting to be scheduled onto banks.
    fn has_queued(&self) -> bool;
    /// Earliest future cycle a queued request could issue (may be early,
    /// never late); `None` when the queue is empty.
    fn next_schedule_time(&self, now: Cycle) -> Option<Cycle>;
    fn is_idle(&self) -> bool;
    fn stats(&self) -> &DramStats;
}

impl DramModel for Dram {
    fn can_accept(&self) -> bool {
        Dram::can_accept(self)
    }

    fn occupancy(&self) -> usize {
        Dram::occupancy(self)
    }

    fn push(&mut self, req: MemReq, now: Cycle) {
        Dram::push(self, req, now)
    }

    fn tick_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
        ch: usize,
    ) {
        Dram::tick_traced(self, now, completions, tel, ch)
    }

    fn next_event(&self) -> Option<Cycle> {
        Dram::next_event(self)
    }

    fn needs_tick(&self, now: Cycle) -> bool {
        Dram::needs_tick(self, now)
    }

    fn has_queued(&self) -> bool {
        Dram::has_queued(self)
    }

    fn next_schedule_time(&self, now: Cycle) -> Option<Cycle> {
        Dram::next_schedule_time(self, now)
    }

    fn is_idle(&self) -> bool {
        Dram::is_idle(self)
    }

    fn stats(&self) -> &DramStats {
        &self.stats
    }
}

macro_rules! channel_delegate {
    ($self:ident, $m:ident $(, $a:expr)*) => {
        match $self {
            DramChannel::Lumped(d) => d.$m($($a),*),
            DramChannel::Timed(d) => d.$m($($a),*),
        }
    };
}

/// Enum dispatch over the configured timing backend. Chosen over trait
/// objects so channels stay `Send` (they cross the mpsc channels of the
/// sharded engine) and the default lumped path keeps static dispatch.
pub enum DramChannel {
    Lumped(Dram),
    Timed(super::dram_timed::TimedDram),
}

impl DramChannel {
    /// Build the backend `cfg.model` selects.
    pub fn new(cfg: &DramConfig) -> DramChannel {
        match cfg.model {
            DramModelKind::Lumped => DramChannel::Lumped(Dram::new(cfg)),
            DramModelKind::Timed => {
                DramChannel::Timed(super::dram_timed::TimedDram::new(cfg))
            }
        }
    }

    pub fn can_accept(&self) -> bool {
        channel_delegate!(self, can_accept)
    }

    pub fn occupancy(&self) -> usize {
        channel_delegate!(self, occupancy)
    }

    pub fn push(&mut self, req: MemReq, now: Cycle) {
        channel_delegate!(self, push, req, now)
    }

    pub fn tick(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        channel_delegate!(self, tick, now, completions)
    }

    pub fn tick_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
        ch: usize,
    ) {
        channel_delegate!(self, tick_traced, now, completions, tel, ch)
    }

    pub fn next_event(&self) -> Option<Cycle> {
        channel_delegate!(self, next_event)
    }

    pub fn needs_tick(&self, now: Cycle) -> bool {
        channel_delegate!(self, needs_tick, now)
    }

    pub fn has_queued(&self) -> bool {
        channel_delegate!(self, has_queued)
    }

    pub fn next_schedule_time(&self, now: Cycle) -> Option<Cycle> {
        channel_delegate!(self, next_schedule_time, now)
    }

    pub fn is_idle(&self) -> bool {
        channel_delegate!(self, is_idle)
    }

    pub fn stats(&self) -> &DramStats {
        match self {
            DramChannel::Lumped(d) => &d.stats,
            DramChannel::Timed(d) => d.stats(),
        }
    }
}

/// Interleaving of the physical address space over N independent DRAM
/// channels (the multi-channel generalization of the paper's single
/// memory-interface IP).
///
/// Channel bits sit just above the interleave granule: channel =
/// `(addr / interleave_bytes) % channels`, and the channel-local address
/// is the original address with those bits squeezed out, so each channel
/// sees a dense, conflict-comparable address space. With one channel the
/// mapping is exactly the identity — the seed single-MIG behavior.
#[derive(Debug, Clone, Copy)]
pub struct ChannelMap {
    ch_bits: u32,
    ilv_shift: u32,
    ch_mask: u64,
}

impl ChannelMap {
    pub fn new(channels: usize, interleave_bytes: u64) -> ChannelMap {
        debug_assert!(crate::util::is_pow2(channels as u64));
        debug_assert!(crate::util::is_pow2(interleave_bytes));
        ChannelMap {
            ch_bits: log2(channels as u64),
            ilv_shift: log2(interleave_bytes),
            ch_mask: channels as u64 - 1,
        }
    }

    pub fn channels(&self) -> usize {
        1 << self.ch_bits
    }

    /// Split a physical address into (channel, channel-local address).
    #[inline]
    pub fn decode(&self, addr: u64) -> (usize, u64) {
        if self.ch_bits == 0 {
            return (0, addr);
        }
        let ch = ((addr >> self.ilv_shift) & self.ch_mask) as usize;
        let hi = addr >> (self.ilv_shift + self.ch_bits);
        let lo = addr & ((1u64 << self.ilv_shift) - 1);
        (ch, (hi << self.ilv_shift) | lo)
    }
}

#[derive(Debug, Clone, Copy)]
struct Inflight {
    req: MemReq,
    done_at: Cycle,
}

/// The DRAM channel model.
pub struct Dram {
    cfg: DramConfig,
    banks: Vec<Bank>,
    /// Requests accepted but not yet scheduled onto the bus.
    queue: VecDeque<(MemReq, Cycle)>,
    /// Requests with a computed completion time.
    inflight: Vec<Inflight>,
    /// Min `done_at` over `inflight` (`Cycle::MAX` when empty) — lets the
    /// run loop skip idle channels without scanning.
    earliest_done: Cycle,
    /// Data bus reserved through this cycle.
    bus_free_at: Cycle,
    pub stats: DramStats,
    bank_shift: u32,
    bank_mask: u64,
    row_shift: u32,
}

impl Dram {
    pub fn new(cfg: &DramConfig) -> Dram {
        Dram {
            banks: vec![Bank::default(); cfg.banks],
            queue: VecDeque::new(),
            inflight: Vec::new(),
            earliest_done: Cycle::MAX,
            bus_free_at: 0,
            stats: DramStats::default(),
            // ROW-BANK-COLUMN order (the MIG default): column bits are
            // lowest, so sequential bursts stay in one open row, then move
            // to the next bank — streams row-hit, scatters activate.
            bank_shift: log2(cfg.row_bytes),
            bank_mask: cfg.banks as u64 - 1,
            row_shift: log2(cfg.row_bytes) + log2(cfg.banks as u64),
            cfg: cfg.clone(),
        }
    }

    #[inline]
    fn bank_of(&self, addr: u64) -> usize {
        ((addr >> self.bank_shift) & self.bank_mask) as usize
    }

    #[inline]
    fn row_of(&self, addr: u64) -> u64 {
        addr >> self.row_shift
    }

    /// Can the controller accept another request this cycle?
    pub fn can_accept(&self) -> bool {
        self.queue.len() + self.inflight.len() < self.cfg.max_outstanding
    }

    /// Number of requests currently queued or in flight.
    pub fn occupancy(&self) -> usize {
        self.queue.len() + self.inflight.len()
    }

    /// Accept a request (caller must have checked [`Dram::can_accept`]).
    pub fn push(&mut self, req: MemReq, now: Cycle) {
        debug_assert!(self.can_accept());
        debug_assert!(req.bytes > 0);
        self.queue.push_back((req, now));
    }

    /// Advance to `now`: schedule queued requests onto banks + bus, and
    /// return all transactions that complete at or before `now`.
    pub fn tick(&mut self, now: Cycle, completions: &mut Vec<MemResp>) {
        self.tick_traced(now, completions, &mut Telemetry::disabled(), 0);
    }

    /// [`Dram::tick`] with a telemetry sink: scheduled requests report
    /// their queue/service spans to `tel` as channel `ch`. Behavior is
    /// identical — telemetry is observation-only.
    pub fn tick_traced(
        &mut self,
        now: Cycle,
        completions: &mut Vec<MemResp>,
        tel: &mut Telemetry,
        ch: usize,
    ) {
        self.schedule(now, tel, ch);
        if self.earliest_done > now {
            return; // nothing due — skip the drain scan
        }
        // Drain completions. Swap-remove keeps this O(n) without realloc.
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done_at <= now {
                let fin = self.inflight.swap_remove(i);
                completions.push(MemResp {
                    id: fin.req.id,
                    port: fin.req.port,
                    done_at: fin.done_at,
                });
            } else {
                i += 1;
            }
        }
        self.earliest_done = self
            .inflight
            .iter()
            .map(|f| f.done_at)
            .min()
            .unwrap_or(Cycle::MAX);
    }

    /// The earliest cycle at which an in-flight transaction completes
    /// (for the run loop's idle skip-ahead). `None` if nothing is in
    /// flight. Callers must also check [`Dram::has_queued`] — queued
    /// requests schedule on the next tick.
    pub fn next_event(&self) -> Option<Cycle> {
        if self.inflight.is_empty() {
            None
        } else {
            Some(self.earliest_done)
        }
    }

    /// Would [`Dram::tick`] do anything at `now` — schedule queued work
    /// or deliver a due completion? Skipping a channel for which this is
    /// false is a provable no-op (used by the event-driven run loop).
    pub fn needs_tick(&self, now: Cycle) -> bool {
        !self.queue.is_empty() || self.earliest_done <= now
    }

    /// True if requests are waiting to be scheduled onto banks.
    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    /// Earliest future cycle at which a queued request could be issued
    /// (bank frees up / bus window opens). `None` when the queue is empty
    /// or something is issuable right now (callers should tick next
    /// cycle in that case). Used by the run loop's idle fast-forward
    /// (§Perf L3 opt #2).
    pub fn next_schedule_time(&self, now: Cycle) -> Option<Cycle> {
        if self.queue.is_empty() {
            return None;
        }
        // Bus saturation guard mirror of `schedule`.
        let bus_gate = self
            .bus_free_at
            .saturating_sub(self.cfg.bus_admission_factor * self.cfg.t_row_miss);
        let mut t = Cycle::MAX;
        for (req, _) in &self.queue {
            let bank = &self.banks[self.bank_of(req.addr)];
            t = t.min(bank.busy_until.max(bus_gate));
        }
        Some(t.max(now + 1))
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.inflight.is_empty()
    }

    /// FR-FCFS-lite: pick row hits first, then oldest; schedule as many
    /// requests as the bus window allows this cycle.
    fn schedule(&mut self, now: Cycle, tel: &mut Telemetry, ch: usize) {
        while !self.queue.is_empty() {
            // Find the best candidate: row hit on a free bank, else oldest
            // whose bank is free.
            let mut pick: Option<usize> = None;
            for (qi, (req, _)) in self.queue.iter().enumerate() {
                let bank = self.banks[self.bank_of(req.addr)];
                if bank.busy_until > now {
                    continue;
                }
                let is_hit = bank.open_row == Some(self.row_of(req.addr));
                if is_hit {
                    pick = Some(qi);
                    break; // row hit beats everything
                }
                if pick.is_none() {
                    pick = Some(qi);
                }
            }
            let Some(qi) = pick else { break };
            // Bus admission: one transaction's beats must fit after
            // bus_free_at; if the bus is already booked more than
            // `bus_admission_factor` row-miss times ahead, stop
            // scheduling this cycle (see `DramConfig::bus_admission_factor`).
            if self.bus_free_at > now + self.cfg.bus_admission_factor * self.cfg.t_row_miss {
                break;
            }
            let (req, enq_at) = self.queue.remove(qi).unwrap();
            self.issue(req, enq_at, now, tel, ch);
        }
    }

    fn issue(&mut self, req: MemReq, enq_at: Cycle, now: Cycle, tel: &mut Telemetry, ch: usize) {
        let beat = self.cfg.beat_bytes();
        let beats = crate::util::ceil_div(req.bytes as u64, beat).max(1);
        let bank_idx = self.bank_of(req.addr);
        let row = self.row_of(req.addr);
        let bank = &mut self.banks[bank_idx];
        // Bank access latency.
        let (access, row_kind) = match bank.open_row {
            Some(r) if r == row => {
                self.stats.row_hits += 1;
                (self.cfg.t_row_hit, "hit")
            }
            Some(_) => {
                self.stats.row_conflicts += 1;
                (self.cfg.t_row_miss + self.cfg.t_precharge, "conflict")
            }
            None => {
                self.stats.row_misses += 1;
                (self.cfg.t_row_miss, "miss")
            }
        };
        let was_hit = matches!(bank.open_row, Some(r) if r == row);
        bank.open_row = Some(row);
        let start = now.max(bank.busy_until);
        let ready = start + self.cfg.t_controller + access;
        // Bank command occupancy: an activation ties the bank up for the
        // access time; back-to-back column reads to an open row pipeline
        // at tCCD (≈4 user cycles).
        bank.busy_until = start + if was_hit { 4 } else { access };
        // Data beats serialize on the shared bus.
        let data_start = ready.max(self.bus_free_at);
        let done_at = data_start + beats;
        self.earliest_done = self.earliest_done.min(done_at);
        self.bus_free_at = done_at;
        self.stats.busy_bus_cycles += beats;
        self.stats.total_queue_wait += now.saturating_sub(enq_at);
        if req.is_write {
            self.stats.writes += 1;
            self.stats.write_bytes += req.bytes as u64;
        } else {
            self.stats.reads += 1;
            self.stats.read_bytes += req.bytes as u64;
        }
        tel.mem_service(req.id, ch, enq_at, now, done_at, row_kind);
        self.inflight.push(Inflight { req, done_at });
    }
}

/// Helper to mint unique request ids.
#[derive(Debug, Default)]
pub struct IdGen(ReqId);

impl IdGen {
    pub fn next(&mut self) -> ReqId {
        self.0 += 1;
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dram() -> Dram {
        Dram::new(&DramConfig::mig_u250())
    }

    fn req(id: ReqId, addr: u64, bytes: u32, is_write: bool) -> MemReq {
        MemReq {
            id,
            addr,
            bytes,
            is_write,
            port: 0,
        }
    }

    fn run_until_done(d: &mut Dram, horizon: Cycle) -> Vec<MemResp> {
        let mut out = Vec::new();
        for c in 0..horizon {
            d.tick(c, &mut out);
            if d.is_idle() {
                break;
            }
        }
        out
    }

    #[test]
    fn single_read_latency_in_expected_band() {
        let mut d = dram();
        d.push(req(1, 0, 64, false), 0);
        let done = run_until_done(&mut d, 1000);
        assert_eq!(done.len(), 1);
        let lat = done[0].done_at;
        // t_controller(8) + t_row_miss(52) + 1 beat = 61.
        assert_eq!(lat, 61);
        assert_eq!(d.stats.reads, 1);
        assert_eq!(d.stats.row_misses, 1);
    }

    #[test]
    fn sequential_stream_gets_row_hits() {
        let mut d = dram();
        // 32 sequential lines → same rows → hits after the first touches.
        for i in 0..32u64 {
            d.push(req(i + 1, i * 64, 64, false), 0);
        }
        let done = run_until_done(&mut d, 10_000);
        assert_eq!(done.len(), 32);
        assert!(
            d.stats.row_hits >= 12,
            "sequential stream should mostly row-hit: {:?}",
            d.stats
        );
    }

    #[test]
    fn random_scatter_conflicts_more_than_stream() {
        // Feed 64 requests through each system, respecting queue limits.
        let run = |addr_of: &dyn Fn(u64) -> u64| -> Cycle {
            let mut d = dram();
            let mut out = Vec::new();
            let mut pushed = 0u64;
            let mut c = 0;
            while out.len() < 64 {
                while pushed < 64 && d.can_accept() {
                    d.push(req(pushed + 1, addr_of(pushed), 64, false), c);
                    pushed += 1;
                }
                d.tick(c, &mut out);
                c += 1;
                assert!(c < 1_000_000, "runaway");
            }
            out.iter().map(|r| r.done_at).max().unwrap()
        };
        let seq_makespan = run(&|i| i * 64);
        // Scatter over many rows of the same few banks.
        let rnd_makespan = run(&|i| (i * 1_048_576 + (i % 2) * 64) % (1 << 30));
        assert!(
            rnd_makespan > seq_makespan,
            "scatter {rnd_makespan} should be slower than stream {seq_makespan}"
        );
    }

    #[test]
    fn burst_amortizes_vs_split_lines() {
        // One 256 B burst vs four 64 B line reads to the same addresses.
        let mut burst = dram();
        burst.push(req(1, 4096, 256, false), 0);
        let b = run_until_done(&mut burst, 10_000);
        let burst_t = b[0].done_at;

        let mut split = dram();
        for i in 0..4u64 {
            split.push(req(i + 1, 4096 + i * 64, 64, false), 0);
        }
        let s = run_until_done(&mut split, 10_000);
        let split_t = s.iter().map(|c| c.done_at).max().unwrap();
        assert!(
            burst_t < split_t,
            "burst {burst_t} should beat split {split_t}"
        );
    }

    #[test]
    fn respects_max_outstanding() {
        let cfg = DramConfig {
            max_outstanding: 4,
            ..DramConfig::mig_u250()
        };
        let mut d = Dram::new(&cfg);
        for i in 0..4u64 {
            assert!(d.can_accept());
            d.push(req(i + 1, i * 64, 64, false), 0);
        }
        assert!(!d.can_accept());
        let mut out = Vec::new();
        for c in 0..200 {
            d.tick(c, &mut out);
        }
        assert!(d.can_accept());
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn writes_recorded_separately() {
        let mut d = dram();
        d.push(req(1, 0, 128, true), 0);
        d.push(req(2, 4096, 64, false), 0);
        run_until_done(&mut d, 1000);
        assert_eq!(d.stats.writes, 1);
        assert_eq!(d.stats.write_bytes, 128);
        assert_eq!(d.stats.reads, 1);
        assert_eq!(d.stats.read_bytes, 64);
    }

    #[test]
    fn channel_map_single_channel_is_identity() {
        let m = ChannelMap::new(1, 4096);
        for addr in [0u64, 1, 63, 4095, 4096, 0x7fff_ffff] {
            assert_eq!(m.decode(addr), (0, addr));
        }
    }

    #[test]
    fn channel_map_interleaves_round_robin() {
        let m = ChannelMap::new(4, 4096);
        assert_eq!(m.channels(), 4);
        // Consecutive granules rotate over all channels.
        for g in 0..16u64 {
            let (ch, _) = m.decode(g * 4096);
            assert_eq!(ch, (g % 4) as usize);
        }
        // Offsets within a granule stay in the granule's channel and the
        // local address is dense: granule g maps to local granule g / 4.
        let (ch, local) = m.decode(5 * 4096 + 17);
        assert_eq!(ch, 1);
        assert_eq!(local, 4096 + 17);
    }

    #[test]
    fn channel_map_local_addresses_are_dense_per_channel() {
        let m = ChannelMap::new(2, 8192);
        let mut locals = Vec::new();
        for g in 0..8u64 {
            let (ch, local) = m.decode(g * 8192);
            if ch == 0 {
                locals.push(local);
            }
        }
        assert_eq!(locals, vec![0, 8192, 2 * 8192, 3 * 8192]);
    }

    #[test]
    fn stats_merge_sums_counters() {
        let mut a = DramStats {
            reads: 2,
            read_bytes: 128,
            row_hits: 1,
            ..DramStats::default()
        };
        let b = DramStats {
            reads: 3,
            writes: 1,
            read_bytes: 192,
            write_bytes: 64,
            row_misses: 2,
            refreshes: 4,
            refresh_steal_cycles: 420,
            turnaround_cycles: 7,
            ..DramStats::default()
        };
        a.merge(&b);
        assert_eq!(a.reads, 5);
        assert_eq!(a.writes, 1);
        assert_eq!(a.read_bytes, 320);
        assert_eq!(a.write_bytes, 64);
        assert_eq!(a.row_hits, 1);
        assert_eq!(a.row_misses, 2);
        assert_eq!(a.refreshes, 4);
        assert_eq!(a.refresh_steal_cycles, 420);
        assert_eq!(a.turnaround_cycles, 7);
    }

    #[test]
    fn channel_enum_dispatches_on_config_model() {
        let cfg = DramConfig::mig_u250();
        let mut lumped = DramChannel::new(&cfg);
        assert!(matches!(lumped, DramChannel::Lumped(_)));
        let timed_cfg = DramConfig {
            model: DramModelKind::Timed,
            ..cfg.clone()
        };
        let mut timed = DramChannel::new(&timed_cfg);
        assert!(matches!(timed, DramChannel::Timed(_)));
        // Both backends serve a request through the shared seam.
        for d in [&mut lumped, &mut timed] {
            assert!(d.is_idle());
            d.push(req(1, 0, 64, false), 0);
            assert!(d.has_queued() && d.needs_tick(0));
            assert_eq!(d.occupancy(), 1);
            let mut out = Vec::new();
            for c in 0..10_000 {
                d.tick(c, &mut out);
                if d.is_idle() {
                    break;
                }
            }
            assert_eq!(out.len(), 1);
            assert_eq!(d.stats().reads, 1);
        }
    }

    #[test]
    fn bus_admission_factor_gates_scheduling() {
        // 4 KiB bursts (64 beats each) to distinct banks: each booking
        // pushes bus_free_at 64 cycles further out, so the admission gate
        // decides how many transactions one tick may start.
        let admitted_first_tick = |factor: u64| {
            let cfg = DramConfig {
                bus_admission_factor: factor,
                ..DramConfig::mig_u250()
            };
            let mut d = Dram::new(&cfg);
            for i in 0..8u64 {
                d.push(req(i + 1, i * 8192, 4096, false), 0);
            }
            let mut out = Vec::new();
            d.tick(0, &mut out);
            d.inflight.len()
        };
        let tight = admitted_first_tick(1);
        let loose = admitted_first_tick(8);
        assert!(
            tight < loose,
            "factor 1 admitted {tight}, factor 8 admitted {loose}"
        );
    }

    #[test]
    fn bus_bandwidth_bounds_throughput() {
        // 1000 back-to-back row-hit beats cannot finish faster than 1000
        // bus cycles.
        let mut d = dram();
        let mut out = Vec::new();
        let mut pushed = 0u64;
        let mut c = 0;
        while out.len() < 1000 {
            while pushed < 1000 && d.can_accept() {
                d.push(req(pushed + 1, (pushed % 128) * 64, 64, false), c);
                pushed += 1;
            }
            d.tick(c, &mut out);
            c += 1;
        }
        let makespan = out.iter().map(|r| r.done_at).max().unwrap();
        assert!(makespan >= 1000, "makespan {makespan} beats bus limit");
    }
}
