//! CAM-based temporary buffer — stage 1 of the Request Reductor (Fig. 3).
//!
//! "A temporary buffer stores the most recent memory reads. It is a
//! CAM-based memory implementation ... Since CAMs are hardware expensive,
//! we keep the number of elements in the buffer small." (§IV-C)
//!
//! Fully-associative, LRU-replaced store of the most recent cache *lines*
//! delivered to this LMB. Element reads that land in a held line are
//! served without touching the cache at all — this is where the COO
//! stream's spatial locality (4 × 16 B elements per 64 B line) pays off.

/// Fully-associative recent-lines buffer (models a small CAM).
pub struct TempBuffer {
    /// (line number, lru stamp); `entries.len() <= cap`.
    entries: Vec<(u64, u64)>,
    cap: usize,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl TempBuffer {
    pub fn new(cap: usize) -> TempBuffer {
        assert!(cap > 0);
        TempBuffer {
            entries: Vec::with_capacity(cap),
            cap,
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Probe for `line`; refreshes LRU on hit.
    pub fn probe(&mut self, line: u64) -> bool {
        self.clock += 1;
        for e in &mut self.entries {
            if e.0 == line {
                e.1 = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Insert a just-arrived line (evicts LRU when full).
    pub fn insert(&mut self, line: u64) {
        self.clock += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == line) {
            e.1 = self.clock;
            return;
        }
        if self.entries.len() < self.cap {
            self.entries.push((line, self.clock));
            return;
        }
        // Evict LRU.
        let lru = self
            .entries
            .iter_mut()
            .min_by_key(|e| e.1)
            .expect("cap > 0");
        *lru = (line, self.clock);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_miss_then_insert_then_hit() {
        let mut tb = TempBuffer::new(4);
        assert!(!tb.probe(10));
        tb.insert(10);
        assert!(tb.probe(10));
        assert_eq!(tb.hits, 1);
        assert_eq!(tb.misses, 1);
        assert!((tb.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_order() {
        let mut tb = TempBuffer::new(2);
        tb.insert(1);
        tb.insert(2);
        assert!(tb.probe(1)); // refresh 1 → 2 becomes LRU
        tb.insert(3); // evicts 2
        assert!(tb.probe(1));
        assert!(tb.probe(3));
        assert!(!tb.probe(2));
        assert_eq!(tb.len(), 2);
    }

    #[test]
    fn reinsert_refreshes_not_duplicates() {
        let mut tb = TempBuffer::new(2);
        tb.insert(5);
        tb.insert(5);
        assert_eq!(tb.len(), 1);
        tb.insert(6);
        tb.insert(7); // evicts 5 (6 was more recent? no: 5 refreshed, 6 newer, evict 5? )
        // After insert(5),insert(5),insert(6): stamps 5→2, 6→3. insert(7)
        // evicts 5.
        assert!(!tb.probe(5));
        assert!(tb.probe(6));
        assert!(tb.probe(7));
    }

    #[test]
    fn sequential_element_stream_hits_three_of_four() {
        // 16 B elements in 64 B lines: element z lives in line z/4.
        let mut tb = TempBuffer::new(8);
        let mut hits = 0;
        for z in 0..400u64 {
            let line = z / 4;
            if tb.probe(line) {
                hits += 1;
            } else {
                tb.insert(line);
            }
        }
        // 300 of 400 probes hit (each line: 1 miss + 3 hits).
        assert_eq!(hits, 300);
    }
}
