//! PE front ends — the compute-fabric side of the memory system.
//!
//! A front end replays one [`PeTrace`]: it keeps a decoupling window of
//! in-flight nonzeros (Type-1: the systolic array's pipeline depth;
//! Type-2: each PE's load queue), issues each nonzero's accesses to the
//! memory system, waits for the loads, spends the compute cycles, and
//! retires. The *system* decides where each access goes (cache / DMA /
//! direct) — the front end only tracks dependency state, which is why the
//! same PE model drives the proposed system and all three baselines.

use std::collections::VecDeque;

use crate::trace::{Access, NnzWork, PeTrace};

use super::Cycle;

/// Access slots within a nonzero: 0 = element, 1/2 = fibers, 3 = store.
pub const ACC_ELEM: usize = 0;
pub const ACC_FIB1: usize = 1;
pub const ACC_FIB2: usize = 2;
pub const ACC_STORE: usize = 3;

/// Pack a completion token: (pe, window slot, access index).
#[inline]
pub fn pack_token(pe: usize, slot: usize, acc: usize) -> u64 {
    ((pe as u64) << 24) | ((slot as u64) << 4) | acc as u64
}

/// Unpack a completion token.
#[inline]
pub fn unpack_token(t: u64) -> (usize, usize, usize) {
    ((t >> 24) as usize, ((t >> 4) & 0xF_FFFF) as usize, (t & 0xF) as usize)
}

#[derive(Debug, Clone)]
struct NnzSlot {
    work: NnzWork,
    /// Whether each access has been handed to the memory system.
    issued: [bool; 4],
    /// Outstanding sub-parts per access (cache-only splits fibers into
    /// lines). 0 ⇒ complete (for issued accesses / absent store).
    parts_left: [u16; 4],
    /// Cycle at which compute finishes (set once all loads complete).
    compute_done: Option<Cycle>,
    /// Accesses (elem, fibers, store) not yet fully complete.
    outstanding: u8,
    /// Cycle each access was issued (for latency accounting).
    issued_at: [Cycle; 4],
}

impl NnzSlot {
    fn new(work: NnzWork) -> NnzSlot {
        NnzSlot {
            work,
            issued: [false, false, false, work.store.is_none()],
            parts_left: [1, 1, 1, u16::from(work.store.is_some())],
            compute_done: None,
            outstanding: 3 + u8::from(work.store.is_some()),
            issued_at: [0; 4],
        }
    }

    fn loads_done(&self) -> bool {
        (0..3).all(|a| self.issued[a] && self.parts_left[a] == 0)
    }

    fn store_done(&self) -> bool {
        self.issued[ACC_STORE] && self.parts_left[ACC_STORE] == 0
    }
}

/// Per-access-class latency accumulators (issue → last part complete).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyStats {
    pub count: u64,
    pub total: u64,
    pub max: u64,
}

impl LatencyStats {
    pub fn record(&mut self, lat: u64) {
        self.count += 1;
        self.total += lat;
        self.max = self.max.max(lat);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total as f64 / self.count as f64
        }
    }

    pub fn merge(&mut self, o: &LatencyStats) {
        self.count += o.count;
        self.total += o.total;
        self.max = self.max.max(o.max);
    }
}

/// Statistics per front end.
#[derive(Debug, Clone, Default)]
pub struct PeStats {
    pub retired: u64,
    pub issued_accesses: u64,
    pub stall_cycles: u64,
    /// Latency by access slot class: [element, fiber-load, fiber-load,
    /// store] — index with ACC_*.
    pub latency: [LatencyStats; 4],
}

/// One PE front end (Type-1: the shared TLU/MLU/MSU; Type-2: one PE).
pub struct PeFrontEnd {
    pub pe: usize,
    /// LMB / router port this front end is attached to.
    pub port: usize,
    trace: PeTrace,
    cursor: usize,
    window: Vec<Option<NnzSlot>>,
    /// Unissued (slot, acc) accesses in program order — avoids the
    /// O(window × 4) scan per issue attempt (§Perf L3 opt #1).
    pending: VecDeque<(u32, u8)>,
    /// Slots whose accesses all completed, with their compute-done cycle
    /// — retire() scans these instead of the window (§Perf L3 opt #3).
    retirable: Vec<(Cycle, u32)>,
    /// Min compute-done cycle over `retirable` (`Cycle::MAX` when empty):
    /// lets `retire` return without scanning until something is due.
    earliest_retire: Cycle,
    /// Free window slots (admission without scanning the window). Which
    /// slot a nonzero lands in is timing-inert — issue order is the
    /// `pending` queue's program order — so any free slot will do.
    free_slots: Vec<u32>,
    occupied: usize,
    /// Accesses this front end may issue per cycle.
    pub issue_width: usize,
    compute_cycles: Cycle,
    pub stats: PeStats,
}

impl PeFrontEnd {
    pub fn new(
        trace: PeTrace,
        port: usize,
        window: usize,
        issue_width: usize,
        compute_cycles: Cycle,
    ) -> PeFrontEnd {
        let window = window.max(1);
        PeFrontEnd {
            pe: trace.pe,
            port,
            trace,
            cursor: 0,
            window: vec![None; window],
            pending: VecDeque::new(),
            retirable: Vec::new(),
            earliest_retire: Cycle::MAX,
            // Reversed so pop() hands out low slots first.
            free_slots: (0..window as u32).rev().collect(),
            occupied: 0,
            issue_width: issue_width.max(1),
            compute_cycles,
            stats: PeStats::default(),
        }
    }

    /// Admit nonzeros from the trace into free window slots (in order).
    pub fn fill_window(&mut self) {
        while self.cursor < self.trace.work.len() {
            let Some(slot) = self.free_slots.pop() else {
                break;
            };
            let slot = slot as usize;
            debug_assert!(self.window[slot].is_none());
            self.occupied += 1;
            let work = self.trace.work[self.cursor];
            self.window[slot] = Some(NnzSlot::new(work));
            self.cursor += 1;
            for acc in [ACC_ELEM, ACC_FIB1, ACC_FIB2] {
                self.pending.push_back((slot as u32, acc as u8));
            }
            if work.store.is_some() {
                self.pending.push_back((slot as u32, ACC_STORE as u8));
            }
        }
    }

    /// Could an issue attempt do anything right now: an unissued access
    /// is pending, or trace work can be admitted into a free window
    /// slot? (Partial line-split issues are tracked by the system.) When
    /// false, an issue visit is a provable no-op — the event-driven run
    /// loop skips this front end.
    pub fn can_issue(&self) -> bool {
        !self.pending.is_empty()
            || (self.cursor < self.trace.work.len() && self.occupied < self.window.len())
    }

    /// Next unissued access in program order (front of the pending
    /// queue). Returns (slot, acc index, access).
    pub fn next_unissued(&self) -> Option<(usize, usize, Access)> {
        let &(slot, acc) = self.pending.front()?;
        let (si, acc) = (slot as usize, acc as usize);
        let s = self.window[si].as_ref().expect("pending entry has live slot");
        let a = match acc {
            ACC_ELEM => s.work.elem,
            ACC_FIB1 => s.work.fibers[0],
            ACC_FIB2 => s.work.fibers[1],
            _ => s.work.store.expect("store slot pre-marked when absent"),
        };
        Some((si, acc, a))
    }

    /// Mark an access as issued with `parts` outstanding sub-requests.
    /// Must be the access `next_unissued` just returned (program order).
    pub fn mark_issued_at(&mut self, slot: usize, acc: usize, parts: u16, now: Cycle) {
        self.mark_issued(slot, acc, parts);
        if let Some(s) = self.window[slot].as_mut() {
            s.issued_at[acc] = now;
        }
    }

    /// Mark an access as issued with `parts` outstanding sub-requests.
    /// Must be the access `next_unissued` just returned (program order).
    pub fn mark_issued(&mut self, slot: usize, acc: usize, parts: u16) {
        debug_assert_eq!(
            self.pending.front(),
            Some(&(slot as u32, acc as u8)),
            "mark_issued out of order"
        );
        self.pending.pop_front();
        let s = self.window[slot].as_mut().expect("slot occupied");
        debug_assert!(!s.issued[acc]);
        s.issued[acc] = true;
        s.parts_left[acc] = parts;
        self.stats.issued_accesses += 1;
    }

    /// One sub-part of (slot, acc) completed at `now`. Returns true when
    /// the whole access (all parts) is now complete.
    pub fn part_done(&mut self, slot: usize, acc: usize, now: Cycle) -> bool {
        let Some(s) = self.window[slot].as_mut() else {
            return false; // late completion after forced retire (doesn't happen in practice)
        };
        debug_assert!(s.issued[acc] && s.parts_left[acc] > 0);
        s.parts_left[acc] -= 1;
        let complete = s.parts_left[acc] == 0;
        if complete {
            self.stats.latency[acc].record(now.saturating_sub(s.issued_at[acc]));
            s.outstanding -= 1;
            if s.compute_done.is_none() && s.loads_done() {
                s.compute_done = Some(now + self.compute_cycles);
            }
            if s.outstanding == 0 {
                let done = s.compute_done.expect("loads done implies compute scheduled");
                self.retirable.push((done, slot as u32));
                self.earliest_retire = self.earliest_retire.min(done);
            }
        }
        complete
    }

    /// Retire finished slots; returns how many retired this call.
    /// Returns without scanning until the earliest compute-done cycle —
    /// identical outcome to a scan that would have removed nothing.
    pub fn retire(&mut self, now: Cycle) -> u64 {
        if now < self.earliest_retire {
            return 0;
        }
        let mut n = 0;
        let mut i = 0;
        while i < self.retirable.len() {
            let (done, slot) = self.retirable[i];
            if done <= now {
                self.retirable.swap_remove(i);
                debug_assert!(self.window[slot as usize].is_some());
                self.window[slot as usize] = None;
                self.free_slots.push(slot);
                self.occupied -= 1;
                n += 1;
            } else {
                i += 1;
            }
        }
        self.earliest_retire = self
            .retirable
            .iter()
            .map(|&(done, _)| done)
            .min()
            .unwrap_or(Cycle::MAX);
        self.stats.retired += n;
        n
    }

    /// All trace work admitted and completed. `occupied` mirrors the
    /// window's live slots, so this is O(1).
    pub fn done(&self) -> bool {
        self.cursor >= self.trace.work.len() && self.occupied == 0
    }

    pub fn total_work(&self) -> usize {
        self.trace.work.len()
    }

    pub fn in_flight(&self) -> usize {
        self.occupied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::AccessClass;

    fn work(z: u64, with_store: bool) -> NnzWork {
        let a = |class, addr| Access {
            class,
            addr,
            bytes: 16,
        };
        NnzWork {
            elem: a(AccessClass::TensorElem, z * 16),
            fibers: [
                a(AccessClass::FiberLoad, 0x10000 + z * 128),
                a(AccessClass::FiberLoad, 0x20000 + z * 128),
            ],
            store: with_store.then(|| a(AccessClass::FiberStore, 0x30000)),
        }
    }

    fn fe(n: usize, window: usize) -> PeFrontEnd {
        let trace = PeTrace {
            pe: 0,
            work: (0..n as u64).map(|z| work(z, z % 2 == 0)).collect(),
        };
        PeFrontEnd::new(trace, 0, window, 2, 1)
    }

    #[test]
    fn token_pack_unpack() {
        for (pe, slot, acc) in [(0, 0, 0), (3, 17, 3), (255, 1023, 2)] {
            assert_eq!(unpack_token(pack_token(pe, slot, acc)), (pe, slot, acc));
        }
    }

    #[test]
    fn lifecycle_issue_complete_retire() {
        let mut fe = fe(1, 4);
        fe.fill_window();
        assert_eq!(fe.in_flight(), 1);
        // Issue all 4 accesses (elem, 2 fibers, store).
        let mut seen = Vec::new();
        while let Some((slot, acc, _a)) = fe.next_unissued() {
            fe.mark_issued(slot, acc, 1);
            seen.push(acc);
        }
        assert_eq!(seen, vec![ACC_ELEM, ACC_FIB1, ACC_FIB2, ACC_STORE]);
        // Complete loads at t=10 → compute done at 11.
        fe.part_done(0, ACC_ELEM, 10);
        fe.part_done(0, ACC_FIB1, 10);
        fe.part_done(0, ACC_FIB2, 10);
        assert_eq!(fe.retire(11), 0, "store still outstanding");
        fe.part_done(0, ACC_STORE, 12);
        assert_eq!(fe.retire(10), 0, "compute not yet done at 10");
        assert_eq!(fe.retire(12), 1);
        assert!(fe.done());
    }

    #[test]
    fn storeless_work_needs_only_loads() {
        let mut fe = fe(2, 1); // window 1: z=0 (store), then z=1 (no store)
        fe.fill_window();
        while let Some((s, a, _)) = fe.next_unissued() {
            fe.mark_issued(s, a, 1);
            fe.part_done(s, a, 5);
        }
        fe.retire(6);
        fe.fill_window();
        // Second item has no store: 3 accesses only.
        let mut count = 0;
        while let Some((s, a, _)) = fe.next_unissued() {
            fe.mark_issued(s, a, 1);
            fe.part_done(s, a, 8);
            count += 1;
        }
        assert_eq!(count, 3);
        assert_eq!(fe.retire(9), 1);
        assert!(fe.done());
    }

    #[test]
    fn multipart_access_completes_after_all_parts() {
        let mut fe = fe(1, 2);
        fe.fill_window();
        let (s, a, _) = fe.next_unissued().unwrap();
        fe.mark_issued(s, a, 3); // e.g. fiber split into 3 lines
        fe.part_done(s, a, 1);
        fe.part_done(s, a, 2);
        // Not done yet: next_unissued moves to the next access meanwhile.
        let (_, a2, _) = fe.next_unissued().unwrap();
        assert_ne!(a2, a);
        fe.part_done(s, a, 3);
        // Access a now complete (no panic, no double count).
    }

    #[test]
    fn window_limits_inflight() {
        let mut fe = fe(10, 3);
        fe.fill_window();
        assert_eq!(fe.in_flight(), 3);
        // Drain one, refill admits exactly one more.
        while let Some((s, a, _)) = fe.next_unissued() {
            fe.mark_issued(s, a, 1);
        }
        for acc in 0..4 {
            fe.part_done(0, acc, 4);
        }
        assert_eq!(fe.retire(20), 1);
        fe.fill_window();
        assert_eq!(fe.in_flight(), 3);
    }
}
