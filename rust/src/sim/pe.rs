//! PE front ends — the compute-fabric side of the memory system.
//!
//! A front end replays one work stream pulled chunk-wise from a
//! [`WorkCursor`] (a [`TraceSource`](crate::trace::TraceSource) stream):
//! it keeps a decoupling window of in-flight nonzeros (Type-1: the
//! systolic array's pipeline depth; Type-2: each PE's load queue),
//! issues each nonzero's accesses to the memory system, waits for the
//! loads, spends the compute cycles, and retires. At most
//! [`WORK_CHUNK`] un-admitted items are buffered at a time, so the
//! front end's memory footprint is independent of stream length. The
//! *system* decides where each access goes (cache / DMA / direct) — the
//! front end only tracks dependency state, which is why the same PE
//! model drives the proposed system and all three baselines.

use std::collections::VecDeque;

use crate::trace::source::{VecCursor, WorkCursor, WORK_CHUNK};
use crate::trace::{Access, NnzWork, PeTrace};

use super::Cycle;

/// Access slots within a nonzero: 0 = element, 1/2 = fibers, 3 = store.
pub const ACC_ELEM: usize = 0;
pub const ACC_FIB1: usize = 1;
pub const ACC_FIB2: usize = 2;
pub const ACC_STORE: usize = 3;

/// Pack a completion token: (pe, window slot, access index).
#[inline]
pub fn pack_token(pe: usize, slot: usize, acc: usize) -> u64 {
    ((pe as u64) << 24) | ((slot as u64) << 4) | acc as u64
}

/// Unpack a completion token.
#[inline]
pub fn unpack_token(t: u64) -> (usize, usize, usize) {
    ((t >> 24) as usize, ((t >> 4) & 0xF_FFFF) as usize, (t & 0xF) as usize)
}

#[derive(Debug, Clone)]
struct NnzSlot {
    work: NnzWork,
    /// Whether each access has been handed to the memory system.
    issued: [bool; 4],
    /// Outstanding sub-parts per access (cache-only splits fibers into
    /// lines). 0 ⇒ complete (for issued accesses / absent store).
    parts_left: [u16; 4],
    /// Cycle at which compute finishes (set once all loads complete).
    compute_done: Option<Cycle>,
    /// Accesses (elem, fibers, store) not yet fully complete.
    outstanding: u8,
    /// Cycle each access was issued (for latency accounting).
    issued_at: [Cycle; 4],
}

impl NnzSlot {
    fn new(work: NnzWork) -> NnzSlot {
        NnzSlot {
            work,
            issued: [false, false, false, work.store.is_none()],
            parts_left: [1, 1, 1, u16::from(work.store.is_some())],
            compute_done: None,
            outstanding: 3 + u8::from(work.store.is_some()),
            issued_at: [0; 4],
        }
    }

    fn loads_done(&self) -> bool {
        (0..3).all(|a| self.issued[a] && self.parts_left[a] == 0)
    }

    fn store_done(&self) -> bool {
        self.issued[ACC_STORE] && self.parts_left[ACC_STORE] == 0
    }
}

/// Number of log2 latency buckets: bucket 0 holds zero-cycle samples,
/// bucket `k >= 1` the range `[2^(k-1), 2^k - 1]` — covers any `u64`.
pub const LATENCY_BUCKETS: usize = 65;

/// Per-access-class latency accumulators (issue → last part complete):
/// count/total/max plus a log2 histogram for percentile estimates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyStats {
    pub count: u64,
    pub total: u64,
    pub max: u64,
    /// Log2 histogram — `buckets[LatencyStats::bucket_of(lat)] += 1`.
    pub buckets: [u64; LATENCY_BUCKETS],
}

// `[u64; 65]` has no derived Default (arrays > 32 predate const-generic
// impls there), so spell it out.
impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats { count: 0, total: 0, max: 0, buckets: [0; LATENCY_BUCKETS] }
    }
}

impl LatencyStats {
    /// Histogram bucket index for one latency sample.
    #[inline]
    pub fn bucket_of(lat: u64) -> usize {
        if lat == 0 {
            0
        } else {
            64 - lat.leading_zeros() as usize
        }
    }

    /// Inclusive `(lo, hi)` value range covered by bucket `k`.
    pub fn bucket_range(k: usize) -> (u64, u64) {
        if k == 0 {
            (0, 0)
        } else {
            let lo = 1u64 << (k - 1);
            (lo, lo - 1 + lo) // 2^k - 1; exact u64::MAX at k = 64
        }
    }

    pub fn record(&mut self, lat: u64) {
        self.count += 1;
        self.total += lat;
        self.max = self.max.max(lat);
        self.buckets[Self::bucket_of(lat)] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile estimate (`q` in 0..=1): the upper bound
    /// of the bucket holding the rank-`ceil(q·count)` sample, clamped to
    /// the observed max. 0 for an empty accumulator; exact whenever the
    /// bucket degenerates (single sample, all-equal, or the max bucket).
    pub fn percentile(&self, q: f64) -> u64 {
        self.percentile_bounds(q).1
    }

    /// Inclusive `(lo, hi)` bounds bracketing the exact nearest-rank
    /// percentile: the covered range of the bucket the ranked sample
    /// fell into, `hi` clamped to the observed max. `(0, 0)` when empty.
    pub fn percentile_bounds(&self, q: f64) -> (u64, u64) {
        if self.count == 0 {
            return (0, 0);
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (k, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let (lo, hi) = Self::bucket_range(k);
                return (lo.min(self.max), hi.min(self.max));
            }
        }
        (self.max, self.max)
    }

    pub fn merge(&mut self, o: &LatencyStats) {
        self.count += o.count;
        self.total += o.total;
        self.max = self.max.max(o.max);
        for (b, ob) in self.buckets.iter_mut().zip(o.buckets.iter()) {
            *b += *ob;
        }
    }
}

/// Statistics per front end.
#[derive(Debug, Clone, Default)]
pub struct PeStats {
    pub retired: u64,
    pub issued_accesses: u64,
    /// Cycles the front end's head access sat stalled (LMB said Stall /
    /// Blocked). Accounted as episode *durations* — from the cycle the
    /// head first stalls to the cycle it finally dispatches — a
    /// definition that depends only on simulated time, never on which
    /// cycles the engine happened to visit, so the counter is
    /// engine-invariant even when the event engine skips ahead.
    pub stall_cycles: u64,
    /// Latency by access slot class: [element, fiber-load, fiber-load,
    /// store] — index with ACC_*.
    pub latency: [LatencyStats; 4],
}

/// One PE front end (Type-1: the shared TLU/MLU/MSU; Type-2: one PE).
pub struct PeFrontEnd {
    pub pe: usize,
    /// LMB / router port this front end is attached to.
    pub port: usize,
    /// Pull cursor over this front end's work stream.
    cursor: Box<dyn WorkCursor>,
    /// Refill buffer: at most [`WORK_CHUNK`] items between cursor pulls.
    buf: Vec<NnzWork>,
    buf_pos: usize,
    /// Items admitted into the window so far / stream total (exact, from
    /// [`TraceSource::stream_len`](crate::trace::TraceSource::stream_len)).
    admitted: usize,
    total: usize,
    window: Vec<Option<NnzSlot>>,
    /// Unissued (slot, acc) accesses in program order — avoids the
    /// O(window × 4) scan per issue attempt (§Perf L3 opt #1).
    pending: VecDeque<(u32, u8)>,
    /// Slots whose accesses all completed, with their compute-done cycle
    /// — retire() scans these instead of the window (§Perf L3 opt #3).
    retirable: Vec<(Cycle, u32)>,
    /// Min compute-done cycle over `retirable` (`Cycle::MAX` when empty):
    /// lets `retire` return without scanning until something is due.
    earliest_retire: Cycle,
    /// Free window slots (admission without scanning the window). Which
    /// slot a nonzero lands in is timing-inert — issue order is the
    /// `pending` queue's program order — so any free slot will do.
    free_slots: Vec<u32>,
    occupied: usize,
    /// Accesses this front end may issue per cycle.
    pub issue_width: usize,
    compute_cycles: Cycle,
    /// Cycle the head access first returned Stall, if a stall episode is
    /// open. The run loop closes the episode when that head dispatches,
    /// accruing `now - stall_since` into `stats.stall_cycles`.
    pub stall_since: Option<Cycle>,
    pub stats: PeStats,
}

impl PeFrontEnd {
    pub fn new(
        pe: usize,
        total: usize,
        cursor: Box<dyn WorkCursor>,
        port: usize,
        window: usize,
        issue_width: usize,
        compute_cycles: Cycle,
    ) -> PeFrontEnd {
        let window = window.max(1);
        PeFrontEnd {
            pe,
            port,
            cursor,
            buf: Vec::new(),
            buf_pos: 0,
            admitted: 0,
            total,
            window: vec![None; window],
            pending: VecDeque::new(),
            retirable: Vec::new(),
            earliest_retire: Cycle::MAX,
            // Reversed so pop() hands out low slots first.
            free_slots: (0..window as u32).rev().collect(),
            occupied: 0,
            issue_width: issue_width.max(1),
            compute_cycles,
            stall_since: None,
            stats: PeStats::default(),
        }
    }

    /// Front end replaying a pre-materialized [`PeTrace`] (unit tests,
    /// tools that build traces by hand).
    pub fn from_trace(
        trace: PeTrace,
        port: usize,
        window: usize,
        issue_width: usize,
        compute_cycles: Cycle,
    ) -> PeFrontEnd {
        let total = trace.work.len();
        PeFrontEnd::new(
            trace.pe,
            total,
            Box::new(VecCursor::new(trace.work)),
            port,
            window,
            issue_width,
            compute_cycles,
        )
    }

    /// Admit nonzeros from the stream into free window slots (in order),
    /// pulling from the cursor in [`WORK_CHUNK`]-sized refills.
    pub fn fill_window(&mut self) {
        while self.admitted < self.total {
            let Some(slot) = self.free_slots.pop() else {
                break;
            };
            let slot = slot as usize;
            debug_assert!(self.window[slot].is_none());
            if self.buf_pos == self.buf.len() {
                self.buf.clear();
                self.buf_pos = 0;
                let got = self.cursor.refill(&mut self.buf, WORK_CHUNK);
                assert!(
                    got > 0,
                    "pe {}: trace source ran dry after {} of {} items",
                    self.pe,
                    self.admitted,
                    self.total
                );
            }
            self.occupied += 1;
            let work = self.buf[self.buf_pos];
            self.buf_pos += 1;
            self.window[slot] = Some(NnzSlot::new(work));
            self.admitted += 1;
            for acc in [ACC_ELEM, ACC_FIB1, ACC_FIB2] {
                self.pending.push_back((slot as u32, acc as u8));
            }
            if work.store.is_some() {
                self.pending.push_back((slot as u32, ACC_STORE as u8));
            }
        }
    }

    /// Would [`PeFrontEnd::fill_window`] admit anything right now
    /// (stream work remains and a window slot is free)? When false, fill
    /// is a provable no-op — the run loop's admission phase skips this
    /// front end, and the sharded engine uses the count of front ends
    /// needing fill as its is-sharding-worthwhile test.
    pub fn needs_fill(&self) -> bool {
        self.admitted < self.total && self.occupied < self.window.len()
    }

    /// Could an issue attempt do anything right now: an unissued access
    /// is pending, or stream work can be admitted into a free window
    /// slot? (Partial line-split issues are tracked by the system.) When
    /// false, an issue visit is a provable no-op — the event-driven run
    /// loop skips this front end.
    pub fn can_issue(&self) -> bool {
        !self.pending.is_empty()
            || (self.admitted < self.total && self.occupied < self.window.len())
    }

    /// Next unissued access in program order (front of the pending
    /// queue). Returns (slot, acc index, access).
    pub fn next_unissued(&self) -> Option<(usize, usize, Access)> {
        let &(slot, acc) = self.pending.front()?;
        let (si, acc) = (slot as usize, acc as usize);
        let s = self.window[si].as_ref().expect("pending entry has live slot");
        let a = match acc {
            ACC_ELEM => s.work.elem,
            ACC_FIB1 => s.work.fibers[0],
            ACC_FIB2 => s.work.fibers[1],
            _ => s.work.store.expect("store slot pre-marked when absent"),
        };
        Some((si, acc, a))
    }

    /// Mark an access as issued with `parts` outstanding sub-requests.
    /// Must be the access `next_unissued` just returned (program order).
    pub fn mark_issued_at(&mut self, slot: usize, acc: usize, parts: u16, now: Cycle) {
        self.mark_issued(slot, acc, parts);
        if let Some(s) = self.window[slot].as_mut() {
            s.issued_at[acc] = now;
        }
    }

    /// Mark an access as issued with `parts` outstanding sub-requests.
    /// Must be the access `next_unissued` just returned (program order).
    pub fn mark_issued(&mut self, slot: usize, acc: usize, parts: u16) {
        debug_assert_eq!(
            self.pending.front(),
            Some(&(slot as u32, acc as u8)),
            "mark_issued out of order"
        );
        self.pending.pop_front();
        let s = self.window[slot].as_mut().expect("slot occupied");
        debug_assert!(!s.issued[acc]);
        s.issued[acc] = true;
        s.parts_left[acc] = parts;
        self.stats.issued_accesses += 1;
    }

    /// One sub-part of (slot, acc) completed at `now`. Returns true when
    /// the whole access (all parts) is now complete.
    pub fn part_done(&mut self, slot: usize, acc: usize, now: Cycle) -> bool {
        let Some(s) = self.window[slot].as_mut() else {
            return false; // late completion after forced retire (doesn't happen in practice)
        };
        debug_assert!(s.issued[acc] && s.parts_left[acc] > 0);
        s.parts_left[acc] -= 1;
        let complete = s.parts_left[acc] == 0;
        if complete {
            self.stats.latency[acc].record(now.saturating_sub(s.issued_at[acc]));
            s.outstanding -= 1;
            if s.compute_done.is_none() && s.loads_done() {
                s.compute_done = Some(now + self.compute_cycles);
            }
            if s.outstanding == 0 {
                let done = s.compute_done.expect("loads done implies compute scheduled");
                self.retirable.push((done, slot as u32));
                self.earliest_retire = self.earliest_retire.min(done);
            }
        }
        complete
    }

    /// Earliest compute-done cycle among finished-but-unretired slots —
    /// a run-loop fast-forward candidate (`None` when nothing is
    /// pending retirement).
    pub fn next_retire(&self) -> Option<Cycle> {
        (self.earliest_retire != Cycle::MAX).then_some(self.earliest_retire)
    }

    /// Retire finished slots; returns how many retired this call.
    /// Returns without scanning until the earliest compute-done cycle —
    /// identical outcome to a scan that would have removed nothing.
    pub fn retire(&mut self, now: Cycle) -> u64 {
        if now < self.earliest_retire {
            return 0;
        }
        let mut n = 0;
        let mut i = 0;
        while i < self.retirable.len() {
            let (done, slot) = self.retirable[i];
            if done <= now {
                self.retirable.swap_remove(i);
                debug_assert!(self.window[slot as usize].is_some());
                self.window[slot as usize] = None;
                self.free_slots.push(slot);
                self.occupied -= 1;
                n += 1;
            } else {
                i += 1;
            }
        }
        self.earliest_retire = self
            .retirable
            .iter()
            .map(|&(done, _)| done)
            .min()
            .unwrap_or(Cycle::MAX);
        self.stats.retired += n;
        n
    }

    /// All stream work admitted and completed. `occupied` mirrors the
    /// window's live slots, so this is O(1).
    pub fn done(&self) -> bool {
        self.admitted >= self.total && self.occupied == 0
    }

    pub fn total_work(&self) -> usize {
        self.total
    }

    pub fn in_flight(&self) -> usize {
        self.occupied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::AccessClass;

    fn work(z: u64, with_store: bool) -> NnzWork {
        let a = |class, addr| Access {
            class,
            addr,
            bytes: 16,
        };
        NnzWork {
            elem: a(AccessClass::TensorElem, z * 16),
            fibers: [
                a(AccessClass::FiberLoad, 0x10000 + z * 128),
                a(AccessClass::FiberLoad, 0x20000 + z * 128),
            ],
            store: with_store.then(|| a(AccessClass::FiberStore, 0x30000)),
        }
    }

    fn fe(n: usize, window: usize) -> PeFrontEnd {
        let trace = PeTrace {
            pe: 0,
            work: (0..n as u64).map(|z| work(z, z % 2 == 0)).collect(),
        };
        PeFrontEnd::from_trace(trace, 0, window, 2, 1)
    }

    #[test]
    fn token_pack_unpack() {
        for (pe, slot, acc) in [(0, 0, 0), (3, 17, 3), (255, 1023, 2)] {
            assert_eq!(unpack_token(pack_token(pe, slot, acc)), (pe, slot, acc));
        }
    }

    #[test]
    fn lifecycle_issue_complete_retire() {
        let mut fe = fe(1, 4);
        fe.fill_window();
        assert_eq!(fe.in_flight(), 1);
        // Issue all 4 accesses (elem, 2 fibers, store).
        let mut seen = Vec::new();
        while let Some((slot, acc, _a)) = fe.next_unissued() {
            fe.mark_issued(slot, acc, 1);
            seen.push(acc);
        }
        assert_eq!(seen, vec![ACC_ELEM, ACC_FIB1, ACC_FIB2, ACC_STORE]);
        // Complete loads at t=10 → compute done at 11.
        fe.part_done(0, ACC_ELEM, 10);
        fe.part_done(0, ACC_FIB1, 10);
        fe.part_done(0, ACC_FIB2, 10);
        assert_eq!(fe.retire(11), 0, "store still outstanding");
        fe.part_done(0, ACC_STORE, 12);
        assert_eq!(fe.retire(10), 0, "compute not yet done at 10");
        assert_eq!(fe.retire(12), 1);
        assert!(fe.done());
    }

    #[test]
    fn storeless_work_needs_only_loads() {
        let mut fe = fe(2, 1); // window 1: z=0 (store), then z=1 (no store)
        fe.fill_window();
        while let Some((s, a, _)) = fe.next_unissued() {
            fe.mark_issued(s, a, 1);
            fe.part_done(s, a, 5);
        }
        fe.retire(6);
        fe.fill_window();
        // Second item has no store: 3 accesses only.
        let mut count = 0;
        while let Some((s, a, _)) = fe.next_unissued() {
            fe.mark_issued(s, a, 1);
            fe.part_done(s, a, 8);
            count += 1;
        }
        assert_eq!(count, 3);
        assert_eq!(fe.retire(9), 1);
        assert!(fe.done());
    }

    #[test]
    fn multipart_access_completes_after_all_parts() {
        let mut fe = fe(1, 2);
        fe.fill_window();
        let (s, a, _) = fe.next_unissued().unwrap();
        fe.mark_issued(s, a, 3); // e.g. fiber split into 3 lines
        fe.part_done(s, a, 1);
        fe.part_done(s, a, 2);
        // Not done yet: next_unissued moves to the next access meanwhile.
        let (_, a2, _) = fe.next_unissued().unwrap();
        assert_ne!(a2, a);
        fe.part_done(s, a, 3);
        // Access a now complete (no panic, no double count).
    }

    #[test]
    fn window_limits_inflight() {
        let mut fe = fe(10, 3);
        fe.fill_window();
        assert_eq!(fe.in_flight(), 3);
        // Drain one, refill admits exactly one more.
        while let Some((s, a, _)) = fe.next_unissued() {
            fe.mark_issued(s, a, 1);
        }
        for acc in 0..4 {
            fe.part_done(0, acc, 4);
        }
        assert_eq!(fe.retire(20), 1);
        fe.fill_window();
        assert_eq!(fe.in_flight(), 3);
    }

    // --- latency histogram -----------------------------------------------

    #[test]
    fn latency_buckets_cover_log2_ranges() {
        for (lat, want) in [(0u64, 0usize), (1, 1), (2, 2), (3, 2), (4, 3), (7, 3), (8, 4)] {
            assert_eq!(LatencyStats::bucket_of(lat), want, "bucket of {lat}");
        }
        assert_eq!(LatencyStats::bucket_of(u64::MAX), 64);
        for k in 0..LATENCY_BUCKETS {
            let (lo, hi) = LatencyStats::bucket_range(k);
            assert!(lo <= hi, "bucket {k} range inverted");
            assert_eq!(LatencyStats::bucket_of(lo), k, "lo of bucket {k}");
            assert_eq!(LatencyStats::bucket_of(hi), k, "hi of bucket {k}");
        }
    }

    #[test]
    fn latency_percentile_edge_cases() {
        let empty = LatencyStats::default();
        assert_eq!(empty.percentile(0.5), 0);
        assert_eq!(empty.percentile_bounds(0.99), (0, 0));

        let mut one = LatencyStats::default();
        one.record(37);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.percentile(q), 37, "single sample, q={q}");
        }

        let mut same = LatencyStats::default();
        for _ in 0..100 {
            same.record(12);
        }
        assert_eq!(same.percentile(0.5), 12);
        assert_eq!(same.percentile(0.99), 12);
        assert_eq!(same.mean(), 12.0);
    }

    #[test]
    fn latency_merge_adds_buckets_elementwise() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        for lat in [0u64, 3, 100] {
            a.record(lat);
        }
        for lat in [5u64, 1000] {
            b.record(lat);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        let mut direct = LatencyStats::default();
        for lat in [0u64, 3, 100, 5, 1000] {
            direct.record(lat);
        }
        assert_eq!(merged, direct);
    }

    /// Satellite: bucketed percentiles must bracket the exact
    /// nearest-rank percentile of the recorded sample vector, for
    /// randomized vectors including empty / single / all-equal shapes.
    #[test]
    fn prop_percentile_bounds_bracket_exact() {
        use crate::util::prop::check;
        use crate::{prop_assert, prop_assert_eq};

        fn exact_percentile(sorted: &[u64], q: f64) -> u64 {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            sorted[rank - 1]
        }

        check(
            "log2 percentile bounds bracket exact",
            200,
            |rng| {
                let shape = rng.gen_range(4);
                let n = match shape {
                    0 => 0,                          // empty
                    1 => 1,                          // single sample
                    2 => rng.gen_usize(2, 64),       // all-equal
                    _ => rng.gen_usize(2, 256),      // general
                };
                let fixed = rng.gen_range(100_000);
                (0..n)
                    .map(|_| if shape == 2 { fixed } else { rng.gen_range(1 << 20) })
                    .collect::<Vec<u64>>()
            },
            |samples| {
                let mut h = LatencyStats::default();
                for &s in samples {
                    h.record(s);
                }
                let mut sorted = samples.clone();
                sorted.sort_unstable();
                for q in [0.5, 0.9, 0.95, 0.99, 1.0] {
                    let (lo, hi) = h.percentile_bounds(q);
                    if sorted.is_empty() {
                        prop_assert_eq!((lo, hi), (0, 0), "empty must yield (0,0)");
                        continue;
                    }
                    let exact = exact_percentile(&sorted, q);
                    prop_assert!(
                        lo <= exact && exact <= hi,
                        "q={q}: exact {exact} outside [{lo}, {hi}] (n={})",
                        sorted.len()
                    );
                    prop_assert!(
                        h.percentile(q) <= h.max,
                        "q={q}: estimate above observed max"
                    );
                }
                Ok(())
            },
        );
    }
}
