//! Recent Request Status Holder (RRSH) — stage 2 of the Request Reductor.
//!
//! "RRSH keeps the status of recently forwarded requests to the cache. If
//! the incoming read request belongs to one of the pending cache-line
//! requests, the PE id and address are kept in the RRSH. When a
//! cache-reply reaches the RRSH, the pending requests corresponding to
//! that cache line are satisfied ... It drastically reduces the memory
//! traffic to the cache." (§IV-C)
//!
//! Unlike a conventional MSHR, the RRSH sits *in front of* the cache and
//! absorbs secondary misses with a wide waiter list (width ∝ number of
//! PEs × elements per line, §IV-C1), implemented over the XOR-based hash
//! table.
//!
//! Sizing rule (§IV-C1): entries ∝ cache lines / associativity. The rule
//! is preserved under LMB banking — both the per-bank cache lines and the
//! per-bank RRSH entries are the configured totals divided by
//! `lmb_banks`, so each bank's RRSH stays proportional to the cache
//! shard it fronts.

use super::xor_hash::{InsertOutcome, XorHashTable};

/// Waiter token: (pe, per-PE bookkeeping id) packed by the caller.
pub type RrshToken = u64;

/// Outcome of presenting an element request's line to the RRSH.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RrshOutcome {
    /// Line not pending: entry created, forward ONE line request to cache.
    Forward,
    /// Line already pending: request absorbed, no cache traffic.
    Absorbed,
    /// Hash conflict or waiter list full — stall this PE for a cycle.
    Stall,
}

#[derive(Debug, Clone, Default)]
struct Pending {
    waiters: Vec<RrshToken>,
}

/// The RRSH unit.
pub struct Rrsh {
    table: XorHashTable<Pending>,
    /// Max waiters per line: tag width + one slot per PE per element slot
    /// (§IV-C1: table width ∝ tag + n_PEs, connected RR × elements/line).
    waiter_cap: usize,
    /// Recycled waiter-list allocations: completed entries return their
    /// storage here and new entries reuse it, keeping the steady-state
    /// request/complete cycle allocation-free.
    pool: Vec<Vec<RrshToken>>,
    pub stat_forwarded: u64,
    pub stat_absorbed: u64,
    pub stat_stalls: u64,
}

impl Rrsh {
    /// `entries` = table capacity (paper: 4096 ∝ cache lines / assoc);
    /// `n_pes`, `elems_per_line` size the waiter list.
    pub fn new(entries: usize, n_pes: usize, elems_per_line: usize) -> Rrsh {
        Rrsh {
            table: XorHashTable::new(entries.next_power_of_two()),
            waiter_cap: (n_pes * elems_per_line).max(4),
            pool: Vec::new(),
            stat_forwarded: 0,
            stat_absorbed: 0,
            stat_stalls: 0,
        }
    }

    /// Present an element request for cache line `line`.
    pub fn request(&mut self, line: u64, token: RrshToken) -> RrshOutcome {
        if let Some(p) = self.table.get_mut(line) {
            if p.waiters.len() >= self.waiter_cap {
                self.stat_stalls += 1;
                return RrshOutcome::Stall;
            }
            p.waiters.push(token);
            self.stat_absorbed += 1;
            return RrshOutcome::Absorbed;
        }
        let pool = &mut self.pool;
        match self.table.try_insert_with(line, || {
            let mut waiters = pool.pop().unwrap_or_default();
            waiters.push(token);
            Pending { waiters }
        }) {
            InsertOutcome::Inserted => {
                self.stat_forwarded += 1;
                RrshOutcome::Forward
            }
            InsertOutcome::Exists => unreachable!("checked above"),
            InsertOutcome::Conflict => {
                self.stat_stalls += 1;
                RrshOutcome::Stall
            }
        }
    }

    /// A cache line arrived: release its waiters into `out` (in arrival
    /// order) and recycle the entry's storage. No-op for untracked lines.
    pub fn complete_into(&mut self, line: u64, out: &mut Vec<RrshToken>) {
        if let Some(mut p) = self.table.remove(line) {
            out.extend(p.waiters.drain(..));
            self.pool.push(p.waiters);
        }
    }

    /// Is this line already being tracked?
    pub fn pending(&self, line: u64) -> bool {
        self.table.get(line).is_some()
    }

    pub fn outstanding_lines(&self) -> usize {
        self.table.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_request_forwards_rest_absorbed() {
        let mut r = Rrsh::new(64, 4, 4);
        assert_eq!(r.request(10, 1), RrshOutcome::Forward);
        assert_eq!(r.request(10, 2), RrshOutcome::Absorbed);
        assert_eq!(r.request(10, 3), RrshOutcome::Absorbed);
        assert_eq!(r.stat_forwarded, 1);
        assert_eq!(r.stat_absorbed, 2);
        assert!(r.pending(10));
        let mut w = Vec::new();
        r.complete_into(10, &mut w);
        assert_eq!(w, vec![1, 2, 3]);
        assert!(!r.pending(10));
        // After completion a new request to the same line forwards again.
        assert_eq!(r.request(10, 4), RrshOutcome::Forward);
    }

    #[test]
    fn waiter_cap_stalls() {
        let mut r = Rrsh::new(64, 1, 4); // cap = 4
        assert_eq!(r.request(5, 0), RrshOutcome::Forward);
        for t in 1..4 {
            assert_eq!(r.request(5, t), RrshOutcome::Absorbed);
        }
        assert_eq!(r.request(5, 9), RrshOutcome::Stall);
        assert_eq!(r.stat_stalls, 1);
    }

    #[test]
    fn traffic_reduction_on_element_stream() {
        // 4 PEs sweeping a COO stream: 16 elements per line region,
        // 64 lines. Cache traffic = forwarded lines only.
        let mut r = Rrsh::new(4096, 4, 4);
        let mut cache_traffic = 0;
        let mut released = Vec::new();
        for z in 0..1024u64 {
            let line = z / 4;
            match r.request(line, z) {
                RrshOutcome::Forward => cache_traffic += 1,
                RrshOutcome::Absorbed => {}
                RrshOutcome::Stall => panic!("unexpected stall"),
            }
            if z % 4 == 3 {
                released.clear();
                r.complete_into(line, &mut released);
                assert_eq!(released.len(), 4);
            }
        }
        assert_eq!(cache_traffic, 256, "1 line request per 4 elements");
    }

    #[test]
    fn complete_unknown_line_empty() {
        let mut r = Rrsh::new(16, 2, 4);
        let mut out = Vec::new();
        r.complete_into(99, &mut out);
        assert!(out.is_empty());
    }
}
