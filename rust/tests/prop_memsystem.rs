//! Property tests over the memory-system simulator: conservation, hit
//! accounting, and cross-variant sanity on randomized tensors and
//! configurations.

use std::sync::Arc;

use mttkrp_memsys::config::{FabricType, SystemConfig, SystemKind};
use mttkrp_memsys::experiment::Scenario;
use mttkrp_memsys::sim::simulate;
use mttkrp_memsys::tensor::CooTensor;
use mttkrp_memsys::trace::Workload;
use mttkrp_memsys::util::prop::check;
use mttkrp_memsys::util::rng::Rng;
use mttkrp_memsys::{prop_assert, prop_assert_eq};

/// Scenario-built workload for a randomized (tensor, config) case.
fn wl(t: &CooTensor, cfg: &SystemConfig) -> Arc<Workload> {
    Scenario::from_tensor(t.clone()).for_config(cfg).workload()
}

fn random_case(rng: &mut Rng) -> (CooTensor, SystemConfig) {
    let dims = [
        rng.gen_range(60) + 4,
        rng.gen_range(5000) + 100,
        rng.gen_range(8000) + 100,
    ];
    let nnz = rng.gen_usize(20, 800);
    let t = CooTensor::random(rng, dims, nnz);
    let mut cfg = if rng.gen_bool(0.5) {
        SystemConfig::config_a()
    } else {
        SystemConfig::config_b()
    };
    // Randomize the synthesis-time knobs within valid ranges.
    cfg.dma.n_buffers = 1 << rng.gen_range(3); // 1..4
    cfg.cache.lines = 1024 << rng.gen_range(3); // 1K..4K lines
    cfg.cache.associativity = 1 << rng.gen_range(2); // 1 or 2
    cfg.rr.rrsh_entries = 512 << rng.gen_range(3);
    cfg.rr.temp_buffer_entries = rng.gen_usize(2, 16);
    cfg.pe.max_inflight = rng.gen_usize(2, 16);
    cfg.pe.fabric = if cfg.n_lmbs == 1 {
        FabricType::Type1
    } else {
        FabricType::Type2
    };
    cfg.validate().expect("randomized config must be valid");
    (t, cfg)
}

#[test]
fn prop_all_accesses_served_all_variants() {
    check(
        "conservation across variants",
        12,
        |rng| random_case(rng),
        |(t, cfg)| {
            let w = wl(t, cfg);
            let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
            for kind in SystemKind::ALL {
                let rep = simulate(&cfg.as_baseline(kind), &w);
                prop_assert_eq!(rep.accesses, expected, "{kind:?} conservation");
                prop_assert!(rep.total_cycles > 0, "{kind:?} zero cycles");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dram_reads_bounded_by_requested_and_alignment() {
    check(
        "dram read bounds",
        12,
        |rng| random_case(rng),
        |(t, cfg)| {
            let w = wl(t, cfg);
            let rep = simulate(cfg, &w);
            // Reads can't exceed the aligned footprint of every load
            // (each load ≤ one 64 B-aligned burst via cache or DMA).
            let load_bound: u64 = w
                .pe_traces
                .iter()
                .flat_map(|p| &p.work)
                .flat_map(|x| x.accesses())
                .filter(|a| !a.class.is_write())
                .map(|a| ((a.bytes as u64 + 127) / 64) * 64)
                .sum();
            prop_assert!(
                rep.dram.read_bytes <= load_bound,
                "read {} > bound {load_bound}",
                rep.dram.read_bytes
            );
            prop_assert!(rep.dram.read_bytes > 0, "no reads at all");
            Ok(())
        },
    );
}

#[test]
fn prop_row_hit_rate_is_a_rate_and_bus_not_overcommitted() {
    check(
        "dram stats sanity",
        12,
        |rng| random_case(rng),
        |(t, cfg)| {
            let w = wl(t, cfg);
            let rep = simulate(cfg, &w);
            let hr = rep.dram.row_hit_rate();
            prop_assert!((0.0..=1.0).contains(&hr), "row hit rate {hr}");
            // Data moved can't exceed one beat per busy bus cycle.
            let moved = rep.dram.read_bytes + rep.dram.write_bytes;
            prop_assert!(
                moved <= rep.dram.busy_bus_cycles * 64,
                "bus overcommitted: {moved} bytes in {} busy cycles",
                rep.dram.busy_bus_cycles
            );
            prop_assert!(
                rep.dram.busy_bus_cycles <= rep.total_cycles + 1,
                "bus busy longer than the run"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_proposed_never_loses_to_ip_only() {
    check(
        "proposed ≤ ip-only",
        10,
        |rng| random_case(rng),
        |(t, cfg)| {
            let w = wl(t, cfg);
            let prop = simulate(cfg, &w);
            let ip = simulate(&cfg.as_baseline(SystemKind::IpOnly), &w);
            prop_assert!(
                prop.total_cycles <= ip.total_cycles * 11 / 10,
                "proposed {} vs ip-only {}",
                prop.total_cycles,
                ip.total_cycles
            );
            Ok(())
        },
    );
}
