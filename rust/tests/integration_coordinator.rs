//! Integration: the coordinator layer — full-stack accelerator runs and
//! the timed CP-ALS driver (experiment E6 at test scale).

use mttkrp_memsys::config::{SystemConfig, SystemKind};
use mttkrp_memsys::coordinator::{run_accelerator, TimedCpAls};
use mttkrp_memsys::mttkrp::CpAlsOptions;
use mttkrp_memsys::runtime::{find_artifacts_dir, Manifest};
use mttkrp_memsys::tensor::{CooTensor, DenseMatrix, Mode};
use mttkrp_memsys::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load(&find_artifacts_dir()?).ok()
}

#[test]
fn accelerator_run_consistent_across_system_kinds() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let r = m.partials.rank;
    let mut rng = Rng::new(400);
    let t = CooTensor::random(&mut rng, [48, 3000, 5000], 3000);
    let d = DenseMatrix::random(&mut rng, 3000, r);
    let c = DenseMatrix::random(&mut rng, 5000, r);
    let mut norms = Vec::new();
    for kind in [SystemKind::Proposed, SystemKind::IpOnly] {
        let cfg = SystemConfig::config_b().as_baseline(kind);
        let (out, report) = run_accelerator(&cfg, &m, &t, Mode::I, &d, &c).unwrap();
        // Numerics must be identical regardless of the memory system —
        // timing and data paths are decoupled by design.
        norms.push(out.fro_norm());
        assert!(report.max_diff_vs_reference < 2e-3);
        assert!(report.sim.total_cycles > 0);
    }
    assert!((norms[0] - norms[1]).abs() < 1e-9);
}

#[test]
fn timed_als_full_pipeline_fit_improves() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let rank = m.partials.rank;
    let mut rng = Rng::new(401);
    // Low-rank-ish structured tensor so the fit visibly improves.
    let t = CooTensor::random(&mut rng, [24, 30, 36], 3000);
    let driver = TimedCpAls::new(SystemConfig::config_b(), m);
    let report = driver
        .run(
            &t,
            CpAlsOptions {
                rank,
                max_iters: 4,
                fit_tol: 0.0,
                seed: 3,
            },
        )
        .unwrap();
    assert_eq!(report.als.iters.len(), 4);
    let first = report.als.iters.first().unwrap().rel_error;
    let last = report.als.iters.last().unwrap().rel_error;
    assert!(last <= first + 1e-6, "rel_error {first} → {last}");
    // Timing must cover all three modes.
    assert_eq!(report.per_mode_sim.len(), 3);
    for s in &report.per_mode_sim {
        assert!(s.total_cycles > 0);
        assert_eq!(s.nnz, t.nnz() as u64);
    }
}

#[test]
fn config_a_and_b_both_drive_the_accelerator() {
    let Some(m) = manifest() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let r = m.partials.rank;
    let mut rng = Rng::new(402);
    let t = CooTensor::random(&mut rng, [32, 800, 900], 1500);
    let d = DenseMatrix::random(&mut rng, 800, r);
    let c = DenseMatrix::random(&mut rng, 900, r);
    for cfg in [SystemConfig::config_a(), SystemConfig::config_b()] {
        let (_, report) = run_accelerator(&cfg, &m, &t, Mode::I, &d, &c).unwrap();
        assert!(report.max_diff_vs_reference < 2e-3, "{}", cfg.label);
    }
}
