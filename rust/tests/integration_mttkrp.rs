//! Cross-validation of every MTTKRP implementation (Algorithm 2,
//! Algorithm 3, fiber Eq. 3/4) and CP-ALS over them.

use mttkrp_memsys::mttkrp::fiber::{mttkrp_fiber_eq3, mttkrp_fiber_eq4};
use mttkrp_memsys::mttkrp::seq::mttkrp_seq_f64;
use mttkrp_memsys::mttkrp::{mttkrp_parallel, mttkrp_seq, CpAls, CpAlsOptions};
use mttkrp_memsys::tensor::{CooTensor, DenseMatrix, Mode};
use mttkrp_memsys::util::rng::Rng;

fn setup(seed: u64, dims: [u64; 3], nnz: usize, r: usize) -> (CooTensor, DenseMatrix, DenseMatrix) {
    let mut rng = Rng::new(seed);
    let t = CooTensor::random(&mut rng, dims, nnz);
    let d = DenseMatrix::random(&mut rng, dims[1] as usize, r);
    let c = DenseMatrix::random(&mut rng, dims[2] as usize, r);
    (t, d, c)
}

#[test]
fn all_variants_agree_with_f64_oracle() {
    let (t, d, c) = setup(200, [50, 40, 45], 5000, 16);
    let oracle = mttkrp_seq_f64(&t, Mode::I, &d, &c);
    let variants: Vec<(&str, DenseMatrix)> = vec![
        ("alg2", mttkrp_seq(&t, Mode::I, &d, &c)),
        ("alg3-p1", mttkrp_parallel(&t, Mode::I, &d, &c, 1)),
        ("alg3-p4", mttkrp_parallel(&t, Mode::I, &d, &c, 4)),
        ("alg3-p7", mttkrp_parallel(&t, Mode::I, &d, &c, 7)),
        ("eq3", mttkrp_fiber_eq3(&t, Mode::I, &d, &c)),
        ("eq4", mttkrp_fiber_eq4(&t, Mode::I, &d, &c)),
    ];
    for (name, got) in variants {
        for (x, (g, o)) in got.data.iter().zip(&oracle).enumerate() {
            assert!(
                (*g as f64 - o).abs() < 2e-3,
                "{name} idx {x}: {g} vs oracle {o}"
            );
        }
    }
}

#[test]
fn modes_j_and_k_agree_across_variants() {
    let mut rng = Rng::new(201);
    let t0 = CooTensor::random(&mut rng, [20, 24, 28], 2000);
    let a = DenseMatrix::random(&mut rng, 20, 8);
    let d = DenseMatrix::random(&mut rng, 24, 8);
    let c = DenseMatrix::random(&mut rng, 28, 8);
    for (mode, m1, m2) in [(Mode::J, &a, &c), (Mode::K, &a, &d)] {
        let mut t = t0.clone();
        t.sort_mode(mode);
        let reference = mttkrp_seq(&t, mode, m1, m2);
        let par = mttkrp_parallel(&t, mode, m1, m2, 4);
        let e3 = mttkrp_fiber_eq3(&t, mode, m1, m2);
        assert!(par.max_abs_diff(&reference) < 1e-3, "{mode:?} parallel");
        assert!(e3.max_abs_diff(&reference) < 1e-3, "{mode:?} eq3");
    }
}

#[test]
fn cp_als_recovers_low_rank_structure() {
    // Exact rank-3 tensor: CP-ALS at rank 4 must fit it almost perfectly.
    let mut rng = Rng::new(202);
    let rank = 3;
    let (i, j, k) = (14, 12, 10);
    let a = DenseMatrix::random(&mut rng, i, rank);
    let d = DenseMatrix::random(&mut rng, j, rank);
    let c = DenseMatrix::random(&mut rng, k, rank);
    let mut t = CooTensor::new("lr", [i as u64, j as u64, k as u64]);
    for ii in 0..i {
        for jj in 0..j {
            for kk in 0..k {
                let mut v = 0f32;
                for x in 0..rank {
                    v += a.at(ii, x) * d.at(jj, x) * c.at(kk, x);
                }
                t.push(ii as u32, jj as u32, kk as u32, v);
            }
        }
    }
    let mut als = CpAls::new(
        &t,
        CpAlsOptions {
            rank: 4,
            max_iters: 40,
            fit_tol: 1e-10,
            seed: 9,
        },
    );
    let report = als.run();
    let final_err = report.iters.last().unwrap().rel_error;
    assert!(final_err < 0.05, "rank-3 data should fit: err {final_err}");
}

#[test]
fn cp_als_error_never_increases_materially() {
    let mut rng = Rng::new(203);
    let t = CooTensor::random(&mut rng, [16, 16, 16], 800);
    let mut als = CpAls::new(
        &t,
        CpAlsOptions {
            rank: 6,
            max_iters: 12,
            fit_tol: 0.0,
            seed: 2,
        },
    );
    let report = als.run();
    for w in report.iters.windows(2) {
        assert!(
            w[1].rel_error <= w[0].rel_error + 5e-3,
            "ALS error rose: {} → {}",
            w[0].rel_error,
            w[1].rel_error
        );
    }
}

#[test]
fn parallel_partition_counts_scale_with_fibers() {
    // Degenerate shapes: single fiber, all-same-i, p > nnz.
    let mut t = CooTensor::new("deg", [1, 8, 8]);
    for z in 0..20 {
        t.push(0, z % 8, (z / 3) % 8, 1.0);
    }
    t.sum_duplicates();
    t.sort_mode(Mode::I);
    let mut rng = Rng::new(204);
    let d = DenseMatrix::random(&mut rng, 8, 4);
    let c = DenseMatrix::random(&mut rng, 8, 4);
    let seq = mttkrp_seq(&t, Mode::I, &d, &c);
    for p in [1, 2, 16] {
        let par = mttkrp_parallel(&t, Mode::I, &d, &c, p);
        assert!(par.max_abs_diff(&seq) < 1e-4, "p={p}");
    }
}
