//! Integration tests for the `experiment` API: sweep determinism across
//! thread counts, equivalence with the hand-rolled driver pipeline it
//! replaced, config-override round-trips/error paths, and the JSON-lines
//! schema machine consumers (CI, pytest) rely on.

use mttkrp_memsys::config::{FabricType, SystemConfig, SystemKind, TopologyKind};
use mttkrp_memsys::experiment::{run_one, Scenario, Sweep};
use mttkrp_memsys::sim::simulate;
use mttkrp_memsys::tensor::{CooTensor, Mode};
use mttkrp_memsys::trace::workload_from_tensor;
use mttkrp_memsys::util::json::Json;
use mttkrp_memsys::util::rng::Rng;

fn hyper_sparse(seed: u64, nnz: usize) -> CooTensor {
    let mut rng = Rng::new(seed);
    CooTensor::random(&mut rng, [96, 20_000, 30_000], nnz)
}

#[test]
fn sweep_is_deterministic_across_thread_counts() {
    let scenario = Scenario::from_tensor(hyper_sparse(31, 1200))
        .for_config(&SystemConfig::config_b());
    let sweep = Sweep::new(SystemConfig::config_b(), scenario)
        .axis("system", &["ip-only", "dma-only", "proposed"])
        .axis("channels", &["1", "2"]);
    let serial = sweep.clone().threads(1).run().unwrap();
    let parallel = sweep.threads(4).run().unwrap();
    assert_eq!(serial.len(), 6);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.runs.iter().zip(&parallel.runs) {
        assert_eq!(a.axes, b.axes, "grid order must not depend on threads");
        assert_eq!(a.label(), b.label());
        assert_eq!(a.report.total_cycles, b.report.total_cycles, "{}", a.label());
        assert_eq!(a.report.accesses, b.report.accesses, "{}", a.label());
        assert_eq!(a.report.dram.reads, b.report.dram.reads, "{}", a.label());
    }
}

#[test]
fn sweep_matches_the_hand_rolled_pipeline_it_replaced() {
    // The old driver pipeline: tensor → workload_from_tensor(6 args) →
    // as_baseline/apply_override → simulate. A sweep resolving the same
    // point must produce the identical report (the fig4 byte-identity
    // guarantee).
    let t = hyper_sparse(32, 1000);
    let base = SystemConfig::config_b();
    let w = workload_from_tensor(
        &t,
        Mode::I,
        base.pe.fabric,
        base.pe.n_pes,
        base.pe.rank,
        base.dram.row_bytes,
    );
    let mut hand_cfg = base.as_baseline(SystemKind::CacheOnly);
    hand_cfg.apply_override("channels", "2").unwrap();
    let hand = simulate(&hand_cfg, &w);

    let runs = Sweep::new(base.clone(), Scenario::from_tensor(t.clone()).for_config(&base))
        .axis("system", &["cache-only", "proposed"])
        .axis("channels", &["1", "2"])
        .run()
        .unwrap();
    let swept = &runs
        .get(&[("system", "cache-only"), ("channels", "2")])
        .unwrap()
        .report;
    assert_eq!(swept.total_cycles, hand.total_cycles);
    assert_eq!(swept.accesses, hand.accesses);
    assert_eq!(swept.dram.reads, hand.dram.reads);
    assert_eq!(swept.dram.row_hits, hand.dram.row_hits);
    assert_eq!(swept.label, hand.label);

    // And run_one on the same scenario equals a plain simulate.
    let single = run_one(&hand_cfg, &Scenario::from_tensor(t).for_config(&hand_cfg));
    assert_eq!(single.total_cycles, hand.total_cycles);
}

#[test]
fn sweep_scenario_axes_vary_the_workload() {
    let t = hyper_sparse(33, 900);
    let nnz = t.nnz() as u64;
    let scenario = Scenario::from_tensor(t).for_config(&SystemConfig::config_b());
    let runs = Sweep::new(SystemConfig::config_b(), scenario)
        .axis("mode", &["i", "j", "k"])
        .threads(2)
        .run()
        .unwrap();
    assert_eq!(runs.len(), 3);
    for run in &runs.runs {
        assert_eq!(run.report.nnz, nnz, "every mode covers every nonzero");
        assert!(run.report.total_cycles > 0);
    }
}

#[test]
fn apply_override_round_trips_every_documented_key() {
    let mut c = SystemConfig::config_a();
    let cases: &[(&str, &str)] = &[
        ("system.n_lmbs", "2"),
        ("cache.associativity", "1"),
        ("cache.lines", "2048"),
        ("cache.line_bits", "256"),
        ("cache.mshr_entries", "16"),
        ("cache.mshr_secondary_cap", "4"),
        ("dma.n_buffers", "8"),
        ("dma.buffer_bytes", "512"),
        ("rr.rrsh_entries", "1024"),
        ("rr.temp_buffer_entries", "4"),
        ("pe.n_pes", "8"),
        ("pe.rank", "16"),
        ("pe.compute_cycles_per_nnz", "2"),
        ("pe.max_inflight", "12"),
        ("interconnect.channels", "4"),
        ("interconnect.link_width", "2"),
        ("interconnect.link_queue", "8"),
        ("interconnect.interleave_bytes", "8192"),
        ("dram.t_row_hit", "30"),
        ("dram.t_row_miss", "60"),
        ("dram.t_controller", "10"),
        ("dram.max_outstanding", "64"),
        ("dram.banks", "8"),
    ];
    for (key, value) in cases {
        c.apply_override(key, value).unwrap_or_else(|e| panic!("{key}: {e}"));
    }
    assert_eq!(c.n_lmbs, 2);
    assert_eq!(c.cache.associativity, 1);
    assert_eq!(c.cache.lines, 2048);
    assert_eq!(c.cache.line_bits, 256);
    assert_eq!(c.cache.mshr_entries, 16);
    assert_eq!(c.cache.mshr_secondary_cap, 4);
    assert_eq!(c.dma.n_buffers, 8);
    assert_eq!(c.dma.buffer_bytes, 512);
    assert_eq!(c.rr.rrsh_entries, 1024);
    assert_eq!(c.rr.temp_buffer_entries, 4);
    assert_eq!(c.pe.n_pes, 8);
    assert_eq!(c.pe.rank, 16);
    assert_eq!(c.pe.compute_cycles_per_nnz, 2);
    assert_eq!(c.pe.max_inflight, 12);
    assert_eq!(c.interconnect.channels, 4);
    assert_eq!(c.interconnect.link_width, 2);
    assert_eq!(c.interconnect.link_queue, 8);
    assert_eq!(c.interconnect.interleave_bytes, 8192);
    assert_eq!(c.dram.t_row_hit, 30);
    assert_eq!(c.dram.t_row_miss, 60);
    assert_eq!(c.dram.t_controller, 10);
    assert_eq!(c.dram.max_outstanding, 64);
    assert_eq!(c.dram.banks, 8);
    // Enum-valued keys.
    c.apply_override("system.kind", "cache-only").unwrap();
    assert_eq!(c.kind, SystemKind::CacheOnly);
    c.apply_override("pe.fabric", "type2").unwrap();
    assert_eq!(c.pe.fabric, FabricType::Type2);
    c.apply_override("interconnect.topology", "line").unwrap();
    assert_eq!(c.interconnect.topology, TopologyKind::Line);
    c.validate().unwrap();
}

#[test]
fn apply_override_interconnect_shorthands_alias_their_full_keys() {
    for (short, full, value) in [
        ("channels", "interconnect.channels", "4"),
        ("topology", "interconnect.topology", "ring"),
        ("link_width", "interconnect.link_width", "3"),
    ] {
        let mut via_short = SystemConfig::config_b();
        via_short.apply_override(short, value).unwrap();
        let mut via_full = SystemConfig::config_b();
        via_full.apply_override(full, value).unwrap();
        assert_eq!(via_short, via_full, "{short} must alias {full}");
    }
}

#[test]
fn apply_override_error_paths_leave_the_config_untouched() {
    let pristine = SystemConfig::config_b();
    let mut c = pristine.clone();
    // Unknown keys.
    assert!(c.apply_override("bogus.key", "1").is_err());
    assert!(c.apply_override("cache.nonexistent", "1").is_err());
    assert!(c.apply_override("channel", "2").is_err(), "near-miss shorthand");
    // Unparsable numbers.
    assert!(c.apply_override("cache.lines", "many").is_err());
    assert!(c.apply_override("dma.buffer_bytes", "-1").is_err());
    assert!(c.apply_override("scale", "0.5").is_err(), "scenario key, not config");
    // Unknown enum values.
    assert!(c.apply_override("system.kind", "hybrid").is_err());
    assert!(c.apply_override("pe.fabric", "type3").is_err());
    assert!(c.apply_override("topology", "torus").is_err());
    assert_eq!(c, pristine, "failed overrides must not mutate the config");
}

#[test]
fn jsonl_output_keeps_fig4_ordering_and_schema() {
    let base = SystemConfig::config_b();
    // Same workload the fig4-ordering integration test pins down.
    let scenario = Scenario::synth01(0.001).for_config(&base);
    let runs = Sweep::new(base, scenario)
        .axis("system", &["ip-only", "cache-only", "dma-only", "proposed"])
        .threads(2)
        .run()
        .unwrap();
    let jsonl = runs.to_jsonl();
    let mut cycles = std::collections::HashMap::new();
    for line in jsonl.lines() {
        let rec = Json::parse(line).expect("every line is standalone JSON");
        let system = rec
            .get("axes")
            .and_then(|a| a.get("system"))
            .and_then(Json::as_str)
            .expect("axes.system present")
            .to_string();
        assert!(rec.get("label").is_some());
        let total = rec.get("total_cycles").and_then(Json::as_f64).unwrap();
        let nested = rec
            .get("report")
            .and_then(|r| r.get("total_cycles"))
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(total, nested, "top-level mirror matches the report");
        cycles.insert(system, total);
    }
    assert_eq!(cycles.len(), 4);
    // Fig. 4 qualitative ordering (the python schema test re-checks this
    // on the CI-produced file).
    assert!(cycles["proposed"] < cycles["ip-only"]);
    assert!(cycles["proposed"] < cycles["cache-only"]);
    assert!(cycles["proposed"] < cycles["dma-only"]);
}
