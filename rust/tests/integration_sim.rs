//! Integration tests: full memory-system simulations across variants,
//! configurations, fabrics and datasets — conservation, ordering, and
//! paper-shape invariants.

use std::sync::Arc;

use mttkrp_memsys::config::{FabricType, SystemConfig, SystemKind};
use mttkrp_memsys::experiment::Scenario;
use mttkrp_memsys::sim::simulate;
use mttkrp_memsys::tensor::{gen, CooTensor, Mode};
use mttkrp_memsys::trace::Workload;
use mttkrp_memsys::util::rng::Rng;

fn hyper_sparse(seed: u64, nnz: usize) -> CooTensor {
    let mut rng = Rng::new(seed);
    CooTensor::random(&mut rng, [128, 30_000, 50_000], nnz)
}

fn wl(t: &CooTensor, fabric: FabricType, cfg: &SystemConfig) -> Arc<Workload> {
    Scenario::from_tensor(t.clone()).for_config(cfg).fabric(fabric).workload()
}

#[test]
fn every_variant_serves_every_access_both_fabrics() {
    let t = hyper_sparse(1, 2000);
    for fabric in [FabricType::Type1, FabricType::Type2] {
        let base = match fabric {
            FabricType::Type1 => SystemConfig::config_a(),
            FabricType::Type2 => SystemConfig::config_b(),
        };
        let w = wl(&t, fabric, &base);
        let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
        for kind in SystemKind::ALL {
            let mut cfg = base.as_baseline(kind);
            cfg.pe.fabric = fabric;
            let rep = simulate(&cfg, &w);
            assert_eq!(rep.accesses, expected, "{fabric:?}/{kind:?} lost accesses");
            assert_eq!(rep.nnz, t.nnz() as u64);
        }
    }
}

#[test]
fn fig4_ordering_holds_on_synthetic_datasets() {
    // The paper's qualitative result at small scale on both datasets.
    for t in [gen::synth_01(0.001), gen::synth_02(0.001)] {
        let base = SystemConfig::config_b();
        let w = wl(&t, FabricType::Type2, &base);
        let runs: Vec<_> = SystemKind::ALL
            .iter()
            .map(|&k| (k, simulate(&base.as_baseline(k), &w)))
            .collect();
        let cycles = |k: SystemKind| {
            runs.iter().find(|(kk, _)| *kk == k).unwrap().1.total_cycles
        };
        assert!(cycles(SystemKind::Proposed) < cycles(SystemKind::DmaOnly));
        assert!(cycles(SystemKind::Proposed) < cycles(SystemKind::CacheOnly));
        assert!(cycles(SystemKind::Proposed) < cycles(SystemKind::IpOnly));
        assert!(cycles(SystemKind::DmaOnly) < cycles(SystemKind::IpOnly));
        // Headline factor in a sane band (paper: 3.5×).
        let speedup =
            cycles(SystemKind::IpOnly) as f64 / cycles(SystemKind::Proposed) as f64;
        assert!(
            (2.0..6.0).contains(&speedup),
            "{}: proposed vs ip-only {speedup:.2} out of band",
            t.name
        );
    }
}

#[test]
fn dram_write_traffic_covers_all_stores_exactly_for_dma_paths() {
    let t = hyper_sparse(3, 1500);
    let cfg = SystemConfig::config_b();
    let w = wl(&t, FabricType::Type2, &cfg);
    let store_bytes: u64 = w
        .pe_traces
        .iter()
        .flat_map(|p| &p.work)
        .filter_map(|x| x.store.map(|s| s.bytes as u64))
        .sum();
    let rep = simulate(&cfg, &w);
    // Proposed stores go via DMA: aligned up to beats, no write combining.
    assert!(rep.dram.write_bytes >= store_bytes);
    assert!(rep.dram.write_bytes <= store_bytes * 2 + 4096);
}

#[test]
fn proposed_moves_fewer_bytes_than_dma_only() {
    // The RR/cache path de-duplicates element lines; DMA-only re-reads
    // every element with garbage (§V-D).
    let t = hyper_sparse(4, 2500);
    let cfg = SystemConfig::config_b();
    let w = wl(&t, FabricType::Type2, &cfg);
    let prop = simulate(&cfg, &w);
    let dma = simulate(&cfg.as_baseline(SystemKind::DmaOnly), &w);
    assert!(
        prop.dram.read_bytes < dma.dram.read_bytes,
        "proposed {} !< dma-only {}",
        prop.dram.read_bytes,
        dma.dram.read_bytes
    );
}

#[test]
fn rr_absorbs_most_element_traffic() {
    let t = gen::synth_01(0.001);
    let cfg = SystemConfig::config_b();
    let w = wl(&t, FabricType::Type2, &cfg);
    let rep = simulate(&cfg, &w);
    let (mut forwarded, mut absorbed_or_served) = (0u64, 0u64);
    for l in &rep.lmbs {
        forwarded += l.rr.forwarded;
        absorbed_or_served += l.rr.absorbed + l.rr.served_temp;
    }
    // 4 × 16 B elements per 64 B line ⇒ ~3 of 4 element reads must never
    // reach the cache.
    let ratio = absorbed_or_served as f64 / (forwarded + absorbed_or_served) as f64;
    assert!(ratio > 0.5, "RR traffic reduction only {ratio:.2}");
}

#[test]
fn more_lmbs_do_not_hurt_type2() {
    let t = hyper_sparse(5, 2500);
    let mut one = SystemConfig::config_b();
    one.n_lmbs = 1;
    let mut four = SystemConfig::config_b();
    four.n_lmbs = 4;
    let w = wl(&t, FabricType::Type2, &four);
    let r1 = simulate(&one, &w);
    let r4 = simulate(&four, &w);
    assert!(
        r4.total_cycles <= r1.total_cycles * 11 / 10,
        "4 LMBs ({}) should not be slower than 1 ({})",
        r4.total_cycles,
        r1.total_cycles
    );
}

#[test]
fn empty_and_tiny_workloads_terminate() {
    let mut t = CooTensor::new("tiny", [4, 4, 4]);
    t.push(1, 2, 3, 1.0);
    t.sort_mode(Mode::I);
    for kind in SystemKind::ALL {
        let cfg = SystemConfig::config_b().as_baseline(kind);
        let w = wl(&t, FabricType::Type2, &cfg);
        let rep = simulate(&cfg, &w);
        assert!(rep.total_cycles > 0);
        assert_eq!(rep.nnz, 1);
    }
}

#[test]
fn deterministic_given_same_inputs() {
    let t = hyper_sparse(6, 1200);
    let cfg = SystemConfig::config_a();
    let w = wl(&t, FabricType::Type1, &cfg);
    let a = simulate(&cfg, &w);
    let b = simulate(&cfg, &w);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.dram.reads, b.dram.reads);
    assert_eq!(a.dram.row_hits, b.dram.row_hits);
}

#[test]
fn latency_accounting_is_sane_and_favours_the_cached_element_path() {
    let t = gen::synth_01(0.001);
    let cfg = SystemConfig::config_b();
    let w = wl(&t, FabricType::Type2, &cfg);
    let rep = simulate(&cfg, &w);
    // Latencies recorded for every class that has traffic.
    assert_eq!(
        rep.latency[0].count,
        t.nnz() as u64,
        "every element load measured"
    );
    assert!(rep.elem_latency_mean() > 0.0);
    assert!(rep.fiber_latency_mean() > 0.0);
    // The proposed design's point: element loads (RR temp-buffer/RRSH +
    // cache) complete with *lower* PE-observed latency than random DRAM
    // fiber bursts.
    assert!(
        rep.elem_latency_mean() < rep.fiber_latency_mean(),
        "elements {:.1} !< fibers {:.1}",
        rep.elem_latency_mean(),
        rep.fiber_latency_mean()
    );
}

#[test]
fn proposed_trades_latency_for_throughput_vs_ip_only() {
    // Little's law in action: ip-only keeps individual accesses fast
    // (almost no queueing — it can't issue enough of them), while the
    // proposed system runs deep queues (higher per-access latency) and
    // wins on throughput, which is what the Fig. 4 metric measures.
    let t = gen::synth_01(0.001);
    let cfg = SystemConfig::config_b();
    let w = wl(&t, FabricType::Type2, &cfg);
    let prop = simulate(&cfg, &w);
    let ip = simulate(&cfg.as_baseline(SystemKind::IpOnly), &w);
    assert!(
        prop.nnz_per_cycle() > 2.0 * ip.nnz_per_cycle(),
        "proposed throughput {:.4} should dwarf ip-only {:.4}",
        prop.nnz_per_cycle(),
        ip.nnz_per_cycle()
    );
    // Sanity on the latency side: ip-only's per-access latency is near
    // the raw DRAM round trip (little queueing).
    assert!(
        ip.elem_latency_mean() < 150.0,
        "ip-only elem latency {:.1} unexpectedly queue-bound",
        ip.elem_latency_mean()
    );
}

#[test]
fn speedups_are_stable_across_scales() {
    // §Sensitivity: Fig. 4 ratios must hold as the dataset scales.
    let mut ratios = Vec::new();
    for scale in [0.0005, 0.002] {
        let t = gen::synth_01(scale);
        let cfg = SystemConfig::config_b();
        let w = wl(&t, FabricType::Type2, &cfg);
        let prop = simulate(&cfg, &w);
        let ip = simulate(&cfg.as_baseline(SystemKind::IpOnly), &w);
        ratios.push(prop.speedup_over(&ip));
    }
    let (a, b) = (ratios[0], ratios[1]);
    assert!(
        (a / b - 1.0).abs() < 0.25,
        "speedup drifted across scales: {a:.2} vs {b:.2}"
    );
}
