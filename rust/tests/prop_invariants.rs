//! Property tests (in-tree harness, `util::prop`) over the substrate
//! invariants: partitioning, tensor transforms, traces, linalg.

use mttkrp_memsys::config::FabricType;
use mttkrp_memsys::experiment::Scenario;
use mttkrp_memsys::mttkrp::linalg::{cholesky, matmul, solve_gram};
use mttkrp_memsys::mttkrp::{mttkrp_parallel, mttkrp_seq};
use mttkrp_memsys::tensor::partition::partitions_fiber_aligned;
use mttkrp_memsys::tensor::{partition_by_nnz, CooTensor, DenseMatrix, Mode};
use mttkrp_memsys::util::prop::check;
use mttkrp_memsys::util::rng::Rng;
use mttkrp_memsys::{prop_assert, prop_assert_eq};

fn random_tensor(rng: &mut Rng) -> CooTensor {
    let dims = [
        rng.gen_range(30) + 2,
        rng.gen_range(40) + 2,
        rng.gen_range(50) + 2,
    ];
    let nnz = rng.gen_usize(1, 400);
    CooTensor::random(rng, dims, nnz)
}

#[test]
fn prop_partitions_cover_disjoint_fiber_aligned() {
    check(
        "partitions cover/disjoint/aligned",
        60,
        |rng| {
            let t = random_tensor(rng);
            let p = rng.gen_usize(1, 9);
            (t, p)
        },
        |(t, p)| {
            let parts = partition_by_nnz(t, Mode::I, *p);
            prop_assert_eq!(parts.len(), *p, "partition count");
            prop_assert!(
                partitions_fiber_aligned(t, Mode::I, &parts),
                "not fiber aligned"
            );
            let total: usize = parts.iter().map(|x| x.len()).sum();
            prop_assert_eq!(total, t.nnz(), "coverage");
            Ok(())
        },
    );
}

#[test]
fn prop_sort_preserves_multiset_and_orders() {
    check(
        "sort preserves nnz multiset",
        40,
        |rng| {
            let t = random_tensor(rng);
            let mode = match rng.gen_range(3) {
                0 => Mode::I,
                1 => Mode::J,
                _ => Mode::K,
            };
            (t, mode)
        },
        |(t, mode)| {
            let mut sorted = t.clone();
            sorted.sort_mode(*mode);
            prop_assert!(sorted.is_sorted_mode(*mode), "not sorted");
            prop_assert_eq!(sorted.nnz(), t.nnz(), "nnz changed");
            let mut a: Vec<_> = (0..t.nnz())
                .map(|z| (t.coords(z), t.vals[z].to_bits()))
                .collect();
            let mut b: Vec<_> = (0..sorted.nnz())
                .map(|z| (sorted.coords(z), sorted.vals[z].to_bits()))
                .collect();
            a.sort_unstable();
            b.sort_unstable();
            prop_assert_eq!(a, b, "multiset changed");
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_mttkrp_equals_sequential() {
    check(
        "alg3 == alg2",
        30,
        |rng| {
            let t = random_tensor(rng);
            let r = rng.gen_usize(1, 12);
            let d = DenseMatrix::random(rng, t.dims[1] as usize, r);
            let c = DenseMatrix::random(rng, t.dims[2] as usize, r);
            let p = rng.gen_usize(1, 7);
            (t, d, c, p)
        },
        |(t, d, c, p)| {
            let seq = mttkrp_seq(t, Mode::I, d, c);
            let par = mttkrp_parallel(t, Mode::I, d, c, *p);
            let diff = par.max_abs_diff(&seq);
            prop_assert!(diff < 1e-3, "diff {diff} at p={p}");
            Ok(())
        },
    );
}

#[test]
fn prop_trace_covers_every_nonzero_and_store_per_fiber() {
    check(
        "trace coverage",
        30,
        |rng| {
            let t = random_tensor(rng);
            let fabric = if rng.gen_bool(0.5) {
                FabricType::Type1
            } else {
                FabricType::Type2
            };
            let pes = rng.gen_usize(1, 6);
            (t, fabric, pes)
        },
        |(t, fabric, pes)| {
            let w = Scenario::from_tensor(t.clone())
                .fabric(*fabric)
                .n_pes(*pes)
                .rank(16)
                .workload();
            let total: usize = w.pe_traces.iter().map(|p| p.work.len()).sum();
            prop_assert_eq!(total, t.nnz(), "work items");
            let stores: usize = w
                .pe_traces
                .iter()
                .flat_map(|p| &p.work)
                .filter(|x| x.store.is_some())
                .count();
            prop_assert_eq!(
                stores,
                t.distinct_along(Mode::I),
                "one store per output fiber"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_gram_solve_roundtrip() {
    check(
        "X = solve(X·G, G)",
        30,
        |rng| {
            let r = rng.gen_usize(2, 10);
            let rows = rng.gen_usize(r + 1, 30);
            let m = DenseMatrix::random(rng, rows, r);
            let x_rows = rng.gen_usize(1, 8);
            let x = DenseMatrix::random(rng, x_rows, r);
            (m, x)
        },
        |(m, x)| {
            let g = m.gram();
            prop_assert!(cholesky(&g).is_some(), "gram not SPD");
            let b = matmul(x, &g);
            let solved = solve_gram(&b, &g);
            let diff = solved.max_abs_diff(x);
            // Conditioning varies; bound scaled by the gram norm.
            let tol = 1e-2 * (1.0 + g.fro_norm() as f32);
            prop_assert!(diff < tol, "solve diff {diff} (tol {tol})");
            Ok(())
        },
    );
}

#[test]
fn prop_dedup_is_idempotent_and_value_preserving() {
    check(
        "sum_duplicates",
        40,
        |rng| {
            let dims = [8, 8, 8];
            let mut t = CooTensor::new("dup", dims);
            for _ in 0..rng.gen_usize(1, 120) {
                t.push(
                    rng.gen_range(8) as u32,
                    rng.gen_range(8) as u32,
                    rng.gen_range(8) as u32,
                    rng.gen_f32_range(-1.0, 1.0),
                );
            }
            t
        },
        |t| {
            let total: f64 = t.vals.iter().map(|&v| v as f64).sum();
            let mut d = t.clone();
            d.sum_duplicates();
            let total_d: f64 = d.vals.iter().map(|&v| v as f64).sum();
            prop_assert!((total - total_d).abs() < 1e-3, "value mass changed");
            let mut coords: Vec<_> = (0..d.nnz()).map(|z| d.coords(z)).collect();
            coords.sort_unstable();
            let n = coords.len();
            coords.dedup();
            prop_assert_eq!(coords.len(), n, "duplicates remain");
            let mut dd = d.clone();
            dd.sum_duplicates();
            prop_assert_eq!(dd.nnz(), d.nnz(), "not idempotent");
            Ok(())
        },
    );
}
