//! Integration tests for the multi-channel interconnect fabric: full
//! system runs across channel counts and topologies — conservation,
//! accounting consistency, the seed-equivalence operating point, and the
//! multi-channel speedup the fabric exists to deliver.

use std::sync::Arc;

use mttkrp_memsys::config::{SystemConfig, SystemKind, TopologyKind};
use mttkrp_memsys::experiment::Scenario;
use mttkrp_memsys::sim::simulate;
use mttkrp_memsys::tensor::{gen, CooTensor};
use mttkrp_memsys::trace::Workload;
use mttkrp_memsys::util::rng::Rng;

fn wl(t: &CooTensor, cfg: &SystemConfig) -> Arc<Workload> {
    Scenario::from_tensor(t.clone()).for_config(cfg).workload()
}

fn with_fabric(base: &SystemConfig, channels: usize, topo: TopologyKind) -> SystemConfig {
    let mut cfg = base.clone();
    cfg.interconnect.channels = channels;
    cfg.interconnect.topology = topo;
    cfg.label = format!("{}-{}ch-{}", cfg.label, channels, topo.name());
    cfg
}

#[test]
fn every_topology_and_channel_count_serves_every_access() {
    let mut rng = Rng::new(21);
    let t = CooTensor::random(&mut rng, [96, 20_000, 30_000], 1500);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
    for channels in [1usize, 2, 4] {
        for topo in TopologyKind::ALL {
            let cfg = with_fabric(&base, channels, topo);
            let rep = simulate(&cfg, &w);
            assert_eq!(
                rep.accesses, expected,
                "{channels}ch/{topo:?} lost accesses"
            );
            assert_eq!(rep.channels.len(), channels);
        }
    }
}

#[test]
fn baselines_also_run_on_multi_channel_fabrics() {
    let mut rng = Rng::new(22);
    let t = CooTensor::random(&mut rng, [64, 10_000, 20_000], 800);
    let base = with_fabric(&SystemConfig::config_b(), 4, TopologyKind::Crossbar);
    let w = wl(&t, &base);
    for kind in SystemKind::ALL {
        let cfg = base.as_baseline(kind);
        let rep = simulate(&cfg, &w);
        assert!(rep.total_cycles > 0, "{kind:?} did not run");
        assert_eq!(rep.nnz, t.nnz() as u64);
    }
}

#[test]
fn four_channels_strictly_reduce_memory_access_time() {
    // The acceptance criterion: on the Fig. 4 workload, channels=4 must
    // strictly beat the seed single channel for the proposed system.
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let one = simulate(&with_fabric(&base, 1, TopologyKind::Crossbar), &w);
    let four = simulate(&with_fabric(&base, 4, TopologyKind::Crossbar), &w);
    assert!(
        four.total_cycles < one.total_cycles,
        "4 channels ({}) must strictly beat 1 channel ({})",
        four.total_cycles,
        one.total_cycles
    );
    // And the traffic must actually spread over the channels.
    let busy: Vec<u64> = four.channels.iter().map(|c| c.reads + c.writes).collect();
    assert!(busy.iter().all(|&n| n > 0), "idle channel: {busy:?}");
}

#[test]
fn aggregate_dram_stats_equal_sum_of_channels() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    for topo in TopologyKind::ALL {
        let rep = simulate(&with_fabric(&base, 4, topo), &w);
        let reads: u64 = rep.channels.iter().map(|c| c.reads).sum();
        let writes: u64 = rep.channels.iter().map(|c| c.writes).sum();
        let read_bytes: u64 = rep.channels.iter().map(|c| c.read_bytes).sum();
        let write_bytes: u64 = rep.channels.iter().map(|c| c.write_bytes).sum();
        assert_eq!(rep.dram.reads, reads, "{topo:?} reads");
        assert_eq!(rep.dram.writes, writes, "{topo:?} writes");
        assert_eq!(rep.dram.read_bytes, read_bytes, "{topo:?} read bytes");
        assert_eq!(rep.dram.write_bytes, write_bytes, "{topo:?} write bytes");
        assert_eq!(rep.fabric.forwarded, reads + writes, "{topo:?} forwards");
    }
}

#[test]
fn store_and_forward_topologies_record_hops_and_link_traffic() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    for topo in [TopologyKind::Line, TopologyKind::Ring] {
        let rep = simulate(&with_fabric(&base, 4, topo), &w);
        assert!(rep.fabric.hops > 0, "{topo:?}: no hops on 4 nodes");
        let link_fwd: u64 = rep.fabric.links.iter().map(|l| l.forwarded).sum();
        assert_eq!(link_fwd, rep.fabric.hops, "{topo:?} hop accounting");
        assert!(rep.max_link_utilization() > 0.0);
        // Ring never needs more hops than line on the same node count.
        assert!(rep.total_cycles > 0);
    }
    // Crossbar takes no hops at all.
    let xbar = simulate(&with_fabric(&base, 4, TopologyKind::Crossbar), &w);
    assert_eq!(xbar.fabric.hops, 0);
}

#[test]
fn multi_channel_runs_are_deterministic() {
    let mut rng = Rng::new(23);
    let t = CooTensor::random(&mut rng, [64, 10_000, 20_000], 900);
    let cfg = with_fabric(&SystemConfig::config_b(), 4, TopologyKind::Ring);
    let w = wl(&t, &cfg);
    let a = simulate(&cfg, &w);
    let b = simulate(&cfg, &w);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.fabric.hops, b.fabric.hops);
    assert_eq!(a.dram.reads, b.dram.reads);
}

#[test]
fn single_channel_default_config_matches_explicit_single_channel() {
    // The default (seed) config IS channels=1 crossbar; spelling it out
    // explicitly must not change a single cycle.
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let implicit = simulate(&base, &w);
    let explicit = simulate(&with_fabric(&base, 1, TopologyKind::Crossbar), &w);
    assert_eq!(implicit.total_cycles, explicit.total_cycles);
    assert_eq!(implicit.dram.reads, explicit.dram.reads);
    assert_eq!(implicit.dram.row_hits, explicit.dram.row_hits);
}

#[test]
fn type1_config_a_also_scales_with_channels() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_a();
    let w = wl(&t, &base);
    let one = simulate(&with_fabric(&base, 1, TopologyKind::Crossbar), &w);
    let four = simulate(&with_fabric(&base, 4, TopologyKind::Crossbar), &w);
    assert!(
        four.total_cycles <= one.total_cycles,
        "channels must not hurt config-a: {} vs {}",
        four.total_cycles,
        one.total_cycles
    );
}
