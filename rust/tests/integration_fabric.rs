//! Integration tests for the multi-channel interconnect fabric: full
//! system runs across channel counts and topologies — conservation,
//! accounting consistency, the seed-equivalence operating point, the
//! multi-channel speedup the fabric exists to deliver, and the banked
//! LMB + reply-network layer on top of it:
//!
//! * `lmb_banks=1` with the reply network off is **report-identical** to
//!   the pre-bank system (the regression anchor — the default config
//!   takes the exact same code path);
//! * per-bank counters partition the per-LMB aggregates;
//! * the reply network conserves completions, only ever adds cycles,
//!   and populates the reply-link counters.

use std::sync::Arc;

use mttkrp_memsys::config::{SystemConfig, SystemKind, TopologyKind};
use mttkrp_memsys::experiment::Scenario;
use mttkrp_memsys::sim::{simulate, MemorySystem};
use mttkrp_memsys::tensor::{gen, CooTensor};
use mttkrp_memsys::trace::Workload;
use mttkrp_memsys::util::rng::Rng;

fn wl(t: &CooTensor, cfg: &SystemConfig) -> Arc<Workload> {
    Scenario::from_tensor(t.clone()).for_config(cfg).workload()
}

fn with_fabric(base: &SystemConfig, channels: usize, topo: TopologyKind) -> SystemConfig {
    let mut cfg = base.clone();
    cfg.interconnect.channels = channels;
    cfg.interconnect.topology = topo;
    cfg.label = format!("{}-{}ch-{}", cfg.label, channels, topo.name());
    cfg
}

#[test]
fn every_topology_and_channel_count_serves_every_access() {
    let mut rng = Rng::new(21);
    let t = CooTensor::random(&mut rng, [96, 20_000, 30_000], 1500);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
    for channels in [1usize, 2, 4] {
        for topo in TopologyKind::ALL {
            let cfg = with_fabric(&base, channels, topo);
            let rep = simulate(&cfg, &w);
            assert_eq!(
                rep.accesses, expected,
                "{channels}ch/{topo:?} lost accesses"
            );
            assert_eq!(rep.channels.len(), channels);
        }
    }
}

#[test]
fn baselines_also_run_on_multi_channel_fabrics() {
    let mut rng = Rng::new(22);
    let t = CooTensor::random(&mut rng, [64, 10_000, 20_000], 800);
    let base = with_fabric(&SystemConfig::config_b(), 4, TopologyKind::Crossbar);
    let w = wl(&t, &base);
    for kind in SystemKind::ALL {
        let cfg = base.as_baseline(kind);
        let rep = simulate(&cfg, &w);
        assert!(rep.total_cycles > 0, "{kind:?} did not run");
        assert_eq!(rep.nnz, t.nnz() as u64);
    }
}

#[test]
fn four_channels_strictly_reduce_memory_access_time() {
    // The acceptance criterion: on the Fig. 4 workload, channels=4 must
    // strictly beat the seed single channel for the proposed system.
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let one = simulate(&with_fabric(&base, 1, TopologyKind::Crossbar), &w);
    let four = simulate(&with_fabric(&base, 4, TopologyKind::Crossbar), &w);
    assert!(
        four.total_cycles < one.total_cycles,
        "4 channels ({}) must strictly beat 1 channel ({})",
        four.total_cycles,
        one.total_cycles
    );
    // And the traffic must actually spread over the channels.
    let busy: Vec<u64> = four.channels.iter().map(|c| c.reads + c.writes).collect();
    assert!(busy.iter().all(|&n| n > 0), "idle channel: {busy:?}");
}

#[test]
fn aggregate_dram_stats_equal_sum_of_channels() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    for topo in TopologyKind::ALL {
        let rep = simulate(&with_fabric(&base, 4, topo), &w);
        let reads: u64 = rep.channels.iter().map(|c| c.reads).sum();
        let writes: u64 = rep.channels.iter().map(|c| c.writes).sum();
        let read_bytes: u64 = rep.channels.iter().map(|c| c.read_bytes).sum();
        let write_bytes: u64 = rep.channels.iter().map(|c| c.write_bytes).sum();
        assert_eq!(rep.dram.reads, reads, "{topo:?} reads");
        assert_eq!(rep.dram.writes, writes, "{topo:?} writes");
        assert_eq!(rep.dram.read_bytes, read_bytes, "{topo:?} read bytes");
        assert_eq!(rep.dram.write_bytes, write_bytes, "{topo:?} write bytes");
        assert_eq!(rep.fabric.forwarded, reads + writes, "{topo:?} forwards");
    }
}

#[test]
fn store_and_forward_topologies_record_hops_and_link_traffic() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    for topo in [TopologyKind::Line, TopologyKind::Ring] {
        let rep = simulate(&with_fabric(&base, 4, topo), &w);
        assert!(rep.fabric.hops > 0, "{topo:?}: no hops on 4 nodes");
        let link_fwd: u64 = rep.fabric.links.iter().map(|l| l.forwarded).sum();
        assert_eq!(link_fwd, rep.fabric.hops, "{topo:?} hop accounting");
        assert!(rep.max_link_utilization() > 0.0);
        // Ring never needs more hops than line on the same node count.
        assert!(rep.total_cycles > 0);
    }
    // Crossbar takes no hops at all.
    let xbar = simulate(&with_fabric(&base, 4, TopologyKind::Crossbar), &w);
    assert_eq!(xbar.fabric.hops, 0);
}

#[test]
fn multi_channel_runs_are_deterministic() {
    let mut rng = Rng::new(23);
    let t = CooTensor::random(&mut rng, [64, 10_000, 20_000], 900);
    let cfg = with_fabric(&SystemConfig::config_b(), 4, TopologyKind::Ring);
    let w = wl(&t, &cfg);
    let a = simulate(&cfg, &w);
    let b = simulate(&cfg, &w);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.fabric.hops, b.fabric.hops);
    assert_eq!(a.dram.reads, b.dram.reads);
}

#[test]
fn single_channel_default_config_matches_explicit_single_channel() {
    // The default (seed) config IS channels=1 crossbar; spelling it out
    // explicitly must not change a single cycle.
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let implicit = simulate(&base, &w);
    let explicit = simulate(&with_fabric(&base, 1, TopologyKind::Crossbar), &w);
    assert_eq!(implicit.total_cycles, explicit.total_cycles);
    assert_eq!(implicit.dram.reads, explicit.dram.reads);
    assert_eq!(implicit.dram.row_hits, explicit.dram.row_hits);
}

#[test]
fn single_bank_reply_off_is_report_identical_to_the_pre_bank_system() {
    // The regression anchor: the default config (lmb_banks=1, reply
    // network off) IS the pre-bank/pre-reply system — the bank map is
    // the identity, the single bank carries the full cache/RR geometry,
    // and completions take the combinational return path. Spelling the
    // defaults out explicitly must not change one counter, on either
    // engine, for any variant.
    let mut rng = Rng::new(31);
    let t = CooTensor::random(&mut rng, [80, 15_000, 25_000], 1200);
    for base in [SystemConfig::config_a(), SystemConfig::config_b()] {
        assert_eq!(base.lmb_banks, 1, "default must stay single-bank");
        assert!(!base.interconnect.reply_network, "default must stay reply-off");
        let w = wl(&t, &base);
        for kind in SystemKind::ALL {
            let implicit_cfg = base.as_baseline(kind);
            let mut explicit_cfg = implicit_cfg.clone();
            explicit_cfg.lmb_banks = 1;
            explicit_cfg.interconnect.reply_network = false;
            let implicit = MemorySystem::new(&implicit_cfg, &w).run(&w.name);
            let explicit = MemorySystem::new(&explicit_cfg, &w).run(&w.name);
            assert_eq!(
                implicit.diff(&explicit),
                None,
                "{kind:?}: explicit banks=1/reply-off diverged from the default"
            );
            // And the single bank's counters ARE the aggregate.
            for l in &implicit.lmbs {
                assert_eq!(l.banks.len(), 1);
                assert_eq!(l.banks[0].cache, l.cache);
                assert_eq!(l.banks[0].rr, l.rr);
            }
            // No reply network → no reply counters, no reply links.
            assert_eq!(implicit.fabric.reply.delivered, 0);
            assert!(implicit.fabric.reply.links.is_empty());
        }
    }
}

#[test]
fn per_bank_counters_partition_the_lmb_aggregates() {
    let t = gen::synth_01(0.001);
    let mut base = SystemConfig::config_b();
    base.interconnect.channels = 4;
    base.lmb_banks = 4;
    let w = wl(&t, &base);
    for topo in TopologyKind::ALL {
        let mut cfg = base.clone();
        cfg.interconnect.topology = topo;
        let rep = simulate(&cfg, &w);
        for l in &rep.lmbs {
            assert_eq!(l.banks.len(), 4);
            let fwd: u64 = l.banks.iter().map(|b| b.rr.forwarded).sum();
            let abs: u64 = l.banks.iter().map(|b| b.rr.absorbed).sum();
            let temp: u64 = l.banks.iter().map(|b| b.rr.served_temp).sum();
            let hits: u64 = l.banks.iter().map(|b| b.cache.hits).sum();
            let misses: u64 = l.banks.iter().map(|b| b.cache.primary_misses).sum();
            assert_eq!(fwd, l.rr.forwarded, "{topo:?} rr.forwarded partition");
            assert_eq!(abs, l.rr.absorbed, "{topo:?} rr.absorbed partition");
            assert_eq!(temp, l.rr.served_temp, "{topo:?} rr.served_temp partition");
            assert_eq!(hits, l.cache.hits, "{topo:?} cache.hits partition");
            assert_eq!(misses, l.cache.primary_misses, "{topo:?} miss partition");
        }
    }
}

#[test]
fn banked_lmbs_serve_every_access_across_bank_counts() {
    let mut rng = Rng::new(33);
    let t = CooTensor::random(&mut rng, [96, 20_000, 30_000], 1500);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
    for banks in [1usize, 2, 4] {
        for kind in [SystemKind::Proposed, SystemKind::CacheOnly] {
            let mut cfg = base.as_baseline(kind);
            cfg.lmb_banks = banks;
            cfg.interconnect.channels = 4;
            cfg.validate().unwrap();
            let rep = simulate(&cfg, &w);
            assert_eq!(rep.accesses, expected, "banks={banks}/{kind:?} lost accesses");
        }
    }
}

#[test]
fn reply_network_conserves_accesses_and_only_adds_cycles() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_b();
    let w = wl(&t, &base);
    let expected: u64 = w.pe_traces.iter().map(|p| p.n_accesses() as u64).sum();
    for channels in [1usize, 4] {
        for topo in TopologyKind::ALL {
            let free_cfg = with_fabric(&base, channels, topo);
            let mut reply_cfg = free_cfg.clone();
            reply_cfg.interconnect.reply_network = true;
            let free = simulate(&free_cfg, &w);
            let modeled = simulate(&reply_cfg, &w);
            assert_eq!(modeled.accesses, expected, "{channels}ch/{topo:?} lost accesses");
            assert!(
                modeled.total_cycles >= free.total_cycles,
                "{channels}ch/{topo:?}: reply network must not speed up \
                 ({} < {})",
                modeled.total_cycles,
                free.total_cycles
            );
            // Every DRAM transaction returned exactly once.
            assert_eq!(
                modeled.fabric.reply.delivered,
                modeled.dram.reads + modeled.dram.writes,
                "{channels}ch/{topo:?} reply accounting"
            );
            // Reply links carry utilization data for the report. (A
            // 1-node line/ring has no physical links — delivery is
            // direct — so only the crossbar's virtual return buses and
            // multi-node fabrics have link rows.)
            if topo == TopologyKind::Crossbar || channels > 1 {
                assert!(!modeled.fabric.reply.links.is_empty());
                let reply_fwd: u64 = modeled.fabric.reply.links.iter().map(|l| l.forwarded).sum();
                assert!(reply_fwd > 0, "{channels}ch/{topo:?}: silent reply links");
            }
            if channels > 1 && topo != TopologyKind::Crossbar {
                assert!(
                    modeled.fabric.reply.hops > 0,
                    "{channels}ch/{topo:?}: store-and-forward replies must hop"
                );
                assert!(modeled.max_reply_link_utilization() > 0.0);
            }
        }
    }
}

#[test]
fn type1_config_a_also_scales_with_channels() {
    let t = gen::synth_01(0.001);
    let base = SystemConfig::config_a();
    let w = wl(&t, &base);
    let one = simulate(&with_fabric(&base, 1, TopologyKind::Crossbar), &w);
    let four = simulate(&with_fabric(&base, 4, TopologyKind::Crossbar), &w);
    assert!(
        four.total_cycles <= one.total_cycles,
        "channels must not hurt config-a: {} vs {}",
        four.total_cycles,
        one.total_cycles
    );
}
